"""Continuous-batching serving stack: correctness and accounting.

The serving tier must be a pure throughput/latency optimization — every
mode (sync baseline, overlapped pipeline, hot-prefix cache, fused
find-and-fetch) returns byte-identical results to ``DeviceIndex.find_batch``
/ the per-pattern oracle.  These tests pin that invariant plus the
bookkeeping the benchmarks report: admission-queue overflow, cache
hit/miss/eviction counters, and the env-var ``ServeConfig`` idiom.
"""

import time

import numpy as np
import pytest

from repro.core.alphabet import DNA, PROTEIN_CLASS
from repro.core.api import EraConfig, EraIndexer
from repro.core.query import RouteCache
from repro.launch.serving import (
    AsyncServer,
    ServeConfig,
    make_hot_workload,
    run_closed_loop,
)


@pytest.fixture(scope="module")
def dev_and_s():
    alpha = DNA
    s = alpha.random_string(4000, seed=11)
    dev = EraIndexer(alpha, EraConfig(
        memory_bytes=1 << 16, build_impl="none",
        packing="dense")).build_device(s, max_pattern_len=64)
    return dev, s


@pytest.fixture(scope="module")
def workload(dev_and_s):
    _, s = dev_and_s
    rng = np.random.default_rng(3)
    return make_hot_workload(s, rng, n_requests=300, hot_pool=12,
                             hot_frac=0.7, min_len=2, max_len=18,
                             n_symbols=4)


class TestServeConfig:
    def test_env_defaults(self, monkeypatch):
        monkeypatch.setenv("REPRO_SERVE_MAX_BATCH", "64")
        monkeypatch.setenv("REPRO_SERVE_CACHE", "17")
        monkeypatch.setenv("REPRO_SERVE_PIPELINE", "0")
        monkeypatch.setenv("REPRO_SERVE_FETCH", "8")
        monkeypatch.setenv("REPRO_SERVE_QUEUE_DEPTH", "99")
        monkeypatch.setenv("REPRO_SERVE_MAX_WAIT_MS", "2.5")
        cfg = ServeConfig()
        assert cfg.max_batch == 64 and cfg.cache_size == 17
        assert cfg.pipeline is False and cfg.fetch == 8
        assert cfg.queue_depth == 99 and cfg.max_wait_ms == 2.5

    def test_overrides_beat_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_SERVE_MAX_BATCH", "64")
        assert ServeConfig(max_batch=8).max_batch == 8

    def test_rejects_unknown_and_invalid(self):
        with pytest.raises(TypeError):
            ServeConfig(not_a_knob=1)
        with pytest.raises(ValueError):
            ServeConfig(max_batch=0)
        with pytest.raises(ValueError):
            ServeConfig(fetch=6)  # not a multiple of 4


class TestModesByteIdentical:
    MODES = [
        dict(pipeline=False, cache_size=0),   # sync baseline
        dict(pipeline=True, cache_size=0),    # overlapped pipeline
        dict(pipeline=True, cache_size=256),  # pipeline + cache
        dict(pipeline=True, cache_size=256, max_batch=16, queue_depth=32),
    ]

    def test_all_modes_match_find_batch(self, dev_and_s, workload):
        dev, _ = dev_and_s
        want = dev.find_batch(workload)
        for kw in self.MODES:
            res, _ = run_closed_loop(dev, workload, ServeConfig(**kw))
            assert len(res) == len(workload)
            for (pos, win), w in zip(res, want):
                np.testing.assert_array_equal(pos, w, err_msg=str(kw))
                assert win is None

    def test_fetch_modes_match_find_fetch_batch(self, dev_and_s, workload):
        dev, _ = dev_and_s
        pats = workload[:80]
        ranges, wins = dev.find_fetch_batch(pats, fetch=16)
        for kw in (dict(pipeline=False, cache_size=0, fetch=16),
                   dict(pipeline=True, cache_size=128, fetch=16)):
            res, _ = run_closed_loop(dev, pats, ServeConfig(**kw))
            for i, (pos, win) in enumerate(res):
                np.testing.assert_array_equal(pos, ranges[i], err_msg=str(kw))
                np.testing.assert_array_equal(win, wins[i], err_msg=str(kw))

    def test_cache_on_off_identical(self, dev_and_s, workload):
        # small batches: the pipeline dispatches batch k+1 before batch
        # k's consume populates the cache, so hits need several batches
        dev, _ = dev_and_s
        on, st_on = run_closed_loop(
            dev, workload, ServeConfig(pipeline=True, cache_size=512,
                                       max_batch=32))
        off, _ = run_closed_loop(
            dev, workload, ServeConfig(pipeline=True, cache_size=0,
                                       max_batch=32))
        for (p1, _), (p2, _) in zip(on, off):
            np.testing.assert_array_equal(p1, p2)
        assert st_on["cache"]["hits"] > 0


class TestAdmissionQueue:
    def test_overflow_rejects_and_counts(self, dev_and_s):
        dev, s = dev_and_s
        server = AsyncServer(dev, ServeConfig(queue_depth=4, pipeline=False,
                                              cache_size=0))
        pat = np.asarray(s[:6])
        accepted = [server.submit(i, pat) for i in range(7)]
        assert accepted == [True] * 4 + [False] * 3
        assert server.n_admitted == 4 and server.n_rejected == 3
        server.drain()
        assert len(server.results) == 4

    def test_closed_loop_retries_rejections(self, dev_and_s, workload):
        dev, _ = dev_and_s
        res, stats = run_closed_loop(
            dev, workload, ServeConfig(queue_depth=8, max_batch=8,
                                       pipeline=True, cache_size=0))
        assert stats["served"] == len(workload)
        want = dev.find_batch(workload)
        for (pos, _), w in zip(res, want):
            np.testing.assert_array_equal(pos, w)

    def test_shapes_are_bucketed_pow2(self, dev_and_s, workload):
        dev, _ = dev_and_s
        _, stats = run_closed_loop(dev, workload,
                                   ServeConfig(pipeline=True, cache_size=0))
        for m_pad, b_pad in stats["shapes"]:
            assert m_pad & (m_pad - 1) == 0 or m_pad == dev.max_pattern_len
            assert b_pad & (b_pad - 1) == 0


class TestRouteCache:
    def test_lru_eviction_and_counters(self):
        c = RouteCache(capacity=2)
        c.put("a", (0, 1))
        c.put("b", (1, 2))
        assert c.get("a") == (0, 1)   # refresh a
        c.put("c", (2, 3))            # evicts b (LRU)
        assert c.get("b") is None
        assert c.get("a") == (0, 1) and c.get("c") == (2, 3)
        assert c.evictions == 1 and c.hits == 3 and c.misses == 1
        assert 0 < c.hit_rate < 1
        c.clear()
        assert len(c) == 0

    def test_zero_capacity_never_stores(self):
        c = RouteCache(capacity=0)
        c.put("a", (0, 1))
        assert c.get("a") is None and len(c) == 0

    def test_find_batch_cached_identity_and_counters(self, dev_and_s,
                                                     workload):
        dev, _ = dev_and_s
        pats = workload[:60]
        want = dev.find_batch(pats)
        cache = RouteCache(capacity=128)
        for _ in range(2):
            got = dev.find_batch_cached(pats, cache)
            for g, w in zip(got, want):
                np.testing.assert_array_equal(g, w)
        assert cache.hits > 0 and cache.misses > 0
        stats = cache.stats()
        assert stats["hits"] == cache.hits and stats["size"] == len(cache)

    def test_eviction_pressure_stays_correct(self, dev_and_s, workload):
        dev, _ = dev_and_s
        pats = workload[:60]
        want = dev.find_batch(pats)
        cache = RouteCache(capacity=3)
        got = dev.find_batch_cached(pats * 2, cache)
        for g, w in zip(got, want * 2):
            np.testing.assert_array_equal(g, w)
        assert cache.evictions > 0 and len(cache) <= 3


class TestPadBatchBuckets:
    def test_pinned_width_and_rows(self, dev_and_s):
        dev, s = dev_and_s
        pats = [np.asarray(s[:5]), np.asarray(s[3:10])]
        padded, lengths, route = dev.pad_batch(pats, m_pad=16, b_pad=8)
        assert padded.shape == (8, 16) and lengths.shape == (8,)
        assert (lengths[2:] == 1).all()  # dummy rows
        st, ct = dev.find_batch_ranges(padded, lengths, route)
        st2, ct2 = dev.find_batch_ranges(*dev.pad_batch(pats))
        np.testing.assert_array_equal(np.asarray(st)[:2], np.asarray(st2))
        np.testing.assert_array_equal(np.asarray(ct)[:2], np.asarray(ct2))

    def test_rejects_bad_buckets(self, dev_and_s):
        dev, s = dev_and_s
        pats = [np.asarray(s[:10])]
        with pytest.raises(ValueError):
            dev.pad_batch(pats, m_pad=6)    # not a multiple of 4
        with pytest.raises(ValueError):
            dev.pad_batch(pats, m_pad=8)    # below the natural width (12)
        with pytest.raises(ValueError):
            dev.pad_batch(pats, b_pad=0)    # fewer rows than patterns


class TestFindFetch:
    def test_windows_match_read_symbols(self, dev_and_s, workload):
        dev, _ = dev_and_s
        pats = workload[:40]
        padded, lengths, route = dev.pad_batch(pats)
        start, count = map(np.asarray,
                           dev.find_batch_ranges(padded, lengths, route))
        _, wins = dev.find_fetch_batch(pats, fetch=16)
        pos0 = dev.ell_host[np.clip(start, 0, dev.n_leaves - 1)]
        ref = np.asarray(dev.read_symbols(pos0, 16))
        n_real = dev.n_leaves
        for i in range(len(pats)):
            if count[i] == 0:
                assert (wins[i] == -1).all()
                continue
            past = pos0[i] + np.arange(16) >= n_real
            np.testing.assert_array_equal(wins[i][~past], ref[i][~past])
            assert (wins[i][past] == dev.s_text.terminal).all()

    def test_dense_and_byte_windows_identical(self):
        alpha = PROTEIN_CLASS
        s = alpha.random_string(1200, seed=5)
        idx = EraIndexer(alpha, EraConfig(
            memory_bytes=1 << 16, build_impl="none")).build(s)
        rng = np.random.default_rng(8)
        pats = [np.asarray(s[i : i + m]) for i, m in zip(
            rng.integers(0, 1100, 12), rng.integers(1, 14, 12))]
        r_d, w_d = idx.to_device(packing="dense").find_fetch_batch(
            pats, fetch=20)
        r_b, w_b = idx.to_device(packing="bytes").find_fetch_batch(
            pats, fetch=20)
        for a, b in zip(r_d, r_b):
            np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(w_d, w_b)

    def test_fetch_validation(self, dev_and_s):
        dev, s = dev_and_s
        with pytest.raises(ValueError):
            dev.find_fetch_batch([np.asarray(s[:4])], fetch=6)
        with pytest.raises(ValueError):
            dev.find_fetch_batch([np.asarray(s[:4])],
                                 fetch=dev.max_pattern_len + 4)


class TestBatchAging:
    """``max_wait_ms`` is per-request batch aging: a partial batch is held
    open until the OLDEST queued request has waited that long, then
    dispatched whatever its size.  (It used to be dead config.)"""

    def test_partial_batch_held_until_age(self, dev_and_s):
        dev, s = dev_and_s
        server = AsyncServer(dev, ServeConfig(
            pipeline=False, cache_size=0, max_batch=8, max_wait_ms=60.0))
        server.submit(0, np.asarray(s[:6]))
        server.submit(1, np.asarray(s[2:8]))
        assert server.pump() is False          # young partial batch: held
        assert server.results == {} and len(server.queue) == 2
        time.sleep(0.08)                       # let the oldest request age
        assert server.pump() is True
        assert sorted(server.results) == [0, 1]
        assert server.n_batches == 1

    def test_full_batch_dispatches_immediately(self, dev_and_s, workload):
        dev, _ = dev_and_s
        server = AsyncServer(dev, ServeConfig(
            pipeline=False, cache_size=0, max_batch=4, max_wait_ms=1e6))
        for i, p in enumerate(workload[:4]):
            server.submit(i, p)
        assert server.pump() is True           # full: aging never consulted
        assert len(server.results) == 4

    def test_drain_terminates_on_aging(self, dev_and_s, workload):
        dev, _ = dev_and_s
        server = AsyncServer(dev, ServeConfig(
            pipeline=True, cache_size=0, max_batch=64, max_wait_ms=5.0))
        for i, p in enumerate(workload[:10]):  # never fills max_batch
            server.submit(i, p)
        server.drain()
        assert len(server.results) == 10 and server.inflight is None

    def test_aged_results_byte_identical(self, dev_and_s, workload):
        dev, _ = dev_and_s
        pats = workload[:10]
        want = dev.find_batch(pats)
        res, _ = run_closed_loop(dev, pats, ServeConfig(
            pipeline=True, cache_size=0, max_batch=64, max_wait_ms=2.0))
        for (pos, _), w in zip(res, want):
            np.testing.assert_array_equal(pos, w)


class TestObservabilityWiring:
    """The serving loop's instrumentation: counters/histograms/spans land
    in the global registry when obs is on, results stay byte-identical,
    and with obs off the server binds only null instruments."""

    @pytest.fixture()
    def obs_on(self):
        from repro import obs
        was_t, was_m = obs.trace_enabled(), obs.metrics_enabled()
        obs.configure(trace=True, metrics_on=True, clear=True)
        yield obs
        obs.configure(trace=was_t, metrics_on=was_m, clear=True)

    def test_registry_wiring_closed_loop(self, dev_and_s, workload, obs_on):
        dev, _ = dev_and_s
        res, stats = run_closed_loop(dev, workload, ServeConfig(
            pipeline=True, cache_size=512, max_batch=32))
        m = obs_on.metrics()
        assert m.counter("serve_requests_total").value >= len(workload)
        assert m.counter("serve_batches_total").value == stats["batches"]
        assert m.counter("serve_cache_hits_total").value \
            == stats["cache"]["hits"]
        fill = m.histogram("serve_batch_fill")
        assert fill.count == stats["batches"]
        assert m.histogram("serve_batch_age_ms").count > 0
        assert m.histogram("serve_queue_wait_ms").count >= len(workload)
        prom = m.to_prometheus()
        assert "serve_cache_hit_rate" in prom
        assert "serve_batch_fill_bucket" in prom

    def test_spans_and_byte_identity(self, dev_and_s, workload, obs_on):
        dev, _ = dev_and_s
        want = dev.find_batch(workload)
        res, _ = run_closed_loop(dev, workload, ServeConfig(
            pipeline=True, cache_size=0, max_batch=32))
        for (pos, _), w in zip(res, want):
            np.testing.assert_array_equal(pos, w)
        names = {e["name"] for e in obs_on.tracer().events()}
        for want_span in ("serve/queue_wait", "serve/pad_pack",
                          "serve/device_dispatch", "serve/consume_sync"):
            assert want_span in names, names
        assert obs_on.validate_chrome_trace(
            obs_on.tracer().to_chrome()) == []

    def test_obs_off_binds_null_instruments(self, dev_and_s):
        from repro import obs
        was_t, was_m = obs.trace_enabled(), obs.metrics_enabled()
        obs.configure(trace=False, metrics_on=False)
        try:
            dev, _ = dev_and_s
            server = AsyncServer(dev, ServeConfig(pipeline=True))
            assert server._m_requests is obs.NULL_INSTRUMENT
            assert server._h_batch_fill is obs.NULL_INSTRUMENT
            assert server._trace_on is False and server._metrics_on is False
        finally:
            obs.configure(trace=was_t, metrics_on=was_m)
