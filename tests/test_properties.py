"""Property-based tests (hypothesis) on the system's invariants."""

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import packing, ref
from repro.core.alphabet import BYTE, DNA, PROTEIN, PROTEIN_CLASS
from repro.core.api import EraConfig, EraIndexer
from repro.core.prepare import pack_words
from repro.kernels.ref import pack_words_ref, suffix_lcp_words_ref
from repro.runtime.scheduler import WorkQueue

SETTINGS = dict(max_examples=25, deadline=None)

WORD_ALPHAS = [DNA, PROTEIN_CLASS, PROTEIN, BYTE]


@st.composite
def dna_strings(draw, min_n=4, max_n=120):
    n = draw(st.integers(min_n, max_n))
    seed = draw(st.integers(0, 2**31 - 1))
    return DNA.random_string(n, seed=seed)


class TestSuffixTreeInvariants:
    @given(s=dna_strings())
    @settings(**SETTINGS)
    def test_every_suffix_is_a_leaf_exactly_once(self, s):
        idx = EraIndexer(DNA, EraConfig(memory_bytes=512, r_bytes=64,
                                        build_impl="none")).build(s)
        leaves = np.concatenate([st_.ell for st_ in idx.subtrees.values()])
        assert sorted(leaves.tolist()) == list(range(len(s)))

    @given(s=dna_strings())
    @settings(**SETTINGS)
    def test_leaves_lexicographically_sorted(self, s):
        idx = EraIndexer(DNA, EraConfig(memory_bytes=512, r_bytes=64,
                                        build_impl="none")).build(s)
        for st_ in idx.subtrees.values():
            suf = [tuple(int(c) for c in s[i:]) for i in st_.ell]
            assert suf == sorted(suf)

    @given(s=dna_strings())
    @settings(**SETTINGS)
    def test_b_offsets_at_least_prefix_len(self, s):
        idx = EraIndexer(DNA, EraConfig(memory_bytes=512, r_bytes=64,
                                        build_impl="none")).build(s)
        for p, st_ in idx.subtrees.items():
            for i in range(1, st_.freq):
                assert st_.b_off[i] >= len(p)

    @given(s=dna_strings(min_n=8), data=st.data())
    @settings(**SETTINGS)
    def test_find_matches_bruteforce(self, s, data):
        idx = EraIndexer(DNA, EraConfig(memory_bytes=1024, r_bytes=64)).build(s)
        m = data.draw(st.integers(1, 5))
        i = data.draw(st.integers(0, len(s) - 1 - m))
        pat = s[i : i + m]
        want = ref.occurrences(s, pat)
        assert np.array_equal(idx.find(pat), want)
        assert np.array_equal(idx.find_walk(pat), want)


class TestPackingOrder:
    @given(st.data())
    @settings(**SETTINGS)
    def test_packed_int_order_is_lexicographic(self, data):
        """The whole sort correctness rests on this isomorphism."""
        w = data.draw(st.sampled_from([4, 8, 16]))
        a = np.array(data.draw(st.lists(st.integers(0, 27), min_size=w, max_size=w)),
                     np.uint8)
        b = np.array(data.draw(st.lists(st.integers(0, 27), min_size=w, max_size=w)),
                     np.uint8)
        pa = np.asarray(pack_words(jnp.asarray(a[None]))).tolist()[0]
        pb = np.asarray(pack_words(jnp.asarray(b[None]))).tolist()[0]
        assert (tuple(a) < tuple(b)) == (pa < pb)
        assert (tuple(a) == tuple(b)) == (pa == pb)

    @given(st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_pack_impls_agree(self, seed):
        rng = np.random.default_rng(seed)
        sym = rng.integers(0, 27, size=(5, 16)).astype(np.uint8)
        np.testing.assert_array_equal(
            np.asarray(pack_words(jnp.asarray(sym))),
            np.asarray(pack_words_ref(jnp.asarray(sym))))


class TestDensePackingProperties:
    """PR 5 word-compare engine invariants: dense round-trips and the
    XOR+clz word LCP vs a naive symbol scan, across all density tiers
    (2-bit DNA, 4-bit protein classes, 8-bit protein/byte)."""

    @given(data=st.data())
    @settings(**SETTINGS)
    def test_pack_unpack_text_roundtrip(self, data):
        alpha = data.draw(st.sampled_from(WORD_ALPHAS))
        n = data.draw(st.integers(1, 300))
        seed = data.draw(st.integers(0, 2**31 - 1))
        extra = data.draw(st.integers(0, 64))
        s = alpha.random_string(n, seed=seed)
        pt = packing.pack_text(s, alpha, extra=extra)
        np.testing.assert_array_equal(packing.unpack_text(pt), s)

    @given(data=st.data())
    @settings(**SETTINGS)
    def test_pack_dense_extract_sym_roundtrip(self, data):
        alpha = data.draw(st.sampled_from(WORD_ALPHAS))
        bits = alpha.dense_bits
        m = data.draw(st.integers(1, 40))
        sym = np.array(data.draw(st.lists(
            st.integers(0, len(alpha.symbols) - 1), min_size=m, max_size=m)),
            np.int32)
        words = packing.pack_dense(jnp.asarray(sym[None, :]), bits)
        for i in range(m):
            got = packing.extract_sym(words, jnp.asarray([i], jnp.int32),
                                      bits)
            assert int(np.asarray(got)[0]) == int(sym[i])

    @given(data=st.data())
    @settings(**SETTINGS)
    def test_word_lcp_equals_naive_symbol_lcp(self, data):
        """XOR + count-leading-zeros + terminal limits == symbol scan."""
        alpha = data.draw(st.sampled_from(WORD_ALPHAS))
        n = data.draw(st.integers(8, 200))
        seed = data.draw(st.integers(0, 2**31 - 1))
        w = data.draw(st.sampled_from([4, 16, 32]))
        s = alpha.random_string(n, seed=seed)
        pt = packing.pack_text(s, alpha, extra=w + 8)
        sp = alpha.pad_string(s, extra=w + 8)
        pos_a = data.draw(st.integers(0, n))
        pos_b = data.draw(st.integers(0, n))
        if pos_a == pos_b:  # contract covers distinct suffixes
            pos_b = (pos_b + 1) % (n + 1)
        got = int(np.asarray(suffix_lcp_words_ref(
            pt, jnp.asarray([pos_a], jnp.int32),
            jnp.asarray([pos_b], jnp.int32), w))[0])
        h = 0
        while h < w and sp[pos_a + h] == sp[pos_b + h]:
            h += 1
        assert got == h


class TestSchedulerInvariants:
    @given(costs=st.lists(st.integers(1, 100), min_size=1, max_size=40),
           fail_at=st.integers(0, 5), seed=st.integers(0, 1000))
    @settings(**SETTINGS)
    def test_all_tasks_complete_despite_failures(self, costs, fail_at, seed):
        rng = np.random.default_rng(seed)
        q = WorkQueue()
        q.add_tasks(costs)
        workers = ["a", "b", "c"]
        dead = set()
        pulls = 0
        while not q.drained:
            alive = [w for w in workers if w not in dead] or ["z"]
            w = alive[int(rng.integers(0, len(alive)))]
            t = q.pull(w)
            if t is None:
                for d in list(dead):
                    q.mark_failed(d)
                continue
            pulls += 1
            if pulls == fail_at and len(dead) < 2:
                dead.add(w)
                q.mark_failed(w)
                continue
            q.complete(t.task_id, worker=w, elapsed_s=0.01 * t.cost)
        st_ = q.stats()
        assert st_["done"] == len(costs)

    @given(costs=st.lists(st.integers(1, 50), min_size=2, max_size=30))
    @settings(**SETTINGS)
    def test_largest_first_dispatch(self, costs):
        q = WorkQueue()
        q.add_tasks(costs)
        seen = []
        while True:
            t = q.pull("w")
            if t is None:
                break
            seen.append(t.cost)
            q.complete(t.task_id, worker="w")
        assert seen == sorted(costs, reverse=True)
