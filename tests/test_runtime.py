"""Runtime substrate: checkpointing, fault-tolerant distributed ERA build,
optimizer behaviour, gradient compression, data pipeline determinism."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ref
from repro.core.alphabet import DNA
from repro.core.api import EraConfig, EraIndexer
from repro.data.tokens import TokenPipelineConfig, batch_at_step
from repro.launch.era_run import build_distributed
from repro.optim import adamw, compress
from repro.runtime import checkpoint
from repro.runtime.scheduler import WorkQueue


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.ones((4,), jnp.int32)}}
        p = str(tmp_path / "ck.npz")
        checkpoint.save(p, tree, step=7, meta={"tag": "x"})
        got, meta = checkpoint.restore(p, tree)
        assert meta["step"] == 7 and meta["tag"] == "x"
        np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))

    def test_restore_validates_shapes(self, tmp_path):
        p = str(tmp_path / "ck.npz")
        checkpoint.save(p, {"a": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            checkpoint.restore(p, {"a": jnp.zeros((3, 3))})

    def test_latest_step(self, tmp_path):
        for s in (10, 30, 20):
            checkpoint.save(str(tmp_path / f"step_{s}.npz"), {"a": jnp.zeros(1)}, step=s)
        assert checkpoint.latest_step_path(str(tmp_path)).endswith("step_30.npz")

    def test_train_state_roundtrip(self, tmp_path):
        from repro.models import transformer as T
        from repro.models.config import smoke_config
        from repro.models.registry import get_config
        cfg = smoke_config(get_config("qwen3-1.7b"))
        params = T.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        opt = adamw.init(params)
        p = str(tmp_path / "train.npz")
        checkpoint.save(p, (params, opt), step=3)
        (p2, o2), meta = checkpoint.restore(p, (params, opt))
        assert meta["step"] == 3
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestDistributedEra:
    def test_matches_serial(self):
        s = DNA.random_string(600, seed=31)
        cfg = EraConfig(memory_bytes=2048, r_bytes=128, build_impl="none")
        serial = EraIndexer(DNA, cfg).build(s)
        dist, qstats, _ = build_distributed(s, DNA, cfg, n_workers=3)
        assert set(dist.subtrees) == set(serial.subtrees)
        for p in serial.subtrees:
            np.testing.assert_array_equal(dist.subtrees[p].ell, serial.subtrees[p].ell)
        assert qstats["done"] == qstats["total"]

    def test_survives_node_failure(self):
        s = DNA.random_string(500, seed=32)
        cfg = EraConfig(memory_bytes=1024, r_bytes=128, build_impl="none")
        idx, qstats, _ = build_distributed(
            s, DNA, cfg, n_workers=3, fail_worker="w1", fail_after=1)
        assert qstats["done"] == qstats["total"]
        assert idx.n_leaves == len(s)
        # queries still correct after recovery
        pat = s[5:9]
        np.testing.assert_array_equal(idx.find(pat), ref.occurrences(s, pat))

    def test_checkpoint_recovery_skips_done_groups(self, tmp_path):
        s = DNA.random_string(400, seed=33)
        cfg = EraConfig(memory_bytes=1024, r_bytes=128, build_impl="none")
        ck = str(tmp_path / "groups.jsonl")
        build_distributed(s, DNA, cfg, n_workers=2, checkpoint_path=ck)
        # second run replays from the log: queue reports all done, no pulls
        q = WorkQueue(checkpoint_path=ck)
        q.add_tasks([1.0] * sum(1 for _ in open(ck)))
        assert q.drained


class TestOptim:
    def test_adamw_converges_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                                total_steps=200, schedule="constant")
        params = {"x": jnp.array([5.0, -3.0])}
        opt = adamw.init(params)
        loss = lambda p: jnp.sum(jnp.square(p["x"] - jnp.array([1.0, 2.0])))
        for _ in range(150):
            g = jax.grad(loss)(params)
            params, opt, _ = adamw.update(cfg, g, opt, params)
        assert float(loss(params)) < 1e-2

    def test_clipping(self):
        g = {"a": jnp.full((10,), 100.0)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert float(adamw.global_norm(clipped)) <= 1.0 + 1e-5
        assert float(norm) > 100.0

    def test_schedule_warmup_and_decay(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
        lr0 = float(adamw.schedule_lr(cfg, jnp.asarray(0)))
        lr10 = float(adamw.schedule_lr(cfg, jnp.asarray(10)))
        lr99 = float(adamw.schedule_lr(cfg, jnp.asarray(99)))
        assert lr0 < lr10 and lr99 < lr10
        assert abs(lr10 - 1.0) < 0.1


class TestCompression:
    def test_quant_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
        q, s = compress.quantize_int8(x)
        err = np.abs(np.asarray(compress.dequantize_int8(q, s) - x))
        assert err.max() <= float(s) / 2 + 1e-6

    def test_error_feedback_reduces_bias(self):
        """With EF, the accumulated compressed sum tracks the true sum."""
        rng = np.random.default_rng(1)
        g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32) * 0.01
        g_tree = {"g": g_true}
        err_tree = compress.init_error_state(g_tree)
        acc_c = np.zeros(64)
        for step in range(50):
            (q, s), err_tree = compress.compress_with_feedback(g_tree, err_tree)
            acc_c += np.asarray(compress.dequantize_int8(q["g"], s["g"]))
        acc_t = np.asarray(g_true) * 50
        np.testing.assert_allclose(acc_c, acc_t, atol=float(s["g"]) * 2 + 1e-5)


class TestDataPipeline:
    def test_deterministic_restart(self):
        cfg = TokenPipelineConfig(vocab=100, batch=4, seq_len=16, seed=5)
        a = batch_at_step(cfg, 42)
        b = batch_at_step(cfg, 42)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = batch_at_step(cfg, 43)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = TokenPipelineConfig(vocab=50, batch=2, seq_len=8, seed=0)
        b = batch_at_step(cfg, 0)
        assert b["tokens"].shape == b["labels"].shape == (2, 8)
