"""End-to-end behaviour tests for the whole system."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ref
from repro.core.alphabet import DNA
from repro.core.api import BuildReport, EraConfig, EraIndexer
from repro.core.prepare import PrepareStats
from repro.core.vertical import VerticalStats
from repro.data.strings import BlockStream, dataset, synthetic_string


class TestEraSystem:
    def test_full_dataset_pipeline(self):
        """dataset -> index -> query, the quickstart path."""
        s, alpha = dataset("dna", 3000, seed=1)
        idx = EraIndexer(alpha, EraConfig(memory_bytes=16384, r_bytes=512)).build(s)
        assert idx.n_leaves == len(s)
        pat = s[100:106]
        assert np.array_equal(idx.find(pat), ref.occurrences(s, pat))

    def test_repeat_heavy_string(self):
        """Planted repeats force deep elastic-range iterations."""
        s = synthetic_string(DNA, 2000, seed=2, repeat_fraction=0.8, repeat_len=128)
        stats = PrepareStats()
        rep = BuildReport(VerticalStats(), stats)
        idx = EraIndexer(DNA, EraConfig(memory_bytes=8192, r_bytes=256,
                                        build_impl="none")).build(s, rep)
        assert idx.n_leaves == len(s)
        assert stats.iterations >= 2  # repeats -> multiple range rounds

    def test_block_stream_skip_reads_less(self):
        s, _ = dataset("dna", 1 << 16, seed=3)
        full = BlockStream(s, block_bytes=1024)
        for _ in full.read_all():
            pass
        sparse = BlockStream(s, block_bytes=1024)
        offs = np.arange(0, len(s), 8192)
        for _ in sparse.read_for_offsets(offs, 64):
            pass
        assert sparse.stats.bytes_read < full.stats.bytes_read


class TestTrainSystem:
    def test_loss_decreases_small_model(self):
        from repro.launch.train import train
        params, losses = train("qwen3-1.7b", smoke=True, steps=30, batch=4,
                               seq=32, lr=2e-3, log_every=5)
        assert len(losses) >= 3
        assert losses[-1] < losses[0], losses

    def test_checkpoint_resume_exact(self, tmp_path):
        from repro.launch.train import train
        ck = str(tmp_path / "ck")
        train("qwen3-1.7b", smoke=True, steps=10, batch=2, seq=16,
              ckpt_dir=ck, ckpt_every=5, log_every=100)
        # resume from step 10 and run to 12: must not error, must load step 10
        params, _ = train("qwen3-1.7b", smoke=True, steps=12, batch=2, seq=16,
                          ckpt_dir=ck, ckpt_every=50, resume=True, log_every=100)
        assert params is not None


class TestServeSystem:
    def test_batched_generation(self):
        from repro.launch.serve import serve
        tokens, stats = serve("qwen3-1.7b", smoke=True, batch=3, prompt_len=8, gen=6)
        assert tokens.shape == (3, 6)
        assert stats["decode_tok_s"] > 0

    def test_ssm_generation(self):
        from repro.launch.serve import serve
        tokens, _ = serve("falcon-mamba-7b", smoke=True, batch=2, prompt_len=8, gen=4)
        assert tokens.shape == (2, 4)


class TestDedupPipeline:
    def test_dedup_flags_duplicates(self):
        from repro.data.tokens import dedup_mask
        rng = np.random.default_rng(0)
        seqs = rng.integers(0, 1000, size=(6, 64), dtype=np.int32)
        seqs[3] = seqs[1]  # exact duplicate content
        keep = dedup_mask(seqs, min_repeat=32)
        assert keep.sum() < 6  # at least one of the duplicates flagged
