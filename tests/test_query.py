"""Device-resident batched query engine vs the numpy oracle paths."""

import numpy as np
import pytest

from repro.core import ref
from repro.core.alphabet import BYTE, DNA, PROTEIN
from repro.core.api import EraConfig, EraIndexer
from repro.core.query import DeviceIndex
from repro.core.suffix_tree import SuffixTreeIndex


def build(alpha, n, *, memory_bytes, seed, build_impl="none"):
    s = alpha.random_string(n, seed=seed)
    idx = EraIndexer(alpha, EraConfig(memory_bytes=memory_bytes, r_bytes=128,
                                      build_impl=build_impl)).build(s)
    return s, idx


def random_patterns(s, rng, count, max_len=12):
    """Planted substrings (hits) across lengths 1..max_len."""
    pats = []
    for _ in range(count):
        m = int(rng.integers(1, max_len + 1))
        i = int(rng.integers(0, len(s) - 1 - m))
        pats.append(np.asarray(s[i : i + m]))
    return pats


class TestFindBatchMatchesOracle:
    @pytest.mark.parametrize("alpha,n,mem", [
        (DNA, 800, 512),        # tight budget: deep prefixes, many sub-trees
        (DNA, 1500, 8192),
        (PROTEIN, 700, 4096),
        (BYTE, 600, 4096),      # codes >= 128: unsigned packed-word order
    ])
    def test_randomized_cross_check(self, alpha, n, mem):
        s, idx = build(alpha, n, memory_bytes=mem, seed=n + mem)
        dev = idx.to_device()
        rng = np.random.default_rng(n)
        pats = random_patterns(s, rng, 30)
        # random patterns over the alphabet: mostly absent for big alphabets
        for _ in range(10):
            m = int(rng.integers(1, 10))
            pats.append(rng.integers(0, len(alpha.symbols), size=m).astype(np.uint8))
        got = dev.find_batch(pats)
        for p, g in zip(pats, got):
            want = idx.find(p)
            np.testing.assert_array_equal(g, want)
            np.testing.assert_array_equal(g, ref.occurrences(s, p))

    def test_empty_hits_and_absent_patterns(self):
        s, idx = build(DNA, 500, memory_bytes=2048, seed=5)
        dev = idx.to_device()
        # a pattern of 16 A's is (almost surely) absent from random DNA
        pats = [np.zeros(16, np.uint8), np.asarray(s[10:14])]
        got = dev.find_batch(pats)
        np.testing.assert_array_equal(got[0], ref.occurrences(s, pats[0]))
        np.testing.assert_array_equal(got[1], idx.find(pats[1]))

    def test_pattern_longer_than_any_suffix(self):
        s, idx = build(DNA, 300, memory_bytes=2048, seed=9)
        dev = idx.to_device(max_pattern_len=1024)
        long_pat = DNA.random_string(len(s) + 7, seed=42)[:-1]
        (got,) = dev.find_batch([long_pat])
        assert got.size == 0

    def test_pattern_shorter_than_vertical_prefix(self):
        # memory_bytes=512 -> f_max ~ 9: prefixes go several symbols deep,
        # so length-1/2 patterns route to MANY whole sub-trees at once
        s, idx = build(DNA, 900, memory_bytes=512, seed=17)
        assert max(len(p) for p in idx.subtrees) >= 3
        dev = idx.to_device()
        pats = [np.array([c], np.uint8) for c in range(4)]
        pats += [np.array([c1, c2], np.uint8) for c1 in range(4) for c2 in range(2)]
        got = dev.find_batch(pats)
        for p, g in zip(pats, got):
            np.testing.assert_array_equal(g, idx.find(p))

    def test_mixed_length_batch_single_call(self):
        s, idx = build(DNA, 600, memory_bytes=1024, seed=3)
        dev = idx.to_device()
        pats = [s[0:1], s[5:13], s[20:52], np.zeros(9, np.uint8)]
        got = dev.find_batch(pats)
        for p, g in zip(pats, got):
            np.testing.assert_array_equal(g, idx.find(p))

    def test_index_fast_path_caches_device(self):
        s, idx = build(DNA, 400, memory_bytes=2048, seed=1)
        pats = random_patterns(s, np.random.default_rng(0), 5)
        got = idx.find_batch(pats)
        assert idx._device is not None
        for p, g in zip(pats, got):
            np.testing.assert_array_equal(g, idx.find(p))

    def test_validation(self):
        s, idx = build(DNA, 300, memory_bytes=2048, seed=2)
        dev = idx.to_device()
        with pytest.raises(ValueError):
            dev.find_batch([])
        with pytest.raises(ValueError):
            dev.find_batch([np.empty(0, np.uint8)])
        with pytest.raises(ValueError):
            dev.find_batch([np.array([99], np.uint8)])  # code out of range
        with pytest.raises(ValueError):
            dev.find_batch([np.zeros(dev.max_pattern_len + 5, np.uint8)])


class TestDeviceIndexStructure:
    def test_concatenated_ell_is_the_suffix_array(self):
        """Prefix-free + covering ⇒ the flattened leaf arrays ARE the SA."""
        s, idx = build(DNA, 400, memory_bytes=1024, seed=11)
        dev = idx.to_device()
        np.testing.assert_array_equal(np.asarray(dev.ell),
                                      ref.suffix_array(s).astype(np.int32))

    def test_routing_table_windows_cover_subtree_slices(self):
        s, idx = build(DNA, 500, memory_bytes=1024, seed=13)
        dev = idx.to_device()
        win_lo = np.asarray(dev.win_lo)
        win_hi = np.asarray(dev.win_hi)
        offs = np.asarray(dev.sub_off)
        freqs = np.asarray(dev.sub_freq)
        total = int(freqs.sum())
        assert dev.n_leaves == total == len(s)
        assert (win_lo >= 0).all() and (win_hi <= total).all()
        # every sub-tree's own routing cell window contains its slice
        pref = np.asarray(dev.sub_prefix)
        plen = np.asarray(dev.sub_plen)
        base = dev.base
        for t in range(dev.n_subtrees):
            kk = min(int(plen[t]), dev.k_route)
            c = 0
            for j in range(kk):
                c = c * base + int(pref[t, j])
            c *= base ** (dev.k_route - kk)
            assert win_lo[c] <= offs[t]
            assert win_hi[c + base ** (dev.k_route - kk) - 1] >= offs[t] + freqs[t]


class TestSaveLoadRoundTrip:
    def test_nodes_survive_save_load_find_walk(self, tmp_path):
        """Built SubTreeNodes used to be dropped on save, so a loaded index
        raised in find_walk; they are persisted now."""
        s, idx = build(DNA, 300, memory_bytes=2048, seed=21,
                       build_impl="numpy")
        p = str(tmp_path / "index.npz")
        idx.save(p)
        idx2 = SuffixTreeIndex.load(p, DNA)
        assert set(idx2.subtrees) == set(idx.subtrees)
        rng = np.random.default_rng(4)
        for pat in random_patterns(s, rng, 8, max_len=6):
            want = idx.find(pat)
            np.testing.assert_array_equal(idx2.find_walk(pat), want)
            np.testing.assert_array_equal(idx2.find(pat), want)

    def test_loaded_index_serves_batched_queries(self, tmp_path):
        s, idx = build(DNA, 300, memory_bytes=2048, seed=23)
        p = str(tmp_path / "index.npz")
        idx.save(p)
        idx2 = SuffixTreeIndex.load(p, DNA)
        dev = DeviceIndex.from_index(idx2)
        pats = random_patterns(s, np.random.default_rng(6), 6)
        for pat, g in zip(pats, dev.find_batch(pats)):
            np.testing.assert_array_equal(g, idx.find(pat))

    def test_device_index_npz_round_trip(self, tmp_path):
        """DeviceIndex.save/load restores every field and serves identical
        results, so serve drivers can warm-start without re-flattening."""
        s, idx = build(BYTE, 400, memory_bytes=4096, seed=31)
        dev = idx.to_device()
        p = str(tmp_path / "dev.npz")
        dev.save(p)
        dev2 = DeviceIndex.load(p)
        assert (dev2.base, dev2.k_route, dev2.n_iter, dev2.max_pattern_len) \
            == (dev.base, dev.k_route, dev.n_iter, dev.max_pattern_len)
        for name in DeviceIndex._BLOB_FIELDS:
            np.testing.assert_array_equal(np.asarray(getattr(dev2, name)),
                                          np.asarray(getattr(dev, name)))
        assert dev2.s_padded.dtype == dev.s_padded.dtype
        pats = random_patterns(s, np.random.default_rng(8), 10)
        for pat, g in zip(pats, dev2.find_batch(pats)):
            np.testing.assert_array_equal(g, idx.find(pat))
