"""Per-architecture smoke tests: reduced same-family configs, one forward /
train / prefill / decode step on CPU, shape + NaN assertions, and
prefill→decode vs full-forward consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.launch import steps as step_lib
from repro.models import transformer as T
from repro.models.config import SHAPES, ShapeConfig, smoke_config
from repro.models.registry import (
    ARCHS, cell_is_runnable, concrete_inputs, get_config, input_specs)
from repro.optim import adamw

SMOKE_TRAIN = ShapeConfig("smoke_train", "train", 16, 2)
SMOKE_PRE = ShapeConfig("smoke_pre", "prefill", 16, 2)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", sorted(ARCHS))
class TestArchSmoke:
    def test_forward_train(self, arch, rng):
        cfg = smoke_config(get_config(arch))
        params = T.init_params(rng, cfg, jnp.float32)
        batch = concrete_inputs(cfg, SMOKE_TRAIN, dtype=jnp.float32)
        logits, aux = jax.jit(lambda p, b: T.forward_train(p, b, cfg))(params, batch)
        assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
        assert not bool(jnp.isnan(logits).any())
        assert not bool(jnp.isnan(aux).any())

    def test_train_step_updates_params(self, arch, rng):
        cfg = smoke_config(get_config(arch))
        params = T.init_params(rng, cfg, jnp.float32)
        opt = adamw.init(params)
        batch = concrete_inputs(cfg, SMOKE_TRAIN, dtype=jnp.float32)
        step = jax.jit(step_lib.make_train_step(cfg, adamw.AdamWConfig(lr=1e-3)))
        new_params, new_opt, metrics = step(params, opt, batch)
        assert float(metrics["loss"]) > 0 and np.isfinite(float(metrics["loss"]))
        # at least one leaf must actually change
        changed = jax.tree.reduce(
            lambda a, b: a or b,
            jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new_params))
        assert changed
        assert int(new_opt.step) == 1

    def test_prefill_then_decode(self, arch, rng):
        cfg = smoke_config(get_config(arch))
        params = T.init_params(rng, cfg, jnp.float32)
        cache = T.init_cache(cfg, 2, 32, dtype=jnp.float32)
        pre = concrete_inputs(cfg, SMOKE_PRE, dtype=jnp.float32)
        logits, cache = jax.jit(lambda p, b, c: T.forward_prefill(p, b, cfg, c))(
            params, pre, cache)
        assert not bool(jnp.isnan(logits).any())
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        logits2, cache = jax.jit(lambda p, t, c: T.forward_decode(p, t, cfg, c))(
            params, tok, cache)
        assert logits2.shape == (2, 1, cfg.vocab)
        assert not bool(jnp.isnan(logits2).any())
        assert int(cache["pos"]) == 17


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "falcon-mamba-7b", "gemma3-4b",
                                  "deepseek-v2-236b", "zamba2-2.7b"])
def test_decode_consistent_with_full_forward(arch):
    """Prefill(t0..t14) + decode(t15) must equal train logits at pos 15."""
    cfg = smoke_config(get_config(arch))
    params = T.init_params(jax.random.PRNGKey(1), cfg, jnp.float32)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 16), dtype=np.int32))

    full, _ = T.forward_train(params, {"tokens": toks}, cfg, remat=False)

    cache = T.init_cache(cfg, 2, 32, dtype=jnp.float32)
    _, cache = T.forward_prefill(params, {"tokens": toks[:, :15]}, cfg, cache)
    dec, _ = T.forward_decode(params, toks[:, 15:16], cfg, cache)

    np.testing.assert_allclose(
        np.asarray(dec[:, 0]), np.asarray(full[:, 15]), rtol=2e-4, atol=2e-4)


def test_cell_matrix_covers_40():
    cells = [(a, s) for a in ARCHS for s in SHAPES]
    assert len(cells) == 40
    runnable = [c for c in cells if cell_is_runnable(get_config(c[0]), SHAPES[c[1]])[0]]
    skipped = [c for c in cells if c not in runnable]
    # long_500k runs only for ssm/hybrid per DESIGN.md
    assert {a for a, s in skipped if s == "long_500k"} == {
        "qwen3-1.7b", "qwen1.5-32b", "gemma3-4b", "qwen3-14b",
        "seamless-m4t-medium", "phi3.5-moe-42b-a6.6b", "deepseek-v2-236b",
        "internvl2-2b"}
    assert len(runnable) == 32


def test_input_specs_all_cells():
    for arch in ARCHS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            if not cell_is_runnable(cfg, shape)[0]:
                continue
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            for v in specs.values():
                assert all(d > 0 for d in v.shape)


def test_param_counts_sane():
    """Rough N sanity vs the published sizes (within 2x)."""
    expect = {"qwen3-1.7b": 1.7e9, "qwen1.5-32b": 32e9, "gemma3-4b": 4e9,
              "qwen3-14b": 14e9, "falcon-mamba-7b": 7e9, "zamba2-2.7b": 2.7e9,
              "phi3.5-moe-42b-a6.6b": 42e9, "deepseek-v2-236b": 236e9,
              "internvl2-2b": 2e9}
    for arch, want in expect.items():
        n = get_config(arch).param_count()
        assert want / 2.2 < n < want * 2.2, (arch, n, want)
    # MoE active < total
    ds = get_config("deepseek-v2-236b")
    assert ds.active_param_count() < 0.2 * ds.param_count()
