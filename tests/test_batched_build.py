"""Batched (G, F) construction engine vs the serial per-group reference.

The batched engine must be a pure performance transform: identical ``ell``
/ ``b_off`` / branching symbols, identical node topology, and identical
query results — across alphabets (including byte, which exercises unsigned
packed-word order) and across group counts > 1 with uneven group sizes
(padding correctness in both the G and F axes).
"""

import numpy as np
import pytest

from repro.core import ref
from repro.core.alphabet import BYTE, DNA, PROTEIN
from repro.core.api import BuildReport, EraConfig, EraIndexer
from repro.core.build import nodes_to_intervals
from repro.core.prepare import ElasticConfig, PrepareStats, subtree_prepare_batch
from repro.core.vertical import VerticalStats


def build_pair(alpha, n, mem, seed, build_impl="none"):
    s = alpha.random_string(n, seed=seed)
    kw = dict(memory_bytes=mem, r_bytes=128, build_impl=build_impl)
    serial = EraIndexer(alpha, EraConfig(construction="serial", **kw)).build(s)
    report = BuildReport(VerticalStats(), PrepareStats())
    batched = EraIndexer(alpha, EraConfig(construction="batched", **kw)).build(s, report)
    return s, serial, batched, report


class TestBatchedEqualsSerial:
    @pytest.mark.parametrize("alpha,n,mem", [
        (DNA, 900, 1024),
        (PROTEIN, 600, 4096),
        (BYTE, 500, 4096),     # codes >= 128: unsigned packed-word order
    ])
    def test_prepare_state_identical(self, alpha, n, mem):
        s, serial, batched, _ = build_pair(alpha, n, mem, seed=n + mem)
        assert set(serial.subtrees) == set(batched.subtrees)
        for p in serial.subtrees:
            a, b = serial.subtrees[p], batched.subtrees[p]
            np.testing.assert_array_equal(a.ell, b.ell, err_msg=str(p))
            np.testing.assert_array_equal(a.b_off, b.b_off, err_msg=str(p))
            np.testing.assert_array_equal(a.b_c1, b.b_c1, err_msg=str(p))
            np.testing.assert_array_equal(a.b_c2, b.b_c2, err_msg=str(p))

    def test_multi_group_uneven_sizes(self):
        """G > 1 with unequal total frequencies: the padded (G, F) state
        must not leak padding into any group's results."""
        s, serial, batched, report = build_pair(DNA, 1200, 768, seed=7)
        assert report.n_groups >= 4
        # uneven: the (G, F) state pads the smaller groups, so demand at
        # least two distinct group totals (else the test proves nothing)
        cfg = EraConfig(memory_bytes=768, r_bytes=128, build_impl="none")
        groups = EraIndexer(DNA, cfg).partition(s)
        assert len({g.total_freq for g in groups}) > 1
        for p in serial.subtrees:
            np.testing.assert_array_equal(
                serial.subtrees[p].ell, batched.subtrees[p].ell)
            np.testing.assert_array_equal(
                serial.subtrees[p].b_off, batched.subtrees[p].b_off)
        # and every leaf position appears exactly once overall
        leaves = np.concatenate([st.ell for st in batched.subtrees.values()])
        assert sorted(leaves.tolist()) == list(range(len(s)))

    @pytest.mark.parametrize("alpha,n,mem", [(DNA, 700, 1024), (PROTEIN, 400, 2048)])
    def test_node_topology_matches_serial_numpy(self, alpha, n, mem):
        """The vmapped padded Cartesian-tree build must produce the same
        canonical intervals as the paper-faithful sequential builder."""
        s, serial, batched, _ = build_pair(alpha, n, mem, seed=n,
                                           build_impl="numpy")
        for p in serial.subtrees:
            assert nodes_to_intervals(serial.subtrees[p].nodes) \
                == nodes_to_intervals(batched.subtrees[p].nodes), p

    @pytest.mark.parametrize("alpha,n,mem", [
        (DNA, 800, 1024), (PROTEIN, 500, 4096), (BYTE, 450, 4096)])
    def test_find_batch_identical(self, alpha, n, mem):
        s, serial, batched, _ = build_pair(alpha, n, mem, seed=n * 3)
        rng = np.random.default_rng(n)
        pats = []
        for _ in range(25):
            m = int(rng.integers(1, 12))
            i = int(rng.integers(0, len(s) - 1 - m))
            pats.append(np.asarray(s[i : i + m]))
        for _ in range(5):  # absent patterns too
            pats.append(rng.integers(0, len(alpha.symbols), size=6).astype(np.uint8))
        got_s = serial.find_batch(pats)
        got_b = batched.find_batch(pats)
        for p, a, b in zip(pats, got_s, got_b):
            np.testing.assert_array_equal(a, b)
            np.testing.assert_array_equal(b, ref.occurrences(s, p))


class TestBuildDeviceDirect:
    def test_matches_serial_flatten_without_subtree_dict(self):
        """string -> DeviceIndex directly: byte-identical query engine,
        no intermediate per-prefix numpy SubTree dict."""
        alpha, n, mem = DNA, 1000, 1024
        s = alpha.random_string(n, seed=41)
        kw = dict(memory_bytes=mem, r_bytes=128, build_impl="none")
        dev_direct = EraIndexer(alpha, EraConfig(construction="batched", **kw)).build_device(s)
        serial = EraIndexer(alpha, EraConfig(construction="serial", **kw)).build(s)
        dev_serial = serial.to_device()
        # the flattened leaf array (the suffix array) is byte-identical
        np.testing.assert_array_equal(np.asarray(dev_direct.ell),
                                      np.asarray(dev_serial.ell))
        np.testing.assert_array_equal(dev_direct.ell_host, dev_serial.ell_host)
        for name in ("sub_off", "sub_freq", "sub_prefix", "sub_plen",
                     "win_lo", "win_hi"):
            np.testing.assert_array_equal(np.asarray(getattr(dev_direct, name)),
                                          np.asarray(getattr(dev_serial, name)))
        rng = np.random.default_rng(5)
        pats = [np.asarray(s[int(i) : int(i) + 6]) for i in rng.integers(0, n - 7, 16)]
        for a, b in zip(dev_direct.find_batch(pats), dev_serial.find_batch(pats)):
            np.testing.assert_array_equal(a, b)

    def test_serial_engine_still_flattens_via_index(self):
        alpha = DNA
        s = alpha.random_string(300, seed=3)
        cfg = EraConfig(memory_bytes=2048, r_bytes=128, build_impl="none",
                        construction="serial")
        dev = EraIndexer(alpha, cfg).build_device(s)
        pat = s[10:16]
        (got,) = dev.find_batch([pat])
        np.testing.assert_array_equal(got, ref.occurrences(s, pat))


class TestDiagnostics:
    def test_convergence_error_carries_group_context(self):
        """The non-convergence error must name the stuck group(s), their
        total frequency, the current range and active count."""
        import jax.numpy as jnp
        s = DNA.random_string(400, seed=9)
        idx = EraIndexer(DNA, EraConfig(memory_bytes=2048, r_bytes=128))
        groups = idx.partition(s)
        s_padded = jnp.asarray(DNA.pad_string(s, extra=520))
        capacity = min(idx.config.f_max, max(g.total_freq for g in groups))
        with pytest.raises(RuntimeError) as ei:
            subtree_prepare_batch(s_padded, groups, capacity,
                                  ElasticConfig(), max_iters=0)
        msg = str(ei.value)
        assert "group" in msg and "total_freq" in msg
        assert "n_active" in msg and "w=" in msg

    def test_serial_convergence_error_carries_context(self):
        import jax.numpy as jnp
        from repro.core.prepare import subtree_prepare
        s = DNA.random_string(300, seed=11)
        idx = EraIndexer(DNA, EraConfig(memory_bytes=2048, r_bytes=128))
        groups = idx.partition(s)
        s_padded = jnp.asarray(DNA.pad_string(s, extra=520))
        capacity = min(idx.config.f_max, max(g.total_freq for g in groups))
        with pytest.raises(RuntimeError) as ei:
            subtree_prepare(s_padded, groups[0], capacity, ElasticConfig(),
                            max_iters=0, group_index=0)
        msg = str(ei.value)
        assert "group=0" in msg and "total_freq" in msg and "w=" in msg

    def test_rejects_unknown_construction(self):
        with pytest.raises(ValueError):
            EraIndexer(DNA, EraConfig(construction="magic"))
