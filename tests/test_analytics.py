"""Analytics engine vs naive numpy oracles (LCP, matching stats, repeats,
distinct substrings, k-mer spectrum) across all three alphabets."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ref
from repro.core.alphabet import BYTE, DNA, PROTEIN
from repro.core.analytics import AnalyticsEngine
from repro.core.api import EraConfig, EraIndexer
from repro.kernels import ref as kref


def build_engine(alpha, n, *, memory_bytes, seed):
    s = alpha.random_string(n, seed=seed)
    idx = EraIndexer(alpha, EraConfig(memory_bytes=memory_bytes, r_bytes=128,
                                      build_impl="none")).build(s)
    return s, idx, idx.analytics()


def naive_matching_stats(s: np.ndarray, q: np.ndarray):
    """O(|q| * |s| * ms) scan: longest match of each query suffix prefix."""
    sn = np.asarray(s, np.int64)
    qn = np.asarray(q, np.int64)
    ms = np.zeros(len(q), np.int64)
    for i in range(len(q)):
        best = 0
        for j in range(len(s)):
            h = 0
            while i + h < len(q) and j + h < len(s) and qn[i + h] == sn[j + h]:
                h += 1
            best = max(best, h)
        ms[i] = best
    return ms


# deliberately tight budgets: deep prefixes, many sub-trees, so the LCP
# array crosses MANY sub-tree boundaries (incl. frequency-1 prefixes)
CASES = [
    (DNA, 700, 512),
    (DNA, 1200, 8192),
    (PROTEIN, 600, 4096),
    (BYTE, 500, 4096),   # codes >= 128: unsigned packed-word order
]


class TestGlobalLcpArray:
    @pytest.mark.parametrize("alpha,n,mem", CASES)
    def test_matches_kasai(self, alpha, n, mem):
        s, idx, eng = build_engine(alpha, n, memory_bytes=mem, seed=n + mem)
        sa = ref.suffix_array(s)
        want = ref.lcp_array(s, sa)
        np.testing.assert_array_equal(eng.lcp_host, want.astype(np.int32))

    def test_boundary_entries_filled(self):
        """Cross-subtree boundary entries come from the suffix_lcp kernel
        path, not from b_off — check them against the direct oracle."""
        s, idx, eng = build_engine(DNA, 900, memory_bytes=512, seed=7)
        assert eng.dev.n_subtrees > 4  # the partition really is split
        offs = np.asarray(eng.dev.sub_off)
        freqs = np.asarray(eng.dev.sub_freq)
        assert (freqs == 1).any()  # frequency-1 prefixes present
        ell = eng.dev.ell_host
        for b in offs[1:]:
            want = ref.suffix_lcp(s, int(ell[b - 1]), int(ell[b]))
            assert eng.lcp_host[b] == want

    def test_lcp_rows_random_pairs(self):
        s, idx, eng = build_engine(DNA, 800, memory_bytes=1024, seed=3)
        sa = ref.suffix_array(s)
        rng = np.random.default_rng(0)
        i = rng.integers(0, len(s), size=64)
        j = rng.integers(0, len(s), size=64)
        got = eng.lcp_rows(i, j)
        for a, b, g in zip(i, j, got):
            assert g == ref.suffix_lcp(s, int(sa[a]), int(sa[b]))


class TestMatchingStats:
    @pytest.mark.parametrize("alpha,n,mem", CASES)
    def test_randomized_cross_check(self, alpha, n, mem):
        s, idx, eng = build_engine(alpha, n, memory_bytes=mem, seed=n * 3)
        rng = np.random.default_rng(n)
        # half planted slice of S (long matches), half random symbols
        # (mostly-absent for big alphabets -> ms == 0 rows + witness == -1)
        i0 = int(rng.integers(0, n // 2))
        q = np.concatenate([
            np.asarray(s[i0 : i0 + 40]),
            rng.integers(0, len(alpha.symbols), size=40).astype(np.uint8),
        ])
        ms, wit = eng.matching_stats(q)
        want = naive_matching_stats(s, q)
        np.testing.assert_array_equal(ms, want)
        sn = np.asarray(s, np.int64)
        for i in range(len(q)):
            if ms[i] > 0:
                w = int(wit[i])
                assert 0 <= w < len(s)
                np.testing.assert_array_equal(sn[w : w + ms[i]],
                                              np.asarray(q[i : i + ms[i]], np.int64))
            else:
                assert wit[i] == -1

    def test_window_caps_lengths(self):
        s, idx, eng = build_engine(DNA, 600, memory_bytes=2048, seed=11)
        q = np.asarray(s[50:150])  # a planted exact slice: deep matches
        full, _ = eng.matching_stats(q)
        capped, _ = eng.matching_stats(q, window=8)
        np.testing.assert_array_equal(capped, np.minimum(full, 8))
        # non-multiple-of-4 windows cap at the REQUESTED value, not the
        # word-rounded one
        capped7, wit7 = eng.matching_stats(q, window=7)
        np.testing.assert_array_equal(capped7, np.minimum(full, 7))
        sn = np.asarray(s, np.int64)
        for i in np.nonzero(capped7 > 0)[0][:10]:
            w = int(wit7[i])
            np.testing.assert_array_equal(
                sn[w : w + capped7[i]], np.asarray(q[i : i + capped7[i]], np.int64))

    def test_whole_string_as_query(self):
        s, idx, eng = build_engine(DNA, 400, memory_bytes=2048, seed=13)
        ms, wit = eng.matching_stats(np.asarray(s))
        # every suffix of S occurs in S: ms[i] == |S| - i (up to the cap)
        want = np.minimum(len(s) - np.arange(len(s)),
                          eng.dev.max_pattern_len)
        np.testing.assert_array_equal(ms, want)

    def test_default_window_works_for_unaligned_max_pattern_len(self):
        """The default window must not round up PAST max_pattern_len when
        the index was flattened with a non-multiple-of-4 cap."""
        alpha = DNA
        s = alpha.random_string(300, seed=41)
        idx = EraIndexer(alpha, EraConfig(memory_bytes=2048, r_bytes=128,
                                          build_impl="none")).build(s)
        eng = idx.analytics(max_pattern_len=66)
        ms, _ = eng.matching_stats(np.asarray(s[10:30]))  # must not raise
        assert ms[0] == 20

    def test_validation(self):
        s, idx, eng = build_engine(DNA, 300, memory_bytes=2048, seed=17)
        with pytest.raises(ValueError):
            eng.matching_stats(np.empty(0, np.uint8))
        with pytest.raises(ValueError):
            eng.matching_stats(np.array([99], np.uint8))
        with pytest.raises(ValueError):
            eng.matching_stats(np.zeros(8, np.uint8),
                               window=eng.dev.max_pattern_len + 64)


class TestRepeats:
    @pytest.mark.parametrize("alpha,n,mem", CASES)
    def test_longest_repeat_matches_lcp_max(self, alpha, n, mem):
        s, idx, eng = build_engine(alpha, n, memory_bytes=mem, seed=n + 1)
        sa = ref.suffix_array(s)
        want = int(ref.lcp_array(s, sa).max())
        rep = eng.longest_repeat()
        assert rep["length"] == want
        sub = np.asarray(s[rep["witness"] : rep["witness"] + rep["length"]])
        occ = ref.occurrences(s, sub)
        assert len(occ) == rep["count"] >= 2
        assert rep["witness"] in occ

    def test_top_repeats_counts_exact(self):
        s, idx, eng = build_engine(DNA, 800, memory_bytes=1024, seed=29)
        reps = eng.top_repeats(8)
        assert reps == sorted(reps, key=lambda r: -r["length"])
        assert len({r["rows"] for r in reps}) == len(reps)  # deduped
        for r in reps:
            sub = np.asarray(s[r["witness"] : r["witness"] + r["length"]])
            assert len(ref.occurrences(s, sub)) == r["count"]

    def test_high_multiplicity_repeat_does_not_flood_topk(self):
        """A motif occurring many times floods the initial top-k candidate
        pool with rows that dedupe to ONE interval; the pool must grow so
        the shorter repeats still surface."""
        rng = np.random.default_rng(37)
        motif = rng.integers(0, 4, size=12).astype(np.uint8)
        parts = []
        for _ in range(50):
            parts.append(motif)
            parts.append(rng.integers(0, 4, size=3).astype(np.uint8))
        s = np.concatenate(parts + [np.array([DNA.terminal_code], np.uint8)])
        idx = EraIndexer(DNA, EraConfig(memory_bytes=8192, r_bytes=128,
                                        build_impl="none")).build(s)
        eng = idx.analytics()
        reps = eng.top_repeats(10)
        assert len(reps) == 10
        for r in reps:
            sub = np.asarray(s[r["witness"] : r["witness"] + r["length"]])
            assert len(ref.occurrences(s, sub)) == r["count"]

    def test_no_repeats(self):
        """A string of all-distinct symbols has an all-zero LCP array."""
        alpha = BYTE
        s = np.concatenate([np.arange(40, dtype=np.uint8),
                            np.array([alpha.terminal_code], np.uint8)])
        idx = EraIndexer(alpha, EraConfig(memory_bytes=4096, r_bytes=128,
                                          build_impl="none")).build(s)
        eng = idx.analytics()
        assert eng.longest_repeat() is None
        assert eng.top_repeats(5) == []


class TestDistinctSubstrings:
    @pytest.mark.parametrize("alpha,n,mem", [(DNA, 250, 1024),
                                             (PROTEIN, 200, 4096),
                                             (BYTE, 150, 4096)])
    def test_matches_bruteforce_set(self, alpha, n, mem):
        s, idx, eng = build_engine(alpha, n, memory_bytes=mem, seed=n)
        sb = bytes(np.asarray(s, np.uint8))
        subs = {sb[i:j] for i in range(len(sb))
                for j in range(i + 1, len(sb) + 1)}
        term = alpha.terminal_code
        no_term = sum(1 for x in subs if term not in x)
        assert eng.distinct_substrings(include_terminal=True) == len(subs)
        assert eng.distinct_substrings() == no_term


class TestKmerSpectrum:
    @pytest.mark.parametrize("alpha,n,mem,k", [
        (DNA, 700, 1024, 3), (DNA, 700, 1024, 8),
        (PROTEIN, 400, 4096, 2), (BYTE, 300, 4096, 2),
    ])
    def test_matches_bruteforce_counter(self, alpha, n, mem, k):
        from collections import Counter

        s, idx, eng = build_engine(alpha, n, memory_bytes=mem, seed=n * k)
        starts, counts = eng.kmer_spectrum(k)
        ns = len(s)
        want = Counter(bytes(np.asarray(s[i : i + k], np.uint8))
                       for i in range(ns - k + 1))
        assert int(counts.sum()) == ns - k + 1
        got = {bytes(np.asarray(s[p : p + k], np.uint8)): int(c)
               for p, c in zip(starts, counts)}
        assert got == dict(want)

    def test_cross_check_vs_kmer_histogram_kernel(self):
        """Spectrum counts must agree bin-by-bin with the kmer_histogram
        oracle for every k-mer fully inside S."""
        k = 4
        s, idx, eng = build_engine(DNA, 900, memory_bytes=1024, seed=5)
        base = idx.alphabet.base
        sp = idx.alphabet.pad_string(s, extra=k + 2)
        hist = np.asarray(kref.kmer_histogram_ref(jnp.asarray(sp), len(s), k, base))
        starts, counts = eng.kmer_spectrum(k)
        for p, c in zip(starts, counts):
            code = 0
            for d in range(k):
                code = code * base + int(s[p + d])
            assert hist[code] == c

    def test_top_kmers_match_counter(self):
        from collections import Counter

        s, idx, eng = build_engine(DNA, 600, memory_bytes=2048, seed=9)
        k = 5
        want = Counter(bytes(np.asarray(s[i : i + k], np.uint8))
                       for i in range(len(s) - k + 1))
        top = eng.top_kmers(k, topk=6)
        assert [t["count"] for t in top] == [c for _, c in want.most_common(6)]
        for t in top:
            assert want[bytes(np.asarray(t["kmer"], np.uint8))] == t["count"]


class TestEnginePersistence:
    def test_save_load_round_trip(self, tmp_path):
        s, idx, eng = build_engine(DNA, 500, memory_bytes=1024, seed=19)
        p = str(tmp_path / "analytics.npz")
        eng.save(p)
        eng2 = AnalyticsEngine.load(p)
        np.testing.assert_array_equal(eng2.lcp_host, eng.lcp_host)
        q = np.asarray(s[40:120])
        for a, b in zip(eng2.matching_stats(q), eng.matching_stats(q)):
            np.testing.assert_array_equal(a, b)
        assert eng2.distinct_substrings() == eng.distinct_substrings()
        assert eng2.longest_repeat() == eng.longest_repeat()

    def test_build_analytics_entry_point(self):
        alpha = DNA
        s = alpha.random_string(400, seed=23)
        cfg = EraConfig(memory_bytes=2048, r_bytes=128, build_impl="none")
        index, eng = EraIndexer(alpha, cfg).build_analytics(s)
        sa = ref.suffix_array(s)
        np.testing.assert_array_equal(
            eng.lcp_host, ref.lcp_array(s, sa).astype(np.int32))

    def test_index_analytics_reuses_cached_device(self):
        s, idx, _ = build_engine(DNA, 300, memory_bytes=2048, seed=25)
        idx.find_batch([np.asarray(s[3:9])])  # populate the device cache
        eng = idx.analytics()
        assert eng.dev is idx._device

    def test_index_analytics_populates_device_cache(self):
        """analytics() before any find_batch must flatten once and share:
        the later find_batch reuses the same DeviceIndex."""
        alpha = DNA
        s = alpha.random_string(300, seed=27)
        idx = EraIndexer(alpha, EraConfig(memory_bytes=2048, r_bytes=128,
                                          build_impl="none")).build(s)
        eng = idx.analytics()
        assert idx._device is eng.dev
        got = idx.find_batch([np.asarray(s[3:9])])
        np.testing.assert_array_equal(got[0], idx.find(np.asarray(s[3:9])))
        assert idx._device is eng.dev  # not rebuilt
