"""The sharded index fabric must be a pure distribution transform.

Construction: :func:`repro.core.fabric.sharded_prepare` (shard_map over a
device mesh, per-shard convergence mask, fused sort key, tail compaction)
must produce the SAME final (G, F) state — ``L``/``b_off``/``b_c1``/
``b_c2`` bit-identical — as the single-device batched engine, across
alphabets, uneven group splits, and the 1-shard degenerate mesh.

Queries: :class:`repro.core.fabric.ShardedIndex` (route-key shards +
replicated route table) must answer ``find_batch`` / ``find_fetch_batch``
identically to one :class:`DeviceIndex` over the whole string, including
patterns short enough to span a shard boundary, and round-trip through
per-shard npz archives.

On a single-device host everything still runs (mesh of one); the CI
fabric leg re-runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the multi-shard
mesh paths execute for real.
"""

import os

import jax
import numpy as np
import pytest

from repro.core import fabric
from repro.core.api import EraConfig, EraIndexer
from repro.core.prepare import subtree_prepare_batch
from repro.core.query import DeviceIndex, route_depth, shard_npz_path
from repro.data.strings import dataset

STATE_FIELDS = ("L", "start", "area", "b_off", "b_c1", "b_c2")
multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs a simulated mesh (XLA_FLAGS="
           "--xla_force_host_platform_device_count=N)")


def _workload(name, n, mem):
    s, alpha = dataset(name, n, seed=0)
    cfg = EraConfig(memory_bytes=mem, r_bytes=512, build_impl="none")
    ix = EraIndexer(alpha, cfg)
    groups = ix.partition(s)
    return s, alpha, ix, groups, ix._capacity(groups), ix._device_text(s)


def _assert_states_equal(ref, got):
    for field in STATE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, field)), np.asarray(getattr(got, field)),
            err_msg=field)


class TestFusedSortKey:
    """sort_fuse packs (major, window, tie) into the fewest uint32 lanes;
    the engine must not notice."""

    @pytest.mark.parametrize("name,n,mem", [
        ("dna", 6_000, 4096),       # 1-lane fused key on small w
        ("protein", 4_000, 8192),
        ("byte", 3_000, 8192),      # codes >= 128: unsigned order
    ])
    def test_bit_identical(self, name, n, mem):
        _, _, ix, groups, cap, sp = _workload(name, n, mem)
        ecfg = ix.config.elastic_config()
        ref = subtree_prepare_batch(sp, groups, cap, ecfg, sort_fuse=False)
        got = subtree_prepare_batch(sp, groups, cap, ecfg, sort_fuse=True)
        _assert_states_equal(ref, got)


class TestShardedPrepare:
    @pytest.mark.parametrize("name,n,mem", [
        ("dna", 6_000, 4096),
        ("protein", 4_000, 8192),
        ("byte", 3_000, 8192),
    ])
    def test_bit_identical(self, name, n, mem):
        _, _, ix, groups, cap, sp = _workload(name, n, mem)
        ecfg = ix.config.elastic_config()
        ref = subtree_prepare_batch(sp, groups, cap, ecfg)
        got = fabric.sharded_prepare(sp, groups, cap, ecfg)
        _assert_states_equal(ref, got)

    def test_one_shard_degenerate_mesh(self):
        _, _, ix, groups, cap, sp = _workload("dna", 6_000, 4096)
        ecfg = ix.config.elastic_config()
        ref = subtree_prepare_batch(sp, groups, cap, ecfg)
        got = fabric.sharded_prepare(sp, groups, cap, ecfg,
                                     mesh=fabric.fabric_mesh(1))
        _assert_states_equal(ref, got)

    @multi_device
    def test_uneven_group_split(self):
        """G not divisible by the mesh: dummy born-converged padding
        groups must never leak into real results."""
        _, _, ix, groups, cap, sp = _workload("dna", 6_000, 4096)
        n_dev = min(4, jax.device_count())
        assert len(groups) % n_dev != 0 or len(groups) > n_dev
        ecfg = ix.config.elastic_config()
        ref = subtree_prepare_batch(sp, groups, cap, ecfg)
        got = fabric.sharded_prepare(sp, groups, cap, ecfg,
                                     mesh=fabric.fabric_mesh(n_dev))
        _assert_states_equal(ref, got)


def _pattern_mix(s, alpha, rng, k_route):
    """Planted + random patterns, including length < k_route so some
    spans cover several route cells (the shard fan-out path).
    ``alpha=None`` skips the random (possibly-missing) patterns."""
    pats = []
    for m in (2, 3, max(1, k_route - 1), k_route, k_route + 3, 12):
        for _ in range(4):
            i = int(rng.integers(0, len(s) - 1 - m))
            pats.append(np.asarray(s[i : i + m], np.int32))
            if alpha is not None:
                pats.append(rng.integers(0, alpha.base, size=m,
                                         dtype=np.int32))
    return pats


class TestShardedIndex:
    @pytest.mark.parametrize("name,n,mem,n_shards", [
        ("dna", 6_000, 4096, 4),
        ("protein", 4_000, 8192, 3),   # uneven entry split
        ("byte", 3_000, 8192, 2),
    ])
    def test_find_identical(self, name, n, mem, n_shards):
        s, alpha, ix, groups, cap, sp = _workload(name, n, mem)
        dev = ix.build_device(s, max_pattern_len=64)
        sh = ix.build_sharded(s, n_shards=n_shards, max_pattern_len=64)
        assert sh.n_shards >= 1
        assert sh.n_leaves == dev.ell.shape[0]
        rng = np.random.default_rng(3)
        pats = _pattern_mix(s, alpha, rng, sh.k_route)
        ref = dev.find_batch(pats)
        got = sh.find_batch(pats)
        for i, (a, b) in enumerate(zip(ref, got)):
            np.testing.assert_array_equal(a, b, err_msg=f"pattern {i}")
        ref_pos, ref_win = dev.find_fetch_batch(pats, fetch=8)
        got_pos, got_win = sh.find_fetch_batch(pats, fetch=8)
        for i, (a, b) in enumerate(zip(ref_pos, got_pos)):
            np.testing.assert_array_equal(a, b, err_msg=f"pattern {i}")
        np.testing.assert_array_equal(ref_win, got_win)

    def test_short_patterns_span_shards(self):
        """Some route spans must actually cross a shard cut, otherwise
        the fan-out/merge path went untested."""
        s, alpha, ix, *_ = _workload("dna", 6_000, 4096)
        sh = ix.build_sharded(s, n_shards=4, max_pattern_len=64)
        if sh.n_shards < 2:
            pytest.skip("route cells did not split")
        spans = [sh.shard_span(np.asarray([c], np.int32))
                 for c in range(alpha.base)]
        assert any(hi > lo for lo, hi in spans)

    def test_one_shard_index(self):
        s, alpha, ix, *_ = _workload("dna", 6_000, 4096)
        dev = ix.build_device(s, max_pattern_len=64)
        sh = ix.build_sharded(s, n_shards=1, max_pattern_len=64)
        assert sh.n_shards == 1
        rng = np.random.default_rng(5)
        pats = _pattern_mix(s, alpha, rng, sh.k_route)
        for a, b in zip(dev.find_batch(pats), sh.find_batch(pats)):
            np.testing.assert_array_equal(a, b)

    def test_route_depth_pinned_across_shards(self):
        s, _, ix, *_ = _workload("dna", 6_000, 4096)
        sh = ix.build_sharded(s, n_shards=4)
        assert len({d.k_route for d in sh.shards}) == 1
        assert sh.k_route == sh.shards[0].k_route

    def test_save_load_roundtrip(self, tmp_path):
        s, alpha, ix, *_ = _workload("dna", 6_000, 4096)
        sh = ix.build_sharded(s, n_shards=3, max_pattern_len=64)
        base = str(tmp_path / "fabric_idx")
        sh.save(base)
        files = fabric.ShardedIndex.shard_files(base)
        assert len(files) == sh.n_shards
        assert files[0] == shard_npz_path(base, 0)
        back = fabric.ShardedIndex.load(base)
        assert back.n_shards == sh.n_shards
        np.testing.assert_array_equal(back.cell_lo, sh.cell_lo)
        rng = np.random.default_rng(9)
        pats = _pattern_mix(s, alpha, rng, sh.k_route)
        for a, b in zip(sh.find_batch(pats), back.find_batch(pats)):
            np.testing.assert_array_equal(a, b)


class TestShardedServing:
    def _pair(self, fetch=0, cache=0):
        from repro.launch.serving import AsyncServer, ServeConfig

        s, alpha, ix, *_ = _workload("dna", 6_000, 4096)
        dev = ix.build_device(s, max_pattern_len=64)
        sh = ix.build_sharded(s, n_shards=4, max_pattern_len=64)
        rng = np.random.default_rng(11)
        pats = _pattern_mix(s, alpha, rng, sh.k_route)
        cfg = dict(pipeline=True, cache_size=cache, fetch=fetch,
                   max_wait_ms=0.0)
        ref_srv = AsyncServer(dev, ServeConfig(**cfg))
        srv = AsyncServer(sh, ServeConfig(**cfg))
        assert srv.sharded and len(srv.caches) == sh.n_shards
        # two passes: the second hits the route cache cross-batch
        ref_srv.serve(pats)
        ref = ref_srv.serve(pats)
        srv.serve(pats)
        got = srv.serve(pats)
        return ref, got, srv

    @pytest.mark.parametrize("fetch,cache", [(0, 0), (0, 256), (8, 256)])
    def test_results_identical(self, fetch, cache):
        ref, got, _ = self._pair(fetch=fetch, cache=cache)
        for i, ((rp, rw), (gp, gw)) in enumerate(zip(ref, got)):
            np.testing.assert_array_equal(rp, gp, err_msg=f"request {i}")
            if fetch:
                np.testing.assert_array_equal(rw, gw, err_msg=f"request {i}")

    def test_cache_partitions_by_shard(self):
        _, _, srv = self._pair(cache=256)
        st = srv.stats()["cache"]
        assert st["hits"] > 0
        assert len(st["per_shard"]) == srv.dev.n_shards


class TestWarmstartShardArchives:
    def test_will_load_normalizes_shard_suffix(self, tmp_path):
        from repro.launch import warmstart

        s, _, ix, *_ = _workload("dna", 6_000, 4096)
        sh = ix.build_sharded(s, n_shards=2, max_pattern_len=64)
        base = str(tmp_path / "warm_idx")
        assert not warmstart.will_load(base, sharded=True)
        assert not warmstart.will_load(base)  # base npz does not exist
        sh.save(base)
        assert warmstart.will_load(base, sharded=True)
        # the per-shard archives must NOT satisfy the unsharded check:
        # a DeviceIndex cache and a ShardedIndex cache are distinct
        assert not warmstart.will_load(base)

    def test_load_or_build_sharded_cache_hit(self, tmp_path):
        from repro.launch import warmstart

        base = str(tmp_path / "warm_idx2")
        n = 6_000

        def build(s, alphabet):
            cfg = EraConfig(memory_bytes=4096, r_bytes=512,
                            build_impl="none")
            return EraIndexer(alphabet, cfg).build_sharded(
                s, n_shards=2, max_pattern_len=64)

        first, s, _, _ = warmstart.load_or_build(
            base, "dna", n, 0, load=fabric.ShardedIndex.load, build=build,
            sharded=True)
        assert warmstart.will_load(base, sharded=True)
        builds = []
        second, s2, _, _ = warmstart.load_or_build(
            base, "dna", n, 0,
            load=fabric.ShardedIndex.load,
            build=lambda *a: builds.append(1), sharded=True)
        assert not builds  # cache hit: build never called
        # string recovery must yield the FULL string (|S| = total leaves,
        # not shard 0's slice) so the driver's workload is sampled right
        assert len(s2) == n + 1
        np.testing.assert_array_equal(np.asarray(s2), np.asarray(s))
        assert second.n_shards == first.n_shards
        rng = np.random.default_rng(13)
        pats = _pattern_mix(s, None, rng, first.k_route)[:8]
        for a, b in zip(first.find_batch(pats), second.find_batch(pats)):
            np.testing.assert_array_equal(a, b)


class TestTraceShardPids:
    def test_shard_spans_get_shard_pid(self):
        from repro.obs.trace import Tracer, validate_chrome_trace

        tr = Tracer(enabled=True)
        with tr.span("fabric/find_batch", shard=2, rows=4):
            pass
        with tr.span("serve/pad_pack", rows=8):
            pass
        chrome = tr.to_chrome()
        assert validate_chrome_trace(chrome) == []
        events = chrome["traceEvents"]
        names = {e["args"].get("name") for e in events if e["ph"] == "M"}
        assert "repro-era shard 2" in names
        shard_evt = next(e for e in events
                         if e["name"] == "fabric/find_batch")
        host_evt = next(e for e in events if e["name"] == "serve/pad_pack")
        assert shard_evt["pid"] == 2
        assert host_evt["pid"] == os.getpid()


class TestMetricsEndpoint:
    def test_serves_prometheus_text(self):
        import urllib.error
        import urllib.request

        from repro import obs
        from repro.launch.serving import start_metrics_server

        registry = obs.metrics()
        server = start_metrics_server(0)
        try:
            port = server.server_address[1]
            body = urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics", timeout=5).read().decode()
            # the endpoint serves the live registry verbatim — empty when
            # REPRO_METRICS is off, the full exposition text when on
            assert body == registry.to_prometheus()
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(
                    f"http://127.0.0.1:{port}/nope", timeout=5)
        finally:
            server.shutdown()


def test_route_depth_helper():
    assert route_depth(4, 512, 1 << 18) == 9   # 4^9 = 2^18
    assert route_depth(4, 3, 1 << 18) == 3     # capped by max_plen
    assert route_depth(256, 512, 1 << 18) == 2
