"""Promoted construction engine: the PR-8 fabric optimizations (fused
single-lane sort keys, tail compaction) as the DEFAULT batched currency,
the word-key node build, and roofline tile autotuning.

All of it must be a pure performance transform: the fused+compacted
batched/streaming/append paths stay bit-identical to the three-lane
lexsort oracle (``REPRO_SORT=lexsort`` / ``REPRO_COMPACT=off``) across
alphabets, the text-derived node divergence rows reproduce the stored
``b_off`` node sets exactly, and an autotuned tile never changes any
result — only the per-grid-step DMA shape.
"""

import os

import numpy as np
import pytest

from repro.core.api import EraConfig, EraIndexer
from repro.core.build import nodes_to_host
from repro.core.prepare import (compaction_width, subtree_prepare,
                                subtree_prepare_batch,
                                subtree_prepare_stream)
from repro.data.strings import dataset
from repro.kernels import ops as kops
from repro.roofline import autotune

ALL_FIELDS = ("L", "start", "area", "b_off", "b_c1", "b_c2")
INDEX_FIELDS = ("ell", "sub_off", "sub_freq", "sub_prefix", "sub_plen",
                "win_lo", "win_hi")


def _workload(name, n, mem, **cfg_kw):
    s, alpha = dataset(name, n, seed=0)
    cfg = EraConfig(memory_bytes=mem, build_impl="none", **cfg_kw)
    ix = EraIndexer(alpha, cfg)
    groups = ix.partition(s)
    return s, alpha, ix, groups, ix._capacity(groups), ix._device_text(s)


def _assert_fields(ref, got, fields=ALL_FIELDS):
    for field in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, field)), np.asarray(getattr(got, field)),
            err_msg=field)


class TestPromotedDefaults:
    def test_fused_and_compaction_default_on(self, monkeypatch):
        monkeypatch.delenv("REPRO_SORT", raising=False)
        monkeypatch.delenv("REPRO_COMPACT", raising=False)
        assert kops._use_sort_fuse() is True
        assert kops._use_compaction() is True

    def test_escape_hatches(self, monkeypatch):
        monkeypatch.setenv("REPRO_SORT", "lexsort")
        monkeypatch.setenv("REPRO_COMPACT", "off")
        assert kops._use_sort_fuse() is False
        assert kops._use_compaction() is False

    def test_unknown_values_raise(self, monkeypatch):
        monkeypatch.setenv("REPRO_SORT", "bogus")
        with pytest.raises(ValueError, match="REPRO_SORT"):
            kops._use_sort_fuse()
        monkeypatch.setenv("REPRO_COMPACT", "bogus")
        with pytest.raises(ValueError, match="REPRO_COMPACT"):
            kops._use_compaction()


class TestCompactionWidth:
    def test_pow2_bucket_with_floor(self):
        assert compaction_width(1, 1024) == 32
        assert compaction_width(33, 1024) == 64
        assert compaction_width(64, 1024) == 64
        assert compaction_width(65, 1024) == 128

    def test_none_until_it_pays(self):
        # active rows still fill more than half the state: full-width step
        assert compaction_width(600, 1024) is None
        assert compaction_width(512, 1024) == 512
        # degenerate capacity below the 32-row floor: never compacts
        assert compaction_width(1, 16) is None


class TestBitIdentity:
    """Fused sort keys + tail compaction vs the lexsort full-width oracle
    — every PrepareState field, not just the index-visible ones: the
    engines run the identical schedule, so even ``start`` must agree."""

    @pytest.mark.parametrize("name,n,mem", [
        ("dna", 6_000, 1 << 12),
        ("protein", 4_000, 1 << 13),
        ("byte", 3_000, 1 << 13),   # codes >= 128: unsigned word order
    ])
    def test_batch_matches_oracle(self, name, n, mem):
        s, alpha, ix, groups, cap, s_padded = _workload(name, n, mem)
        ecfg = ix.config.elastic_config()
        fused = subtree_prepare_batch(s_padded, groups, cap, ecfg,
                                      sort_fuse=True, compact=True)
        oracle = subtree_prepare_batch(s_padded, groups, cap, ecfg,
                                       sort_fuse=False, compact=False)
        _assert_fields(oracle, fused)

    def test_batch_matches_serial_per_group(self):
        s, alpha, ix, groups, cap, s_padded = _workload("dna", 5_000, 1 << 12)
        ecfg = ix.config.elastic_config()
        batched = subtree_prepare_batch(s_padded, groups, cap, ecfg,
                                        sort_fuse=True, compact=True)
        for g_i, g in enumerate(groups):
            serial = subtree_prepare(s_padded, g, cap, ecfg)
            f = g.total_freq
            for field in ALL_FIELDS:
                np.testing.assert_array_equal(
                    np.asarray(getattr(batched, field))[g_i, :f],
                    np.asarray(getattr(serial, field))[:f],
                    err_msg=f"group {g_i} field {field}")

    def test_stream_matches_oracle(self):
        s, alpha, ix, groups, cap, s_padded = _workload("dna", 8_000, 1 << 12)
        ecfg = ix.config.elastic_config()
        oracle = subtree_prepare_batch(s_padded, groups, cap, ecfg,
                                       sort_fuse=False, compact=False)
        streamed, srep = subtree_prepare_stream(
            s_padded, groups, cap, ecfg, device_budget=1 << 16,
            sort_fuse=True, compact=True)
        assert srep.n_chunks > 1
        _assert_fields(oracle, streamed)

    def test_degenerate_one_group_budget(self):
        """A memory budget so generous the partition yields one virtual
        tree: G == 1, so compaction only engages on the convergence tail
        (and not at all while active rows fill over half the state)."""
        s, alpha, ix, groups, cap, s_padded = _workload(
            "dna", 4_000, 1 << 22)
        assert len(groups) == 1
        ecfg = ix.config.elastic_config()
        fused = subtree_prepare_batch(s_padded, groups, cap, ecfg,
                                      sort_fuse=True, compact=True)
        oracle = subtree_prepare_batch(s_padded, groups, cap, ecfg,
                                       sort_fuse=False, compact=False)
        _assert_fields(oracle, fused)


class TestAppendPath:
    def test_append_matches_rebuild_and_bumps_epoch(self, monkeypatch):
        s, alpha = dataset("dna", 5_000, seed=0)
        cfg = EraConfig(memory_bytes=1 << 12, build_impl="none")
        ix = EraIndexer(alpha, cfg)
        dev = ix.build_device(s)

        rng = np.random.default_rng(3)
        extra = rng.integers(0, alpha.base - 1, size=800, dtype=np.uint8)
        s_new = np.concatenate([s[:-1], extra,
                                np.asarray([s[-1]], s.dtype)])

        dev2, _ = ix.append_device(dev, s_new)
        assert dev2.epoch == dev.epoch + 1

        # mid-append epoch bump: a second append keeps counting
        extra2 = rng.integers(0, alpha.base - 1, size=400, dtype=np.uint8)
        s_new2 = np.concatenate([s_new[:-1], extra2,
                                 np.asarray([s_new[-1]], s_new.dtype)])
        dev3, _ = ix.append_device(dev2, s_new2)
        assert dev3.epoch == dev.epoch + 2

        # the appended index (fused+compacted re-run path) must be
        # bit-identical to a from-scratch rebuild under the lexsort oracle
        monkeypatch.setenv("REPRO_SORT", "lexsort")
        monkeypatch.setenv("REPRO_COMPACT", "off")
        rebuilt = EraIndexer(alpha, cfg).build_device(s_new2)
        for field in INDEX_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(dev3, field)),
                np.asarray(getattr(rebuilt, field)), err_msg=field)


class TestWordNodeBuild:
    @pytest.mark.parametrize("name,n,mem", [
        ("dna", 2_500, 1 << 11),
        ("byte", 1_500, 1 << 12),
    ])
    def test_words_matches_state_nodes(self, name, n, mem):
        s, alpha = dataset(name, n, seed=0)
        kw = dict(memory_bytes=mem, r_bytes=128, build_impl="parallel",
                  construction="batched")
        ref = EraIndexer(alpha, EraConfig(node_lcp="state", **kw)).build(s)
        got = EraIndexer(alpha, EraConfig(node_lcp="words", **kw)).build(s)
        assert set(ref.subtrees) == set(got.subtrees)
        checked = 0
        for p in ref.subtrees:
            a, b = ref.subtrees[p].nodes, got.subtrees[p].nodes
            if a is None:
                assert b is None
                continue
            a, b = nodes_to_host(a), nodes_to_host(b)
            np.testing.assert_array_equal(a.parent, b.parent, err_msg=str(p))
            np.testing.assert_array_equal(a.depth, b.depth, err_msg=str(p))
            np.testing.assert_array_equal(a.witness, b.witness,
                                          err_msg=str(p))
            checked += 1
        assert checked > 0

    def test_rejects_unknown_node_lcp(self):
        with pytest.raises(ValueError, match="node_lcp"):
            EraIndexer(dataset("dna", 100)[1],
                       EraConfig(node_lcp="bogus"))


class TestAutotune:
    @pytest.fixture(autouse=True)
    def _clean_table(self, monkeypatch):
        monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
        monkeypatch.delenv("REPRO_AUTOTUNE_TABLE", raising=False)
        autotune.set_active_table(None)
        yield
        autotune.set_active_table(None)

    def test_model_pick_constraints(self):
        # smallest candidate passing the DMA floor wins (all candidates
        # tie at the dispatch-overhead plateau of the time model)
        assert autotune.model_pick("range_gather") == 512
        # the w <= tile kernel contract caps from below
        assert autotune.model_pick("range_gather", w_cap=4096) == 4096
        # nothing feasible: fall back to static default, still >= w_cap
        huge = autotune.model_pick("range_gather", w_cap=100_000)
        assert huge >= 100_000

    def test_n_bucket_pow2(self):
        assert autotune.n_bucket(1) == 2
        assert autotune.n_bucket(60_000) == 1 << 16
        assert autotune.n_bucket(1 << 16) == 1 << 16

    def test_table_roundtrip(self, tmp_path):
        t = autotune.AutotuneTable()
        t.put("cpu", "range_gather", 2, 60_000, 1024, source="measured")
        path = t.save(str(tmp_path / "tbl.json"))
        loaded = autotune.AutotuneTable.load(path)
        # any n in the same pow2 bucket resolves to the entry
        assert loaded.get("cpu", "range_gather", 2, 40_000) == 1024
        assert loaded.get("cpu", "range_gather", 2, 70_000) is None
        assert loaded.get("cpu", "suffix_lcp", 2, 60_000) is None

    def test_tile_for_resolution_order(self, monkeypatch):
        # no table, no env: the pre-autotune static defaults, exactly
        assert autotune.tile_for("range_gather", backend="cpu", bits=32,
                                 n=10_000) == autotune.DEFAULT_TILE
        assert autotune.tile_for("kmer_histogram", backend="cpu", bits=32,
                                 n=10_000) == 512
        # w_cap floor applies even on the default path
        assert autotune.tile_for("range_gather", backend="cpu", bits=32,
                                 n=10_000, w_cap=3000) == 3000
        # model mode: the roofline pick
        monkeypatch.setenv("REPRO_AUTOTUNE", "model")
        assert autotune.tile_for("range_gather", backend="cpu", bits=32,
                                 n=10_000) == autotune.model_pick(
                                     "range_gather")
        # an installed table entry wins over the model
        t = autotune.AutotuneTable()
        t.put("cpu", "range_gather", 32, 10_000, 4096)
        autotune.set_active_table(t)
        assert autotune.tile_for("range_gather", backend="cpu", bits=32,
                                 n=10_000) == 4096
        # table active but key missing: model pick, not static default
        assert autotune.tile_for("suffix_lcp", backend="cpu", bits=32,
                                 n=10_000) == autotune.model_pick(
                                     "suffix_lcp")

    def test_tile_for_unknown_mode_raises(self, monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE", "bogus")
        with pytest.raises(ValueError, match="REPRO_AUTOTUNE"):
            autotune.tile_for("range_gather", backend="cpu", bits=32, n=100)

    def test_fill_model_covers_kernels(self):
        t = autotune.AutotuneTable()
        t.fill_model("cpu", {"range_gather": 64, "suffix_lcp": 256},
                     bits=2, n=60_000)
        assert t.get("cpu", "range_gather", 2, 60_000) == 512
        assert t.get("cpu", "suffix_lcp", 2, 60_000) == 512

    def test_measured_sweep_returns_feasible_argmin(self):
        calls = []
        best, timings = autotune.measured_sweep(
            lambda tile: calls.append(tile), candidates=(512, 1024),
            repeats=1)
        assert best in (512, 1024)
        assert set(timings) == {512, 1024}

    def test_autotuned_build_bit_identical(self):
        """End to end: an installed model-filled table changes only the
        kernel grid shapes — the flattened index is bit-identical."""
        s, alpha = dataset("dna", 4_000, seed=0)
        cfg = EraConfig(memory_bytes=1 << 12, build_impl="none")
        base = EraIndexer(alpha, cfg).build_device(s)
        t = autotune.AutotuneTable()
        t.fill_model("cpu", {"range_gather": 64, "range_gather_words": 64,
                             "suffix_lcp": 256}, bits=2, n=len(s))
        autotune.set_active_table(t)
        tuned = EraIndexer(alpha, cfg).build_device(s)
        for field in INDEX_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(base, field)),
                np.asarray(getattr(tuned, field)), err_msg=field)
