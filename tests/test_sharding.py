"""Sharding rules + tiny-mesh lowering checks (1 device; the 512-device
pass is the dry-run deliverable, run via repro.launch.dryrun)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import sharding as shd
from repro.launch import steps as step_lib
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.models.config import ShapeConfig, smoke_config
from repro.models.registry import concrete_inputs, get_config
from repro.optim import adamw


def fake_mesh(shape=(4, 8), axes=("data", "model")):
    """An abstract mesh for rule checks (no devices needed for specs)."""
    import types
    m = types.SimpleNamespace()
    m.axis_names = axes
    m.shape = dict(zip(axes, shape))
    m.size = int(np.prod(shape))
    return m


class TestRules:
    def test_divisible_dims_shard(self):
        m = fake_mesh()
        assert shd.spec_for((1024, 16, 128), ("embed", "heads", "head"), m) == P(None, "model", None)
        assert shd.spec_for((151936, 2048), ("vocab", "embed"), m) == P("model", None)

    def test_indivisible_dims_replicate(self):
        m = fake_mesh((4, 16))
        # 8 kv heads on a 16-way model axis: replicate
        assert shd.spec_for((1024, 8, 128), ("embed", "kv_heads", "head"), m) == P(None, None, None)

    def test_no_duplicate_mesh_axes(self):
        m = fake_mesh()
        spec = shd.spec_for((16, 1024, 6400), ("experts", "embed", "mlp"), m)
        used = [s for s in spec if s is not None]
        assert len(used) == len(set(used))
        assert spec[0] == "model"  # EP wins over TP for expert stacks

    def test_param_shardings_cover_tree(self):
        cfg = get_config("phi3.5-moe-42b-a6.6b")
        m = fake_mesh((16, 16))
        specs = T.model_specs(cfg)
        from repro.models.nn import Spec
        leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, Spec))
        assert len(leaves) > 10
        for s in leaves:
            p = shd.spec_for(s.shape, s.axes, m)
            assert len(p) == len(s.shape)


class TestHostMeshLowering:
    """End-to-end lowering on the 1-device host mesh (structure checks)."""

    @pytest.mark.parametrize("arch", ["qwen3-1.7b", "phi3.5-moe-42b-a6.6b",
                                      "falcon-mamba-7b"])
    def test_train_step_lowers_with_shardings(self, arch):
        cfg = smoke_config(get_config(arch))
        mesh = make_host_mesh()
        specs = T.model_specs(cfg)
        p_sh = shd.param_shardings(specs, mesh)
        params = T.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        opt = adamw.init(params)
        batch = concrete_inputs(cfg, ShapeConfig("s", "train", 16, 2), dtype=jnp.float32)
        step = step_lib.make_train_step(cfg, adamw.AdamWConfig())
        with mesh:
            lowered = jax.jit(step, in_shardings=(p_sh, None, None)).lower(params, opt, batch)
            compiled = lowered.compile()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            assert cost.get("flops", 0) > 0

    def test_decode_step_lowers_with_cache_shardings(self):
        cfg = smoke_config(get_config("qwen3-1.7b"))
        mesh = make_host_mesh()
        cache = T.init_cache(cfg, 2, 32, dtype=jnp.float32)
        c_sh = shd.cache_shardings(cfg, mesh, cache)
        params = T.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        step = step_lib.make_decode_step(cfg)
        tok = jnp.zeros((2, 1), jnp.int32)
        with mesh:
            lowered = jax.jit(step, in_shardings=(None, None, c_sh)).lower(params, tok, cache)
            lowered.compile()


class TestCacheShardings:
    def test_kv_cache_rules(self):
        cfg = get_config("qwen3-14b")
        m = fake_mesh((16, 16))
        cache = jax.eval_shape(lambda: T.init_cache(cfg, 128, 1024))

        # emulate NamedSharding via spec_for logic by calling cache_shardings
        # with a real 1-device mesh is covered above; here check decode dims
        # divisibility logic stays sound for B=128 over 16 and kv=8 over 16.
        dp = 16
        assert 128 % dp == 0      # batch shards
        assert 8 % 16 != 0        # kv heads replicate
