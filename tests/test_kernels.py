"""Pallas kernels vs pure-jnp oracles, interpret=True, shape/dtype sweeps."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ref as kref
from repro.kernels.kmer_histogram import kmer_histogram
from repro.kernels.lcp import lcp_pairs
from repro.kernels.pattern_probe import pattern_probe
from repro.kernels.range_gather import range_gather_pack
from repro.kernels.suffix_lcp import suffix_lcp_pairs


class TestRangeGatherPack:
    @pytest.mark.parametrize("n,f,w,tile", [
        (100, 7, 4, 32), (1000, 33, 16, 64), (5000, 128, 64, 256),
        (300, 5, 32, 32), (257, 64, 8, 128), (4096, 256, 128, 512),
    ])
    def test_matches_ref(self, n, f, w, tile):
        rng = np.random.default_rng(n + f)
        s = rng.integers(0, 5, size=n).astype(np.uint8)
        s[-1] = 4
        offs = rng.integers(0, n, size=f).astype(np.int32)
        got = range_gather_pack(jnp.asarray(s), jnp.asarray(offs), w,
                                tile=tile, interpret=True)
        want = kref.range_gather_pack_ref(jnp.asarray(s), jnp.asarray(offs), w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("dtype", [np.uint8, np.int32])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(0)
        s = rng.integers(0, 21, size=500).astype(dtype)
        s[-1] = 20
        offs = rng.integers(0, 480, size=17).astype(np.int32)
        got = range_gather_pack(jnp.asarray(s), jnp.asarray(offs), 16,
                                tile=64, interpret=True)
        want = kref.range_gather_pack_ref(jnp.asarray(s), jnp.asarray(offs), 16)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_tile_boundary_straddle(self):
        """Reads crossing the tile boundary must see both tiles."""
        tile = 32
        s = np.arange(128, dtype=np.int32) % 27
        offs = np.array([tile - 1, tile - 3, 2 * tile - 2], np.int32)
        got = range_gather_pack(jnp.asarray(s), jnp.asarray(offs), 8,
                                tile=tile, interpret=True)
        want = kref.range_gather_pack_ref(jnp.asarray(s), jnp.asarray(offs), 8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestKmerHistogram:
    @pytest.mark.parametrize("n,k,base,tile", [
        (100, 1, 5, 32), (1000, 2, 5, 64), (4000, 3, 5, 128),
        (900, 2, 21, 64), (333, 1, 27, 32), (2048, 4, 5, 256),
    ])
    def test_matches_ref(self, n, k, base, tile):
        rng = np.random.default_rng(n * k)
        s = rng.integers(0, base - 1, size=n).astype(np.uint8)
        s[-1] = base - 1
        sp = np.concatenate([s, np.full(k + 2, base - 1, np.uint8)])
        got = kmer_histogram(jnp.asarray(sp), n, k, base, tile=tile, interpret=True)
        want = kref.kmer_histogram_ref(jnp.asarray(sp), n, k, base)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_total_count_equals_windows(self):
        n, k, base = 777, 2, 5
        rng = np.random.default_rng(5)
        sp = np.concatenate([rng.integers(0, 4, size=n).astype(np.uint8),
                             np.full(k + 2, 4, np.uint8)])
        got = kmer_histogram(jnp.asarray(sp), n, k, base, tile=64, interpret=True)
        assert int(np.asarray(got).sum()) == n


class TestLcpPairs:
    @pytest.mark.parametrize("f,w,blk", [(7, 4, 32), (50, 16, 32), (333, 32, 64),
                                          (128, 64, 128)])
    def test_matches_ref(self, f, w, blk):
        rng = np.random.default_rng(f * w)
        a = rng.integers(0, 2**25, size=(f, w // 4)).astype(np.int32)
        b = np.where(rng.random((f, w // 4)) < 0.5,
                     rng.integers(0, 2**25, size=(f, w // 4)).astype(np.int32), a)
        got = lcp_pairs(jnp.asarray(a), jnp.asarray(b), w, blk=blk, interpret=True)
        want = kref.lcp_pairs_ref(jnp.asarray(a), jnp.asarray(b), w)
        for g, x in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(x))

    def test_identical_rows(self):
        a = np.full((9, 4), 12345, np.int32)
        lcp, c1, c2 = lcp_pairs(jnp.asarray(a), jnp.asarray(a), 16, blk=16,
                                interpret=True)
        assert (np.asarray(lcp) == 16).all()
        assert (np.asarray(c1) == 0).all() and (np.asarray(c2) == 0).all()


class TestSuffixLcpPairs:
    @pytest.mark.parametrize("n,b,w,tile,codes", [
        (300, 7, 4, 32, 5), (1000, 33, 16, 64, 21), (2000, 64, 32, 256, 27),
        (500, 16, 8, 128, 256),  # byte alphabet
    ])
    def test_matches_ref(self, n, b, w, tile, codes):
        rng = np.random.default_rng(n * b + w)
        s = rng.integers(0, codes, size=n).astype(np.uint8)
        s[-1] = codes - 1
        sp = np.concatenate([s, np.full(w + 8, codes - 1, np.uint8)])
        pos_a = rng.integers(0, n, size=b).astype(np.int32)
        # mix of random pairs and near-identical pairs (deep LCPs)
        pos_b = np.where(rng.random(b) < 0.5, pos_a,
                         rng.integers(0, n, size=b)).astype(np.int32)
        got = suffix_lcp_pairs(jnp.asarray(sp), jnp.asarray(pos_a),
                               jnp.asarray(pos_b), w, tile=tile, interpret=True)
        want = kref.suffix_lcp_pairs_ref(jnp.asarray(sp), jnp.asarray(pos_a),
                                         jnp.asarray(pos_b), w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_ref_matches_symbol_scan(self):
        """The packed-word oracle equals a direct symbol-by-symbol scan."""
        rng = np.random.default_rng(7)
        n, w = 400, 16
        s = rng.integers(0, 4, size=n).astype(np.uint8)
        s[-1] = 4
        sp = np.concatenate([s, np.full(w + 8, 4, np.uint8)])
        pos_a = rng.integers(0, n, size=25).astype(np.int32)
        pos_b = rng.integers(0, n, size=25).astype(np.int32)
        got = np.asarray(kref.suffix_lcp_pairs_ref(
            jnp.asarray(sp), jnp.asarray(pos_a), jnp.asarray(pos_b), w))
        for a, b, g in zip(pos_a, pos_b, got):
            h = 0
            while h < w and sp[a + h] == sp[b + h]:
                h += 1
            assert g == h

    def test_tile_boundary_straddle(self):
        tile = 32
        s = (np.arange(160) % 3).astype(np.uint8)
        s[-1] = 3
        pos_a = np.array([tile - 2, tile - 1, 2 * tile - 3], np.int32)
        pos_b = np.array([2 * tile - 2, tile - 1, 5], np.int32)
        got = suffix_lcp_pairs(jnp.asarray(s), jnp.asarray(pos_a),
                               jnp.asarray(pos_b), 8, tile=tile, interpret=True)
        want = kref.suffix_lcp_pairs_ref(jnp.asarray(s), jnp.asarray(pos_a),
                                         jnp.asarray(pos_b), 8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestPatternProbe:
    @pytest.mark.parametrize("n,b,m,tile,codes", [
        (300, 7, 4, 32, 5), (1000, 33, 8, 64, 21), (2000, 64, 16, 256, 27),
        (500, 16, 12, 128, 256),  # byte alphabet: top bit of packed words set
    ])
    def test_matches_ref(self, n, b, m, tile, codes):
        rng = np.random.default_rng(n + b)
        s = rng.integers(0, codes, size=n).astype(np.uint8)
        s[-1] = codes - 1
        pos = rng.integers(0, n - 1, size=b).astype(np.int32)
        m_pad = -(-m // 4) * 4
        lengths = rng.integers(1, m + 1, size=b)
        sym = rng.integers(0, codes, size=(b, m_pad)).astype(np.int32)
        valid = np.arange(m_pad)[None, :] < lengths[:, None]
        pat = np.asarray(kref.pack_words_ref(jnp.asarray(np.where(valid, sym, 0))))
        mask = np.asarray(kref.pack_words_ref(jnp.asarray(np.where(valid, 0xFF, 0))))
        got = pattern_probe(jnp.asarray(s), jnp.asarray(pos), jnp.asarray(pat),
                            jnp.asarray(mask), tile=tile, interpret=True)
        want = kref.pattern_probe_ref(jnp.asarray(s), jnp.asarray(pos),
                                      jnp.asarray(pat), jnp.asarray(mask))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_prefix_match_is_zero(self):
        s = np.array([0, 1, 2, 3, 0, 1, 2, 4], np.uint8)
        # pattern "1 2" at pos 1 and 5: prefix match -> 0; at pos 0: S bigger?
        pat_sym = np.zeros((3, 4), np.int32)
        pat_sym[:, :2] = [1, 2]
        valid = np.broadcast_to(np.arange(4)[None, :] < 2, (3, 4))
        pat = np.asarray(kref.pack_words_ref(jnp.asarray(np.where(valid, pat_sym, 0))))
        mask = np.asarray(kref.pack_words_ref(jnp.asarray(np.where(valid, 0xFF, 0))))
        pos = np.array([1, 5, 0], np.int32)
        got = np.asarray(pattern_probe(jnp.asarray(s), jnp.asarray(pos),
                                       jnp.asarray(pat), jnp.asarray(mask),
                                       tile=32, interpret=True))
        np.testing.assert_array_equal(got, [0, 0, -1])


class TestPipelineWithKernels:
    def test_era_identical_under_pallas(self, monkeypatch):
        """The full ERA pipeline must be bit-identical with Pallas kernels."""
        monkeypatch.setenv("REPRO_KERNELS", "jnp")
        from repro.core.alphabet import DNA
        from repro.core.api import EraConfig, EraIndexer

        s = DNA.random_string(300, seed=21)
        cfg = EraConfig(memory_bytes=2048, r_bytes=128, build_impl="none")
        a = EraIndexer(DNA, cfg).build(s)
        monkeypatch.setenv("REPRO_KERNELS", "pallas")
        b = EraIndexer(DNA, cfg).build(s)
        assert set(a.subtrees) == set(b.subtrees)
        for p in a.subtrees:
            np.testing.assert_array_equal(a.subtrees[p].ell, b.subtrees[p].ell)
            np.testing.assert_array_equal(a.subtrees[p].b_off, b.subtrees[p].b_off)
