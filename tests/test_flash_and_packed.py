"""Flash-attention kernel + 2-bit packed ERA path (the §Perf changes)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention


def ref_attn(q, k, v, causal=True):
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d).astype(jnp.float32)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32)) / np.sqrt(d)
    if causal:
        sk = k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d)


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,kv,d,bq,bk,causal", [
        (2, 128, 4, 2, 32, 32, 64, True),
        (1, 256, 8, 8, 64, 64, 128, True),
        (2, 128, 4, 1, 32, 64, 32, False),
        (1, 64, 2, 2, 16, 16, 16, True),
        (2, 96, 4, 4, 32, 32, 32, True),   # non-pow2 seq, blk divides
    ])
    def test_matches_reference(self, b, s, h, kv, d, bq, bk, causal):
        rng = np.random.default_rng(s * h)
        q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
        got = flash_attention(q, k, v, causal=causal, blk_q=bq, blk_k=bk,
                              interpret=True)
        want = ref_attn(q, k, v, causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=2e-5)

    def test_bf16(self):
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.normal(size=(1, 128, 4, 32)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.bfloat16)
        got = flash_attention(q, k, v, blk_q=32, blk_k=64, interpret=True)
        want = ref_attn(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                                   rtol=0.05, atol=0.05)


class TestPackedPath:
    def test_gather_extraction(self):
        rng = np.random.default_rng(0)
        n = 1000
        s = rng.integers(0, 4, size=n).astype(np.uint8)
        words = kref.pack_string_2bit(jnp.asarray(s))
        offs = rng.integers(0, n - 200, size=23).astype(np.int32)
        w = 64
        packed = np.asarray(kref.packed_gather_ref(words, jnp.asarray(offs), w))
        for i, off in enumerate(offs):
            got = [(int(word) >> (30 - 2 * k)) & 3
                   for word in packed[i] for k in range(16)]
            assert got == s[off:off + w].tolist()

    def test_lcp_matches_symbols(self):
        rng = np.random.default_rng(1)
        n = 600
        s = rng.integers(0, 4, size=n).astype(np.uint8)
        words = kref.pack_string_2bit(jnp.asarray(s))
        a_off = rng.integers(0, n - 100, size=31).astype(np.int32)
        b_off = rng.integers(0, n - 100, size=31).astype(np.int32)
        w = 32
        A = kref.packed_gather_ref(words, jnp.asarray(a_off), w)
        B = kref.packed_gather_ref(words, jnp.asarray(b_off), w)
        lcp, c1, c2 = (np.asarray(x) for x in kref.lcp_pairs_packed_ref(A, B, w))
        for i in range(31):
            sa, sb = s[a_off[i]:a_off[i] + w], s[b_off[i]:b_off[i] + w]
            l = 0
            while l < w and sa[l] == sb[l]:
                l += 1
            assert lcp[i] == l
            if l < w:
                assert (c1[i], c2[i]) == (sa[l], sb[l])

    def test_packed_key_order_is_lexicographic(self):
        rng = np.random.default_rng(2)
        n = 500
        s = rng.integers(0, 4, size=n).astype(np.uint8)
        words = kref.pack_string_2bit(jnp.asarray(s))
        offs = rng.integers(0, n - 80, size=40).astype(np.int32)
        keys = np.asarray(kref.packed_gather_ref(words, jnp.asarray(offs), 32),
                          dtype=np.uint32)
        for i in range(39):
            sa = tuple(s[offs[i]:offs[i] + 32])
            sb = tuple(s[offs[i + 1]:offs[i + 1] + 32])
            ka, kb = tuple(keys[i]), tuple(keys[i + 1])
            assert (sa < sb) == (ka < kb) or sa == sb
