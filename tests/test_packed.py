"""Dense k-bit text pipeline: packed ↔ byte bit-identity end-to-end.

The tentpole invariant: the dense-packed string representation (paper §6.1
generalized per alphabet) must produce IDENTICAL sort keys, construction
arrays, query results and analytics as the byte path — density only changes
bytes moved.  These tests pin that invariant at every layer: the gather
primitive, the Pallas kernels, construction, find_batch, matching
statistics, and npz persistence (including legacy byte-format archives).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import packing
from repro.core.alphabet import BYTE, DNA, PROTEIN, PROTEIN_CLASS
from repro.core.api import EraConfig, EraIndexer
from repro.core.build import bucket_pad_widths, pad_width
from repro.core.query import DeviceIndex
from repro.kernels import ref as kref
from repro.kernels.packed_gather import pattern_probe_packed, range_gather_packed

ALPHAS = [DNA, PROTEIN_CLASS, PROTEIN, BYTE]


def build_pair(alpha, n, *, mem, seed):
    """(s, byte-packing index, dense-packing index) over one string."""
    s = alpha.random_string(n, seed=seed)
    mk = lambda mode: EraIndexer(alpha, EraConfig(
        memory_bytes=mem, r_bytes=128, build_impl="none", packing=mode)).build(s)
    return s, mk("bytes"), mk("dense")


class TestDenseBits:
    def test_alphabet_density_tiers(self):
        assert DNA.dense_bits == 2
        assert PROTEIN_CLASS.dense_bits == 4
        assert PROTEIN.dense_bits == 8   # 20 symbols: byte fallback
        assert BYTE.dense_bits == 8

    @pytest.mark.parametrize("alpha", ALPHAS, ids=lambda a: a.name)
    def test_pack_unpack_roundtrip(self, alpha):
        s = alpha.random_string(777, seed=1)
        pt = packing.pack_text(s, alpha, extra=64)
        np.testing.assert_array_equal(packing.unpack_text(pt), s)
        assert pt.nbytes * 8 >= len(s) * alpha.dense_bits

    def test_pack_rejects_unterminated(self):
        with pytest.raises(ValueError):
            packing.pack_text(np.zeros(5, np.uint8), DNA)


class TestGatherPackDense:
    @pytest.mark.parametrize("alpha", ALPHAS, ids=lambda a: a.name)
    @pytest.mark.parametrize("w", [4, 16, 64])
    def test_matches_byte_gather(self, alpha, w):
        """The invariant everything rests on: identical byte sort keys."""
        rng = np.random.default_rng(w)
        s = alpha.random_string(900, seed=9)
        pt = packing.pack_text(s, alpha, extra=w + 8)
        sp = alpha.pad_string(s, extra=w + 8)
        offs = np.concatenate([
            rng.integers(0, len(s), size=65),
            [len(s) - 2, len(s) - 1, len(s), len(s) + 3],  # terminal tail
        ]).astype(np.int32)
        got = packing.gather_pack_dense(pt, jnp.asarray(offs), w)
        want = packing.gather_pack(jnp.asarray(sp), jnp.asarray(offs), w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_key_order_is_lexicographic(self):
        s = DNA.random_string(400, seed=2)
        pt = packing.pack_text(s, DNA, extra=40)
        rng = np.random.default_rng(3)
        offs = rng.integers(0, len(s), size=50).astype(np.int32)
        keys = np.asarray(packing.as_u32(
            packing.gather_pack_dense(pt, jnp.asarray(offs), 32)))
        sp = DNA.pad_string(s, extra=40)
        for i in range(len(offs) - 1):
            sa = tuple(sp[offs[i] : offs[i] + 32])
            sb = tuple(sp[offs[i + 1] : offs[i + 1] + 32])
            ka, kb = tuple(keys[i]), tuple(keys[i + 1])
            assert (sa < sb) == (ka < kb) or sa == sb


class TestPackedKernels:
    @pytest.mark.parametrize("alpha,n,f,w,tile", [
        (DNA, 300, 7, 4, 32), (DNA, 1000, 33, 16, 64),
        (PROTEIN_CLASS, 800, 21, 32, 64), (BYTE, 500, 16, 8, 32),
    ], ids=lambda v: getattr(v, "name", v))
    def test_range_gather_packed_matches_ref(self, alpha, n, f, w, tile):
        rng = np.random.default_rng(n + f)
        s = alpha.random_string(n, seed=n)
        pt = packing.pack_text(s, alpha, extra=w + 8)
        offs = rng.integers(0, n, size=f).astype(np.int32)
        got = range_gather_packed(pt, jnp.asarray(offs), w, tile=tile,
                                  interpret=True)
        want = kref.range_gather_packed_ref(pt, jnp.asarray(offs), w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_word_tile_boundary_straddle(self):
        """Reads crossing the uint32-word tile boundary see both tiles."""
        tile = 32  # words = 512 2-bit symbols per tile
        s = DNA.random_string(3 * 32 * 16, seed=8)
        pt = packing.pack_text(s, DNA, extra=72)
        spw = pt.syms_per_word
        offs = np.array([tile * spw - 1, tile * spw - 17, tile * spw,
                         2 * tile * spw - 3], np.int32)
        got = range_gather_packed(pt, jnp.asarray(offs), 64, tile=tile,
                                  interpret=True)
        want = kref.range_gather_packed_ref(pt, jnp.asarray(offs), 64)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("alpha,n,b,m", [
        (DNA, 400, 19, 4), (PROTEIN_CLASS, 700, 33, 8), (BYTE, 500, 16, 12),
    ], ids=lambda v: getattr(v, "name", v))
    def test_pattern_probe_packed_matches_byte_ref(self, alpha, n, b, m):
        rng = np.random.default_rng(n + b)
        s = alpha.random_string(n, seed=n)
        pt = packing.pack_text(s, alpha, extra=32)
        sp = alpha.pad_string(s, extra=32)
        pos = rng.integers(0, n, size=b).astype(np.int32)
        m_pad = -(-m // 4) * 4
        lengths = rng.integers(1, m + 1, size=b)
        sym = rng.integers(0, alpha.base, size=(b, m_pad)).astype(np.int32)
        valid = np.arange(m_pad)[None, :] < lengths[:, None]
        pat = kref.pack_words_ref(jnp.asarray(np.where(valid, sym, 0)))
        mask = kref.pack_words_ref(jnp.asarray(np.where(valid, 0xFF, 0)))
        got = pattern_probe_packed(pt, jnp.asarray(pos), pat, mask,
                                   tile=32, interpret=True)
        want = kref.pattern_probe_ref(jnp.asarray(sp), jnp.asarray(pos),
                                      pat, mask)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestConstructionBitIdentity:
    @pytest.mark.parametrize("alpha,n,mem", [
        (DNA, 800, 2048), (PROTEIN_CLASS, 700, 4096), (PROTEIN, 600, 4096),
        (BYTE, 500, 4096),
    ], ids=lambda v: getattr(v, "name", v))
    def test_construction_arrays_equal(self, alpha, n, mem):
        """ell / b_off / b_c1 / b_c2 identical between dense and byte."""
        _, idx_b, idx_d = build_pair(alpha, n, mem=mem, seed=n)
        assert set(idx_b.subtrees) == set(idx_d.subtrees)
        for p in idx_b.subtrees:
            for field in ("ell", "b_off", "b_c1", "b_c2"):
                np.testing.assert_array_equal(
                    getattr(idx_b.subtrees[p], field),
                    getattr(idx_d.subtrees[p], field),
                    err_msg=f"{alpha.name} prefix={p} field={field}")

    def test_serial_engine_dense(self):
        """The paper-faithful serial engine reads dense storage too."""
        alpha = DNA
        s = alpha.random_string(500, seed=4)
        mk = lambda mode: EraIndexer(alpha, EraConfig(
            memory_bytes=2048, r_bytes=128, build_impl="none",
            construction="serial", packing=mode)).build(s)
        a, b = mk("bytes"), mk("dense")
        for p in a.subtrees:
            np.testing.assert_array_equal(a.subtrees[p].ell, b.subtrees[p].ell)
            np.testing.assert_array_equal(a.subtrees[p].b_off, b.subtrees[p].b_off)


class TestServingBitIdentity:
    @pytest.mark.parametrize("alpha,n,mem", [
        (DNA, 900, 2048), (PROTEIN_CLASS, 700, 4096), (BYTE, 500, 4096),
    ], ids=lambda v: getattr(v, "name", v))
    def test_find_batch_equal(self, alpha, n, mem):
        s, idx_b, _ = build_pair(alpha, n, mem=mem, seed=n + 1)
        dev_b = idx_b.to_device(packing="bytes")
        dev_d = idx_b.to_device(packing="dense")
        assert dev_d.packed and not dev_b.packed
        rng = np.random.default_rng(5)
        pats = [np.asarray(s[i : i + m]) for i, m in zip(
            rng.integers(0, n - 20, 25), rng.integers(1, 17, 25))]
        pats += [rng.integers(0, len(alpha.symbols), size=int(m)).astype(np.uint8)
                 for m in rng.integers(1, 10, 8)]
        for pd, pb, p in zip(dev_d.find_batch(pats), dev_b.find_batch(pats), pats):
            np.testing.assert_array_equal(pd, pb)
            np.testing.assert_array_equal(pd, idx_b.find(p))

    def test_auto_packs_sub_byte_alphabets_only(self):
        for alpha, expect in ((DNA, True), (PROTEIN_CLASS, True),
                              (PROTEIN, False), (BYTE, False)):
            s = alpha.random_string(300, seed=0)
            dev = EraIndexer(alpha, EraConfig(
                memory_bytes=4096, r_bytes=128,
                build_impl="none")).build_device(s)
            assert dev.packed == expect, alpha.name
            if expect:
                byte_equiv = len(alpha.pad_string(
                    s, extra=dev.max_pattern_len + 8))
                assert dev.string_nbytes <= \
                    byte_equiv * alpha.dense_bits // 8 + 8

    @pytest.mark.parametrize("alpha", [DNA, PROTEIN_CLASS],
                             ids=lambda a: a.name)
    def test_matching_stats_equal(self, alpha):
        s, idx_b, _ = build_pair(alpha, 800, mem=4096, seed=13)
        eng_b = idx_b.analytics(packing="bytes")
        eng_d = idx_b.analytics(packing="dense")
        assert eng_d.dev.packed
        np.testing.assert_array_equal(eng_b.lcp_host, eng_d.lcp_host)
        rng = np.random.default_rng(6)
        q = np.concatenate([s[100:180],
                            rng.integers(0, len(alpha.symbols),
                                         size=60).astype(np.uint8)])
        ms_b, wit_b = eng_b.matching_stats(q, window=48)
        ms_d, wit_d = eng_d.matching_stats(q, window=48)
        np.testing.assert_array_equal(ms_b, ms_d)
        np.testing.assert_array_equal(wit_b, wit_d)

    def test_read_symbols_and_string_codes(self):
        s, idx_b, _ = build_pair(DNA, 400, mem=2048, seed=21)
        dev = idx_b.to_device(packing="dense")
        np.testing.assert_array_equal(dev.string_codes(), s)
        pos = np.array([0, 5, len(s) - 3], np.int32)
        got = np.asarray(dev.read_symbols(pos, 6))
        sp = DNA.pad_string(s, extra=8)
        want = np.stack([sp[p : p + 6] for p in pos]).astype(np.int32)
        np.testing.assert_array_equal(got, want)


class TestPackedPersistence:
    def test_packed_npz_round_trip(self, tmp_path):
        s, idx_b, _ = build_pair(DNA, 600, mem=2048, seed=31)
        dev = idx_b.to_device()  # auto -> dense for DNA
        assert dev.packed
        p = str(tmp_path / "dev_packed.npz")
        dev.save(p)
        dev2 = DeviceIndex.load(p)
        assert dev2.packed and dev2.s_bits == dev.s_bits == 2
        assert (dev2.base, dev2.k_route, dev2.n_iter, dev2.max_pattern_len) \
            == (dev.base, dev.k_route, dev.n_iter, dev.max_pattern_len)
        np.testing.assert_array_equal(np.asarray(dev2.s_text.words),
                                      np.asarray(dev.s_text.words))
        np.testing.assert_array_equal(dev2.string_codes(), s)
        pats = [np.asarray(s[i : i + 8]) for i in (3, 77, 300)]
        for a, b in zip(dev2.find_batch(pats), dev.find_batch(pats)):
            np.testing.assert_array_equal(a, b)

    def test_byte_saves_keep_legacy_format_and_load(self, tmp_path):
        """Byte-path archives must stay in the original blob layout so
        pre-packing caches (and older readers) keep working."""
        s, idx_b, _ = build_pair(DNA, 400, mem=2048, seed=33)
        dev_b = idx_b.to_device(packing="bytes")
        blobs = dev_b.to_blobs()
        assert "s_padded" in blobs and "s_words" not in blobs
        assert blobs["meta"].shape == (4,)  # the pre-packing meta layout
        p = str(tmp_path / "dev_legacy.npz")
        dev_b.save(p)
        dev2 = DeviceIndex.load(p)
        assert not dev2.packed
        pats = [np.asarray(s[i : i + 6]) for i in (1, 50, 200)]
        for a, b in zip(dev2.find_batch(pats), idx_b.find_batch(pats)):
            np.testing.assert_array_equal(a, b)

    def test_analytics_engine_packed_round_trip(self, tmp_path):
        from repro.core.analytics import AnalyticsEngine

        s, idx_b, _ = build_pair(DNA, 500, mem=2048, seed=35)
        eng = idx_b.analytics(packing="dense")
        p = str(tmp_path / "eng_packed.npz")
        eng.save(p)
        eng2 = AnalyticsEngine.load(p)
        assert eng2.dev.packed
        np.testing.assert_array_equal(eng2.lcp_host, eng.lcp_host)
        q = np.asarray(s[50:120])
        ms, wit = eng.matching_stats(q, window=32)
        ms2, wit2 = eng2.matching_stats(q, window=32)
        np.testing.assert_array_equal(ms, ms2)
        np.testing.assert_array_equal(wit, wit2)


class TestBucketedNodeBuild:
    def test_bucket_pad_widths_partition(self):
        rng = np.random.default_rng(7)
        freqs = np.concatenate([rng.integers(1, 9, 40),
                                rng.integers(50, 300, 6), [4000]])
        buckets = bucket_pad_widths(freqs)
        assert 1 <= len(buckets) <= 3
        seen = np.sort(np.concatenate([idx for _, idx in buckets]))
        np.testing.assert_array_equal(seen, np.arange(len(freqs)))
        widths = [w for w, _ in buckets]
        assert widths == sorted(widths, reverse=True)
        for w, idx in buckets:
            assert w == pad_width(int(freqs[idx].max()))  # exact, no over-pad
            assert all(pad_width(int(freqs[i])) <= w for i in idx)

    def test_bucket_single_and_empty(self):
        assert bucket_pad_widths([]) == []
        (w, idx), = bucket_pad_widths([5, 5, 5])
        assert w == pad_width(5) and list(idx) == [0, 1, 2]

    def test_skewed_mix_builds_identical_trees(self):
        """A skewed prefix mix exercises >= 2 buckets and must produce the
        same trees as the serial per-prefix builder."""
        from repro.core.build import nodes_to_intervals

        s = DNA.random_string(1500, seed=41)
        mk = lambda c: EraIndexer(DNA, EraConfig(
            memory_bytes=8192, r_bytes=128, build_impl="numpy",
            construction=c)).build(s)
        ser, bat = mk("serial"), mk("batched")
        freqs = [st.freq for _, st in sorted(bat.subtrees.items())]
        assert len(bucket_pad_widths(freqs)) >= 2  # mix actually skewed
        for p in ser.subtrees:
            assert nodes_to_intervals(ser.subtrees[p].nodes) == \
                nodes_to_intervals(bat.subtrees[p].nodes)
