"""Dense k-bit text pipeline: packed ↔ byte bit-identity end-to-end.

The tentpole invariant: the dense-packed string representation (paper §6.1
generalized per alphabet) must produce IDENTICAL sort keys, construction
arrays, query results and analytics as the byte path — density only changes
bytes moved.  These tests pin that invariant at every layer: the gather
primitive, the Pallas kernels, construction, find_batch, matching
statistics, and npz persistence (including legacy byte-format archives).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import packing
from repro.core.alphabet import BYTE, DNA, PROTEIN, PROTEIN_CLASS
from repro.core.api import EraConfig, EraIndexer
from repro.core.build import bucket_pad_widths, pad_width
from repro.core.query import DeviceIndex
from repro.kernels import ref as kref
from repro.kernels.packed_gather import (
    pattern_probe_packed,
    pattern_probe_words,
    range_gather_packed,
    range_gather_words,
    suffix_lcp_words,
)
from repro.kernels.probe_gather import probe_gather_packed, probe_gather_words

ALPHAS = [DNA, PROTEIN_CLASS, PROTEIN, BYTE]


def build_pair(alpha, n, *, mem, seed):
    """(s, byte-packing index, dense-packing index) over one string."""
    s = alpha.random_string(n, seed=seed)
    mk = lambda mode: EraIndexer(alpha, EraConfig(
        memory_bytes=mem, r_bytes=128, build_impl="none", packing=mode)).build(s)
    return s, mk("bytes"), mk("dense")


class TestDenseBits:
    def test_alphabet_density_tiers(self):
        assert DNA.dense_bits == 2
        assert PROTEIN_CLASS.dense_bits == 4
        assert PROTEIN.dense_bits == 8   # 20 symbols: byte fallback
        assert BYTE.dense_bits == 8

    @pytest.mark.parametrize("alpha", ALPHAS, ids=lambda a: a.name)
    def test_pack_unpack_roundtrip(self, alpha):
        s = alpha.random_string(777, seed=1)
        pt = packing.pack_text(s, alpha, extra=64)
        np.testing.assert_array_equal(packing.unpack_text(pt), s)
        assert pt.nbytes * 8 >= len(s) * alpha.dense_bits

    def test_pack_rejects_unterminated(self):
        with pytest.raises(ValueError):
            packing.pack_text(np.zeros(5, np.uint8), DNA)


class TestGatherPackDense:
    @pytest.mark.parametrize("alpha", ALPHAS, ids=lambda a: a.name)
    @pytest.mark.parametrize("w", [4, 16, 64])
    def test_matches_byte_gather(self, alpha, w):
        """The invariant everything rests on: identical byte sort keys."""
        rng = np.random.default_rng(w)
        s = alpha.random_string(900, seed=9)
        pt = packing.pack_text(s, alpha, extra=w + 8)
        sp = alpha.pad_string(s, extra=w + 8)
        offs = np.concatenate([
            rng.integers(0, len(s), size=65),
            [len(s) - 2, len(s) - 1, len(s), len(s) + 3],  # terminal tail
        ]).astype(np.int32)
        got = packing.gather_pack_dense(pt, jnp.asarray(offs), w)
        want = packing.gather_pack(jnp.asarray(sp), jnp.asarray(offs), w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_key_order_is_lexicographic(self):
        s = DNA.random_string(400, seed=2)
        pt = packing.pack_text(s, DNA, extra=40)
        rng = np.random.default_rng(3)
        offs = rng.integers(0, len(s), size=50).astype(np.int32)
        keys = np.asarray(packing.as_u32(
            packing.gather_pack_dense(pt, jnp.asarray(offs), 32)))
        sp = DNA.pad_string(s, extra=40)
        for i in range(len(offs) - 1):
            sa = tuple(sp[offs[i] : offs[i] + 32])
            sb = tuple(sp[offs[i + 1] : offs[i + 1] + 32])
            ka, kb = tuple(keys[i]), tuple(keys[i + 1])
            assert (sa < sb) == (ka < kb) or sa == sb


class TestPackedKernels:
    @pytest.mark.parametrize("alpha,n,f,w,tile", [
        (DNA, 300, 7, 4, 32), (DNA, 1000, 33, 16, 64),
        (PROTEIN_CLASS, 800, 21, 32, 64), (BYTE, 500, 16, 8, 32),
    ], ids=lambda v: getattr(v, "name", v))
    def test_range_gather_packed_matches_ref(self, alpha, n, f, w, tile):
        rng = np.random.default_rng(n + f)
        s = alpha.random_string(n, seed=n)
        pt = packing.pack_text(s, alpha, extra=w + 8)
        offs = rng.integers(0, n, size=f).astype(np.int32)
        got = range_gather_packed(pt, jnp.asarray(offs), w, tile=tile,
                                  interpret=True)
        want = kref.range_gather_packed_ref(pt, jnp.asarray(offs), w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_word_tile_boundary_straddle(self):
        """Reads crossing the uint32-word tile boundary see both tiles."""
        tile = 32  # words = 512 2-bit symbols per tile
        s = DNA.random_string(3 * 32 * 16, seed=8)
        pt = packing.pack_text(s, DNA, extra=72)
        spw = pt.syms_per_word
        offs = np.array([tile * spw - 1, tile * spw - 17, tile * spw,
                         2 * tile * spw - 3], np.int32)
        got = range_gather_packed(pt, jnp.asarray(offs), 64, tile=tile,
                                  interpret=True)
        want = kref.range_gather_packed_ref(pt, jnp.asarray(offs), 64)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("alpha,n,b,m", [
        (DNA, 400, 19, 4), (PROTEIN_CLASS, 700, 33, 8), (BYTE, 500, 16, 12),
    ], ids=lambda v: getattr(v, "name", v))
    def test_pattern_probe_packed_matches_byte_ref(self, alpha, n, b, m):
        rng = np.random.default_rng(n + b)
        s = alpha.random_string(n, seed=n)
        pt = packing.pack_text(s, alpha, extra=32)
        sp = alpha.pad_string(s, extra=32)
        pos = rng.integers(0, n, size=b).astype(np.int32)
        m_pad = -(-m // 4) * 4
        lengths = rng.integers(1, m + 1, size=b)
        sym = rng.integers(0, alpha.base, size=(b, m_pad)).astype(np.int32)
        valid = np.arange(m_pad)[None, :] < lengths[:, None]
        pat = kref.pack_words_ref(jnp.asarray(np.where(valid, sym, 0)))
        mask = kref.pack_words_ref(jnp.asarray(np.where(valid, 0xFF, 0)))
        got = pattern_probe_packed(pt, jnp.asarray(pos), pat, mask,
                                   tile=32, interpret=True)
        want = kref.pattern_probe_ref(jnp.asarray(sp), jnp.asarray(pos),
                                      pat, mask)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestConstructionBitIdentity:
    @pytest.mark.parametrize("alpha,n,mem", [
        (DNA, 800, 2048), (PROTEIN_CLASS, 700, 4096), (PROTEIN, 600, 4096),
        (BYTE, 500, 4096),
    ], ids=lambda v: getattr(v, "name", v))
    def test_construction_arrays_equal(self, alpha, n, mem):
        """ell / b_off / b_c1 / b_c2 identical between dense and byte."""
        _, idx_b, idx_d = build_pair(alpha, n, mem=mem, seed=n)
        assert set(idx_b.subtrees) == set(idx_d.subtrees)
        for p in idx_b.subtrees:
            for field in ("ell", "b_off", "b_c1", "b_c2"):
                np.testing.assert_array_equal(
                    getattr(idx_b.subtrees[p], field),
                    getattr(idx_d.subtrees[p], field),
                    err_msg=f"{alpha.name} prefix={p} field={field}")

    def test_serial_engine_dense(self):
        """The paper-faithful serial engine reads dense storage too."""
        alpha = DNA
        s = alpha.random_string(500, seed=4)
        mk = lambda mode: EraIndexer(alpha, EraConfig(
            memory_bytes=2048, r_bytes=128, build_impl="none",
            construction="serial", packing=mode)).build(s)
        a, b = mk("bytes"), mk("dense")
        for p in a.subtrees:
            np.testing.assert_array_equal(a.subtrees[p].ell, b.subtrees[p].ell)
            np.testing.assert_array_equal(a.subtrees[p].b_off, b.subtrees[p].b_off)


class TestServingBitIdentity:
    @pytest.mark.parametrize("alpha,n,mem", [
        (DNA, 900, 2048), (PROTEIN_CLASS, 700, 4096), (BYTE, 500, 4096),
    ], ids=lambda v: getattr(v, "name", v))
    def test_find_batch_equal(self, alpha, n, mem):
        s, idx_b, _ = build_pair(alpha, n, mem=mem, seed=n + 1)
        dev_b = idx_b.to_device(packing="bytes")
        dev_d = idx_b.to_device(packing="dense")
        assert dev_d.packed and not dev_b.packed
        rng = np.random.default_rng(5)
        pats = [np.asarray(s[i : i + m]) for i, m in zip(
            rng.integers(0, n - 20, 25), rng.integers(1, 17, 25))]
        pats += [rng.integers(0, len(alpha.symbols), size=int(m)).astype(np.uint8)
                 for m in rng.integers(1, 10, 8)]
        for pd, pb, p in zip(dev_d.find_batch(pats), dev_b.find_batch(pats), pats):
            np.testing.assert_array_equal(pd, pb)
            np.testing.assert_array_equal(pd, idx_b.find(p))

    def test_auto_packs_sub_byte_alphabets_only(self):
        for alpha, expect in ((DNA, True), (PROTEIN_CLASS, True),
                              (PROTEIN, False), (BYTE, False)):
            s = alpha.random_string(300, seed=0)
            dev = EraIndexer(alpha, EraConfig(
                memory_bytes=4096, r_bytes=128,
                build_impl="none")).build_device(s)
            assert dev.packed == expect, alpha.name
            if expect:
                byte_equiv = len(alpha.pad_string(
                    s, extra=dev.max_pattern_len + 8))
                assert dev.string_nbytes <= \
                    byte_equiv * alpha.dense_bits // 8 + 8

    @pytest.mark.parametrize("alpha", [DNA, PROTEIN_CLASS],
                             ids=lambda a: a.name)
    def test_matching_stats_equal(self, alpha):
        s, idx_b, _ = build_pair(alpha, 800, mem=4096, seed=13)
        eng_b = idx_b.analytics(packing="bytes")
        eng_d = idx_b.analytics(packing="dense")
        assert eng_d.dev.packed
        np.testing.assert_array_equal(eng_b.lcp_host, eng_d.lcp_host)
        rng = np.random.default_rng(6)
        q = np.concatenate([s[100:180],
                            rng.integers(0, len(alpha.symbols),
                                         size=60).astype(np.uint8)])
        ms_b, wit_b = eng_b.matching_stats(q, window=48)
        ms_d, wit_d = eng_d.matching_stats(q, window=48)
        np.testing.assert_array_equal(ms_b, ms_d)
        np.testing.assert_array_equal(wit_b, wit_d)

    def test_read_symbols_and_string_codes(self):
        s, idx_b, _ = build_pair(DNA, 400, mem=2048, seed=21)
        dev = idx_b.to_device(packing="dense")
        np.testing.assert_array_equal(dev.string_codes(), s)
        pos = np.array([0, 5, len(s) - 3], np.int32)
        got = np.asarray(dev.read_symbols(pos, 6))
        sp = DNA.pad_string(s, extra=8)
        want = np.stack([sp[p : p + 6] for p in pos]).astype(np.int32)
        np.testing.assert_array_equal(got, want)


class TestPackedPersistence:
    def test_packed_npz_round_trip(self, tmp_path):
        s, idx_b, _ = build_pair(DNA, 600, mem=2048, seed=31)
        dev = idx_b.to_device()  # auto -> dense for DNA
        assert dev.packed
        p = str(tmp_path / "dev_packed.npz")
        dev.save(p)
        dev2 = DeviceIndex.load(p)
        assert dev2.packed and dev2.s_bits == dev.s_bits == 2
        assert (dev2.base, dev2.k_route, dev2.n_iter, dev2.max_pattern_len) \
            == (dev.base, dev.k_route, dev.n_iter, dev.max_pattern_len)
        np.testing.assert_array_equal(np.asarray(dev2.s_text.words),
                                      np.asarray(dev.s_text.words))
        np.testing.assert_array_equal(dev2.string_codes(), s)
        pats = [np.asarray(s[i : i + 8]) for i in (3, 77, 300)]
        for a, b in zip(dev2.find_batch(pats), dev.find_batch(pats)):
            np.testing.assert_array_equal(a, b)

    def test_byte_saves_keep_legacy_format_and_load(self, tmp_path):
        """Byte-path archives must stay in the original blob layout so
        pre-packing caches (and older readers) keep working."""
        s, idx_b, _ = build_pair(DNA, 400, mem=2048, seed=33)
        dev_b = idx_b.to_device(packing="bytes")
        blobs = dev_b.to_blobs()
        assert "s_padded" in blobs and "s_words" not in blobs
        # pre-packing meta layout + the trailing epoch entry (archives
        # without it still load — tests/test_stream.py holds that)
        assert blobs["meta"].shape == (5,)
        p = str(tmp_path / "dev_legacy.npz")
        dev_b.save(p)
        dev2 = DeviceIndex.load(p)
        assert not dev2.packed
        pats = [np.asarray(s[i : i + 6]) for i in (1, 50, 200)]
        for a, b in zip(dev2.find_batch(pats), idx_b.find_batch(pats)):
            np.testing.assert_array_equal(a, b)

    def test_analytics_engine_packed_round_trip(self, tmp_path):
        from repro.core.analytics import AnalyticsEngine

        s, idx_b, _ = build_pair(DNA, 500, mem=2048, seed=35)
        eng = idx_b.analytics(packing="dense")
        p = str(tmp_path / "eng_packed.npz")
        eng.save(p)
        eng2 = AnalyticsEngine.load(p)
        assert eng2.dev.packed
        np.testing.assert_array_equal(eng2.lcp_host, eng.lcp_host)
        q = np.asarray(s[50:120])
        ms, wit = eng.matching_stats(q, window=32)
        ms2, wit2 = eng2.matching_stats(q, window=32)
        np.testing.assert_array_equal(ms, ms2)
        np.testing.assert_array_equal(wit, wit2)


class TestBucketedNodeBuild:
    def test_bucket_pad_widths_partition(self):
        rng = np.random.default_rng(7)
        freqs = np.concatenate([rng.integers(1, 9, 40),
                                rng.integers(50, 300, 6), [4000]])
        buckets = bucket_pad_widths(freqs)  # histogram-driven auto count
        assert len(buckets) >= 1
        seen = np.sort(np.concatenate([idx for _, idx in buckets]))
        np.testing.assert_array_equal(seen, np.arange(len(freqs)))
        widths = [w for w, _ in buckets]
        assert widths == sorted(widths, reverse=True)
        for w, idx in buckets:
            assert w == pad_width(int(freqs[idx].max()))  # exact, no over-pad
            assert all(pad_width(int(freqs[i])) <= w for i in idx)

    def test_bucket_legacy_cap(self):
        """An explicit integer max_buckets keeps the PR-4 semantics."""
        rng = np.random.default_rng(7)
        freqs = np.concatenate([rng.integers(1, 9, 40),
                                rng.integers(50, 300, 6), [4000]])
        buckets = bucket_pad_widths(freqs, max_buckets=3)
        assert 1 <= len(buckets) <= 3
        seen = np.sort(np.concatenate([idx for _, idx in buckets]))
        np.testing.assert_array_equal(seen, np.arange(len(freqs)))

    def test_auto_objective_never_worse_than_capped(self):
        """The auto tuner minimizes padded cells PLUS the per-bucket
        dispatch overhead, so ITS objective is never worse than any
        legacy fixed-cap partition's (raw cells alone can be: a merge
        that wastes fewer cells than one dispatch costs is a win)."""
        from repro.core.build import BUCKET_OVERHEAD_CELLS

        rng = np.random.default_rng(13)
        objective = lambda bs: (sum(w * len(idx) for w, idx in bs)
                                + len(bs) * BUCKET_OVERHEAD_CELLS)
        for trial in range(20):
            freqs = np.concatenate([
                rng.integers(1, 5, int(rng.integers(1, 300))),
                rng.integers(30, 70, int(rng.integers(1, 20))),
                rng.integers(900, 1100, int(rng.integers(1, 4)))])
            auto = objective(bucket_pad_widths(freqs))
            for cap in (1, 2, 3, 4):
                assert auto <= objective(
                    bucket_pad_widths(freqs, max_buckets=cap))

    def test_auto_collapses_uniform_and_splits_skewed(self):
        (w, idx), = bucket_pad_widths([5] * 200)  # uniform: one bucket
        assert w == pad_width(5) and len(idx) == 200
        skew = [2] * 500 + [3000]  # heavy tail: the split pays for itself
        assert len(bucket_pad_widths(skew)) == 2

    def test_bucket_single_and_empty(self):
        assert bucket_pad_widths([]) == []
        (w, idx), = bucket_pad_widths([5, 5, 5])
        assert w == pad_width(5) and list(idx) == [0, 1, 2]

    def test_skewed_mix_builds_identical_trees(self):
        """A skewed prefix mix makes the auto-tuner choose >= 2 buckets
        and must produce the same trees as the serial per-prefix builder.
        (A uniform mix collapses to one bucket by design — the skew is
        planted so the multi-bucket path actually runs.)"""
        from repro.core.build import nodes_to_intervals

        rng = np.random.default_rng(41)
        s = np.concatenate([
            np.zeros(2500, np.uint8),  # long 'A' run -> one huge prefix
            rng.integers(0, 4, size=1200).astype(np.uint8),
            [DNA.terminal_code],
        ]).astype(np.uint8)
        mk = lambda c: EraIndexer(DNA, EraConfig(
            memory_bytes=64 << 10, r_bytes=128, build_impl="numpy",
            construction=c)).build(s)
        ser, bat = mk("serial"), mk("batched")
        freqs = [st.freq for _, st in sorted(bat.subtrees.items())]
        assert len(bucket_pad_widths(freqs)) >= 2  # mix actually skewed
        for p in ser.subtrees:
            assert nodes_to_intervals(ser.subtrees[p].nodes) == \
                nodes_to_intervals(bat.subtrees[p].nodes)


class TestWordCompareKernels:
    """PR 5 word-compare kernel family vs its jnp oracles (interpret mode)."""

    @pytest.mark.parametrize("alpha,n,f,w,tile", [
        (DNA, 900, 33, 16, 32), (DNA, 2000, 64, 64, 64),
        (PROTEIN_CLASS, 800, 21, 32, 64), (BYTE, 500, 16, 8, 32),
    ], ids=lambda v: getattr(v, "name", v))
    def test_range_gather_words_matches_ref(self, alpha, n, f, w, tile):
        rng = np.random.default_rng(n + f)
        s = alpha.random_string(n, seed=n)
        pt = packing.pack_text(s, alpha, extra=w + 8)
        offs = np.concatenate([
            rng.integers(0, n, size=f),
            [n - 2, n - 1, n],  # virtual-terminal tail
        ]).astype(np.int32)
        got = range_gather_words(pt, jnp.asarray(offs), w, tile=tile,
                                 interpret=True)
        want = kref.range_gather_words_ref(pt, jnp.asarray(offs), w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_word_tile_boundary_straddle(self):
        tile = 32
        s = DNA.random_string(3 * 32 * 16, seed=8)
        pt = packing.pack_text(s, DNA, extra=72)
        spw = pt.syms_per_word
        offs = np.array([tile * spw - 1, tile * spw - 17, tile * spw,
                         2 * tile * spw - 3], np.int32)
        got = range_gather_words(pt, jnp.asarray(offs), 64, tile=tile,
                                 interpret=True)
        want = kref.range_gather_words_ref(pt, jnp.asarray(offs), 64)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("alpha,n,b,m", [
        (DNA, 400, 25, 4), (DNA, 900, 40, 16),
        (PROTEIN_CLASS, 700, 33, 8), (BYTE, 500, 16, 12),
    ], ids=lambda v: getattr(v, "name", v))
    def test_pattern_probe_words_matches_byte_oracle(self, alpha, n, b, m):
        """The word probe must agree with BOTH its own jnp ref and the
        byte probe oracle, terminal tail positions included."""
        rng = np.random.default_rng(n + b)
        s = alpha.random_string(n, seed=n)
        pt = packing.pack_text(s, alpha, extra=32)
        sp = alpha.pad_string(s, extra=32)
        pos = np.concatenate([rng.integers(0, n, size=b - 5),
                              rng.integers(max(0, n - m), n + 1, 5)]
                             ).astype(np.int32)
        m_pad = -(-m // 4) * 4
        lengths = rng.integers(1, m + 1, size=len(pos)).astype(np.int32)
        sym = rng.integers(0, len(alpha.symbols),
                           size=(len(pos), m_pad)).astype(np.int32)
        for i in range(0, len(pos), 3):  # plant exact matches (verdict 0)
            j = int(rng.integers(0, n - m_pad))
            sym[i] = sp[j : j + m_pad]
            pos[i] = j
        valid = np.arange(m_pad)[None, :] < lengths[:, None]
        pat_b = kref.pack_words_ref(jnp.asarray(np.where(valid, sym, 0)))
        mask_b = kref.pack_words_ref(jnp.asarray(np.where(valid, 0xFF, 0)))
        want = kref.pattern_probe_ref(jnp.asarray(sp), jnp.asarray(pos),
                                      pat_b, mask_b)

        bits = pt.bits
        pat_d = packing.pack_pattern_dense(
            jnp.asarray(np.where(valid, sym, 0)), bits, pt.terminal)
        mask_d = packing.pack_dense(
            jnp.asarray(np.where(valid, (1 << bits) - 1, 0)), bits)
        ref_w = kref.pattern_probe_words_ref(pt, jnp.asarray(pos), pat_d,
                                             mask_d, jnp.asarray(lengths))
        got = pattern_probe_words(pt, jnp.asarray(pos), pat_d, mask_d,
                                  jnp.asarray(lengths), tile=64,
                                  interpret=True)
        np.testing.assert_array_equal(np.asarray(ref_w), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("alpha,n,b,w", [
        (DNA, 900, 40, 16), (DNA, 2000, 64, 64),
        (PROTEIN_CLASS, 700, 33, 32), (BYTE, 500, 16, 8),
    ], ids=lambda v: getattr(v, "name", v))
    def test_suffix_lcp_words_matches_byte_oracle(self, alpha, n, b, w):
        rng = np.random.default_rng(n * b + w)
        s = alpha.random_string(n, seed=n)
        pt = packing.pack_text(s, alpha, extra=w + 8)
        sp = alpha.pad_string(s, extra=w + 8)
        pos_a = rng.integers(0, n, size=b).astype(np.int32)
        # deep-LCP pairs: nearby offsets in a repetitive region
        pos_b = np.where(rng.random(b) < 0.5,
                         np.clip(pos_a + rng.integers(1, 4, b), 0, n),
                         rng.integers(0, n, size=b)).astype(np.int32)
        keep = pos_a != pos_b
        pos_a, pos_b = pos_a[keep], pos_b[keep]
        want = kref.suffix_lcp_pairs_ref(jnp.asarray(sp), jnp.asarray(pos_a),
                                         jnp.asarray(pos_b), w)
        ref_w = kref.suffix_lcp_words_ref(pt, jnp.asarray(pos_a),
                                          jnp.asarray(pos_b), w)
        got = suffix_lcp_words(pt, jnp.asarray(pos_a), jnp.asarray(pos_b), w,
                               tile=64, interpret=True)
        np.testing.assert_array_equal(np.asarray(ref_w), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_lcp_adjacent_words_matches_byte_lcp_adjacent(self):
        """The elastic-sort divergence stage: word keys + limits must give
        the byte path's (lcp, c1, c2), terminal divergences included."""
        from repro.core.prepare import lcp_adjacent

        for alpha in (DNA, PROTEIN_CLASS, BYTE):
            rng = np.random.default_rng(3)
            n, w = 700, 16
            s = alpha.random_string(n, seed=17)
            pt = packing.pack_text(s, alpha, extra=w + 8)
            sp = alpha.pad_string(s, extra=w + 8)
            # distinct sorted offsets: the contract covers distinct
            # suffixes only (equal positions tie-break via limits, which
            # the byte rows resolve by continuing through equal padding)
            offs = np.unique(np.concatenate([
                rng.integers(0, n, 60), [n - 3, n - 1, n]])).astype(np.int32)
            byte_keys = packing.gather_pack(jnp.asarray(sp),
                                            jnp.asarray(offs), w)
            lcp_b, c1_b, c2_b = lcp_adjacent(byte_keys, w)
            keys = packing.gather_words_dense(pt, jnp.asarray(offs), w)
            lim = packing.word_limit(pt.n_real, jnp.asarray(offs), w)
            prev = jnp.concatenate([keys[:1], keys[:-1]], axis=0)
            prev_lim = jnp.concatenate([lim[:1], lim[:-1]])
            lcp_w, c1_w, c2_w = packing.lcp_adjacent_words(
                prev, keys, prev_lim, lim, w, pt.bits, pt.terminal)
            # entry 0 compares a row against itself — garbage in both
            # paths by contract, callers mask it
            for bb, ww in ((lcp_b, lcp_w), (c1_b, c1_w), (c2_b, c2_w)):
                np.testing.assert_array_equal(np.asarray(bb)[1:],
                                              np.asarray(ww)[1:],
                                              err_msg=alpha.name)


class TestFusedProbeGather:
    """PR 6 fused find-and-fetch: ONE launch must be bit-identical to the
    two-launch probe → gather composition in both currencies, with fetch
    widths on either side of the pattern width and terminal-tail
    positions included."""

    @staticmethod
    def _probe_batch(alpha, n, b, m, seed):
        """(pt, sp, pos, sym, lengths, m_pad): a probe workload with tail
        positions and planted exact matches, mirroring the probe tests."""
        rng = np.random.default_rng(seed)
        s = alpha.random_string(n, seed=n)
        pt = packing.pack_text(s, alpha, extra=96)
        sp = alpha.pad_string(s, extra=96)
        pos = np.concatenate([rng.integers(0, n, size=b - 4),
                              rng.integers(max(0, n - m), n + 1, 4)]
                             ).astype(np.int32)
        m_pad = -(-m // 4) * 4
        lengths = rng.integers(1, m + 1, size=len(pos)).astype(np.int32)
        sym = rng.integers(0, len(alpha.symbols),
                           size=(len(pos), m_pad)).astype(np.int32)
        for i in range(0, len(pos), 3):
            j = int(rng.integers(0, n - m_pad))
            sym[i] = sp[j : j + m_pad]
            pos[i] = j
        return pt, sp, pos, sym, lengths, m_pad

    @pytest.mark.parametrize("alpha,n,b,m,fetch", [
        (DNA, 900, 24, 8, 32),          # fetch wider than the pattern
        (DNA, 700, 16, 16, 4),          # fetch narrower than the pattern
        (PROTEIN_CLASS, 700, 20, 8, 16),
        (BYTE, 500, 12, 12, 12),
    ], ids=lambda v: getattr(v, "name", v))
    def test_words_fused_equals_two_launch(self, alpha, n, b, m, fetch):
        pt, _, pos, sym, lengths, m_pad = self._probe_batch(
            alpha, n, b, m, seed=n + m)
        valid = np.arange(m_pad)[None, :] < lengths[:, None]
        pat_d = packing.pack_pattern_dense(
            jnp.asarray(np.where(valid, sym, 0)), pt.bits, pt.terminal)
        mask_d = packing.pack_dense(
            jnp.asarray(np.where(valid, (1 << pt.bits) - 1, 0)), pt.bits)
        pos_j, len_j = jnp.asarray(pos), jnp.asarray(lengths)

        cmp_want = kref.pattern_probe_words_ref(pt, pos_j, pat_d, mask_d,
                                                len_j)
        win_want = kref.range_gather_words_ref(pt, pos_j, fetch)
        cmp_ref, win_ref = kref.probe_gather_words_ref(
            pt, pos_j, pat_d, mask_d, len_j, fetch=fetch)
        cmp_got, win_got = probe_gather_words(pt, pos_j, pat_d, mask_d,
                                              len_j, fetch=fetch, tile=64,
                                              interpret=True)
        for got in ((cmp_ref, win_ref), (cmp_got, win_got)):
            np.testing.assert_array_equal(np.asarray(got[0]),
                                          np.asarray(cmp_want))
            np.testing.assert_array_equal(np.asarray(got[1]),
                                          np.asarray(win_want))

    @pytest.mark.parametrize("alpha,n,b,m,fetch", [
        (DNA, 900, 24, 8, 32), (DNA, 700, 16, 16, 4),
        (PROTEIN_CLASS, 700, 20, 8, 16), (BYTE, 500, 12, 12, 12),
    ], ids=lambda v: getattr(v, "name", v))
    def test_packed_fused_equals_two_launch(self, alpha, n, b, m, fetch):
        pt, _, pos, sym, lengths, m_pad = self._probe_batch(
            alpha, n, b, m, seed=2 * n + m)
        valid = np.arange(m_pad)[None, :] < lengths[:, None]
        pat_w = kref.pack_words_ref(jnp.asarray(np.where(valid, sym, 0)))
        mask_w = kref.pack_words_ref(jnp.asarray(np.where(valid, 0xFF, 0)))
        pos_j = jnp.asarray(pos)

        cmp_want = kref.pattern_probe_packed_ref(pt, pos_j, pat_w, mask_w)
        win_want = kref.range_gather_packed_ref(pt, pos_j, fetch)
        cmp_ref, win_ref = kref.probe_gather_packed_ref(
            pt, pos_j, pat_w, mask_w, fetch=fetch)
        cmp_got, win_got = probe_gather_packed(pt, pos_j, pat_w, mask_w,
                                               fetch=fetch, tile=64,
                                               interpret=True)
        for got in ((cmp_ref, win_ref), (cmp_got, win_got)):
            np.testing.assert_array_equal(np.asarray(got[0]),
                                          np.asarray(cmp_want))
            np.testing.assert_array_equal(np.asarray(got[1]),
                                          np.asarray(win_want))

    @pytest.mark.parametrize("leg", ["default", "pallas", "byte"])
    def test_ops_dispatch_three_legs(self, leg, monkeypatch):
        """The ops-layer fused dispatch equals the composition of the
        ops-layer probe + gather under every oracle leg (and on the plain
        byte string, where the fused form IS that composition)."""
        from repro.kernels import ops as kops

        if leg == "pallas":
            monkeypatch.setenv("REPRO_KERNELS", "pallas")
        elif leg == "byte":
            monkeypatch.setenv("REPRO_WORD_COMPARE", "byte")
        alpha, n, b, m, fetch = DNA, 600, 16, 8, 16
        pt, sp, pos, sym, lengths, m_pad = self._probe_batch(
            alpha, n, b, m, seed=99)
        valid = np.arange(m_pad)[None, :] < lengths[:, None]
        pos_j, len_j = jnp.asarray(pos), jnp.asarray(lengths)

        pat_d = packing.pack_pattern_dense(
            jnp.asarray(np.where(valid, sym, 0)), pt.bits, pt.terminal)
        mask_d = packing.pack_dense(
            jnp.asarray(np.where(valid, (1 << pt.bits) - 1, 0)), pt.bits)
        cmp_f, win_f = kops.probe_gather_words(pt, pos_j, pat_d, mask_d,
                                               len_j, fetch)
        np.testing.assert_array_equal(
            np.asarray(cmp_f),
            np.asarray(kops.pattern_probe_words(pt, pos_j, pat_d, mask_d,
                                                len_j)))
        np.testing.assert_array_equal(
            np.asarray(win_f),
            np.asarray(kops.range_gather_words(pt, pos_j, fetch)))

        pat_w = kref.pack_words_ref(jnp.asarray(np.where(valid, sym, 0)))
        mask_w = kref.pack_words_ref(jnp.asarray(np.where(valid, 0xFF, 0)))
        for s_text in (pt, jnp.asarray(sp)):
            cmp_f, win_f = kops.probe_gather(s_text, pos_j, pat_w, mask_w,
                                             fetch)
            np.testing.assert_array_equal(
                np.asarray(cmp_f),
                np.asarray(kops.pattern_probe(s_text, pos_j, pat_w, mask_w)))
            np.testing.assert_array_equal(
                np.asarray(win_f),
                np.asarray(kops.range_gather_pack(s_text, pos_j, fetch)))


class TestWordCompareEndToEnd:
    """The word-compare path (default for dense text) vs the byte-key
    comparison oracle (REPRO_WORD_COMPARE=byte): construction arrays,
    find_batch, matching statistics and the global LCP must be
    bit-identical across all four alphabets."""

    @staticmethod
    def _dense_indexer(alpha, mem):
        return EraIndexer(alpha, EraConfig(
            memory_bytes=mem, r_bytes=128, build_impl="none",
            packing="dense"))

    @pytest.mark.parametrize("alpha,n,mem", [
        (DNA, 900, 2048), (PROTEIN_CLASS, 700, 4096), (PROTEIN, 600, 4096),
        (BYTE, 500, 4096),
    ], ids=lambda v: getattr(v, "name", v))
    def test_construction_word_vs_byte_compare(self, alpha, n, mem,
                                               monkeypatch):
        s = alpha.random_string(n, seed=n + 7)
        monkeypatch.setenv("REPRO_WORD_COMPARE", "byte")
        idx_byte = self._dense_indexer(alpha, mem).build(s)
        monkeypatch.setenv("REPRO_WORD_COMPARE", "word")
        idx_word = self._dense_indexer(alpha, mem).build(s)
        assert set(idx_byte.subtrees) == set(idx_word.subtrees)
        for p in idx_byte.subtrees:
            for field in ("ell", "b_off", "b_c1", "b_c2"):
                np.testing.assert_array_equal(
                    getattr(idx_byte.subtrees[p], field),
                    getattr(idx_word.subtrees[p], field),
                    err_msg=f"{alpha.name} prefix={p} field={field}")

    @pytest.mark.parametrize("alpha,n,mem", [
        (DNA, 900, 2048), (PROTEIN_CLASS, 700, 4096), (PROTEIN, 600, 4096),
        (BYTE, 500, 4096),
    ], ids=lambda v: getattr(v, "name", v))
    def test_find_batch_word_vs_byte_compare(self, alpha, n, mem,
                                             monkeypatch):
        s = alpha.random_string(n, seed=n + 9)
        idx = self._dense_indexer(alpha, mem).build(s)
        dev = idx.to_device(packing="dense")
        assert dev.packed
        rng = np.random.default_rng(4)
        pats = [np.asarray(s[i : i + m]) for i, m in zip(
            rng.integers(0, n - 20, 20), rng.integers(1, 17, 20))]
        pats += [rng.integers(0, len(alpha.symbols), size=int(m)
                              ).astype(np.uint8)
                 for m in rng.integers(1, 9, 8)]
        monkeypatch.setenv("REPRO_WORD_COMPARE", "byte")
        res_byte = dev.find_batch(pats)
        monkeypatch.setenv("REPRO_WORD_COMPARE", "word")
        res_word = dev.find_batch(pats)
        for a, b, p in zip(res_word, res_byte, pats):
            np.testing.assert_array_equal(a, b)
            np.testing.assert_array_equal(a, idx.find(p))

    def test_terminal_pattern_falls_back_to_byte_probe(self):
        """A (degenerate) pattern carrying the terminal sentinel code must
        still resolve — the word path declines it and the byte-key probe
        serves the batch."""
        s = DNA.random_string(400, seed=77)
        idx = self._dense_indexer(DNA, 2048).build(s)
        dev = idx.to_device(packing="dense")
        pats = [np.asarray(s[10:16]),
                np.array([0, DNA.terminal_code], np.uint8)]
        got = dev.find_batch(pats)
        np.testing.assert_array_equal(got[0], idx.find(pats[0]))
        np.testing.assert_array_equal(got[1], idx.find(pats[1]))

    @pytest.mark.parametrize("alpha", [DNA, PROTEIN_CLASS, BYTE],
                             ids=lambda a: a.name)
    def test_terminal_fallback_mixed_batch(self, alpha, monkeypatch):
        """A MIXED batch — some patterns carrying the sentinel, some not —
        takes the byte-probe fallback as a whole; every row must agree
        with the per-pattern oracle, with the word path's answers for the
        sentinel-free rows, and with the pinned byte-compare leg."""
        n = 500
        s = alpha.random_string(n, seed=31)
        idx = self._dense_indexer(alpha, 4096).build(s)
        dev = idx.to_device(packing="dense")
        rng = np.random.default_rng(13)
        clean = [np.asarray(s[i : i + m]) for i, m in zip(
            rng.integers(0, n - 20, 8), rng.integers(1, 12, 8))]
        term = alpha.terminal_code
        sentinel = [
            np.array([term], np.uint8),                 # lone sentinel
            np.asarray(s[n - 2 :]),                     # true string tail
            np.concatenate([clean[0],
                            np.array([term], np.uint8)]),
        ]
        mixed = clean[:4] + sentinel + clean[4:]

        got = dev.find_batch(mixed)
        for g, p in zip(got, mixed):
            np.testing.assert_array_equal(g, idx.find(p),
                                          err_msg=alpha.name)
        # sentinel-free rows must match what the word path answers alone
        word_only = dev.find_batch(clean)
        for g, p in zip(word_only, clean):
            np.testing.assert_array_equal(g, idx.find(p),
                                          err_msg=alpha.name)
        # and the whole mixed batch under the pinned byte-compare oracle
        monkeypatch.setenv("REPRO_WORD_COMPARE", "byte")
        got_byte = dev.find_batch(mixed)
        for a, b in zip(got, got_byte):
            np.testing.assert_array_equal(a, b, err_msg=alpha.name)

    @pytest.mark.parametrize("alpha", [DNA, PROTEIN_CLASS, BYTE],
                             ids=lambda a: a.name)
    def test_matching_stats_and_global_lcp(self, alpha, monkeypatch):
        s = alpha.random_string(800, seed=23)
        idx = self._dense_indexer(alpha, 4096).build(s)
        monkeypatch.setenv("REPRO_WORD_COMPARE", "byte")
        eng_byte = idx.analytics(packing="dense")
        rng = np.random.default_rng(6)
        q = np.concatenate([s[100:180],
                            rng.integers(0, len(alpha.symbols),
                                         size=60).astype(np.uint8)])
        ms_b, wit_b = eng_byte.matching_stats(q, window=48)
        monkeypatch.setenv("REPRO_WORD_COMPARE", "word")
        eng_word = idx.analytics(packing="dense")
        ms_w, wit_w = eng_word.matching_stats(q, window=48)
        np.testing.assert_array_equal(eng_byte.lcp_host, eng_word.lcp_host)
        np.testing.assert_array_equal(ms_b, ms_w)
        np.testing.assert_array_equal(wit_b, wit_w)
