"""Out-of-core streaming construction and incremental append.

The streaming pipeline (:func:`repro.core.prepare.subtree_prepare_stream`)
must be a pure SCHEDULING transform: slicing the vertical-partition groups
into device-budget-sized chunks and double-buffering the host→device state
copies may change when work happens, never what it produces.  With the
default elastic config the per-chunk range schedule coincides with the
one-shot schedule (the range saturates at ``w_max`` whenever the active
row count is below the budget), so ALL six PrepareState fields are
bit-identical; with a tiny range budget the schedules diverge and only the
schedule-dependent ``start`` cursor may differ — every field the flattened
index reads stays bit-identical either way (Fig. 9b: range choice never
changes results).

Incremental append (:meth:`EraIndexer.append_device`) must produce an
index bit-identical to a full rebuild of the extended string while
rebuilding only the affected sub-trees, and must bump ``epoch`` so the
serving tier's RouteCaches invalidate (:meth:`AsyncServer.update_index`).
"""

import os

import numpy as np
import pytest

from repro.core import iomodel, packing
from repro.core.alphabet import ALPHABETS
from repro.core.api import EraConfig, EraIndexer
from repro.core.prepare import subtree_prepare_batch, subtree_prepare_stream
from repro.core.query import DeviceIndex
from repro.data.strings import dataset

ALL_FIELDS = ("L", "start", "area", "b_off", "b_c1", "b_c2")
# `start` is a per-row cursor advanced by the (schedule-dependent) range
# width and dead once the row resolves; every other field is
# schedule-invariant by the Fig. 9b argument.
RESULT_FIELDS = tuple(f for f in ALL_FIELDS if f != "start")
INDEX_FIELDS = ("ell", "sub_off", "sub_freq", "sub_prefix", "sub_plen",
                "win_lo", "win_hi")


def _workload(name, n, mem, **cfg_kw):
    s, alpha = dataset(name, n, seed=0)
    cfg = EraConfig(memory_bytes=mem, build_impl="none", **cfg_kw)
    ix = EraIndexer(alpha, cfg)
    groups = ix.partition(s)
    return s, alpha, ix, groups, ix._capacity(groups), ix._device_text(s)


def _assert_fields(ref, got, fields):
    for field in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, field)), np.asarray(getattr(got, field)),
            err_msg=field)


def _appended(s, alphabet, m, seed=3):
    """s_new = S_old's real symbols + m fresh symbols + terminal."""
    rng = np.random.default_rng(seed)
    extra = rng.integers(0, alphabet.base - 1, size=m, dtype=np.uint8)
    return np.concatenate([s[:-1], extra, s[-1:]])


class TestPlanner:
    def test_unbounded_is_one_chunk(self):
        plan = iomodel.plan_stream(37, 100)
        assert plan.chunks == ((0, 37),)
        assert plan.peak_bytes == 2 * 37 * iomodel.state_bytes_per_group(100)

    def test_tiny_budget_floors_at_one_group(self):
        plan = iomodel.plan_stream(10, 100, budget_bytes=1)
        assert plan.n_chunks == 10
        assert all(hi - lo == 1 for lo, hi in plan.chunks)
        # the floor overshoots a 1-byte budget; peak_bytes reports it
        assert plan.peak_bytes > plan.budget_bytes

    def test_chunks_tile_the_group_range(self):
        per = iomodel.state_bytes_per_group(64)
        plan = iomodel.plan_stream(11, 64, budget_bytes=2 * 3 * per)
        assert plan.groups_per_chunk == 3
        flat = [g for lo, hi in plan.chunks for g in range(lo, hi)]
        assert flat == list(range(11))
        assert plan.peak_bytes <= plan.budget_bytes

    def test_single_buffer_doubles_chunk_size(self):
        per = iomodel.state_bytes_per_group(64)
        double = iomodel.plan_stream(12, 64, budget_bytes=4 * per)
        single = iomodel.plan_stream(12, 64, budget_bytes=4 * per,
                                     double_buffer=False)
        assert double.groups_per_chunk == 2
        assert single.groups_per_chunk == 4
        assert single.buffers == 1

    def test_reserved_bytes_shrink_chunks(self):
        per = iomodel.state_bytes_per_group(64)
        plan = iomodel.plan_stream(12, 64, budget_bytes=2 * 4 * per,
                                   reserved_bytes=2 * 2 * per)
        assert plan.groups_per_chunk == 2
        assert plan.peak_bytes <= plan.budget_bytes

    def test_empty(self):
        assert iomodel.plan_stream(0, 64).n_chunks == 0
        assert iomodel.plan_stream(0, 64).groups_per_chunk == 0


class TestStreamBitIdentity:
    """Budget <= 1/8 of total state, saturated range schedule: every
    PrepareState field must match the one-shot batched engine exactly."""

    @pytest.mark.parametrize("name,n", [
        ("dna", 30_000),
        ("protein", 16_000),
        ("byte", 9_000),
    ])
    def test_all_six_fields(self, name, n):
        # memory 128KB -> f_max = 2457 < 4096: the elastic range saturates
        # at w_max every iteration, so chunk schedules == global schedule
        _, _, ix, groups, cap, sp = _workload(name, n, 128 << 10)
        assert len(groups) >= 2
        ecfg = ix.config.elastic_config()
        total = len(groups) * iomodel.state_bytes_per_group(cap)
        ref = subtree_prepare_batch(sp, groups, cap, ecfg)
        got, sr = subtree_prepare_stream(sp, groups, cap, ecfg,
                                         device_budget=total // 8)
        assert sr.n_chunks >= 2
        assert sr.bytes_copied > 0
        _assert_fields(ref, got, ALL_FIELDS)

    def test_divergent_schedule_keeps_results(self):
        # r_bytes=512: the range depends on each chunk's OWN active count,
        # so per-chunk schedules diverge from the global one — `start`
        # may differ, every result field must not (Fig. 9b)
        _, _, ix, groups, cap, sp = _workload("dna", 12_000, 16 << 10,
                                              r_bytes=512)
        ecfg = ix.config.elastic_config()
        total = len(groups) * iomodel.state_bytes_per_group(cap)
        ref = subtree_prepare_batch(sp, groups, cap, ecfg)
        got, sr = subtree_prepare_stream(sp, groups, cap, ecfg,
                                         device_budget=total // 8)
        assert sr.n_chunks >= 2
        _assert_fields(ref, got, RESULT_FIELDS)

    def test_degenerate_budgets(self):
        _, _, ix, groups, cap, sp = _workload("dna", 8_000, 64 << 10)
        ecfg = ix.config.elastic_config()
        ref = subtree_prepare_batch(sp, groups, cap, ecfg)
        # unbounded -> one chunk (the streaming build IS the one-shot)
        one, sr1 = subtree_prepare_stream(sp, groups, cap, ecfg)
        assert sr1.n_chunks == 1
        _assert_fields(ref, one, ALL_FIELDS)
        # 1-byte budget -> one group per chunk (the planner's floor)
        per, srn = subtree_prepare_stream(sp, groups, cap, ecfg,
                                          device_budget=1)
        assert srn.n_chunks == len(groups)
        _assert_fields(ref, per, ALL_FIELDS)
        # overlap off -> synchronous copies, same results, nothing hidden
        sync, srs = subtree_prepare_stream(sp, groups, cap, ecfg,
                                           device_budget=1, overlap=False)
        assert srs.copy_hidden_s == 0.0
        _assert_fields(ref, sync, ALL_FIELDS)

    def test_empty_groups_raise(self):
        _, _, ix, groups, cap, sp = _workload("dna", 2_000, 64 << 10)
        with pytest.raises(ValueError):
            subtree_prepare_stream(sp, [], cap, ix.config.elastic_config())


class TestBuildStream:
    def test_index_matches_one_shot(self):
        s, alpha = dataset("dna", 30_000, seed=0)
        ix = EraIndexer(alpha, EraConfig(memory_bytes=128 << 10,
                                         build_impl="none"))
        ref = ix.build_device(s, max_pattern_len=64)
        total = ref.n_leaves * iomodel.STATE_CELL_BYTES  # >= true state size
        dev, sr = ix.build_stream(s, device_budget=total // 8,
                                  max_pattern_len=64)
        assert sr.n_chunks >= 2
        for f in INDEX_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, f)), np.asarray(getattr(dev, f)),
                err_msg=f)
        pats = [s[i:i + 9] for i in range(0, 256, 4)]
        for a, b in zip(ref.find_batch(pats), dev.find_batch(pats)):
            np.testing.assert_array_equal(a, b)


class TestAppend:
    def test_device_bit_identity(self):
        s, alpha = dataset("dna", 24_000, seed=0)
        ix = EraIndexer(alpha, EraConfig(memory_bytes=128 << 10,
                                         build_impl="none"))
        dev = ix.build_device(s, max_pattern_len=64)
        s_new = _appended(s, alpha, 1_500)
        dev2, arep = ix.append_device(dev, s_new)
        full = ix.build_device(s_new, max_pattern_len=64)
        for f in INDEX_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(full, f)), np.asarray(getattr(dev2, f)),
                err_msg=f)
        np.testing.assert_array_equal(full.string_codes(),
                                      dev2.string_codes())
        assert dev2.epoch == dev.epoch + 1
        assert arep.n_new == arep.n_old + 1_500
        assert arep.leaves_rebuilt + arep.leaves_reused == dev2.n_leaves
        pats = [s_new[i:i + 8] for i in range(0, 200, 2)]
        for a, b in zip(full.find_batch(pats), dev2.find_batch(pats)):
            np.testing.assert_array_equal(a, b)

    def test_sharded_append_matches_rebuild(self):
        s, alpha = dataset("dna", 16_000, seed=0)
        ix = EraIndexer(alpha, EraConfig(memory_bytes=64 << 10,
                                         build_impl="none"))
        sh = ix.build_sharded(s, n_shards=2, max_pattern_len=64)
        s_new = _appended(s, alpha, 900)
        sh2, arep = ix.append_sharded(sh, s_new)
        full = ix.build_sharded(s_new, n_shards=2, max_pattern_len=64)
        assert sh2.epoch == sh.epoch + 1
        p_a, f_a, e_a = sh2.flat_table()
        p_b, f_b, e_b = full.flat_table()
        assert p_a == p_b
        np.testing.assert_array_equal(f_a, f_b)
        np.testing.assert_array_equal(e_a, e_b)
        pats = [s_new[i:i + 7] for i in range(0, 128, 2)]
        for a, b in zip(full.find_batch(pats), sh2.find_batch(pats)):
            np.testing.assert_array_equal(a, b)

    def test_rejects_non_extension(self):
        s, alpha = dataset("dna", 4_000, seed=0)
        ix = EraIndexer(alpha, EraConfig(memory_bytes=64 << 10,
                                         build_impl="none"))
        dev = ix.build_device(s, max_pattern_len=64)
        mutated = _appended(s, alpha, 100)
        mutated[5] = (mutated[5] + 1) % (alpha.base - 1)  # not a prefix
        with pytest.raises(ValueError):
            ix.append_device(dev, mutated)
        with pytest.raises(ValueError):
            ix.append_device(dev, s)  # not strictly longer


class TestEpochPersistence:
    @pytest.mark.parametrize("pack", ["bytes", "dense"])
    def test_roundtrip(self, tmp_path, pack):
        s, alpha = dataset("dna", 6_000, seed=0)
        ix = EraIndexer(alpha, EraConfig(memory_bytes=64 << 10,
                                         build_impl="none"))
        dev = ix.build_device(s, max_pattern_len=64, packing=pack)
        dev2, _ = ix.append_device(dev, _appended(s, alpha, 200))
        assert dev2.epoch == 1
        path = str(tmp_path / f"idx_{pack}")
        dev2.save(path)
        assert DeviceIndex.load(path).epoch == 1

    @pytest.mark.parametrize("pack,legacy_meta", [
        ("bytes", 4),   # pre-append byte layout: 4 meta entries
        ("dense", 6),   # pre-append dense layout: 6 meta entries
    ])
    def test_legacy_archives_load_as_epoch_zero(self, tmp_path, pack,
                                                legacy_meta):
        s, alpha = dataset("dna", 6_000, seed=0)
        ix = EraIndexer(alpha, EraConfig(memory_bytes=64 << 10,
                                         build_impl="none"))
        dev = ix.build_device(s, max_pattern_len=64, packing=pack)
        blobs = dev.to_blobs()
        blobs["meta"] = blobs["meta"][:legacy_meta]
        path = str(tmp_path / "legacy.npz")
        np.savez_compressed(path, **blobs)
        assert DeviceIndex.load(path).epoch == 0


class TestServingSwap:
    def _server(self, dev):
        from repro.launch.serving import AsyncServer, ServeConfig
        return AsyncServer(dev, ServeConfig(pipeline=True, cache_size=256,
                                            max_batch=64))

    def test_epoch_change_flushes_caches(self):
        s, alpha = dataset("dna", 10_000, seed=0)
        ix = EraIndexer(alpha, EraConfig(memory_bytes=64 << 10,
                                         build_impl="none"))
        dev = ix.build_device(s, max_pattern_len=64)
        srv = self._server(dev)
        pats = [np.asarray(s[i:i + 8], np.int32) for i in range(100)]
        srv.serve(pats)
        assert sum(len(c) for c in srv.caches) > 0
        s_new = _appended(s, alpha, 300)
        dev2, _ = ix.append_device(dev, s_new)
        info = srv.update_index(dev2)
        assert info["flushed"] and info["epoch"] == 1
        assert sum(len(c) for c in srv.caches) == 0
        # post-swap results match a fresh server over a full rebuild
        full = ix.build_device(s_new, max_pattern_len=64)
        got = srv.serve(pats)
        want = self._server(full).serve(pats)
        for (a, _), (b, _) in zip(got, want):
            np.testing.assert_array_equal(a, b)
        # same-epoch swap keeps the (re-warmed) caches
        warm = sum(len(c) for c in srv.caches)
        assert warm > 0
        info2 = srv.update_index(dev2)
        assert not info2["flushed"]
        assert sum(len(c) for c in srv.caches) == warm

    def test_shard_count_change_rebuilds_caches(self):
        s, alpha = dataset("dna", 10_000, seed=0)
        ix = EraIndexer(alpha, EraConfig(memory_bytes=64 << 10,
                                         build_impl="none"))
        dev = ix.build_device(s, max_pattern_len=64)
        srv = self._server(dev)
        srv.serve([np.asarray(s[i:i + 8], np.int32) for i in range(32)])
        sh = ix.build_sharded(s, n_shards=2, max_pattern_len=64)
        info = srv.update_index(sh)
        assert info["flushed"] and info["shards"] == 2
        assert len(srv.caches) == 2 and srv.sharded


class TestPackStream:
    @pytest.mark.parametrize("name", ["dna", "protein", "byte"])
    @pytest.mark.parametrize("chunk", [1, 7, 4096])
    def test_bit_identical_to_pack_text(self, name, chunk):
        alpha = ALPHABETS[name]
        rng = np.random.default_rng(0)
        codes = rng.integers(0, alpha.terminal_code, size=3_333,
                             dtype=np.uint8)
        codes = np.concatenate([codes, [alpha.terminal_code]]).astype(np.uint8)
        ref = packing.pack_text(codes, alpha)
        got = packing.pack_text_stream(
            (codes[i:i + chunk] for i in range(0, codes.size, chunk)), alpha)
        np.testing.assert_array_equal(np.asarray(ref.words),
                                      np.asarray(got.words))
        assert int(ref.n_real) == int(got.n_real)
        assert (ref.bits, ref.terminal) == (got.bits, got.terminal)

    def test_rejects_unterminated(self):
        alpha = ALPHABETS["dna"]
        with pytest.raises(ValueError):
            packing.pack_text_stream([np.zeros(5, np.uint8)], alpha)
        with pytest.raises(ValueError):
            packing.pack_text_stream([], alpha)


class TestMigration:
    def test_byte_archive_migrates_to_dense(self, tmp_path):
        from repro.launch.warmstart import migrate_archive

        s, alpha = dataset("dna", 8_000, seed=0)
        ix = EraIndexer(alpha, EraConfig(memory_bytes=64 << 10,
                                         build_impl="none"))
        dev_b = ix.build_device(s, max_pattern_len=64, packing="bytes")
        dev_d = ix.build_device(s, max_pattern_len=64, packing="dense")
        path = str(tmp_path / "idx")
        dev_b.save(path)
        assert migrate_archive(path, chunk_symbols=1_000) is True
        assert migrate_archive(path) is False  # already dense: no-op
        mig = DeviceIndex.load(path)
        assert mig.packed
        np.testing.assert_array_equal(np.asarray(mig.s_text.words),
                                      np.asarray(dev_d.s_text.words))
        for f in INDEX_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(mig, f)), np.asarray(getattr(dev_d, f)),
                err_msg=f)
        pats = [s[i:i + 9] for i in range(0, 64, 2)]
        for a, b in zip(dev_b.find_batch(pats), mig.find_batch(pats)):
            np.testing.assert_array_equal(a, b)

    def test_migrate_archives_covers_shards(self, tmp_path):
        from repro.launch.warmstart import migrate_archives

        s, alpha = dataset("dna", 8_000, seed=0)
        ix = EraIndexer(alpha, EraConfig(memory_bytes=64 << 10,
                                         build_impl="none"))
        sh = ix.build_sharded(s, n_shards=2, max_pattern_len=64,
                              packing="bytes")
        base = str(tmp_path / "shidx")
        sh.save(base)
        done = migrate_archives(base)
        assert len(done) == 2
        from repro.core.fabric import ShardedIndex
        mig = ShardedIndex.load(base)
        assert all(d.packed for d in mig.shards)
        pats = [s[i:i + 7] for i in range(0, 64, 2)]
        for a, b in zip(sh.find_batch(pats), mig.find_batch(pats)):
            np.testing.assert_array_equal(a, b)
