"""ERA core correctness: paper worked example + oracle sweeps."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ref
from repro.core.alphabet import DNA, ENGLISH, PROTEIN
from repro.core.api import BuildReport, EraConfig, EraIndexer
from repro.core.build import build_numpy, build_parallel, build_scan, nodes_to_intervals
from repro.core.prepare import ElasticConfig, PrepareStats
from repro.core.vertical import (
    VerticalStats,
    group_prefixes,
    vertical_partition,
    vertical_partition_grouped,
)

PAPER_S = "TGGTGGTGGTGCGGTGATGGTGC"  # Figure 2


class TestPaperExample:
    """The worked example of §4.2.2 (Table 1, Example 2, Figure 4/5)."""

    def test_reference_lb_matches_paper(self):
        s = DNA.encode(PAPER_S)
        ell, b = ref.era_reference_lb(s, DNA.encode("TG", terminate=False))
        assert list(ell) == [14, 9, 20, 6, 17, 3, 0]
        sym = DNA.char_of
        decoded = [(sym(c1), sym(c2), off) for c1, c2, off in b]
        assert decoded == [("A", "C", 2), ("G", "$", 3), ("C", "G", 2),
                           ("G", "$", 6), ("C", "G", 5), ("C", "G", 8)]

    def test_prepare_matches_paper(self):
        """SubTreePrepare on T_TG reproduces Example 2's final trace."""
        from repro.core.prepare import subtree_prepare
        from repro.core.vertical import SubTreePrefix, VirtualTree

        s = DNA.encode(PAPER_S)
        p = DNA.encode("TG", terminate=False)
        pos = ref.prefix_positions(s, p)
        vt = VirtualTree(prefixes=[SubTreePrefix(
            symbols=tuple(int(x) for x in p), freq=len(pos), positions=pos)])
        s_pad = jnp.asarray(DNA.pad_string(s, extra=64))
        state = subtree_prepare(s_pad, vt, capacity=8,
                                cfg=ElasticConfig(r_budget_symbols=28, w_min=4, w_max=16))
        assert list(np.asarray(state.L)[:7]) == [14, 9, 20, 6, 17, 3, 0]
        assert list(np.asarray(state.b_off)[1:7]) == [2, 3, 2, 6, 5, 8]
        sym = DNA.char_of
        c1 = [sym(int(c)) for c in np.asarray(state.b_c1)[1:7]]
        c2 = [sym(int(c)) for c in np.asarray(state.b_c2)[1:7]]
        assert c1 == ["A", "G", "C", "G", "C", "C"]
        assert c2 == ["C", "$", "G", "$", "G", "G"]

    def test_paper_frequency_claims(self):
        """§4.1: f_TG = 7; extending TG gives f_TGA=1, f_TGC=2, f_TGG=4."""
        s = DNA.encode(PAPER_S)
        assert ref.prefix_frequency(s, DNA.encode("TG", terminate=False)) == 7
        assert ref.prefix_frequency(s, DNA.encode("TGA", terminate=False)) == 1
        assert ref.prefix_frequency(s, DNA.encode("TGC", terminate=False)) == 2
        assert ref.prefix_frequency(s, DNA.encode("TGG", terminate=False)) == 4
        assert ref.prefix_frequency(s, DNA.encode("TGT", terminate=False)) == 0


class TestVerticalPartitioning:
    @pytest.mark.parametrize("strategy", ["histogram", "positions"])
    def test_matches_bruteforce(self, strategy):
        s = DNA.random_string(300, seed=1)
        want = {p: f for p, f in ref.vertical_partition_ref(s, DNA.base, f_max=20)}
        got = vertical_partition(s, DNA.base, 20, strategy=strategy)
        got_map = {p.symbols: p.freq for p in got}
        assert got_map == want
        for p in got:  # position lists must be exact
            assert np.array_equal(p.positions,
                                  ref.prefix_positions(s, np.array(p.symbols, np.uint8)))

    def test_partition_covers_all_suffixes(self):
        s = PROTEIN.random_string(500, seed=2)
        parts = vertical_partition(s, PROTEIN.base, 30)
        assert sum(p.freq for p in parts) == len(s)

    def test_grouping_respects_budget_and_is_exhaustive(self):
        s = DNA.random_string(800, seed=3)
        parts = vertical_partition(s, DNA.base, 25)
        groups = group_prefixes(parts, 25)
        assert sum(len(g.prefixes) for g in groups) == len(parts)
        for g in groups:
            assert g.total_freq <= 25
        # FFD should beat one-group-per-prefix substantially
        assert len(groups) < len(parts)

    def test_strategies_agree(self):
        s = ENGLISH.random_string(400, seed=4)
        a = {p.symbols: p.freq for p in vertical_partition(s, ENGLISH.base, 15, strategy="histogram")}
        b = {p.symbols: p.freq for p in vertical_partition(s, ENGLISH.base, 15, strategy="positions")}
        assert a == b

    @pytest.mark.parametrize("alpha,n,fmax", [(DNA, 400, 18), (PROTEIN, 350, 25)])
    def test_histogram_kernel_path_identical(self, monkeypatch, alpha, n, fmax):
        """The kmer_histogram kernel counting pass must produce the exact
        same partition (prefixes, frequencies AND positions) as the host
        searchsorted path."""
        s = alpha.random_string(n, seed=n)
        monkeypatch.setenv("REPRO_KERNELS", "jnp")
        host = vertical_partition(s, alpha.base, fmax, strategy="histogram")
        monkeypatch.setenv("REPRO_KERNELS", "pallas")
        kern = vertical_partition(s, alpha.base, fmax, strategy="histogram")
        assert [(p.symbols, p.freq) for p in host] \
            == [(p.symbols, p.freq) for p in kern]
        for a, b in zip(host, kern):
            np.testing.assert_array_equal(a.positions, b.positions)
            np.testing.assert_array_equal(
                a.positions, ref.prefix_positions(s, np.array(a.symbols, np.uint8)))


class TestPrepare:
    @pytest.mark.parametrize("alpha,n,fmax,r", [
        (DNA, 400, 24, 64), (PROTEIN, 300, 16, 32), (ENGLISH, 350, 12, 256)])
    def test_lb_matches_oracle(self, alpha, n, fmax, r):
        s = alpha.random_string(n, seed=n)
        idx = EraIndexer(alpha, EraConfig(memory_bytes=fmax * 32, r_bytes=r,
                                          build_impl="none")).build(s)
        for prefix, st in list(idx.subtrees.items())[:20]:
            ell_ref, b_ref = ref.era_reference_lb(s, np.array(prefix, np.uint8))
            assert np.array_equal(st.ell, ell_ref), prefix
            got = [(int(st.b_c1[i]), int(st.b_c2[i]), int(st.b_off[i]))
                   for i in range(1, len(ell_ref))]
            assert got == b_ref, prefix

    def test_elastic_equals_static_results(self):
        """Elastic range changes I/O, never results (paper Fig. 9b ablation)."""
        s = DNA.random_string(600, seed=9)
        kw = dict(memory_bytes=2048, build_impl="none")
        ela = EraIndexer(DNA, EraConfig(r_bytes=128, elastic=True, **kw)).build(s)
        sta = EraIndexer(DNA, EraConfig(r_bytes=128, elastic=False, static_w=16, **kw)).build(s)
        assert set(ela.subtrees) == set(sta.subtrees)
        for p in ela.subtrees:
            assert np.array_equal(ela.subtrees[p].ell, sta.subtrees[p].ell)
            assert np.array_equal(ela.subtrees[p].b_off, sta.subtrees[p].b_off)

    def test_elastic_range_grows(self):
        s = DNA.random_string(2000, seed=5)
        stats = PrepareStats()
        rep = BuildReport(VerticalStats(), stats)
        EraIndexer(DNA, EraConfig(memory_bytes=8192, r_bytes=512,
                                  build_impl="none")).build(s, rep)
        # as areas resolve, later ranges must be >= earlier ones on average
        assert max(stats.ranges) > min(stats.ranges)
        assert stats.active_history[0] >= stats.active_history[-1]


class TestBuilders:
    @pytest.mark.parametrize("n,seed", [(30, 0), (80, 1), (200, 2)])
    def test_all_builders_match_interval_oracle(self, n, seed):
        s = DNA.random_string(n, seed=seed)
        sa = ref.suffix_array(s)
        lcp = ref.lcp_array(s, sa)
        b = lcp.astype(np.int32)
        b[0] = 0
        want = ref.tree_intervals(b, len(sa))
        assert nodes_to_intervals(build_numpy(sa.astype(np.int32), b, len(s))) == want
        assert nodes_to_intervals(
            build_scan(jnp.asarray(sa, jnp.int32), jnp.asarray(b), len(s))) == want
        assert nodes_to_intervals(
            build_parallel(jnp.asarray(sa, jnp.int32), jnp.asarray(b), len(s))) == want

    def test_internal_nodes_bounded_by_leaves(self):
        """Paper §4.1: #internal nodes == #leaves (bound used for Eq. 1)."""
        s = DNA.random_string(150, seed=3)
        idx = EraIndexer(DNA, EraConfig(memory_bytes=1024, build_impl="numpy")).build(s)
        for st in idx.subtrees.values():
            n_int = int(st.nodes.n_nodes) - int(st.nodes.n_leaves)
            assert n_int <= max(1, st.freq)


class TestEndToEnd:
    @pytest.mark.parametrize("alpha,n", [(DNA, 500), (PROTEIN, 400), (ENGLISH, 300)])
    def test_queries_match_bruteforce(self, alpha, n):
        s = alpha.random_string(n, seed=n + 7)
        idx = EraIndexer(alpha, EraConfig(memory_bytes=4096, r_bytes=128)).build(s)
        assert idx.n_leaves == len(s)
        rng = np.random.default_rng(0)
        for _ in range(15):
            m = int(rng.integers(1, 7))
            i = int(rng.integers(0, len(s) - m))
            pat = s[i : i + m]
            want = ref.occurrences(s, pat)
            assert np.array_equal(idx.find(pat), want)
            assert np.array_equal(idx.find_walk(pat), want)

    def test_absent_patterns(self):
        s = DNA.random_string(200, seed=11)
        idx = EraIndexer(DNA, EraConfig(memory_bytes=2048)).build(s)
        for q in range(8):
            pat = DNA.random_string(9, seed=500 + q)[:-1]
            assert np.array_equal(idx.find(pat), ref.occurrences(s, pat))

    def test_save_load_roundtrip(self, tmp_path):
        s = DNA.random_string(200, seed=13)
        idx = EraIndexer(DNA, EraConfig(memory_bytes=2048, build_impl="none")).build(s)
        p = str(tmp_path / "index.npz")
        idx.save(p)
        from repro.core.suffix_tree import SuffixTreeIndex
        idx2 = SuffixTreeIndex.load(p, DNA)
        assert set(idx2.subtrees) == set(idx.subtrees)
        pat = s[10:14]
        assert np.array_equal(idx2.find(pat), idx.find(pat))
