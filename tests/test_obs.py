"""Observability subsystem: overhead contract, exporters, correctness.

The obs layer's promises, in order of importance:

1. **Disabled mode is a no-op** — hot paths (serving loop, kernel
   dispatch) call ``span()``/``counter()`` unconditionally, so with the
   knobs off those must return shared null singletons and record nothing.
2. **Exporters round-trip** — span names/attributes survive both the
   Chrome ``trace_event`` export (and validate against the schema subset)
   and the JSONL export.
3. **Histograms are honest** — fixed-bucket percentiles land within a
   bucket's width of the numpy ground truth.
4. **Thread safety** — the async serving loop plus worker threads hammer
   one counter/histogram concurrently; totals must be exact.
"""

import json
import threading

import numpy as np
import pytest

from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    Metrics,
    NULL_INSTRUMENT,
    pow2_buckets,
)
from repro.obs.trace import NULL_SPAN, Tracer, validate_chrome_trace


# ---------------------------------------------------------------------------
# disabled-mode no-op guarantees
# ---------------------------------------------------------------------------

class TestDisabledMode:
    def test_disabled_span_is_shared_null(self):
        tr = Tracer(enabled=False)
        assert tr.span("a") is NULL_SPAN
        assert tr.span("b", rows=3) is NULL_SPAN

    def test_disabled_span_records_nothing(self):
        tr = Tracer(enabled=False)
        with tr.span("a", k=1) as sp:
            sp.set(more=2)
        tr.instant("point")
        tr.complete("c", 0, 100)
        assert tr.events() == []

    def test_disabled_registry_hands_out_null_instrument(self):
        m = Metrics(enabled=False)
        assert m.counter("c") is NULL_INSTRUMENT
        assert m.gauge("g") is NULL_INSTRUMENT
        assert m.histogram("h") is NULL_INSTRUMENT
        assert m.instruments() == []

    def test_null_instrument_absorbs_everything(self):
        n = NULL_INSTRUMENT
        n.inc()
        n.inc(5)
        n.dec()
        n.set(3)
        n.observe(1.5)
        assert n.value == 0.0 and n.count == 0 and n.sum == 0.0
        assert n.percentile(99) == 0.0

    def test_disabled_exports_are_empty(self):
        tr = Tracer(enabled=False)
        m = Metrics(enabled=False)
        chrome = tr.to_chrome()
        assert validate_chrome_trace(chrome) == []
        assert [e for e in chrome["traceEvents"] if e["ph"] != "M"] == []
        assert m.to_prometheus() == ""

    def test_env_knob_gates(self, monkeypatch):
        monkeypatch.setenv("REPRO_TRACE", "0")
        monkeypatch.setenv("REPRO_METRICS", "")
        assert Tracer().enabled is False
        assert Metrics().enabled is False
        monkeypatch.setenv("REPRO_TRACE", "1")
        monkeypatch.setenv("REPRO_METRICS", "1")
        assert Tracer().enabled is True
        assert Metrics().enabled is True


# ---------------------------------------------------------------------------
# spans: nesting, attributes, exporters
# ---------------------------------------------------------------------------

class TestTracer:
    def test_span_nesting_depths(self):
        tr = Tracer(enabled=True)
        with tr.span("outer"):
            with tr.span("middle"):
                with tr.span("inner"):
                    pass
        by_name = {e["name"]: e for e in tr.events()}
        assert by_name["outer"]["depth"] == 0
        assert by_name["middle"]["depth"] == 1
        assert by_name["inner"]["depth"] == 2

    def test_nesting_contains_child_interval(self):
        tr = Tracer(enabled=True)
        with tr.span("outer"):
            with tr.span("inner"):
                pass
        by_name = {e["name"]: e for e in tr.events()}
        o, i = by_name["outer"], by_name["inner"]
        assert o["ts_ns"] <= i["ts_ns"]
        assert i["ts_ns"] + i["dur_ns"] <= o["ts_ns"] + o["dur_ns"]

    def test_attribute_roundtrip_chrome(self):
        tr = Tracer(enabled=True)
        with tr.span("serve/pad_pack", rows=8, b_pad=16) as sp:
            sp.set(fill=np.float64(0.5), note="hi")
        chrome = tr.to_chrome()
        assert validate_chrome_trace(chrome) == []
        # the whole export must survive real json serialization
        evts = json.loads(json.dumps(chrome))["traceEvents"]
        (evt,) = [e for e in evts if e["name"] == "serve/pad_pack"]
        assert evt["ph"] == "X" and evt["cat"] == "serve"
        assert evt["args"] == {"rows": 8, "b_pad": 16, "fill": 0.5,
                               "note": "hi"}

    def test_attribute_roundtrip_jsonl(self):
        tr = Tracer(enabled=True)
        with tr.span("a", k=1):
            pass
        with tr.span("b", q=np.int32(7)):
            pass
        lines = tr.to_jsonl().strip().splitlines()
        objs = [json.loads(ln) for ln in lines]
        assert [o["name"] for o in objs] == ["a", "b"]
        assert objs[0]["args"] == {"k": 1}
        assert objs[1]["args"] == {"q": 7}  # numpy scalar degraded

    def test_complete_and_instant_events(self):
        tr = Tracer(enabled=True)
        origin = tr._t_origin
        tr.complete("serve/queue_wait", origin + 1000, 5000, rows=4)
        tr.instant("mark")
        evts = tr.events()
        assert evts[0]["ph"] == "X" and evts[0]["dur_ns"] == 5000
        assert evts[1]["ph"] == "i" and evts[1]["dur_ns"] == 0
        assert validate_chrome_trace(tr.to_chrome()) == []

    def test_ring_buffer_bounds_memory(self):
        tr = Tracer(capacity=8, enabled=True)
        for i in range(50):
            with tr.span(f"s{i}"):
                pass
        assert len(tr.events()) <= 8
        assert tr.n_dropped >= 42
        # the newest span is always retained
        assert tr.events()[-1]["name"] == "s49"

    def test_clear(self):
        tr = Tracer(enabled=True)
        with tr.span("x"):
            pass
        tr.clear()
        assert tr.events() == [] and tr.n_dropped == 0

    def test_validator_catches_garbage(self):
        assert validate_chrome_trace([]) != []
        assert validate_chrome_trace({"traceEvents": [{"ph": "X"}]}) != []
        bad_dur = {"traceEvents": [
            {"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 0}]}
        assert any("dur" in e for e in validate_chrome_trace(bad_dur))


# ---------------------------------------------------------------------------
# metrics: instruments, percentiles, exporters
# ---------------------------------------------------------------------------

class TestInstruments:
    def test_counter_monotonic(self):
        m = Metrics(enabled=True)
        c = m.counter("reqs_total")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_registration_is_idempotent(self):
        m = Metrics(enabled=True)
        a = m.counter("c", impl="pallas")
        b = m.counter("c", impl="pallas")
        other = m.counter("c", impl="ref")
        assert a is b and a is not other
        a.inc()
        assert b.value == 1 and other.value == 0

    def test_kind_conflict_raises(self):
        m = Metrics(enabled=True)
        m.counter("x")
        with pytest.raises(ValueError):
            m.gauge("x")

    def test_callback_gauge_and_rebind(self):
        m = Metrics(enabled=True)
        box = {"v": 1.0}
        g = m.gauge("depth", fn=lambda: box["v"])
        box["v"] = 7.0
        assert g.value == 7.0
        # newest callback wins on re-registration (fresh server instance)
        m.gauge("depth", fn=lambda: 42.0)
        assert g.value == 42.0

    def test_callback_gauge_exception_is_nan(self):
        m = Metrics(enabled=True)

        def boom():
            raise RuntimeError("gone")

        g = m.gauge("dead", fn=boom)
        assert np.isnan(g.value)
        snap = m.snapshot()
        assert snap["gauges"][0]["value"] is None  # JSON-safe

    def test_histogram_bucket_validation(self):
        with pytest.raises(ValueError):
            Histogram("h", buckets=(2.0, 1.0))
        with pytest.raises(ValueError):
            Histogram("h", buckets=())

    @pytest.mark.parametrize("q", [10, 25, 50, 75, 90, 99])
    def test_percentiles_vs_numpy(self, q):
        rng = np.random.default_rng(0)
        samples = rng.gamma(2.0, 5.0, size=5000)  # ms-ish latency shape
        h = Histogram("lat_ms", buckets=DEFAULT_BUCKETS)
        for v in samples:
            h.observe(v)
        got = h.percentile(q)
        truth = float(np.percentile(samples, q))
        # accuracy bound = the owning bucket's width
        bounds = (0.0,) + DEFAULT_BUCKETS
        i = int(np.searchsorted(DEFAULT_BUCKETS, truth))
        i = min(i, len(DEFAULT_BUCKETS) - 1)
        width = bounds[i + 1] - bounds[i]
        assert abs(got - truth) <= width

    def test_percentile_edge_cases(self):
        h = Histogram("h", buckets=(1.0, 2.0))
        assert np.isnan(h.percentile(50))  # empty
        h.observe(100.0)                   # +Inf bucket
        assert h.percentile(50) == 2.0     # clamps to last finite bound
        with pytest.raises(ValueError):
            h.percentile(101)

    def test_pow2_buckets(self):
        assert pow2_buckets(1, 16) == (1.0, 2.0, 4.0, 8.0, 16.0)
        assert pow2_buckets(1, 10) == (1.0, 2.0, 4.0, 8.0, 10.0)


class TestExporters:
    def _registry(self):
        m = Metrics(enabled=True)
        m.counter("reqs_total", help="total requests", impl="pallas").inc(3)
        m.gauge("depth_now").set(5)
        h = m.histogram("wait_ms", buckets=(1.0, 10.0), help="queue wait")
        h.observe(0.5)
        h.observe(4.0)
        h.observe(50.0)
        return m

    def test_snapshot_json(self):
        snap = json.loads(self._registry().to_json())
        (c,) = snap["counters"]
        assert c == {"name": "reqs_total", "labels": {"impl": "pallas"},
                     "value": 3.0}
        (h,) = snap["histograms"]
        assert h["count"] == 3 and h["sum"] == 54.5
        assert h["bucket_counts"] == [1, 1, 1]
        assert h["p50"] is not None and h["p99"] == 10.0

    def test_prometheus_text_format(self):
        text = self._registry().to_prometheus()
        assert "# HELP reqs_total total requests" in text
        assert "# TYPE reqs_total counter" in text
        assert 'reqs_total{impl="pallas"} 3.0' in text
        assert "# TYPE wait_ms histogram" in text
        # cumulative buckets, integer-formatted bounds, +Inf == _count
        assert 'wait_ms_bucket{le="1"} 1' in text
        assert 'wait_ms_bucket{le="10"} 2' in text
        assert 'wait_ms_bucket{le="+Inf"} 3' in text
        assert "wait_ms_sum 54.5" in text
        assert "wait_ms_count 3" in text
        assert text.endswith("\n")

    def test_prometheus_headers_once_per_name(self):
        m = Metrics(enabled=True)
        m.counter("c", help="h", impl="a").inc()
        m.counter("c", help="h", impl="b").inc()
        text = m.to_prometheus()
        assert text.count("# TYPE c counter") == 1


# ---------------------------------------------------------------------------
# thread safety
# ---------------------------------------------------------------------------

class TestThreadSafety:
    N_THREADS = 8
    N_OPS = 2000

    def test_concurrent_counter_exact(self):
        m = Metrics(enabled=True)

        def work():
            # re-fetch per call like real instrumentation sites do
            for _ in range(self.N_OPS):
                m.counter("hits_total").inc()

        threads = [threading.Thread(target=work)
                   for _ in range(self.N_THREADS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert m.counter("hits_total").value == self.N_THREADS * self.N_OPS

    def test_concurrent_histogram_exact(self):
        m = Metrics(enabled=True)
        h = m.histogram("obs_ms", buckets=(1.0, 2.0, 4.0))

        def work(seed):
            rng = np.random.default_rng(seed)
            for v in rng.uniform(0, 5, self.N_OPS):
                h.observe(float(v))

        threads = [threading.Thread(target=work, args=(i,))
                   for i in range(self.N_THREADS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        total = self.N_THREADS * self.N_OPS
        assert h.count == total
        assert sum(h.bucket_counts()) == total

    def test_concurrent_spans_all_recorded(self):
        tr = Tracer(capacity=1 << 16, enabled=True)

        def work(tid):
            for i in range(200):
                with tr.span(f"t{tid}/op", i=i):
                    pass

        threads = [threading.Thread(target=work, args=(i,))
                   for i in range(self.N_THREADS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        evts = tr.events()
        assert len(evts) == self.N_THREADS * 200
        # per-thread nesting depth stayed flat (thread-local depth)
        assert all(e["depth"] == 0 for e in evts)
        assert validate_chrome_trace(tr.to_chrome()) == []


# ---------------------------------------------------------------------------
# the global facade
# ---------------------------------------------------------------------------

class TestFacade:
    def test_configure_and_export_all(self, tmp_path):
        from repro import obs
        was_t, was_m = obs.trace_enabled(), obs.metrics_enabled()
        try:
            obs.configure(trace=True, metrics_on=True, clear=True)
            with obs.tracer().span("facade/x", a=1):
                pass
            obs.metrics().counter("facade_total").inc()
            tpath = str(tmp_path / "trace.json")
            mpath = str(tmp_path / "metrics.prom")
            written = obs.export_all(trace_path=tpath, metrics_path=mpath)
            assert written == [tpath, mpath]
            with open(tpath) as f:
                assert validate_chrome_trace(json.load(f)) == []
            with open(mpath) as f:
                assert "facade_total 1.0" in f.read()
        finally:
            obs.configure(trace=was_t, metrics_on=was_m, clear=True)

    def test_export_all_disabled_writes_nothing(self, tmp_path):
        from repro import obs
        was_t, was_m = obs.trace_enabled(), obs.metrics_enabled()
        try:
            obs.configure(trace=False, metrics_on=False)
            assert obs.export_all(
                trace_path=str(tmp_path / "t.json"),
                metrics_path=str(tmp_path / "m.prom")) == []
            assert list(tmp_path.iterdir()) == []
        finally:
            obs.configure(trace=was_t, metrics_on=was_m)
