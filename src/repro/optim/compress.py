"""Gradient compression for DP all-reduce with error feedback.

Per-tensor symmetric int8 quantization: each worker quantizes its local
gradient, the all-reduce runs on int8 payloads (8x less DP wire traffic),
and the quantization residual is carried into the next step (error
feedback — keeps convergence within noise of fp32 all-reduce for smooth
objectives).  Used by the explicit-DP (``shard_map``) training mode; with
GSPMD-automatic DP the all-reduce is implicit and compression is applied
pre-psum inside the shard_map body.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads: Any, err: Any):
    """Returns ((q_tree, scale_tree), new_err).

    The caller all-reduces ``q`` (mean of dequantized values) across DP;
    ``new_err`` holds what quantization dropped, added back next step.
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = quantize_int8(target)
        recon = dequantize_int8(q, scale)
        return (q, scale), target - recon

    leaves, treedef = jax.tree.flatten(grads)
    e_leaves = treedef.flatten_up_to(err)
    q_leaves, s_leaves, ne_leaves = [], [], []
    for g, e in zip(leaves, e_leaves):
        (q, s), ne = one(g, e)
        q_leaves.append(q)
        s_leaves.append(s)
        ne_leaves.append(ne)
    return (
        (treedef.unflatten(q_leaves), treedef.unflatten(s_leaves)),
        treedef.unflatten(ne_leaves),
    )


def decompress(qs: Any, scales: Any) -> Any:
    return jax.tree.map(dequantize_int8, qs, scales)


def psum_compressed(grads: Any, err: Any, axis_name: str):
    """shard_map-side compressed DP all-reduce (mean) with error feedback."""
    (qs, scales), new_err = compress_with_feedback(grads, err)
    deq = decompress(qs, scales)
    summed = jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), deq)
    return summed, new_err
