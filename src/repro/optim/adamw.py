"""AdamW + gradient clipping + schedules, from scratch (no optax).

Moments are kept in float32 regardless of parameter dtype (bf16-safe);
the update math runs in float32 and casts back.  State is a plain pytree
so it shards exactly like the parameters (moments inherit the param
PartitionSpecs) and checkpoints with the generic runtime.checkpoint.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array       # int32 scalar
    m: Any                # pytree like params (f32)
    v: Any                # pytree like params (f32)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | constant


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(1, cfg.warmup_steps))
    if cfg.schedule == "constant":
        return cfg.lr * warm
    t = jnp.clip((s - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    grads_f32, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads_f32)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
