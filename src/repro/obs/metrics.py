"""Metrics registry: counters, gauges, fixed-bucket histograms + exporters.

The :class:`Metrics` registry hands out three instrument kinds, each
addressed by ``(name, labels)`` — repeated registration returns the SAME
instrument (one dict lookup), so hot paths may either re-fetch per call
or bind once at init:

* :class:`Counter`   — monotonic float total (``inc``);
* :class:`Gauge`     — last-set value (``set``/``inc``/``dec``), or a
  *callback* gauge whose value is computed at snapshot time (wire a
  cache's ``hit_rate`` or a queue's ``len`` without polling);
* :class:`Histogram` — fixed upper-bound buckets with total sum/count;
  p50/p99 (any quantile) are derived host-side by linear interpolation
  inside the owning bucket.

Two exporters: :meth:`Metrics.snapshot` (plain JSON-able dict, histograms
carry derived p50/p99) and :meth:`Metrics.to_prometheus` (the Prometheus
text exposition format — counters get ``# TYPE``/``# HELP`` headers,
histograms expand to cumulative ``_bucket{le=...}`` series + ``_sum`` /
``_count``).

Thread safety: every mutation takes the instrument's lock (the async
serving loop and any worker threads may hammer one counter concurrently);
snapshots lock per instrument, so they are consistent per instrument and
lock-free across the registry.

Overhead contract: a disabled registry's ``counter``/``gauge``/
``histogram`` return the shared null instruments — one method call
returning a constant; ``inc``/``observe`` on them are empty methods.

Enable with ``REPRO_METRICS=1`` or via :func:`repro.obs.configure`.
"""

from __future__ import annotations

import json
import math
import os
import threading

# default histogram buckets: latency-ish spread in ms (callers pass their
# own for anything that is not a millisecond latency)
DEFAULT_BUCKETS = (0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
                   100.0, 250.0, 1000.0)


class _NullInstrument:
    """Shared no-op counter/gauge/histogram for the disabled registry."""

    __slots__ = ()

    def inc(self, n=1) -> None:
        pass

    def dec(self, n=1) -> None:
        pass

    def set(self, v) -> None:
        pass

    def observe(self, v) -> None:
        pass

    @property
    def value(self) -> float:
        return 0.0

    @property
    def count(self) -> int:
        return 0

    @property
    def sum(self) -> float:
        return 0.0

    def percentile(self, q) -> float:
        return 0.0


NULL_INSTRUMENT = _NullInstrument()


class Counter:
    """Monotonic total.  ``inc`` with a negative amount raises — use a
    Gauge for values that go down."""

    kind = "counter"
    __slots__ = ("name", "help", "labels", "_value", "_lock")

    def __init__(self, name: str, help: str = "", labels: dict | None = None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: inc({n}) must be >= 0")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Last-set value, or a zero-arg callback evaluated at snapshot time."""

    kind = "gauge"
    __slots__ = ("name", "help", "labels", "_value", "_fn", "_lock")

    def __init__(self, name: str, help: str = "", labels: dict | None = None,
                 fn=None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self._value = 0.0
        self._fn = fn
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1) -> None:
        self.inc(-n)

    @property
    def value(self) -> float:
        if self._fn is not None:
            try:
                return float(self._fn())
            except Exception:
                return float("nan")
        return self._value


class Histogram:
    """Fixed-bucket histogram: ``buckets`` are sorted upper bounds; one
    implicit +Inf bucket catches the tail.  Quantiles interpolate
    linearly inside the owning bucket (the +Inf bucket clamps to the last
    finite bound), so accuracy is the bucket resolution — pick buckets to
    match the scale you care about."""

    kind = "histogram"
    __slots__ = ("name", "help", "labels", "buckets", "_counts", "_sum",
                 "_count", "_lock")

    def __init__(self, name: str, buckets=DEFAULT_BUCKETS, help: str = "",
                 labels: dict | None = None):
        bounds = tuple(float(b) for b in buckets)
        if not bounds or list(bounds) != sorted(set(bounds)):
            raise ValueError(
                f"histogram {name}: buckets must be sorted, unique, "
                f"non-empty (got {buckets!r})")
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self.buckets = bounds
        self._counts = [0] * (len(bounds) + 1)  # [..., +Inf]
        self._sum = 0.0
        self._count = 0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        i = _bisect(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def bucket_counts(self) -> list[int]:
        with self._lock:
            return list(self._counts)

    def percentile(self, q: float) -> float:
        """The q-th percentile (q in [0, 100]), interpolated within the
        owning bucket.  Returns nan when nothing was observed."""
        if not 0 <= q <= 100:
            raise ValueError(f"q={q} must be in [0, 100]")
        with self._lock:
            counts = list(self._counts)
            total = self._count
        if total == 0:
            return float("nan")
        rank = q / 100.0 * total
        cum = 0
        for i, c in enumerate(counts):
            cum += c
            if cum >= rank and c > 0:
                lo = 0.0 if i == 0 else self.buckets[i - 1]
                hi = self.buckets[min(i, len(self.buckets) - 1)]
                if i >= len(self.buckets):
                    return self.buckets[-1]  # +Inf bucket clamps
                frac = (rank - (cum - c)) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        return self.buckets[-1]


def _bisect(bounds, v) -> int:
    lo, hi = 0, len(bounds)
    while lo < hi:
        mid = (lo + hi) // 2
        if v <= bounds[mid]:
            hi = mid
        else:
            lo = mid + 1
    return lo


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _escape(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


class Metrics:
    """The instrument registry.  ``enabled=None`` reads ``REPRO_METRICS``."""

    def __init__(self, enabled: bool | None = None):
        if enabled is None:
            enabled = os.environ.get("REPRO_METRICS", "0") not in ("", "0")
        self.enabled = bool(enabled)
        self._by_key: dict[tuple, object] = {}
        self._kinds: dict[str, str] = {}     # name -> kind (conflict guard)
        self._lock = threading.Lock()

    # ---- registration (idempotent; a dict lookup on repeat calls) ---------

    def _get(self, cls, name: str, help: str, labels: dict, **kwargs):
        if not self.enabled:
            return NULL_INSTRUMENT
        key = (name, _label_key(labels))
        inst = self._by_key.get(key)
        if inst is not None:
            if inst.kind != cls.kind:
                raise ValueError(
                    f"metric {name!r} already registered as a {inst.kind}, "
                    f"cannot re-register as a {cls.kind}")
            return inst
        with self._lock:
            inst = self._by_key.get(key)
            if inst is not None:
                if inst.kind != cls.kind:
                    raise ValueError(
                        f"metric {name!r} already registered as a "
                        f"{inst.kind}, cannot re-register as a {cls.kind}")
                return inst
            kind = self._kinds.get(name)
            if kind is not None and kind != cls.kind:
                raise ValueError(
                    f"metric {name!r} already registered as a {kind}, "
                    f"cannot re-register as a {cls.kind}")
            inst = cls(name, help=help, labels=labels, **kwargs)
            self._kinds[name] = cls.kind
            self._by_key[key] = inst
            return inst

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", fn=None, **labels) -> Gauge:
        g = self._get(Gauge, name, help, labels, fn=fn)
        if fn is not None and isinstance(g, Gauge):
            g._fn = fn  # re-registration rebinds the callback (newest wins)
        return g

    def histogram(self, name: str, buckets=DEFAULT_BUCKETS, help: str = "",
                  **labels) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def instruments(self) -> list:
        with self._lock:
            return list(self._by_key.values())

    def clear(self) -> None:
        with self._lock:
            self._by_key.clear()
            self._kinds.clear()

    # ---- exporters --------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-able view: per-instrument values, histograms with derived
        p50/p99 (the host-side percentile path the ISSUE asks for)."""
        out: dict[str, list] = {"counters": [], "gauges": [], "histograms": []}
        for inst in self.instruments():
            entry = {"name": inst.name, "labels": dict(inst.labels)}
            if inst.kind == "counter":
                entry["value"] = inst.value
                out["counters"].append(entry)
            elif inst.kind == "gauge":
                val = inst.value
                entry["value"] = None if math.isnan(val) else val
                out["gauges"].append(entry)
            else:
                entry.update(
                    count=inst.count, sum=inst.sum,
                    buckets=list(inst.buckets),
                    bucket_counts=inst.bucket_counts(),
                    p50=_nan_none(inst.percentile(50)),
                    p99=_nan_none(inst.percentile(99)),
                )
                out["histograms"].append(entry)
        return out

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), indent=2, sort_keys=True)

    def to_prometheus(self) -> str:
        """The Prometheus text exposition format (0.0.4)."""
        lines: list[str] = []
        seen_header: set[str] = set()
        for inst in sorted(self.instruments(),
                           key=lambda i: (i.name, _label_key(i.labels))):
            if inst.name not in seen_header:
                seen_header.add(inst.name)
                if inst.help:
                    lines.append(f"# HELP {inst.name} {inst.help}")
                lines.append(f"# TYPE {inst.name} {inst.kind}")
            if inst.kind in ("counter", "gauge"):
                val = inst.value
                if isinstance(val, float) and math.isnan(val):
                    val = "NaN"
                lines.append(f"{inst.name}{_label_str(inst.labels)} {val}")
            else:
                counts = inst.bucket_counts()
                cum = 0
                for bound, c in zip(inst.buckets, counts):
                    cum += c
                    labels = dict(inst.labels, le=_fmt_bound(bound))
                    lines.append(
                        f"{inst.name}_bucket{_label_str(labels)} {cum}")
                cum += counts[-1]
                labels = dict(inst.labels, le="+Inf")
                lines.append(f"{inst.name}_bucket{_label_str(labels)} {cum}")
                ls = _label_str(inst.labels)
                lines.append(f"{inst.name}_sum{ls} {inst.sum}")
                lines.append(f"{inst.name}_count{ls} {inst.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def write_prometheus(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_prometheus())
        return path

    def write_json(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path


def _fmt_bound(b: float) -> str:
    return str(int(b)) if float(b).is_integer() else repr(b)


def _nan_none(v: float):
    return None if math.isnan(v) else v


def pow2_buckets(lo: float, hi: float) -> tuple:
    """Power-of-two bucket bounds from lo to hi inclusive (queue depths,
    batch rows, elastic ranges — anything the code itself buckets pow2)."""
    out = []
    b = float(lo)
    while b < hi:
        out.append(b)
        b *= 2
    out.append(float(hi))
    return tuple(out)
