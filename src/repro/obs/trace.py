"""Flight-recorder tracing: nestable wall-clock spans in a ring buffer.

A :class:`Tracer` records *spans* — named wall-clock intervals with
arbitrary key/value attributes — into a lock-protected in-memory ring
buffer (a bounded ``deque``: the recorder never grows without bound, old
spans fall off the back).  Spans nest per thread: the exporters carry a
``depth`` per event and Chrome/Perfetto nests complete events on the same
thread track automatically, so the serving loop's ``serve/pump`` >
``serve/pad_pack`` > ``serve/device_dispatch`` hierarchy renders as a
flame graph with zero extra bookkeeping.

Two exporters:

* :meth:`Tracer.to_chrome` — the Chrome ``trace_event`` JSON object
  format (``{"traceEvents": [...]}``, ``ph="X"`` complete events with
  microsecond ``ts``/``dur``).  Load it at https://ui.perfetto.dev or
  ``chrome://tracing``.
* :meth:`Tracer.to_jsonl` — one plain JSON object per line, for ad-hoc
  ``jq``/pandas analysis without a trace viewer.

Overhead contract (the reason this module has no dependencies and no
clever features): when tracing is disabled every ``span()`` call returns
the shared :data:`NULL_SPAN` singleton after one attribute check — no
allocation, no clock read, no lock.  The enabled-path cost is two
``perf_counter_ns`` reads plus one locked ``deque.append`` per span.

Enable with ``REPRO_TRACE=1`` (the ``REPRO_SERVE_*`` env idiom) or
programmatically via :func:`repro.obs.configure`.
"""

from __future__ import annotations

import json
import os
import threading
import time


class _NullSpan:
    """Shared no-op span: the entire disabled-mode tracing surface."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs) -> None:
        pass


NULL_SPAN = _NullSpan()


class _Span:
    """One live span (context manager); records itself into the tracer
    ring buffer on exit.  ``set(**attrs)`` adds attributes mid-span."""

    __slots__ = ("_tracer", "name", "attrs", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        self._depth = self._tracer._push()
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter_ns() - self._t0
        self._tracer._pop()
        self._tracer._record(self.name, self._t0, dur, self._depth,
                             self.attrs)
        return False

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)


class Tracer:
    """Ring-buffered span recorder (thread-safe).

    ``capacity`` bounds the buffer (oldest spans drop first);
    ``enabled=None`` reads the ``REPRO_TRACE`` env knob.
    """

    def __init__(self, capacity: int = 1 << 16,
                 enabled: bool | None = None):
        if capacity < 1:
            raise ValueError(f"capacity={capacity} must be >= 1")
        if enabled is None:
            enabled = os.environ.get("REPRO_TRACE", "0") not in ("", "0")
        self.enabled = bool(enabled)
        self.capacity = capacity
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self._t_origin = time.perf_counter_ns()
        self.n_dropped = 0

    # ---- recording --------------------------------------------------------

    def span(self, name: str, **attrs):
        """A context manager timing ``name``; disabled -> :data:`NULL_SPAN`."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, attrs)

    def instant(self, name: str, **attrs) -> None:
        """A zero-duration point event (rendered as an arrow/mark)."""
        if not self.enabled:
            return
        self._record(name, time.perf_counter_ns(), 0,
                     getattr(self._local, "depth", 0), attrs, ph="i")

    def complete(self, name: str, t_start_ns: int, dur_ns: int,
                 **attrs) -> None:
        """Record an explicitly-timed span (e.g. a queue wait measured
        from a request's admission timestamp)."""
        if not self.enabled:
            return
        self._record(name, t_start_ns, dur_ns,
                     getattr(self._local, "depth", 0), attrs)

    def _push(self) -> int:
        depth = getattr(self._local, "depth", 0)
        self._local.depth = depth + 1
        return depth

    def _pop(self) -> None:
        self._local.depth = getattr(self._local, "depth", 1) - 1

    def _record(self, name, t0_ns, dur_ns, depth, attrs, ph="X") -> None:
        evt = {
            "name": name,
            "ph": ph,
            "ts_ns": t0_ns - self._t_origin,
            "dur_ns": dur_ns,
            "tid": threading.get_ident(),
            "depth": depth,
            "args": attrs,
        }
        with self._lock:
            if len(self._events) >= self.capacity:
                # ring semantics without deque: drop the oldest half in one
                # slice (amortized O(1) per append, keeps events ordered)
                drop = max(1, self.capacity // 2)
                del self._events[:drop]
                self.n_dropped += drop
            self._events.append(evt)

    # ---- inspection / export ----------------------------------------------

    def events(self) -> list[dict]:
        """A snapshot copy of the buffered events (oldest first)."""
        with self._lock:
            return [dict(e) for e in self._events]

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.n_dropped = 0

    def to_chrome(self) -> dict:
        """The Chrome ``trace_event`` object format (Perfetto-loadable).

        Spans carrying a ``shard`` attribute (the sharded index fabric
        stamps one on every per-shard dispatch) get that shard id as
        their ``pid``, so a multi-shard run renders as one process track
        per shard and traces from different shards merge side by side;
        everything else stays on the host process track.
        """
        pid = os.getpid()
        out = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                "args": {"name": "repro-era"}}]
        shard_pids: set[int] = set()
        for e in self.events():
            cat = e["name"].split("/", 1)[0]
            shard = e["args"].get("shard")
            if isinstance(shard, (int, float)) and not isinstance(shard, bool):
                evt_pid = int(shard)
                shard_pids.add(evt_pid)
            else:
                evt_pid = pid
            evt = {
                "name": e["name"],
                "cat": cat,
                "ph": e["ph"],
                "ts": e["ts_ns"] / 1e3,   # trace_event ts is microseconds
                "pid": evt_pid,
                "tid": e["tid"],
                "args": {k: _jsonable(v) for k, v in e["args"].items()},
            }
            if e["ph"] == "X":
                evt["dur"] = e["dur_ns"] / 1e3
            else:
                evt["s"] = "t"            # instant scope: thread
            out.append(evt)
        for k in sorted(shard_pids):
            out.insert(1, {"name": "process_name", "ph": "M", "pid": k,
                           "tid": 0, "args": {"name": f"repro-era shard {k}"}})
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def to_jsonl(self) -> str:
        """One JSON object per line: name, ts_ns, dur_ns, tid, depth, args."""
        lines = []
        for e in self.events():
            e = dict(e, args={k: _jsonable(v) for k, v in e["args"].items()})
            lines.append(json.dumps(e, sort_keys=True))
        return "\n".join(lines) + ("\n" if lines else "")

    def write_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path

    def write_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_jsonl())
        return path


def _jsonable(v):
    """Attributes must survive json.dumps; numpy scalars and other
    oddballs degrade to their Python/str forms rather than raising."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    for cast in (int, float):
        try:
            return cast(v)
        except (TypeError, ValueError):
            continue
    return str(v)


def validate_chrome_trace(obj) -> list[str]:
    """Validate an object against the ``trace_event`` JSON schema subset
    this module emits.  Returns a list of problems (empty = valid) so CI
    can print every violation instead of stopping at the first."""
    errors: list[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a 'traceEvents' list"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    for i, e in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            errors.append(f"{where}: not an object")
            continue
        for key in ("name", "ph", "pid", "tid"):
            if key not in e:
                errors.append(f"{where}: missing {key!r}")
        if not isinstance(e.get("name"), str):
            errors.append(f"{where}: name must be a string")
        ph = e.get("ph")
        if ph not in ("X", "B", "E", "i", "I", "M", "C"):
            errors.append(f"{where}: unknown phase {ph!r}")
        if ph == "M":
            continue
        if not isinstance(e.get("ts"), (int, float)) or e.get("ts", -1) < 0:
            errors.append(f"{where}: ts must be a number >= 0")
        if ph == "X" and (not isinstance(e.get("dur"), (int, float))
                          or e.get("dur", -1) < 0):
            errors.append(f"{where}: complete event needs dur >= 0")
        if "args" in e and not isinstance(e["args"], dict):
            errors.append(f"{where}: args must be an object")
    return errors
