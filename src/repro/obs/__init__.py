"""repro.obs — zero-dependency flight-recorder observability.

One process-global :class:`~repro.obs.trace.Tracer` and one
:class:`~repro.obs.metrics.Metrics` registry, both OFF by default and
gated on env knobs following the ``REPRO_SERVE_*`` idiom:

* ``REPRO_TRACE=1``    — record spans (ring buffer; Chrome/Perfetto +
  JSONL exporters).  ``REPRO_TRACE_OUT`` overrides the default export
  path (``era_trace.json``).
* ``REPRO_METRICS=1``  — record counters/gauges/histograms (JSON +
  Prometheus-text exporters).  ``REPRO_METRICS_OUT`` overrides the
  default export path (``era_metrics.prom``).

Overhead budget (the contract instrumented hot paths rely on): with the
knobs unset, ``tracer().span(...)`` is an attribute check returning the
shared null span and ``metrics().counter(...)`` returns the shared null
instrument — a dict-lookup-and-no-op ceiling, verified by
``tests/test_obs.py`` and the CI trace-smoke overhead gate.

Enablement is resolved when an instrument is CREATED: call
:func:`configure` (tests, smoke drivers) before building the objects you
want instrumented — instruments bound while a registry was disabled stay
null.  Processes driven purely by the env knobs never notice (the knobs
are fixed at startup).

Usage:

    from repro import obs
    with obs.tracer().span("serve/pad_pack", rows=8):
        ...
    obs.metrics().counter("serve_batches_total").inc()
    obs.export_all()          # writes trace + metrics files when enabled
"""

from __future__ import annotations

import os
import threading

from repro.obs.metrics import (  # noqa: F401  (re-exported)
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    Metrics,
    NULL_INSTRUMENT,
    pow2_buckets,
)
from repro.obs.trace import (  # noqa: F401  (re-exported)
    NULL_SPAN,
    Tracer,
    validate_chrome_trace,
)

_lock = threading.Lock()
_tracer: Tracer | None = None
_metrics: Metrics | None = None


def tracer() -> Tracer:
    """The process-global tracer (created on first use from the env)."""
    global _tracer
    if _tracer is None:
        with _lock:
            if _tracer is None:
                _tracer = Tracer()
    return _tracer


def metrics() -> Metrics:
    """The process-global metrics registry (created on first use)."""
    global _metrics
    if _metrics is None:
        with _lock:
            if _metrics is None:
                _metrics = Metrics()
    return _metrics


def trace_enabled() -> bool:
    return tracer().enabled


def metrics_enabled() -> bool:
    return metrics().enabled


def configure(trace: bool | None = None, metrics_on: bool | None = None,
              clear: bool = False) -> None:
    """Programmatic override of the env gating (tests / smoke drivers).

    ``trace`` / ``metrics_on``: True/False to force, None to leave as-is.
    ``clear`` drops recorded spans and registered instruments first.
    Instruments already bound by callers keep their old (possibly null)
    identity — flip BEFORE constructing what you want observed.
    """
    t, m = tracer(), metrics()
    if clear:
        t.clear()
        m.clear()
    if trace is not None:
        t.enabled = bool(trace)
    if metrics_on is not None:
        m.enabled = bool(metrics_on)


def export_all(trace_path: str | None = None,
               metrics_path: str | None = None) -> list[str]:
    """Write every enabled exporter's artifact; returns the paths written.

    Defaults honor ``REPRO_TRACE_OUT`` / ``REPRO_METRICS_OUT``; a
    disabled layer writes nothing (so drivers can call this
    unconditionally at exit).
    """
    written: list[str] = []
    t, m = tracer(), metrics()
    if t.enabled:
        path = trace_path or os.environ.get("REPRO_TRACE_OUT",
                                            "era_trace.json")
        written.append(t.write_chrome(path))
    if m.enabled:
        path = metrics_path or os.environ.get("REPRO_METRICS_OUT",
                                              "era_metrics.prom")
        written.append(m.write_prometheus(path))
    return written
