"""Selective state-space blocks: Mamba-1 (falcon-mamba) and a multi-head
scalar-decay Mamba-2 (zamba2's backbone).

Training runs the recurrence as a ``jax.lax.associative_scan`` over the
sequence axis (TPU-friendly: log-depth, matmul-free); decode is the O(1)
single-step update carrying ``(conv_state, ssm_state)`` — the reason the
SSM/hybrid archs are the ones that run ``long_500k``.

Mamba-2 here is the SSD simplification used for systems purposes: scalar
decay per head, shared B/C of width ``d_state`` — the tensor shapes and
arithmetic intensity match the published block; the exact SSD chunked
algorithm is an optimization alternative, not a different interface.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.nn import Spec


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, int(np.ceil(cfg.d_model / 16)))


def mamba1_specs(cfg: ModelConfig) -> dict:
    d, di, n, k = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv
    r = _dt_rank(cfg)
    return {
        "in_proj": Spec((d, 2 * di), ("embed", "inner")),
        "conv_w": Spec((k, di), (None, "inner")),
        "conv_b": Spec((di,), ("inner",), "zeros"),
        "x_proj": Spec((di, r + 2 * n), ("inner", None)),
        "dt_proj": Spec((r, di), (None, "inner")),
        "dt_bias": Spec((di,), ("inner",), "zeros"),
        "A_log": Spec((di, n), ("inner", None), "ones"),
        "D": Spec((di,), ("inner",), "ones"),
        "out_proj": Spec((di, d), ("inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d.  x: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad, w[:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return out + b


def _ssm_scan(a: jax.Array, b: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t along axis 1; returns all h_t."""

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def mamba1(p: dict, x: jax.Array, cfg: ModelConfig, state: tuple | None = None,
           return_state: bool = False):
    """x: (B,S,d).  state (decode): (conv_state (B,K-1,di), h (B,di,N)).

    Returns (y, new_state).  ``return_state=True`` in full-sequence mode
    extracts the final (conv, h) state — the SSM prefill path.
    """
    b, s, d = x.shape
    di, n, k = cfg.d_inner, cfg.d_state, cfg.d_conv
    r = _dt_rank(cfg)

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)

    if state is None:
        raw = xs
        if return_state:  # last K-1 pre-conv inputs feed future decode steps
            pad = jnp.zeros((b, max(0, (k - 1) - s), di), xs.dtype)
            new_conv = jnp.concatenate([pad, raw[:, -(k - 1):, :]], axis=1)
        else:
            new_conv = None
        xs = _causal_conv(xs, p["conv_w"], p["conv_b"])
    else:
        conv_state, h0 = state
        window = jnp.concatenate([conv_state, xs], axis=1)  # (B, K, di) for S=1
        xs = jnp.einsum("bkc,kc->bc", window[:, -k:], p["conv_w"])[:, None, :] + p["conv_b"]
        new_conv = window[:, -(k - 1):, :]
    xs = jax.nn.silu(xs)

    proj = jnp.einsum("bsc,ce->bse", xs, p["x_proj"])
    dt_r, bc, cc = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rc->bsc", dt_r, p["dt_proj"]) + p["dt_bias"])
    a_mat = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, N)

    decay = jnp.exp(dt[..., None].astype(jnp.float32) * a_mat)       # (B,S,di,N)
    drive = (dt[..., None] * bc[:, :, None, :] * xs[..., None]).astype(jnp.float32)

    if state is None:
        h = _ssm_scan(decay, drive)                                   # (B,S,di,N)
        new_h = h[:, -1] if return_state else None
    else:
        h = decay * h0[:, None] + drive
        new_h = h[:, 0]

    y = jnp.einsum("bsdn,bsn->bsd", h.astype(x.dtype), cc)
    y = y + p["D"] * xs
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"])
    new_state = None if new_h is None else (new_conv, new_h)
    return out, new_state


def mamba2_specs(cfg: ModelConfig) -> dict:
    d, di, n, k = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv
    nh = cfg.ssm_heads
    return {
        "in_proj": Spec((d, 2 * di), ("embed", "inner")),
        "conv_w": Spec((k, di), (None, "inner")),
        "conv_b": Spec((di,), ("inner",), "zeros"),
        "bc_proj": Spec((d, 2 * n), ("embed", None)),
        "dt_proj": Spec((d, nh), ("embed", None)),
        "dt_bias": Spec((nh,), (None,), "zeros"),
        "A_log": Spec((nh,), (None,), "ones"),
        "D": Spec((di,), ("inner",), "ones"),
        "out_proj": Spec((di, d), ("inner", "embed")),
    }


def mamba2(p: dict, x: jax.Array, cfg: ModelConfig, state: tuple | None = None,
           return_state: bool = False):
    """Multi-head scalar-decay SSD block.  state: (conv (B,K-1,di), h (B,NH,HD,N))."""
    b, s, d = x.shape
    di, n, k, nh = cfg.d_inner, cfg.d_state, cfg.d_conv, cfg.ssm_heads
    hd = di // nh

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)

    if state is None:
        raw = xs
        if return_state:
            pad = jnp.zeros((b, max(0, (k - 1) - s), di), xs.dtype)
            new_conv = jnp.concatenate([pad, raw[:, -(k - 1):, :]], axis=1)
        else:
            new_conv = None
        xs = _causal_conv(xs, p["conv_w"], p["conv_b"])
    else:
        conv_state, h0 = state
        window = jnp.concatenate([conv_state, xs], axis=1)
        xs = jnp.einsum("bkc,kc->bc", window[:, -k:], p["conv_w"])[:, None, :] + p["conv_b"]
        new_conv = window[:, -(k - 1):, :]
    xs = jax.nn.silu(xs)

    bc = jnp.einsum("bsd,dn->bsn", x, p["bc_proj"])
    b_in, c_out = jnp.split(bc, 2, axis=-1)                 # (B,S,N) each
    dt = jax.nn.softplus(jnp.einsum("bsd,dh->bsh", x, p["dt_proj"]) + p["dt_bias"])
    a = -jnp.exp(p["A_log"].astype(jnp.float32))            # (NH,)

    xh = xs.reshape(b, s, nh, hd)
    decay = jnp.exp(dt.astype(jnp.float32) * a)             # (B,S,NH)
    drive = (dt[..., None, None] * xh[..., None] * b_in[:, :, None, None, :])
    # (B,S,NH,HD,N)

    if state is None:
        h = _ssm_scan(decay[..., None, None], drive.astype(jnp.float32))
        new_h = h[:, -1] if return_state else None
    else:
        h = decay[..., None, None] * h0[:, None] + drive.astype(jnp.float32)
        new_h = h[:, 0]

    y = jnp.einsum("bshdn,bsn->bshd", h.astype(x.dtype), c_out).reshape(b, s, di)
    y = y + p["D"] * xs
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"])
    new_state = None if new_h is None else (new_conv, new_h)
    return out, new_state
