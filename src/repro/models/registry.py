"""Architecture registry: ``--arch <id>`` → ModelConfig, plus the per-cell
input specs (ShapeDtypeStruct stand-ins — no allocation) for the dry-run.
"""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import SHAPES, ModelConfig, ShapeConfig

ARCHS = {
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
    "qwen1.5-32b": "repro.configs.qwen1_5_32b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "internvl2-2b": "repro.configs.internvl2_2b",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[arch]).CONFIG


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch × shape) is a valid dry-run cell; reason if skipped.

    ``long_500k`` needs sub-quadratic attention: run for SSM/hybrid, skip
    for pure full-attention archs (incl. gemma3 — its global layers are
    full attention and its published context is 128k < 500k).  See
    DESIGN.md §Arch-applicability.
    """
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: long_500k skipped (DESIGN.md)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(shp):
        return jax.ShapeDtypeStruct(shp, i32)

    if shape.kind == "train":
        batch = {"tokens": tok((b, s)), "labels": tok((b, s))}
        if cfg.family == "encdec":
            # encoder frames + decoder tokens (frames len = seq len)
            batch = {
                "frontend": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), dtype),
                "tokens": tok((b, s)),
                "labels": tok((b, s)),
            }
        elif cfg.frontend:  # vlm: patches + text (labels cover full sequence)
            batch = {
                "frontend": jax.ShapeDtypeStruct((b, cfg.frontend_len, cfg.frontend_dim), dtype),
                "tokens": tok((b, s)),
                "labels": tok((b, cfg.frontend_len + s)),
            }
        return batch

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {
                "frontend": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), dtype),
                "tokens": tok((b, min(s, 1024))),  # decoder prompt
            }
        if cfg.frontend:
            return {
                "frontend": jax.ShapeDtypeStruct((b, cfg.frontend_len, cfg.frontend_dim), dtype),
                "tokens": tok((b, s - cfg.frontend_len)),
            }
        return {"tokens": tok((b, s))}

    # decode: one new token against a seq_len cache
    return {"tokens": tok((b, 1))}


def concrete_inputs(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                    dtype=jnp.float32) -> dict:
    """Small concrete batch for smoke tests (same structure as input_specs)."""
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, shape, dtype=dtype)
    out = {}
    for k, v in specs.items():
        if v.dtype in (jnp.int32, np.int32):
            out[k] = jnp.asarray(rng.integers(0, cfg.vocab, size=v.shape, dtype=np.int32))
        else:
            out[k] = jnp.asarray(rng.normal(size=v.shape), dtype=dtype)
    return out
