"""Model / shape configuration for the assigned architecture zoo."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | ssm | hybrid | moe | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads

    # attention flavor
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0      # 0 = full attention
    global_every: int = 0        # gemma3: layer is global iff (i+1) % global_every == 0

    # MLA (deepseek-v2)
    mla: bool = False
    kv_lora: int = 0
    q_lora: int = 0
    rope_dims: int = 64

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0      # leading layers with dense FFN (deepseek: 1)
    capacity_factor: float = 1.25

    # SSM
    ssm: str = ""                # "" | mamba1 | mamba2
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    ssm_heads: int = 8           # mamba2 head count
    attn_every: int = 0          # zamba2: shared attn block every k layers

    # enc-dec
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # modality frontend stubs ([audio]/[vlm]: precomputed embeddings)
    frontend: str = ""           # "" | patches | frames
    frontend_len: int = 0
    frontend_dim: int = 0

    tie_embeddings: bool = True
    norm_eps: float = 1e-6

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid decode state is O(1) or
        sequence-shardable)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch decodes (seamless via its decoder)

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: shared + top_k experts)."""
        return _param_count(self, active_only=True)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d = cfg.d_model
    hd = cfg.head_dim
    n = 0

    def attn_params() -> int:
        if cfg.mla:
            kv_in = cfg.kv_lora
            p = d * (cfg.q_lora or d) // (d if not cfg.q_lora else 1)
            q = (cfg.q_lora * cfg.n_heads * hd + d * cfg.q_lora) if cfg.q_lora else d * cfg.n_heads * hd
            k = d * cfg.kv_lora + cfg.kv_lora * cfg.n_heads * hd * 2  # k_nope + v up-proj
            r = d * cfg.rope_dims
            o = cfg.n_heads * hd * d
            return q + k + r + o
        qkv = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads)
        return qkv + cfg.n_heads * hd * d

    def mlp_params(dff: int) -> int:
        return 3 * d * dff

    def ssm_params() -> int:
        di = cfg.d_inner
        return 2 * d * di + di * d + di * (cfg.d_conv + 2 * cfg.d_state + 2) + di

    if cfg.family in ("dense", "vlm"):
        n += cfg.n_layers * (attn_params() + mlp_params(cfg.d_ff))
    elif cfg.family == "moe":
        dense = cfg.n_dense_layers
        moe_layers = cfg.n_layers - dense
        n += cfg.n_layers * attn_params() + dense * mlp_params(cfg.d_ff)
        dffe = cfg.d_ff_expert or cfg.d_ff
        shared = cfg.n_shared_experts * mlp_params(dffe)
        routed = cfg.top_k if active_only else cfg.n_experts
        n += moe_layers * (shared + routed * mlp_params(dffe) + d * cfg.n_experts)
    elif cfg.family == "ssm":
        n += cfg.n_layers * ssm_params()
    elif cfg.family == "hybrid":
        n += cfg.n_layers * ssm_params()
        if cfg.attn_every:
            n += attn_params() + mlp_params(cfg.d_ff)  # ONE shared block
    elif cfg.family == "encdec":
        n += cfg.n_enc_layers * (attn_params() + mlp_params(cfg.d_ff))
        n += cfg.n_dec_layers * (2 * attn_params() + mlp_params(cfg.d_ff))
    n += cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    return n


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        n_layers=4 if cfg.attn_every else max(2, min(3, cfg.n_layers)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=16,
        d_ff=128,
        vocab=256,
        kv_lora=32 if cfg.mla else 0,
        q_lora=32 if cfg.q_lora else 0,
        rope_dims=8 if cfg.mla else cfg.rope_dims,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        d_ff_expert=64 if cfg.d_ff_expert else 0,
        n_dense_layers=min(cfg.n_dense_layers, 1),
        d_state=min(cfg.d_state, 8),
        ssm_heads=2 if cfg.ssm == "mamba2" else cfg.ssm_heads,
        attn_every=2 if cfg.attn_every else 0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        n_dec_layers=2 if cfg.n_dec_layers else 0,
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else 0,
        global_every=min(cfg.global_every, 2) if cfg.global_every else 0,
        frontend_len=4 if cfg.frontend else 0,
        frontend_dim=32 if cfg.frontend else 0,
    )
