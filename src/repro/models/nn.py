"""Parameter-spec system + neural layers (pure JAX, no flax).

Every parameter is declared once as a :class:`Spec` carrying its shape AND
its logical sharding axes — a single source of truth consumed both by
``init_params`` (real or abstract init via ``jax.eval_shape``) and by
``repro.sharding`` (logical axes → mesh ``PartitionSpec``).

Layers are pure functions ``f(params_dict, inputs, cfg, ...)``.  Layer
stacks are homogeneous pytrees with a leading ``layers`` axis consumed by
``jax.lax.scan``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# Spec system
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Spec:
    shape: tuple
    axes: tuple  # logical axis names (len == len(shape)); None = replicated
    init: str = "normal"  # normal | zeros | ones
    scale: float | None = None  # None -> 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stack_specs(tree: Any, n: int) -> Any:
    """Prepend a ``layers`` dimension to every Spec (for lax.scan stacks)."""
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        tree,
        is_leaf=lambda x: isinstance(x, Spec),
    )


def init_params(rng: jax.Array, tree: Any, dtype=jnp.float32) -> Any:
    """Materialize a Spec tree into arrays (deterministic per-path folds)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=lambda x: isinstance(x, Spec))

    def make(i, s: Spec):
        if s.init == "zeros":
            return jnp.zeros(s.shape, dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, dtype)
        fan_in = s.shape[-2] if len(s.shape) >= 2 else max(1, s.shape[-1])
        scale = s.scale if s.scale is not None else 1.0 / np.sqrt(fan_in)
        k = jax.random.fold_in(rng, i)
        return (jax.random.normal(k, s.shape, jnp.float32) * scale).astype(dtype)

    return jax.tree.unflatten(treedef, [make(i, s) for i, s in enumerate(leaves)])


def axes_tree(tree: Any) -> Any:
    """The logical-axes pytree matching ``init_params`` output."""
    return jax.tree.map(
        lambda s: s.axes, tree, is_leaf=lambda x: isinstance(x, Spec)
    )


# ---------------------------------------------------------------------------
# Elementary ops
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def rope_tables(positions: jax.Array, dim: int, theta: float):
    """cos/sin tables: positions (…,) -> (…, dim//2)."""
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D//2) or (S, D//2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    while cos.ndim < x1.ndim:  # broadcast over heads
        cos = cos[..., None, :]
        sin = sin[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def causal_mask(q_pos: jax.Array, k_pos: jax.Array, window: jax.Array | int,
                is_global: jax.Array | bool = True) -> jax.Array:
    """(…, Sq, Sk) boolean mask.  ``window`` <= 0 or ``is_global`` = full
    causal; else sliding-window causal."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    causal = diff >= 0
    win = jnp.asarray(window)
    use_window = jnp.logical_and(win > 0, jnp.logical_not(jnp.asarray(is_global)))
    windowed = jnp.logical_and(causal, diff < jnp.maximum(win, 1))
    return jnp.where(use_window, windowed, causal)


def _sdpa(q, k, v, mask, *, kv_groups: int) -> jax.Array:
    """q: (B,Sq,H,D); k/v: (B,Sk,KV,D); H = KV * kv_groups.

    GQA is computed in grouped form without materializing repeated K/V.
    """
    b, sq, h, d = q.shape
    kv = k.shape[2]
    qg = q.reshape(b, sq, kv, kv_groups, d)
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k) * scale
    logits = logits.astype(jnp.float32)
    neg = jnp.finfo(jnp.float32).min
    while mask.ndim < logits.ndim:  # (…,Sq,Sk) -> (B,KV,G,Sq,Sk)
        mask = mask[None]
    logits = jnp.where(mask, logits, neg)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, sq, h, d)


# ---------------------------------------------------------------------------
# Attention (GQA + qk-norm + bias + sliding window; KV cache)
# ---------------------------------------------------------------------------

def attention_specs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        "wq": Spec((d, h, hd), ("embed", "heads", "head")),
        "wk": Spec((d, kv, hd), ("embed", "kv_heads", "head")),
        "wv": Spec((d, kv, hd), ("embed", "kv_heads", "head")),
        "wo": Spec((h, hd, d), ("heads", "head", "embed")),
    }
    if cfg.qkv_bias and not cross:
        s["bq"] = Spec((h, hd), ("heads", "head"), "zeros")
        s["bk"] = Spec((kv, hd), ("kv_heads", "head"), "zeros")
        s["bv"] = Spec((kv, hd), ("kv_heads", "head"), "zeros")
    if cfg.qk_norm and not cross:
        s["q_norm"] = Spec((hd,), (None,), "zeros")
        s["k_norm"] = Spec((hd,), (None,), "zeros")
    return s


def attention(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    q_pos: jax.Array,           # (B, Sq) absolute positions
    window: jax.Array | int = 0,
    is_global: jax.Array | bool = True,
    cache: tuple | None = None,  # (k_cache, v_cache) (B, S_max, KV, hd)
    cache_index: jax.Array | None = None,  # scalar write position
    kv_source: jax.Array | None = None,    # cross-attention memory (B, Sk, d)
    bidirectional: bool = False,
):
    """Returns (y, new_cache)."""
    b, sq, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = x if kv_source is None else kv_source

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", src, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", src, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if kv_source is None:  # rope only for self-attention
        cos_q, sin_q = rope_tables(q_pos, hd, cfg.rope_theta)
        q = apply_rope(q, cos_q, sin_q)
        k_pos_new = q_pos
        cos_k, sin_k = rope_tables(k_pos_new, hd, cfg.rope_theta)
        k = apply_rope(k, cos_k, sin_k)

    if cache is not None:
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, cache_index, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, cache_index, 0, 0))
        k, v = k_cache, v_cache
        k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)[None, :]
        valid = k_pos <= (cache_index + sq - 1)
        mask = causal_mask(q_pos, k_pos, window, is_global) & valid[:, None, :]
        new_cache = (k_cache, v_cache)
    else:
        k_pos = q_pos
        if bidirectional or kv_source is not None:
            mask = jnp.ones((b, sq, k.shape[1]), bool)
        else:
            mask = causal_mask(q_pos, k_pos, window, is_global)
        new_cache = None

    # mask: (B, Sq, Sk) -> (B, 1, 1, Sq, Sk) broadcasting over (KV, G)
    out = _sdpa(q, k, v, mask[:, None, None, :, :], kv_groups=h // kvh)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_specs(cfg: ModelConfig) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ql, kvl, rd = cfg.q_lora, cfg.kv_lora, cfg.rope_dims
    s = {
        "w_dkv": Spec((d, kvl), ("embed", "kv_lora")),
        "kv_norm": Spec((kvl,), (None,), "zeros"),
        "w_uk": Spec((kvl, h, hd), ("kv_lora", "heads", "head")),
        "w_uv": Spec((kvl, h, hd), ("kv_lora", "heads", "head")),
        "w_kr": Spec((d, rd), ("embed", None)),
        "wo": Spec((h, hd, d), ("heads", "head", "embed")),
    }
    if ql:
        s["w_dq"] = Spec((d, ql), ("embed", None))
        s["q_norm"] = Spec((ql,), (None,), "zeros")
        s["w_uq"] = Spec((ql, h, hd), (None, "heads", "head"))
        s["w_uqr"] = Spec((ql, h, rd), (None, "heads", None))
    else:
        s["w_uq"] = Spec((d, h, hd), ("embed", "heads", "head"))
        s["w_uqr"] = Spec((d, h, rd), ("embed", "heads", None))
    return s


def mla_attention(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    q_pos: jax.Array,
    cache: tuple | None = None,   # (c_kv (B,S,kvl), k_rope (B,S,rd))
    cache_index: jax.Array | None = None,
):
    b, sq, d = x.shape
    h, hd, rd = cfg.n_heads, cfg.head_dim, cfg.rope_dims

    if cfg.q_lora:
        cq = rms_norm(jnp.einsum("bsd,dq->bsq", x, p["w_dq"]), p["q_norm"], cfg.norm_eps)
    else:
        cq = x
    q_nope = jnp.einsum("bsq,qhk->bshk", cq, p["w_uq"])
    q_rope = jnp.einsum("bsq,qhr->bshr", cq, p["w_uqr"])
    cos, sin = rope_tables(q_pos, rd, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    c_kv = rms_norm(jnp.einsum("bsd,dl->bsl", x, p["w_dkv"]), p["kv_norm"], cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_kr"])
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]

    if cache is not None:
        ckv_cache, kr_cache = cache
        ckv_cache = jax.lax.dynamic_update_slice(ckv_cache, c_kv.astype(ckv_cache.dtype), (0, cache_index, 0))
        kr_cache = jax.lax.dynamic_update_slice(kr_cache, k_rope.astype(kr_cache.dtype), (0, cache_index, 0))
        c_kv, k_rope = ckv_cache, kr_cache
        k_pos = jnp.arange(c_kv.shape[1], dtype=jnp.int32)[None, :]
        valid = k_pos <= (cache_index + sq - 1)
        mask = causal_mask(q_pos, k_pos, 0, True) & valid[:, None, :]
        new_cache = (ckv_cache, kr_cache)
    else:
        k_pos = q_pos
        mask = causal_mask(q_pos, k_pos, 0, True)
        new_cache = None

    # up-project cached latents (the naive/faithful path; the absorbed-matmul
    # variant is a §Perf hillclimb change)
    k_nope = jnp.einsum("btl,lhk->bthk", c_kv, p["w_uk"])
    v = jnp.einsum("btl,lhk->bthk", c_kv, p["w_uv"])

    scale = 1.0 / np.sqrt(hd + rd)
    logits = (
        jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
        + jnp.einsum("bshr,btr->bhst", q_rope, k_rope)
    ) * scale
    logits = logits.astype(jnp.float32)
    neg = jnp.finfo(jnp.float32).min
    logits = jnp.where(mask[:, None, :, :], logits, neg)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthk->bshk", probs, v)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLPs — dense SwiGLU and top-k routed MoE (capacity-based, EP-shardable)
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "w_gate": Spec((d, f), ("embed", "mlp")),
        "w_up": Spec((d, f), ("embed", "mlp")),
        "w_down": Spec((f, d), ("mlp", "embed")),
    }


def mlp(p: dict, x: jax.Array) -> jax.Array:
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    return jnp.einsum("bsf,fd->bsd", g * u, p["w_down"])


def moe_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    e = cfg.n_experts
    fe = cfg.d_ff_expert or cfg.d_ff
    s = {
        "router": Spec((d, e), ("embed", None)),
        "w_gate": Spec((e, d, fe), ("experts", "embed", "mlp")),
        "w_up": Spec((e, d, fe), ("experts", "embed", "mlp")),
        "w_down": Spec((e, fe, d), ("experts", "mlp", "embed")),
    }
    if cfg.n_shared_experts:
        s["shared"] = mlp_specs(cfg, d_ff=fe * cfg.n_shared_experts)
    return s


def moe(p: dict, x: jax.Array, cfg: ModelConfig):
    """Top-k routed MoE with fixed expert capacity (sort-free scatter).

    Returns (y, aux_loss).  Expert weights carry the ``experts`` logical
    axis → EP sharding over the ``model`` mesh axis; the token permute
    becomes an all-to-all under GSPMD.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, ids = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(ids[:, 0], e), axis=0)
    router_mean = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * router_mean)

    cap = int(np.ceil(t * k / e * cfg.capacity_factor))
    cap = max(cap, 4)

    flat_ids = ids.reshape(-1)                      # (T*k,)
    flat_gate = gate_vals.reshape(-1)
    # rank of each assignment within its expert (capacity slot)
    onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)       # (T*k, E)
    ranks = (jnp.cumsum(onehot, axis=0) - onehot) * onehot      # (T*k, E)
    slot = jnp.sum(ranks, axis=-1)                              # (T*k,)
    keep = slot < cap
    token_of = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    # scatter tokens into (E, cap, d)
    buf = jnp.zeros((e, cap, d), xt.dtype)
    eids = jnp.where(keep, flat_ids, 0)
    slts = jnp.where(keep, slot, 0)
    contrib = jnp.where(keep[:, None], xt[token_of], 0)
    buf = buf.at[eids, slts].add(contrib)

    # expert FFNs (grouped einsum — EP shards the leading E axis)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y_e = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])

    # gather back
    out_flat = y_e[eids, slts]                                  # (T*k, d)
    out_flat = jnp.where(keep[:, None], out_flat, 0) * flat_gate[:, None].astype(xt.dtype)
    y = jnp.zeros_like(xt).at[token_of].add(out_flat)

    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], x).reshape(t, d)
    return y.reshape(b, s, d), aux
