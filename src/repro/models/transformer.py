"""Model assembly for the assigned architecture zoo.

Every family is assembled from the layers in ``nn.py`` / ``ssm.py`` with
``jax.lax.scan`` over stacked per-layer parameters (compact HLO — critical
for 512-device dry-run compiles), ``jax.checkpoint`` around the layer body
in training mode, and explicit cache pytrees for decode.

Entry points (all pure functions of (params, batch) given a config):

* ``model_specs(cfg)``       — the parameter Spec tree (single source of
                               truth for shapes AND logical sharding axes)
* ``forward_train``          — full-sequence logits (+ MoE aux loss)
* ``forward_prefill``        — logits for the last position + filled cache
* ``forward_decode``         — one-token step against the cache
* ``init_cache(cfg, B, S)``  — abstract-friendly cache construction
"""

from __future__ import annotations

import contextlib
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import nn, ssm
from repro.models.config import ModelConfig
from repro.models.nn import Spec

# ---------------------------------------------------------------------------
# Layer-stack scan with optional full unrolling.
#
# XLA's cost_analysis counts a while-loop body ONCE regardless of trip
# count; the roofline pipeline therefore compiles reduced-depth model
# variants fully unrolled (straight-line HLO, exact costs) and extrapolates
# Q(L) = b + a·L to full depth.  Production lowering keeps the scan.
# ---------------------------------------------------------------------------

_UNROLL = False


@contextlib.contextmanager
def unrolled_layers():
    """Trace layer stacks unrolled (for exact cost_analysis); not for
    production compiles — HLO size grows linearly with depth."""
    global _UNROLL
    old = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = old


def _scan(f, init, xs):
    n = jax.tree.leaves(xs)[0].shape[0]
    return jax.lax.scan(f, init, xs, unroll=n if _UNROLL else 1)


# ---------------------------------------------------------------------------
# Optional activation-sharding constraint (sequence parallelism).
#
# For archs whose head count does not divide the model axis (qwen3-14b /
# qwen1.5-32b: 40 heads on 16), TP cannot shard attention and GSPMD falls
# back to replicated compute with giant logits all-reduces (§Perf cell A).
# Constraining activations to (batch→data, seq→model) shards the S² work
# 16-way instead; K/V get a cheap per-layer all-gather.
# ---------------------------------------------------------------------------

_ACT_SPEC = None


@contextlib.contextmanager
def activation_sharding(spec):
    """spec: PartitionSpec for (B, S, D) activations, or None."""
    global _ACT_SPEC
    old = _ACT_SPEC
    _ACT_SPEC = spec
    try:
        yield
    finally:
        _ACT_SPEC = old


def _constrain(x):
    if _ACT_SPEC is not None and x.ndim == 3:
        return jax.lax.with_sharding_constraint(x, _ACT_SPEC)
    return x


# ---------------------------------------------------------------------------
# Spec assembly
# ---------------------------------------------------------------------------

def _ln(cfg: ModelConfig) -> Spec:
    return Spec((cfg.d_model,), (None,), "zeros")


def _dense_block_specs(cfg: ModelConfig, cross: bool = False) -> dict:
    s = {"ln1": _ln(cfg), "attn": nn.attention_specs(cfg), "ln2": _ln(cfg),
         "mlp": nn.mlp_specs(cfg)}
    if cross:
        s["lnx"] = _ln(cfg)
        s["xattn"] = nn.attention_specs(cfg, cross=True)
    return s


def _moe_block_specs(cfg: ModelConfig) -> dict:
    attn = nn.mla_specs(cfg) if cfg.mla else nn.attention_specs(cfg)
    return {"ln1": _ln(cfg), "attn": attn, "ln2": _ln(cfg), "moe": nn.moe_specs(cfg)}


def _mamba_block_specs(cfg: ModelConfig) -> dict:
    mk = ssm.mamba2_specs if cfg.ssm == "mamba2" else ssm.mamba1_specs
    return {"ln": _ln(cfg), "ssm": mk(cfg)}


def model_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    specs: dict[str, Any] = {
        "embed": Spec((cfg.vocab, d), ("vocab", "embed"), scale=1.0),
        "final_norm": _ln(cfg),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = Spec((d, cfg.vocab), ("embed", "vocab"))
    if cfg.frontend:
        specs["frontend_proj"] = Spec((cfg.frontend_dim, d), (None, "embed"))

    fam = cfg.family
    if fam in ("dense", "vlm"):
        specs["layers"] = nn.stack_specs(_dense_block_specs(cfg), cfg.n_layers)
    elif fam == "moe":
        n_moe = cfg.n_layers - cfg.n_dense_layers
        if cfg.n_dense_layers:
            dense = {"ln1": _ln(cfg), "ln2": _ln(cfg), "mlp": nn.mlp_specs(cfg),
                     "attn": nn.mla_specs(cfg) if cfg.mla else nn.attention_specs(cfg)}
            specs["dense_layers"] = nn.stack_specs(dense, cfg.n_dense_layers)
        specs["layers"] = nn.stack_specs(_moe_block_specs(cfg), n_moe)
    elif fam == "ssm":
        specs["layers"] = nn.stack_specs(_mamba_block_specs(cfg), cfg.n_layers)
    elif fam == "hybrid":
        assert cfg.attn_every and cfg.n_layers % cfg.attn_every == 0
        specs["layers"] = nn.stack_specs(_mamba_block_specs(cfg), cfg.n_layers)
        specs["shared_attn"] = _dense_block_specs(cfg)  # ONE shared block
    elif fam == "encdec":
        specs["enc_layers"] = nn.stack_specs(_dense_block_specs(cfg), cfg.n_enc_layers)
        specs["dec_layers"] = nn.stack_specs(
            _dense_block_specs(cfg, cross=True), cfg.n_dec_layers)
    else:
        raise ValueError(fam)
    return specs


def init_params(rng: jax.Array, cfg: ModelConfig, dtype=jnp.float32):
    return nn.init_params(rng, model_specs(cfg), dtype)


def param_logical_axes(cfg: ModelConfig):
    return nn.axes_tree(model_specs(cfg))


# ---------------------------------------------------------------------------
# Blocks (forward)
# ---------------------------------------------------------------------------

def _dense_block(p, x, cfg: ModelConfig, *, q_pos, window, is_global,
                 cache=None, cache_index=None, enc_out=None, bidirectional=False):
    x = _constrain(x)
    h, kv = nn.attention(
        p["attn"], nn.rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
        q_pos=q_pos, window=window, is_global=is_global,
        cache=cache, cache_index=cache_index, bidirectional=bidirectional,
    )
    x = x + h
    if enc_out is not None:
        hx, _ = nn.attention(
            p["xattn"], nn.rms_norm(x, p["lnx"], cfg.norm_eps), cfg,
            q_pos=q_pos, kv_source=enc_out,
        )
        x = x + hx
    x = x + nn.mlp(p["mlp"], nn.rms_norm(x, p["ln2"], cfg.norm_eps))
    return x, kv


def _moe_block(p, x, cfg: ModelConfig, *, q_pos, cache=None, cache_index=None):
    if cfg.mla:
        h, kv = nn.mla_attention(p["attn"], nn.rms_norm(x, p["ln1"], cfg.norm_eps),
                                 cfg, q_pos=q_pos, cache=cache, cache_index=cache_index)
    else:
        h, kv = nn.attention(p["attn"], nn.rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
                             q_pos=q_pos, window=0, is_global=True,
                             cache=cache, cache_index=cache_index)
    x = x + h
    y, aux = nn.moe(p["moe"], nn.rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return x + y, kv, aux


def _mamba_block(p, x, cfg: ModelConfig, state=None):
    fn = ssm.mamba2 if cfg.ssm == "mamba2" else ssm.mamba1
    h, new_state = fn(p["ssm"], nn.rms_norm(x, p["ln"], cfg.norm_eps), cfg, state)
    return x + h, new_state


def _is_global_flags(cfg: ModelConfig, n: int) -> jnp.ndarray:
    if cfg.sliding_window and cfg.global_every:
        return jnp.array([(i + 1) % cfg.global_every == 0 for i in range(n)])
    if cfg.sliding_window:
        return jnp.zeros(n, bool)
    return jnp.ones(n, bool)


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------

def _embed_tokens(params, tokens, cfg: ModelConfig, dtype):
    scale = jnp.asarray(np.sqrt(cfg.d_model), dtype)  # keep compute dtype
    return jnp.take(params["embed"], tokens, axis=0).astype(dtype) * scale


def _logits(params, x, cfg: ModelConfig):
    x = nn.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    return jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))


def _frontend(params, batch, cfg: ModelConfig, dtype):
    """Prepend stub modality embeddings (patches/frames) to token embeds."""
    emb = _embed_tokens(params, batch["tokens"], cfg, dtype)
    if cfg.frontend and "frontend" in batch:
        fr = jnp.einsum("btf,fd->btd", batch["frontend"].astype(dtype),
                        params["frontend_proj"].astype(dtype))
        emb = jnp.concatenate([fr, emb], axis=1)
    return emb


# ---------------------------------------------------------------------------
# Training forward (full sequence)
# ---------------------------------------------------------------------------

def forward_train(params, batch, cfg: ModelConfig, *, remat: bool = True,
                  remat_policy: str = "none"):
    """Returns (logits, aux_loss)."""
    dtype = params["final_norm"].dtype
    fam = cfg.family

    if fam == "encdec":
        return _encdec_train(params, batch, cfg, remat)

    x = _frontend(params, batch, cfg, dtype)
    b, s, _ = x.shape
    q_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    aux_total = jnp.zeros((), jnp.float32)

    def maybe_remat(f):
        if not remat:
            return f
        policy = None
        if remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(f, policy=policy)

    if fam in ("dense", "vlm"):
        flags = _is_global_flags(cfg, cfg.n_layers)

        def body(carry, inp):
            lp, is_g = inp
            y, _ = _dense_block(lp, carry, cfg, q_pos=q_pos,
                                window=cfg.sliding_window, is_global=is_g)
            return y, None

        x, _ = _scan(maybe_remat(body), x, (params["layers"], flags))

    elif fam == "moe":
        if cfg.n_dense_layers:
            def dbody(carry, lp):
                xx = carry
                if cfg.mla:
                    h, _ = nn.mla_attention(lp["attn"], nn.rms_norm(xx, lp["ln1"], cfg.norm_eps),
                                            cfg, q_pos=q_pos)
                else:
                    h, _ = nn.attention(lp["attn"], nn.rms_norm(xx, lp["ln1"], cfg.norm_eps),
                                        cfg, q_pos=q_pos, window=0, is_global=True)
                xx = xx + h
                xx = xx + nn.mlp(lp["mlp"], nn.rms_norm(xx, lp["ln2"], cfg.norm_eps))
                return xx, None

            x, _ = _scan(maybe_remat(dbody), x, params["dense_layers"])

        def body(carry, lp):
            xx, aux = carry
            y, _, a = _moe_block(lp, xx, cfg, q_pos=q_pos)
            return (y, aux + a), None

        (x, aux_total), _ = _scan(maybe_remat(body), (x, aux_total), params["layers"])

    elif fam == "ssm":
        def body(carry, lp):
            y, _ = _mamba_block(lp, carry, cfg)
            return y, None

        x, _ = _scan(maybe_remat(body), x, params["layers"])

    elif fam == "hybrid":
        n_chunk = cfg.n_layers // cfg.attn_every
        chunked = jax.tree.map(
            lambda a: a.reshape((n_chunk, cfg.attn_every) + a.shape[1:]),
            params["layers"])
        shared = params["shared_attn"]

        def inner(carry, lp):
            y, _ = _mamba_block(lp, carry, cfg)
            return y, None

        def chunk_body(carry, chunk_params):
            y, _ = _scan(inner, carry, chunk_params)
            y, _ = _dense_block(shared, y, cfg, q_pos=q_pos, window=0, is_global=True)
            return y, None

        x, _ = _scan(maybe_remat(chunk_body), x, chunked)

    logits = _logits(params, x, cfg)
    return logits, aux_total


def _encdec_train(params, batch, cfg: ModelConfig, remat: bool):
    dtype = params["final_norm"].dtype
    fr = batch["frontend"].astype(dtype)
    enc = jnp.einsum("btf,fd->btd", fr, params["frontend_proj"].astype(dtype))
    b, t, _ = enc.shape
    enc_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def ebody(carry, lp):
        y, _ = _dense_block(lp, carry, cfg, q_pos=enc_pos, window=0,
                            is_global=True, bidirectional=True)
        return y, None

    ebody_ = jax.checkpoint(ebody) if remat else ebody
    enc, _ = _scan(ebody_, enc, params["enc_layers"])

    dec = _embed_tokens(params, batch["tokens"], cfg, dtype)
    s = dec.shape[1]
    q_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def dbody(carry, lp):
        y, _ = _dense_block(lp, carry, cfg, q_pos=q_pos, window=0,
                            is_global=True, enc_out=enc)
        return y, None

    dbody_ = jax.checkpoint(dbody) if remat else dbody
    dec, _ = _scan(dbody_, dec, params["dec_layers"])
    return _logits(params, dec, cfg), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    fam = cfg.family
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    pos = jnp.zeros((), jnp.int32)
    if fam in ("dense", "vlm"):
        shape = (cfg.n_layers, batch, max_len, kvh, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype), "pos": pos}
    if fam == "moe":
        n_moe = cfg.n_layers - cfg.n_dense_layers
        if cfg.mla:
            c = {"ckv": jnp.zeros((n_moe, batch, max_len, cfg.kv_lora), dtype),
                 "kr": jnp.zeros((n_moe, batch, max_len, cfg.rope_dims), dtype),
                 "pos": pos}
            if cfg.n_dense_layers:
                c["d_ckv"] = jnp.zeros((cfg.n_dense_layers, batch, max_len, cfg.kv_lora), dtype)
                c["d_kr"] = jnp.zeros((cfg.n_dense_layers, batch, max_len, cfg.rope_dims), dtype)
            return c
        c = {"k": jnp.zeros((n_moe, batch, max_len, kvh, hd), dtype),
             "v": jnp.zeros((n_moe, batch, max_len, kvh, hd), dtype), "pos": pos}
        if cfg.n_dense_layers:
            c["d_k"] = jnp.zeros((cfg.n_dense_layers, batch, max_len, kvh, hd), dtype)
            c["d_v"] = jnp.zeros((cfg.n_dense_layers, batch, max_len, kvh, hd), dtype)
        return c
    if fam == "ssm":
        di, n, k = cfg.d_inner, cfg.d_state, cfg.d_conv
        return {"conv": jnp.zeros((cfg.n_layers, batch, k - 1, di), dtype),
                "h": jnp.zeros((cfg.n_layers, batch, di, n), jnp.float32), "pos": pos}
    if fam == "hybrid":
        di, n, k = cfg.d_inner, cfg.d_state, cfg.d_conv
        nh, hdim = cfg.ssm_heads, cfg.d_inner // cfg.ssm_heads
        n_chunk = cfg.n_layers // cfg.attn_every
        return {
            "conv": jnp.zeros((cfg.n_layers, batch, k - 1, di), dtype),
            "h": jnp.zeros((cfg.n_layers, batch, nh, hdim, n), jnp.float32),
            "k": jnp.zeros((n_chunk, batch, max_len, kvh, hd), dtype),
            "v": jnp.zeros((n_chunk, batch, max_len, kvh, hd), dtype),
            "pos": pos,
        }
    if fam == "encdec":
        return {"k": jnp.zeros((cfg.n_dec_layers, batch, max_len, kvh, hd), dtype),
                "v": jnp.zeros((cfg.n_dec_layers, batch, max_len, kvh, hd), dtype),
                "enc": jnp.zeros((batch, cfg.frontend_len, cfg.d_model), dtype),
                "pos": pos}
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Prefill + decode
# ---------------------------------------------------------------------------

def forward_prefill(params, batch, cfg: ModelConfig, cache):
    """Fill the cache with the prompt; return (last-position logits, cache)."""
    dtype = params["final_norm"].dtype
    fam = cfg.family
    idx = cache["pos"]

    if fam == "encdec":
        enc = jnp.einsum("btf,fd->btd", batch["frontend"].astype(dtype),
                         params["frontend_proj"].astype(dtype))
        b, t, _ = enc.shape
        enc_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

        def ebody(carry, lp):
            y, _ = _dense_block(lp, carry, cfg, q_pos=enc_pos, window=0,
                                is_global=True, bidirectional=True)
            return y, None

        enc, _ = _scan(ebody, enc, params["enc_layers"])
        dec = _embed_tokens(params, batch["tokens"], cfg, dtype)
        s = dec.shape[1]
        q_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def dbody(carry, inp):
            lp, kc, vc = inp
            y, kv = _dense_block(lp, carry, cfg, q_pos=q_pos, window=0,
                                 is_global=True, enc_out=enc,
                                 cache=(kc, vc), cache_index=idx)
            return y, kv

        dec, (ks, vs) = _scan(dbody, dec, (params["dec_layers"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs, "enc": enc.astype(cache["enc"].dtype),
                     "pos": idx + s}
        return _logits(params, dec[:, -1:], cfg), new_cache

    x = _frontend(params, batch, cfg, dtype)
    b, s, _ = x.shape
    q_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s)) + idx

    if fam in ("dense", "vlm"):
        flags = _is_global_flags(cfg, cfg.n_layers)

        def body(carry, inp):
            lp, is_g, kc, vc = inp
            y, kv = _dense_block(lp, carry, cfg, q_pos=q_pos,
                                 window=cfg.sliding_window, is_global=is_g,
                                 cache=(kc, vc), cache_index=idx)
            return y, kv

        x, (ks, vs) = _scan(body, x, (params["layers"], flags, cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs, "pos": idx + s}

    elif fam == "moe":
        new_cache = dict(cache)
        if cfg.n_dense_layers:
            def dbody(carry, inp):
                if cfg.mla:
                    lp, c1, c2 = inp
                    h, kv = nn.mla_attention(lp["attn"], nn.rms_norm(carry, lp["ln1"], cfg.norm_eps),
                                             cfg, q_pos=q_pos, cache=(c1, c2), cache_index=idx)
                else:
                    lp, c1, c2 = inp
                    h, kv = nn.attention(lp["attn"], nn.rms_norm(carry, lp["ln1"], cfg.norm_eps),
                                         cfg, q_pos=q_pos, window=0, is_global=True,
                                         cache=(c1, c2), cache_index=idx)
                xx = carry + h
                xx = xx + nn.mlp(lp["mlp"], nn.rms_norm(xx, lp["ln2"], cfg.norm_eps))
                return xx, kv

            keys = ("d_ckv", "d_kr") if cfg.mla else ("d_k", "d_v")
            x, (c1s, c2s) = _scan(
                dbody, x, (params["dense_layers"], cache[keys[0]], cache[keys[1]]))
            new_cache[keys[0]], new_cache[keys[1]] = c1s, c2s

        def body(carry, inp):
            lp, c1, c2 = inp
            xx, aux = carry
            y, kv, a = _moe_block(lp, xx, cfg, q_pos=q_pos, cache=(c1, c2), cache_index=idx)
            return (y, aux + a), kv

        keys = ("ckv", "kr") if cfg.mla else ("k", "v")
        (x, _), (c1s, c2s) = _scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["layers"], cache[keys[0]], cache[keys[1]]))
        new_cache[keys[0]], new_cache[keys[1]] = c1s, c2s
        new_cache["pos"] = idx + s

    elif fam == "ssm":
        # prefill for SSM = full-sequence scan, extracting the final state
        fn = ssm.mamba2 if cfg.ssm == "mamba2" else ssm.mamba1

        def body(carry, lp):
            xln = nn.rms_norm(carry, lp["ln"], cfg.norm_eps)
            y, st = fn(lp["ssm"], xln, cfg, None, return_state=True)
            return carry + y, st

        x, (convs, hs) = _scan(body, x, params["layers"])
        new_cache = {"conv": convs.astype(cache["conv"].dtype), "h": hs,
                     "pos": idx + s}

    elif fam == "hybrid":
        n_chunk = cfg.n_layers // cfg.attn_every
        chunked = jax.tree.map(
            lambda a: a.reshape((n_chunk, cfg.attn_every) + a.shape[1:]),
            params["layers"])
        shared = params["shared_attn"]
        fn = ssm.mamba2 if cfg.ssm == "mamba2" else ssm.mamba1

        def inner(carry, lp):
            xln = nn.rms_norm(carry, lp["ln"], cfg.norm_eps)
            y, st = fn(lp["ssm"], xln, cfg, None, return_state=True)
            return carry + y, st

        def chunk_body(carry, inp):
            cp, kc, vc = inp
            y, sts = _scan(inner, carry, cp)
            y, kv = _dense_block(shared, y, cfg, q_pos=q_pos, window=0,
                                 is_global=True, cache=(kc, vc), cache_index=idx)
            return y, (sts, kv)

        x, (sts, kvs) = _scan(chunk_body, x, (chunked, cache["k"], cache["v"]))
        convs, hs = sts
        new_cache = {
            "conv": convs.reshape(cache["conv"].shape).astype(cache["conv"].dtype),
            "h": hs.reshape(cache["h"].shape),
            "k": kvs[0], "v": kvs[1],
            "pos": idx + s,
        }

    return _logits(params, x[:, -1:], cfg), new_cache


def forward_decode(params, token, cfg: ModelConfig, cache):
    """One decode step.  token: (B, 1) int32.  Returns (logits, cache)."""
    dtype = params["final_norm"].dtype
    fam = cfg.family
    idx = cache["pos"]
    x = _embed_tokens(params, token, cfg, dtype)
    b = x.shape[0]
    q_pos = jnp.full((b, 1), idx, jnp.int32)
    new_cache = dict(cache)

    if fam in ("dense", "vlm"):
        flags = _is_global_flags(cfg, cfg.n_layers)

        def body(carry, inp):
            lp, is_g, kc, vc = inp
            y, kv = _dense_block(lp, carry, cfg, q_pos=q_pos,
                                 window=cfg.sliding_window, is_global=is_g,
                                 cache=(kc, vc), cache_index=idx)
            return y, kv

        x, (ks, vs) = _scan(body, x, (params["layers"], flags, cache["k"], cache["v"]))
        new_cache.update(k=ks, v=vs)

    elif fam == "moe":
        if cfg.n_dense_layers:
            def dbody(carry, inp):
                lp, c1, c2 = inp
                if cfg.mla:
                    h, kv = nn.mla_attention(lp["attn"], nn.rms_norm(carry, lp["ln1"], cfg.norm_eps),
                                             cfg, q_pos=q_pos, cache=(c1, c2), cache_index=idx)
                else:
                    h, kv = nn.attention(lp["attn"], nn.rms_norm(carry, lp["ln1"], cfg.norm_eps),
                                         cfg, q_pos=q_pos, window=0, is_global=True,
                                         cache=(c1, c2), cache_index=idx)
                xx = carry + h
                xx = xx + nn.mlp(lp["mlp"], nn.rms_norm(xx, lp["ln2"], cfg.norm_eps))
                return xx, kv

            keys = ("d_ckv", "d_kr") if cfg.mla else ("d_k", "d_v")
            x, (c1s, c2s) = _scan(
                dbody, x, (params["dense_layers"], cache[keys[0]], cache[keys[1]]))
            new_cache[keys[0]], new_cache[keys[1]] = c1s, c2s

        def body(carry, inp):
            lp, c1, c2 = inp
            xx, aux = carry
            y, kv, a = _moe_block(lp, xx, cfg, q_pos=q_pos, cache=(c1, c2), cache_index=idx)
            return (y, aux + a), kv

        keys = ("ckv", "kr") if cfg.mla else ("k", "v")
        (x, _), (c1s, c2s) = _scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["layers"], cache[keys[0]], cache[keys[1]]))
        new_cache[keys[0]], new_cache[keys[1]] = c1s, c2s

    elif fam == "ssm":
        def body(carry, inp):
            lp, conv_c, h_c = inp
            y, st = _mamba_block(lp, carry, cfg, state=(conv_c, h_c))
            return y, st

        x, (convs, hs) = _scan(body, x, (params["layers"], cache["conv"], cache["h"]))
        new_cache.update(conv=convs, h=hs)

    elif fam == "hybrid":
        n_chunk = cfg.n_layers // cfg.attn_every
        chunked = jax.tree.map(
            lambda a: a.reshape((n_chunk, cfg.attn_every) + a.shape[1:]),
            params["layers"])
        conv_c = cache["conv"].reshape((n_chunk, cfg.attn_every) + cache["conv"].shape[1:])
        h_c = cache["h"].reshape((n_chunk, cfg.attn_every) + cache["h"].shape[1:])
        shared = params["shared_attn"]

        def inner(carry, inp):
            lp, cc, hh = inp
            y, st = _mamba_block(lp, carry, cfg, state=(cc, hh))
            return y, st

        def chunk_body(carry, inp):
            cp, cc, hh, kc, vc = inp
            y, sts = _scan(inner, carry, (cp, cc, hh))
            y, kv = _dense_block(shared, y, cfg, q_pos=q_pos, window=0,
                                 is_global=True, cache=(kc, vc), cache_index=idx)
            return y, (sts, kv)

        x, (sts, kvs) = _scan(
            chunk_body, x, (chunked, conv_c, h_c, cache["k"], cache["v"]))
        convs, hs = sts
        new_cache.update(
            conv=convs.reshape(cache["conv"].shape),
            h=hs.reshape(cache["h"].shape),
            k=kvs[0], v=kvs[1],
        )

    elif fam == "encdec":
        enc = cache["enc"].astype(dtype)

        def body(carry, inp):
            lp, kc, vc = inp
            y, kv = _dense_block(lp, carry, cfg, q_pos=q_pos, window=0,
                                 is_global=True, enc_out=enc,
                                 cache=(kc, vc), cache_index=idx)
            return y, kv

        x, (ks, vs) = _scan(body, x, (params["dec_layers"], cache["k"], cache["v"]))
        new_cache.update(k=ks, v=vs)

    new_cache["pos"] = idx + 1
    return _logits(params, x, cfg), new_cache
