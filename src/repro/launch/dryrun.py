import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent — sharding
propagates, the per-device program fits, the collective schedule exists —
and extracts the roofline terms (cost_analysis + HLO collective parse).
Results are appended incrementally to a JSON artifact consumed by
EXPERIMENTS.md §Dry-run / §Roofline and by ``benchmarks/``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      [--arch qwen3-1.7b] [--shape train_4k] [--multi-pod {off,on,both}] \
      [--out experiments/dryrun.json] [--remat-policy none|dots]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.launch import steps
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.config import SHAPES
from repro.models.registry import ARCHS, cell_is_runnable, get_config, input_specs
from repro.optim import adamw
from repro.roofline import analysis as roofline


def _abstract_params(cfg, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg, dtype))


def _tokens_per_step(cfg, shape) -> float:
    if shape.kind == "train":
        return shape.global_batch * shape.seq_len
    return shape.global_batch * (shape.seq_len if shape.kind == "prefill" else 1)


def model_flops(cfg, shape) -> float:
    """Global useful FLOPs: 6·N_active·tokens (train) or 2·N_active·tokens."""
    n_active = cfg.active_param_count()
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * _tokens_per_step(cfg, shape)


def build_cell(cfg, shape, mesh, *, remat_policy: str = "none",
               dtype=jnp.bfloat16, variant: str = "base"):
    """Returns (fn, args_abstract, in_shardings, out_shardings, donate).

    variants (§Perf hillclimb):
      base     — the paper-faithful/naive distribution
      sp       — sequence parallelism: activations constrained to
                 (batch→data, seq→model); rescues non-divisible-head archs
      seqcache — decode KV cache sequence dim sharded over model
                 (flash-decoding-style partial softmax under GSPMD)
    """
    params_abs = _abstract_params(cfg, dtype)
    specs_tree = T.model_specs(cfg)
    p_shard = shd.param_shardings(specs_tree, mesh)
    batch_abs = input_specs(cfg, shape, dtype=dtype)
    b_shard = shd.batch_shardings(mesh, batch_abs)

    if shape.kind == "train":
        opt_abs = jax.eval_shape(adamw.init, params_abs)
        o_shard = adamw.AdamWState(
            step=shd.replicated(mesh),
            m=jax.tree.map(lambda _, s: s, params_abs, p_shard),
            v=jax.tree.map(lambda _, s: s, params_abs, p_shard),
        )
        fn = steps.make_train_step(cfg, adamw.AdamWConfig(), remat_policy=remat_policy)
        args = (params_abs, opt_abs, batch_abs)
        in_sh = (p_shard, o_shard, b_shard)
        out_sh = (p_shard, o_shard, None)
        donate = (0, 1)
        return fn, args, in_sh, out_sh, donate

    seq_parallel = shape.name == "long_500k"
    cache_abs = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len, dtype))
    c_shard = shd.cache_shardings(cfg, mesh, cache_abs, seq_parallel=seq_parallel)
    if variant == "seqcache":
        from jax.sharding import NamedSharding, PartitionSpec as P

        def seq_over_model(x, s):
            if len(x.shape) >= 4 and x.shape[2] % mesh.shape["model"] == 0:
                parts = list(s.spec) + [None] * (len(x.shape) - len(s.spec))
                parts[2] = "model"
                parts[-2] = None if parts[-2] == "model" else parts[-2]
                parts[-1] = None if parts[-1] == "model" else parts[-1]
                return NamedSharding(mesh, P(*parts))
            return s

        c_shard = jax.tree.map(seq_over_model, cache_abs, c_shard)

    if shape.kind == "prefill":
        fn = steps.make_prefill_step(cfg)
        args = (params_abs, batch_abs, cache_abs)
        in_sh = (p_shard, b_shard, c_shard)
        out_sh = (None, c_shard)
        donate = (2,)
        return fn, args, in_sh, out_sh, donate

    # decode
    fn = steps.make_decode_step(cfg)
    tok_abs = batch_abs  # {"tokens": (B,1)}
    args = (params_abs, tok_abs["tokens"], cache_abs)
    in_sh = (p_shard, shd.batch_sharding(mesh, shape.global_batch, 2), c_shard)
    out_sh = (None, c_shard)
    donate = (2,)
    return fn, args, in_sh, out_sh, donate


# ---------------------------------------------------------------------------
# Depth extrapolation: XLA cost_analysis counts a scan (while-loop) body
# ONCE, not × trip count (verified empirically).  All layer stacks here are
# scanned, so per-cell FLOPs / bytes / collective-bytes are derived from two
# reduced-depth compiles and a linear fit Q(L) = b + a·L evaluated at the
# full depth — every number stays grounded in real compiled SPMD HLO.
# ---------------------------------------------------------------------------

def _depth_points(cfg) -> tuple[int, int]:
    if cfg.family == "hybrid":
        return cfg.attn_every, 2 * cfg.attn_every
    if cfg.family == "encdec":
        return 4, 8  # 2enc+2dec, 4enc+4dec
    if cfg.family == "moe" and cfg.n_dense_layers:
        return cfg.n_dense_layers + 2, cfg.n_dense_layers + 4
    return 2, 4


def _with_depth(cfg, depth: int):
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, n_layers=depth,
                                   n_enc_layers=depth // 2,
                                   n_dec_layers=depth // 2)
    return dataclasses.replace(cfg, n_layers=depth)


def _cell_costs(cfg, shape, mesh, remat_policy: str, variant: str = "base"):
    """(flops, hbm_bytes, wire_bytes) per device for one compiled cell."""
    import contextlib

    from jax.sharding import PartitionSpec as P

    from repro.roofline.analysis import parse_collectives

    fn, args, in_sh, out_sh, donate = build_cell(
        cfg, shape, mesh, remat_policy=remat_policy, variant=variant)
    sp_ctx = (T.activation_sharding(P(shd.dp_axes(mesh), "model", None))
              if variant == "sp" else contextlib.nullcontext())
    with sp_ctx, mesh, T.unrolled_layers():
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate).lower(*args).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        coll = parse_collectives(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll.wire_bytes))


def extrapolated_costs(cfg, shape, mesh, remat_policy: str, variant: str = "base"):
    l1, l2 = _depth_points(cfg)
    q1 = _cell_costs(_with_depth(cfg, l1), shape, mesh, remat_policy, variant)
    q2 = _cell_costs(_with_depth(cfg, l2), shape, mesh, remat_policy, variant)
    lf = cfg.n_layers
    out = []
    for a, b in zip(q1, q2):
        slope = (b - a) / (l2 - l1)
        out.append(max(0.0, a + slope * (lf - l1)))
    return tuple(out)  # (flops, hbm_bytes, wire_bytes) at full depth


# ---------------------------------------------------------------------------
# ERA engine dry-run cell: the paper's own workload on the production mesh.
# One elastic-range SubTreePrepare iteration, vmapped over a per-device
# batch of virtual trees, groups sharded over every mesh axis (ERA has no
# matmul to TP-shard: all 512 chips are independent workers — §5).  The
# string is replicated (the shared-nothing broadcast).  Zero collectives
# in the step is the *proof* of the paper's no-merge parallelism.
# ---------------------------------------------------------------------------

ERA_GENOME_N = 2_100_000_000  # human-genome scale, int32-offset safe
ERA_F_M = 1 << 20             # leaves per virtual tree (MTS 32MB @ 32B/node)
ERA_RANGE_W = 64


def build_era_cell(mesh, *, w: int = ERA_RANGE_W, n: int = ERA_GENOME_N,
                   f_m: int = ERA_F_M, packed: bool = False):
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.packing import PackedText
    from repro.core.prepare import PrepareState
    from repro.launch.era_run import era_prepare_batch

    g = mesh.size  # one virtual tree per chip
    all_axes = tuple(mesh.axis_names)
    rep = NamedSharding(mesh, P())
    if packed:
        # dense 2-bit DNA storage: 16 symbols / uint32 word — the
        # replicated string costs n/4 bytes of HBM per chip, not n
        s_abs = PackedText(
            words=jax.ShapeDtypeStruct((n // 16,), jnp.uint32),
            n_real=jax.ShapeDtypeStruct((), jnp.int32),
            bits=2, terminal=4)
        s_shard = PackedText(words=rep, n_real=rep, bits=2, terminal=4)
    else:
        s_abs = jax.ShapeDtypeStruct((n,), jnp.uint8)
        s_shard = rep
    st_abs = PrepareState(
        L=jax.ShapeDtypeStruct((g, f_m), jnp.int32),
        start=jax.ShapeDtypeStruct((g, f_m), jnp.int32),
        area=jax.ShapeDtypeStruct((g, f_m), jnp.int32),
        b_off=jax.ShapeDtypeStruct((g, f_m), jnp.int32),
        b_c1=jax.ShapeDtypeStruct((g, f_m), jnp.int32),
        b_c2=jax.ShapeDtypeStruct((g, f_m), jnp.int32),
    )
    by_group = NamedSharding(mesh, P(all_axes, None))
    st_shard = PrepareState(*([by_group] * 6))

    def fn(s_padded, states):
        return era_prepare_batch(s_padded, states, w=w)

    args = (s_abs, st_abs)
    in_sh = (s_shard, st_shard)
    out_sh = (st_shard, NamedSharding(mesh, P(all_axes)))
    return fn, args, in_sh, out_sh, (1,)


def run_era_cell(multi_pod: bool, *, packed: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": "era-genome" + ("-packed" if packed else ""),
           "shape": "prepare_2.1G", "mesh": "2x16x16" if multi_pod else "16x16",
           "remat_policy": "n/a", "variant": "base"}
    t0 = time.perf_counter()
    try:
        fn, args, in_sh, out_sh, donate = build_era_cell(mesh, packed=packed)
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
            # single iteration; no scan over layers -> costs are exact
            terms, coll = roofline.terms_from_compiled(
                compiled, mesh.size, 0.0, hlo_text=hlo)
        rec.update(
            status="ok", t_compile_s=round(time.perf_counter() - t0, 2),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_estimate_bytes": (
                    mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
            },
            roofline=terms.to_dict(),
            collectives={"counts": coll.count_by_kind,
                         "result_bytes": coll.bytes_by_kind,
                         "wire_bytes_per_device": coll.wire_bytes},
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             remat_policy: str = "none", variant: str = "base") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "remat_policy": remat_policy,
        "variant": variant,
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.perf_counter()

    import contextlib

    from jax.sharding import PartitionSpec as P

    sp_ctx = (T.activation_sharding(P(shd.dp_axes(mesh), "model", None))
              if variant == "sp" else contextlib.nullcontext())
    try:
        fn, args, in_sh, out_sh, donate = build_cell(
            cfg, shape, mesh, remat_policy=remat_policy, variant=variant)
        with sp_ctx, mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.perf_counter() - t0
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t1

            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
            terms, coll = roofline.terms_from_compiled(
                compiled, chips, model_flops(cfg, shape), hlo_text=hlo)
        # depth-extrapolated costs (scan bodies are cost-counted once;
        # see module comment) — these are the table-of-record numbers
        flops_x, hbm_x, wire_x = extrapolated_costs(cfg, shape, mesh,
                                                    remat_policy, variant)
        terms_x = roofline.RooflineTerms(
            flops=flops_x, hbm_bytes=hbm_x, wire_bytes=wire_x,
            chips=chips, model_flops=model_flops(cfg, shape))
        rec.update(
            status="ok",
            t_lower_s=round(t_lower, 2),
            t_compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_estimate_bytes": (
                    mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes - mem.alias_size_in_bytes
                ),
            },
            roofline=terms_x.to_dict(),
            roofline_raw_hlo=terms.to_dict(),  # un-extrapolated (body-once)
            collectives={
                "counts": coll.count_by_kind,
                "result_bytes": coll.bytes_by_kind,
                "wire_bytes_per_device": coll.wire_bytes,
            },
        )
    except Exception as e:  # a failing cell is a bug to fix, but keep going
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="both")
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--remat-policy", default="none")
    ap.add_argument("--variant", default="base",
                    choices=["base", "sp", "seqcache"])
    args = ap.parse_args()

    era_only = args.arch in ("era", "era-packed")
    archs = list(ARCHS) if args.arch == "all" else ([] if era_only else [args.arch])
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"], r.get("remat_policy", "none"),
             r.get("variant", "base"))
            for r in results if r.get("status") in ("ok", "skipped")}

    for arch in archs:
        for shape_name in shapes:
            for mp in pods:
                mesh_name = "2x16x16" if mp else "16x16"
                key = (arch, shape_name, mesh_name, args.remat_policy, args.variant)
                if key in done:
                    print(f"[cached] {key}")
                    continue
                print(f"[dryrun] {arch} × {shape_name} × {mesh_name} "
                      f"variant={args.variant} ...", flush=True)
                rec = run_cell(arch, shape_name, mp, args.remat_policy, args.variant)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" tc={r['t_compute_s']:.3g}s tm={r['t_memory_s']:.3g}s"
                             f" tx={r['t_collective_s']:.3g}s"
                             f" useful={r['useful_flops_ratio']:.2f}"
                             f" compile={rec['t_compile_s']}s")
                elif status == "error":
                    extra = " " + rec["error"][:200]
                print(f"  -> {status}{extra}", flush=True)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"],
                               r.get("remat_policy", "none"),
                               r.get("variant", "base")) != key]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    # ERA engine cells (paper-representative; included in 'all' sweeps)
    if args.arch in ("all", "era", "era-packed"):
        packed_opts = {"all": [False, True], "era": [False],
                       "era-packed": [True]}[args.arch]
        for packed in packed_opts:
            for mp in pods:
                name = "era-genome" + ("-packed" if packed else "")
                mesh_name = "2x16x16" if mp else "16x16"
                key = (name, "prepare_2.1G", mesh_name, "n/a", "base")
                if key in done:
                    print(f"[cached] {key}")
                    continue
                print(f"[dryrun] {name} × prepare_2.1G × {mesh_name} ...", flush=True)
                rec = run_era_cell(mp, packed=packed)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"  -> ok bottleneck={r['bottleneck']}"
                          f" tc={r['t_compute_s']:.3g}s tm={r['t_memory_s']:.3g}s"
                          f" tx={r['t_collective_s']:.3g}s", flush=True)
                else:
                    print(f"  -> {rec['status']} {rec.get('error', '')[:200]}", flush=True)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"],
                               r.get("remat_policy", "none"),
                               r.get("variant", "base")) != key]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_err = sum(1 for r in results if r["status"] == "error")
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        for r in results:
            if r["status"] == "error":
                print(f"  ERROR {r['arch']} × {r['shape']} × {r['mesh']}: {r['error'][:200]}")


if __name__ == "__main__":
    main()
