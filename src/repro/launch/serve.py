"""Batched serving driver: prefill a batch of prompts, then decode.

CPU example (smoke model):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import steps as step_lib
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.models.config import smoke_config
from repro.models.registry import get_config


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, dtype=jnp.float32, seed: int = 0):
    cfg = get_config(arch)
    if smoke:
        cfg = smoke_config(cfg)

    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype)
    rng = np.random.default_rng(seed)
    max_len = prompt_len + gen + 1

    batch_in = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(batch, prompt_len), dtype=np.int32))}
    if cfg.family == "encdec" or cfg.frontend:
        batch_in["frontend"] = jnp.asarray(
            rng.normal(size=(batch, cfg.frontend_len, cfg.frontend_dim)), dtype)

    cache = T.init_cache(cfg, batch, max_len, dtype=dtype)
    prefill = jax.jit(step_lib.make_prefill_step(cfg))
    decode = jax.jit(step_lib.make_decode_step(cfg), donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch_in, cache)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    t_prefill = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        tok, cache = decode(params, tok, cache)
        out.append(tok)
    t_decode = time.perf_counter() - t0
    tokens = jnp.concatenate(out, axis=1)
    return tokens, {"t_prefill_s": t_prefill, "t_decode_s": t_decode,
                    "decode_tok_s": batch * (gen - 1) / max(t_decode, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    tokens, stats = serve(args.arch, smoke=args.smoke, batch=args.batch,
                          prompt_len=args.prompt_len, gen=args.gen)
    print("generated:", np.asarray(tokens)[:, :8], "...")
    print(stats)


if __name__ == "__main__":
    main()
