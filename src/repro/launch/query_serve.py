"""Sustained batched query serving driver (read-side analogue of serve.py).

Builds an ERA index over a dataset, flattens it to the device-resident
:class:`repro.core.query.DeviceIndex`, then drives a sustained loop of
padded pattern batches through ``find_batch_ranges`` and reports
queries/sec plus per-batch latency — the serving-shaped measurement the
ROADMAP's heavy-traffic north star asks for.

CPU example:
  PYTHONPATH=src python -m repro.launch.query_serve --dataset dna \
      --n 100000 --batch 256 --iters 20
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.api import EraConfig, EraIndexer
from repro.core.query import DeviceIndex
from repro.launch.warmstart import load_or_build, will_load


def make_workload(s: np.ndarray, rng: np.random.Generator, *, batch: int,
                  min_len: int, max_len: int, planted_frac: float,
                  n_symbols: int) -> list[np.ndarray]:
    """A batch mixing planted substrings (guaranteed hits) with random
    patterns (mostly misses) across a uniform length mix."""
    pats = []
    for _ in range(batch):
        m = int(rng.integers(min_len, max_len + 1))
        if rng.random() < planted_frac:
            i = int(rng.integers(0, len(s) - 1 - m))
            pats.append(np.asarray(s[i : i + m]))
        else:
            pats.append(rng.integers(0, n_symbols, size=m).astype(np.uint8))
    return pats


def serve_queries(dataset_name: str = "dna", *, n: int = 100_000,
                  batch: int = 256, iters: int = 20, min_len: int = 4,
                  max_len: int = 24, planted_frac: float = 0.7,
                  memory_bytes: int = 1 << 20, seed: int = 0,
                  index_path: str | None = None):
    if not 1 <= min_len <= max_len:
        raise ValueError(f"need 1 <= min_len <= max_len, got [{min_len}, {max_len}]")
    if iters < 1 or batch < 1:
        raise ValueError(f"need iters >= 1 and batch >= 1, got {iters}, {batch}")
    rng = np.random.default_rng(seed + 1)

    max_len4 = -(-max_len // 4) * 4  # pad_batch rounds to whole packed words
    if not will_load(index_path) and max_len >= n:
        # cold-path fast precondition: fail before paying the build
        # (make_workload needs at least one valid start per planted length)
        raise ValueError(f"max_len {max_len} must be < --n {n}")

    def build(s, alphabet):
        # batched construction -> DeviceIndex directly (no SubTree dict)
        cfg = EraConfig(memory_bytes=memory_bytes, build_impl="none")
        return EraIndexer(alphabet, cfg).build_device(
            s, max_pattern_len=max(64, max_len4))

    # warm start: the npz round-trip skips build + flatten entirely
    dev, s, alphabet, t_build = load_or_build(
        index_path, dataset_name, n, seed,
        load=DeviceIndex.load, build=build)
    if max_len >= len(s) - 1:  # need a valid start for every planted length
        raise ValueError(
            f"max_len {max_len} must be < indexed string length - 1 = {len(s) - 1}")
    if max_len4 > dev.max_pattern_len:
        raise ValueError(
            f"--max-len {max_len} exceeds the cached index's "
            f"max_pattern_len={dev.max_pattern_len}; delete the cache at "
            f"--index-path or rebuild cold with a larger --max-len")

    # pre-pad every batch so the timed loop measures routing + search only
    batches = []
    for _ in range(iters):
        pats = make_workload(s, rng, batch=batch, min_len=min_len,
                             max_len=max_len, planted_frac=planted_frac,
                             n_symbols=len(alphabet.symbols))
        batches.append(dev.pad_batch(pats))

    # warmup: one compile per padded width in the mix, SYNCED per width —
    # blocking only on the last batch would let earlier widths still be
    # compiling/dispatching when the timed loop starts
    warmed: set[int] = set()
    for padded, lengths, route in batches:
        if padded.shape[1] in warmed:
            continue
        warmed.add(padded.shape[1])
        start, count = dev.find_batch_ranges(padded, lengths, route)
        jax.block_until_ready((start, count))

    lat = []
    hits = 0
    t0 = time.perf_counter()
    for padded, lengths, route in batches:
        t1 = time.perf_counter()
        start, count = dev.find_batch_ranges(padded, lengths, route)
        jax.block_until_ready((start, count))
        lat.append(time.perf_counter() - t1)
        hits += int(np.asarray(count).sum())
    t_serve = time.perf_counter() - t0

    lat = np.array(lat)
    return {
        "dataset": dataset_name,
        "n_symbols": len(s),
        "n_subtrees": dev.n_subtrees,
        "k_route": dev.k_route,
        "t_build_s": round(t_build, 3),
        "batches": iters,
        "batch": batch,
        "queries": iters * batch,
        "hits": hits,
        "qps": round(iters * batch / max(t_serve, 1e-9), 1),
        "batch_p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
        "batch_p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="dna")
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--min-len", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=24)
    ap.add_argument("--planted-frac", type=float, default=0.7)
    ap.add_argument("--index-path", default=None,
                    help="npz cache: load the flattened index if the file "
                         "exists, else build once and save it there")
    args = ap.parse_args()
    stats = serve_queries(args.dataset, n=args.n, batch=args.batch,
                          iters=args.iters, min_len=args.min_len,
                          max_len=args.max_len,
                          planted_frac=args.planted_frac,
                          index_path=args.index_path)
    print(stats)


if __name__ == "__main__":
    main()
