"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
the dry-run needs 512 host placeholder devices while tests need 1).
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax >= 0.5 wants explicit axis_types; 0.4.x doesn't have AxisType.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a ``pod`` axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests (1x1, same axis names)."""
    return _make_mesh((1, 1), ("data", "model"))


def make_fabric_mesh(n_shards: int | None = None):
    """1-D ``("shard",)`` mesh for the sharded index fabric
    (:mod:`repro.core.fabric`): the batched construction loop shard_maps
    its G axis over it and ``ShardedIndex`` places one route-key shard
    per device.  CPU-testable via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before
    jax import — ``repro.launch.shard_run`` handles that)."""
    n = jax.device_count() if n_shards is None else n_shards
    if not 1 <= n <= jax.device_count():
        raise ValueError(
            f"n_shards={n} needs 1..{jax.device_count()} devices")
    return _make_mesh((n,), ("shard",))
