"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
the dry-run needs 512 host placeholder devices while tests need 1).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a ``pod`` axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests (1x1, same axis names)."""
    auto = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh((1, 1), ("data", "model"), axis_types=auto)
