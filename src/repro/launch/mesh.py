"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
the dry-run needs 512 host placeholder devices while tests need 1).
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax >= 0.5 wants explicit axis_types; 0.4.x doesn't have AxisType.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a ``pod`` axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests (1x1, same axis names)."""
    return _make_mesh((1, 1), ("data", "model"))
