"""Shared npz warm-start logic for the serving drivers.

``query_serve`` caches a :class:`repro.core.query.DeviceIndex`,
``analytics_serve`` an :class:`repro.core.analytics.AnalyticsEngine`; both
follow the same discipline: normalize the cache path (``np.savez``
silently appends ``.npz``, so the existence check must too), load +
validate against the requested dataset if the file exists, otherwise
build once and save.
"""

from __future__ import annotations

import os
import sys
import time
from typing import Callable

import numpy as np

from repro.core.query import npz_path
from repro.data.strings import dataset


def normalize_npz(path: str | None) -> str | None:
    """The path ``np.savez_compressed`` will actually write."""
    return None if path is None else npz_path(path)


def shard_archives(index_path: str | None) -> list:
    """The ``{path}_shard{k}.npz`` siblings a sharded index saved under
    ``index_path``, in shard order (empty when there are none)."""
    if index_path is None:
        return []
    from repro.core.fabric import ShardedIndex
    return ShardedIndex.shard_files(index_path)


def will_load(index_path: str | None, *, sharded: bool = False) -> bool:
    """True when :func:`load_or_build` would take the cache path — lets
    drivers run cold-path preconditions before paying the build.

    A sharded index never writes the base ``{path}.npz`` — it saves
    ``{path}_shard{k}.npz`` per shard — so the existence check must
    normalize the per-shard suffix rather than collide on the base name
    (a DeviceIndex cache and a ShardedIndex cache under the same path
    are distinct archives).
    """
    if sharded:
        return bool(shard_archives(index_path))
    path = normalize_npz(index_path)
    return path is not None and os.path.exists(path)


def load_or_build(index_path: str | None, dataset_name: str, n: int,
                  seed: int, *, load: Callable, build: Callable,
                  dev_of: Callable = lambda obj: obj,
                  sharded: bool = False):
    """Load ``load(path)`` from the npz cache, else ``build(s, alphabet)``
    and save.  ``dev_of`` extracts the underlying DeviceIndex (identity for
    query_serve, ``eng.dev`` for analytics_serve) for validation and string
    recovery.  Returns ``(obj, s, alphabet, t_seconds)``.

    A cache hit serves WHATEVER string the npz was built from — the
    alphabet base must match and an ``n`` mismatch prints a notice, but
    ``seed`` is deliberately not validated: the cache's purpose is reusing
    one built index across runs, and the served string is always recovered
    from the npz itself, so results stay self-consistent.

    ``sharded`` switches the cache discipline to per-shard archives
    (``{path}_shard{k}.npz``): existence means "any shard archive
    present", and ``load``/``build(...).save`` are expected to be the
    :class:`repro.core.fabric.ShardedIndex` pair, which handle the
    suffixing themselves.
    """
    path = index_path if sharded else normalize_npz(index_path)
    t0 = time.perf_counter()
    if path and will_load(index_path, sharded=sharded):
        obj = load(path)
        dev = dev_of(obj)
        s = dev.string_codes()  # n_leaves symbols == |S|, any representation
        alphabet = dataset(dataset_name, 1, seed=seed)[1]
        if alphabet.base != dev.base:
            raise ValueError(
                f"dataset {dataset_name!r} (base {alphabet.base}) does not "
                f"match the cached index at {path} (base {dev.base})")
        if len(s) != n + 1:  # dataset() appends the terminal: n -> n+1 codes
            print(f"warmstart: cached index at {path} holds {len(s)} symbols, "
                  f"ignoring requested --n {n}", file=sys.stderr)
    else:
        s, alphabet = dataset(dataset_name, n, seed=seed)
        obj = build(s, alphabet)
        if path:
            obj.save(path)
    return obj, s, alphabet, time.perf_counter() - t0
