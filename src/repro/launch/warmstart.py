"""Shared npz warm-start logic for the serving drivers.

``query_serve`` caches a :class:`repro.core.query.DeviceIndex`,
``analytics_serve`` an :class:`repro.core.analytics.AnalyticsEngine`; both
follow the same discipline: normalize the cache path (``np.savez``
silently appends ``.npz``, so the existence check must too), load +
validate against the requested dataset if the file exists, otherwise
build once and save.
"""

from __future__ import annotations

import os
import sys
import time
from typing import Callable

import numpy as np

from repro.core.query import npz_path
from repro.data.strings import dataset


def normalize_npz(path: str | None) -> str | None:
    """The path ``np.savez_compressed`` will actually write."""
    return None if path is None else npz_path(path)


def shard_archives(index_path: str | None) -> list:
    """The ``{path}_shard{k}.npz`` siblings a sharded index saved under
    ``index_path``, in shard order (empty when there are none)."""
    if index_path is None:
        return []
    from repro.core.fabric import ShardedIndex
    return ShardedIndex.shard_files(index_path)


def will_load(index_path: str | None, *, sharded: bool = False) -> bool:
    """True when :func:`load_or_build` would take the cache path — lets
    drivers run cold-path preconditions before paying the build.

    A sharded index never writes the base ``{path}.npz`` — it saves
    ``{path}_shard{k}.npz`` per shard — so the existence check must
    normalize the per-shard suffix rather than collide on the base name
    (a DeviceIndex cache and a ShardedIndex cache under the same path
    are distinct archives).
    """
    if sharded:
        return bool(shard_archives(index_path))
    path = normalize_npz(index_path)
    return path is not None and os.path.exists(path)


def _alphabet_by_base(base: int):
    from repro.core.alphabet import ALPHABETS
    for al in ALPHABETS.values():
        if al.base == base:
            return al
    raise ValueError(f"no registered alphabet has base {base}")


def migrate_archive(path: str, *, chunk_symbols: int = 1 << 20,
                    verify: bool = True) -> bool:
    """Re-pack one legacy byte-layout npz archive to dense storage IN
    PLACE, chunk by chunk, without rebuilding the index.

    A byte archive stores the terminal-padded string as ``s_padded`` and a
    4(+epoch)-entry ``meta``; the dense layout stores ``s_words`` (uint32,
    ``Alphabet.dense_bits`` bits/symbol) and extends ``meta`` with
    ``[s_bits, n_real]`` before the trailing epoch.  All routing/leaf
    blobs are representation-independent and are carried over verbatim —
    only the string representation changes, so the migrated archive loads
    into a :class:`~repro.core.query.DeviceIndex` that answers every query
    identically (``tests/test_stream.py`` holds that equivalence).

    The string is fed to :func:`repro.core.packing.pack_text_stream` in
    ``chunk_symbols``-sized chunks — peak extra host memory is one chunk,
    not the decoded string.  ``verify`` additionally packs the full string
    with :func:`pack_text` and insists on word-for-word bit identity
    before anything is written (cheap next to the npz re-compression, and
    the whole point of a trustworthy migration).

    Returns True when the archive was migrated, False when it was already
    dense (no-op).  Raises on a missing or unrecognizable archive.
    """
    from repro.core import packing

    path = npz_path(path)
    with np.load(path) as data:
        if "s_words" in data:
            return False
        if "s_padded" not in data or "meta" not in data:
            raise ValueError(f"{path} is not a DeviceIndex archive")
        blobs = {k: data[k] for k in data.files}
    meta = np.asarray(blobs.pop("meta"), np.int64)
    base, max_plen = int(meta[0]), int(meta[3])
    epoch = int(meta[4]) if meta.size > 4 else 0
    alphabet = _alphabet_by_base(base)
    s_padded = np.asarray(blobs.pop("s_padded"), np.uint8)
    # the stored string is terminal-PADDED and shard archives carry the
    # full string regardless of their leaf count, so the real length is
    # where the terminal first appears (it only ever occurs at the end)
    term = np.flatnonzero(s_padded == alphabet.terminal_code)
    if term.size == 0:
        raise ValueError(f"{path} stores an unterminated string")
    codes = s_padded[:int(term[0]) + 1]  # real symbols + one terminal
    chunks = (codes[i:i + chunk_symbols]
              for i in range(0, codes.size, chunk_symbols))
    pt = packing.pack_text_stream(chunks, alphabet, extra=max_plen + 8)
    if verify:
        ref = packing.pack_text(codes, alphabet, extra=max_plen + 8)
        if not (np.array_equal(np.asarray(pt.words), np.asarray(ref.words))
                and int(pt.n_real) == int(ref.n_real)):
            raise AssertionError(
                f"streamed re-pack of {path} diverged from pack_text")
    blobs["s_words"] = np.asarray(pt.words)
    blobs["meta"] = np.array(
        [base, int(meta[1]), int(meta[2]), max_plen,
         pt.bits, int(pt.n_real), epoch], np.int64)
    tmp = path + ".tmp.npz"   # already .npz-suffixed: savez won't rename it
    np.savez_compressed(tmp, **blobs)
    os.replace(tmp, path)
    return True


def migrate_archives(index_path: str, *, chunk_symbols: int = 1 << 20,
                     verify: bool = True) -> list[str]:
    """Migrate a cache path's byte archives to dense storage: the base
    ``{path}.npz`` (if present) and every ``{path}_shard{k}.npz`` sibling.
    Returns the list of archive files actually migrated."""
    done = []
    base = normalize_npz(index_path)
    targets = ([base] if base and os.path.exists(base) else [])
    targets += shard_archives(index_path)
    for f in targets:
        if migrate_archive(f, chunk_symbols=chunk_symbols, verify=verify):
            done.append(f)
    return done


def load_or_build(index_path: str | None, dataset_name: str, n: int,
                  seed: int, *, load: Callable, build: Callable,
                  dev_of: Callable = lambda obj: obj,
                  sharded: bool = False):
    """Load ``load(path)`` from the npz cache, else ``build(s, alphabet)``
    and save.  ``dev_of`` extracts the underlying DeviceIndex (identity for
    query_serve, ``eng.dev`` for analytics_serve) for validation and string
    recovery.  Returns ``(obj, s, alphabet, t_seconds)``.

    A cache hit serves WHATEVER string the npz was built from — the
    alphabet base must match and an ``n`` mismatch prints a notice, but
    ``seed`` is deliberately not validated: the cache's purpose is reusing
    one built index across runs, and the served string is always recovered
    from the npz itself, so results stay self-consistent.

    ``sharded`` switches the cache discipline to per-shard archives
    (``{path}_shard{k}.npz``): existence means "any shard archive
    present", and ``load``/``build(...).save`` are expected to be the
    :class:`repro.core.fabric.ShardedIndex` pair, which handle the
    suffixing themselves.
    """
    path = index_path if sharded else normalize_npz(index_path)
    t0 = time.perf_counter()
    if path and will_load(index_path, sharded=sharded):
        obj = load(path)
        dev = dev_of(obj)
        s = dev.string_codes()  # n_leaves symbols == |S|, any representation
        alphabet = dataset(dataset_name, 1, seed=seed)[1]
        if alphabet.base != dev.base:
            raise ValueError(
                f"dataset {dataset_name!r} (base {alphabet.base}) does not "
                f"match the cached index at {path} (base {dev.base})")
        if len(s) != n + 1:  # dataset() appends the terminal: n -> n+1 codes
            print(f"warmstart: cached index at {path} holds {len(s)} symbols, "
                  f"ignoring requested --n {n}", file=sys.stderr)
    else:
        s, alphabet = dataset(dataset_name, n, seed=seed)
        obj = build(s, alphabet)
        if path:
            obj.save(path)
    return obj, s, alphabet, time.perf_counter() - t0
