"""Distributed ERA construction driver.

Maps the paper's two parallel architectures (§5) onto this machine:

* **shared-memory / shared-disk** → multi-device single host: the string is
  replicated (one HBM copy per device), virtual trees are distributed by
  the fault-tolerant work queue, each device runs the elastic-range
  pipeline on its groups.  Workers are simulated device contexts on CPU;
  on a real pod each worker is one chip driven by the same loop.

* **shared-nothing** → multi-pod: identical structure; the initial string
  broadcast cost (paper Table 3 excludes it; we report it) is modeled by
  the I/O layer.

The ``model`` mesh axis is idle for ERA (no matmul to TP-shard) — all 512
chips act as independent workers, giving 512-way task parallelism, which
is exactly the paper's scaling story (no merge phase).

Also provides ``era_prepare_batch``: a ``shard_map``-able batched step
(vmapped over a per-device batch of groups) used by the dry-run to prove
the ERA step itself lowers on the production mesh.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alphabet import ALPHABETS
from repro.core.api import BuildReport, EraConfig, EraIndexer
from repro.core.prepare import PrepareState, init_state, prepare_step
from repro.core.vertical import VerticalStats
from repro.core.prepare import PrepareStats
from repro.data.strings import dataset
from repro.runtime.scheduler import WorkQueue


# ---------------------------------------------------------------------------
# shard_map-able batched prepare step (for the dry-run / real pods)
# ---------------------------------------------------------------------------

def era_prepare_batch(s_padded: jax.Array, states: PrepareState, *, w: int,
                      packed: bool = False):
    """One elastic-range iteration for a batch of virtual trees.

    states: PrepareState with leading group-batch dim (G, F).  The caller
    shard_maps / shards G over (pod, data, model) — groups are independent,
    so the only communication is the replicated string read.

    ``packed``: 2-bit packed string (paper §6.1) — s_padded is uint32 words
    of 16 symbols; 4x less gather traffic and 4x fewer sort key words.
    """
    step = lambda st: prepare_step(s_padded, st, w=w, packed=packed)
    return jax.vmap(step)(states)


def stack_states(states: list[PrepareState]) -> PrepareState:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


# ---------------------------------------------------------------------------
# Worker-pool construction driver (simulated workers on CPU)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WorkerReport:
    worker: str
    groups: int = 0
    seconds: float = 0.0


def build_distributed(
    s: np.ndarray,
    alphabet,
    era_cfg: EraConfig,
    n_workers: int = 4,
    *,
    checkpoint_path: str | None = None,
    fail_worker: str | None = None,
    fail_after: int = 1,
):
    """Master/worker construction with the fault-tolerant queue.

    ``fail_worker`` simulates a node loss after ``fail_after`` completed
    groups (the failure-injection path used by tests): its in-flight work
    is re-queued and picked up by the survivors.
    """
    indexer = EraIndexer(alphabet, era_cfg)
    report = BuildReport(VerticalStats(), PrepareStats())
    groups = indexer.partition(s, report)
    capacity = min(era_cfg.f_max, max((g.total_freq for g in groups), default=2))
    s_padded = jnp.asarray(alphabet.pad_string(s, extra=2 * era_cfg.w_max + 8))

    queue = WorkQueue(checkpoint_path=checkpoint_path)
    queue.add_tasks([g.total_freq for g in groups], payloads=groups)

    workers = [f"w{i}" for i in range(n_workers)]
    dead: set[str] = set()
    completed: dict[int, list] = {}
    per_worker = {w: WorkerReport(worker=w) for w in workers}
    fail_count = 0

    while not queue.drained:
        progressed = False
        for w in workers:
            if w in dead:
                continue
            task = queue.pull(w)
            if task is None:
                continue
            progressed = True
            t0 = time.perf_counter()
            subtrees = indexer.process_group(s_padded, task.payload, capacity)
            dt = time.perf_counter() - t0
            if w == fail_worker and fail_count >= fail_after:
                # simulate the node dying mid-task: no completion recorded
                dead.add(w)
                queue.mark_failed(w)
                continue
            queue.complete(task.task_id, worker=w, elapsed_s=dt)
            completed[task.task_id] = subtrees
            per_worker[w].groups += 1
            per_worker[w].seconds += dt
            if w == fail_worker:
                fail_count += 1
        if not progressed and not queue.drained:
            # everything in flight on dead workers: force requeue
            for w in list(dead):
                queue.mark_failed(w)

    from repro.core.suffix_tree import SuffixTreeIndex

    subtrees = {}
    for sts in completed.values():
        for st in sts:
            subtrees[st.prefix] = st
    idx = SuffixTreeIndex(s=np.asarray(s), alphabet=alphabet, subtrees=subtrees)
    return idx, queue.stats(), list(per_worker.values())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="dna")
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--memory-mb", type=float, default=1.0)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    s, alpha = dataset(args.dataset, args.n)
    cfg = EraConfig(memory_bytes=int(args.memory_mb * (1 << 20)), build_impl="none")
    t0 = time.perf_counter()
    idx, qstats, workers = build_distributed(
        s, alpha, cfg, n_workers=args.workers, checkpoint_path=args.checkpoint)
    dt = time.perf_counter() - t0
    print(f"indexed {args.n} symbols in {dt:.2f}s with {args.workers} workers")
    print(f"queue: {qstats}")
    for w in workers:
        print(f"  {w.worker}: {w.groups} groups, {w.seconds:.2f}s")
    print(f"leaves={idx.n_leaves} subtrees={len(idx.subtrees)}")


if __name__ == "__main__":
    main()
