"""Distributed ERA construction driver.

Maps the paper's two parallel architectures (§5) onto this machine:

* **shared-memory / shared-disk** → multi-device single host: the string is
  replicated (one HBM copy per device), virtual trees are distributed by
  the fault-tolerant work queue, each device runs the elastic-range
  pipeline on its groups.  Workers are simulated device contexts on CPU;
  on a real pod each worker is one chip driven by the same loop.

* **shared-nothing** → multi-pod: identical structure; the initial string
  broadcast cost (paper Table 3 excludes it; we report it) is modeled by
  the I/O layer.

The ``model`` mesh axis is idle for ERA (no matmul to TP-shard) — all 512
chips act as independent workers, giving 512-way task parallelism, which
is exactly the paper's scaling story (no merge phase).

``era_prepare_batch`` — the ``shard_map``-able batched step used by the
dry-run to prove the ERA step lowers on the production mesh — is a thin
alias for the shared batched engine in :mod:`repro.core.prepare`; the
worker pool below consumes the same engine (each worker pulls a CHUNK of
groups and runs one vmapped elastic loop over it) instead of a private
per-group loop.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.core.alphabet import ALPHABETS
from repro.core.api import BuildReport, EraConfig, EraIndexer
from repro.core.prepare import PrepareState, prepare_step_batch
from repro.core.vertical import VerticalStats
from repro.core.prepare import PrepareStats
from repro.data.strings import dataset
from repro.runtime.scheduler import WorkQueue


# ---------------------------------------------------------------------------
# shard_map-able batched prepare step (for the dry-run / real pods)
# ---------------------------------------------------------------------------

def era_prepare_batch(s_padded, states: PrepareState, *, w: int):
    """One elastic-range iteration for a batch of virtual trees.

    states: PrepareState with leading group-batch dim (G, F).  The caller
    shard_maps / shards G over (pod, data, model) — groups are independent,
    so the only communication is the replicated string read.

    ``s_padded`` is either the terminal-padded byte string or a dense
    k-bit :class:`repro.core.packing.PackedText` (paper §6.1: 2-bit DNA —
    ``8/bits``x less replicated string HBM and gather traffic); the
    representation dispatches inside the step and results are identical.

    The implementation is the shared batched construction engine
    (:func:`repro.core.prepare.prepare_step_batch`) — the same step the
    default ``EraIndexer.build`` pipeline drives to convergence.
    """
    return prepare_step_batch(s_padded, states, w=w)


# ---------------------------------------------------------------------------
# Worker-pool construction driver (simulated workers on CPU)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WorkerReport:
    worker: str
    groups: int = 0
    seconds: float = 0.0


def build_distributed(
    s: np.ndarray,
    alphabet,
    era_cfg: EraConfig,
    n_workers: int = 4,
    *,
    checkpoint_path: str | None = None,
    fail_worker: str | None = None,
    fail_after: int = 1,
    groups_per_pull: int = 4,
):
    """Master/worker construction with the fault-tolerant queue.

    Each worker turn pulls up to ``groups_per_pull`` virtual trees and runs
    them through the shared batched (G, F) engine
    (``EraIndexer.process_groups``) — one vmapped elastic loop per chunk,
    the same engine the single-host ``build`` uses — then completes the
    tasks individually so failure/recovery stays per-group.

    ``fail_worker`` simulates a node loss after ``fail_after`` completed
    groups (the failure-injection path used by tests): its in-flight work
    is re-queued and picked up by the survivors.
    """
    indexer = EraIndexer(alphabet, era_cfg)
    report = BuildReport(VerticalStats(), PrepareStats())
    groups = indexer.partition(s, report)
    capacity = indexer._capacity(groups)
    s_padded = indexer._device_text(s)  # dense-packed for DNA (EraConfig.packing)

    queue = WorkQueue(checkpoint_path=checkpoint_path)
    queue.add_tasks([g.total_freq for g in groups], payloads=groups)

    workers = [f"w{i}" for i in range(n_workers)]
    dead: set[str] = set()
    completed: dict[int, list] = {}
    per_worker = {w: WorkerReport(worker=w) for w in workers}
    fail_count = 0

    while not queue.drained:
        progressed = False
        for w in workers:
            if w in dead:
                continue
            tasks = []
            while len(tasks) < max(1, groups_per_pull):
                task = queue.pull(w)
                if task is None:
                    break
                tasks.append(task)
            if not tasks:
                continue
            progressed = True
            t0 = time.perf_counter()
            results = indexer.process_groups(
                s_padded, [t.payload for t in tasks], capacity)
            dt = (time.perf_counter() - t0) / len(tasks)
            for task, subtrees in zip(tasks, results):
                if w == fail_worker and fail_count >= fail_after:
                    # simulate the node dying mid-chunk: this task and the
                    # rest of the chunk stay in flight and get re-queued
                    dead.add(w)
                    queue.mark_failed(w)
                    break
                queue.complete(task.task_id, worker=w, elapsed_s=dt)
                completed[task.task_id] = subtrees
                per_worker[w].groups += 1
                per_worker[w].seconds += dt
                if w == fail_worker:
                    fail_count += 1
        if not progressed and not queue.drained:
            # everything in flight on dead workers: force requeue
            for w in list(dead):
                queue.mark_failed(w)

    from repro.core.suffix_tree import SuffixTreeIndex

    subtrees = {}
    for sts in completed.values():
        for st in sts:
            subtrees[st.prefix] = st
    idx = SuffixTreeIndex(s=np.asarray(s), alphabet=alphabet, subtrees=subtrees)
    return idx, queue.stats(), list(per_worker.values())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="dna")
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--memory-mb", type=float, default=1.0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--batch-groups", type=int, default=4,
                    help="virtual trees per worker pull (batched engine width)")
    ap.add_argument("--stream", action="store_true",
                    help="out-of-core single-host build: double-buffered "
                         "chunk pipeline instead of the worker pool")
    ap.add_argument("--device-budget-mb", type=float, default=None,
                    help="device bytes the streaming PrepareState may "
                         "occupy (with --stream; default unbounded = one "
                         "chunk)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable the standby-buffer copy/compute overlap "
                         "(with --stream; the synchronous baseline)")
    ap.add_argument("--sort", default=None, choices=["fused", "lexsort"],
                    help="elastic-step sort engine: fused single-lane keys "
                         "(default) or the three-lane lexsort oracle")
    ap.add_argument("--no-compact", action="store_true",
                    help="disable tail compaction (sort every row even "
                         "after its group has converged)")
    ap.add_argument("--autotune", default=None,
                    choices=["off", "table", "model"],
                    help="kernel tile selection: off = static defaults, "
                         "table = on-disk autotune table (fall back to the "
                         "roofline model), model = roofline model only")
    ap.add_argument("--autotune-table", default=None,
                    help="autotune table path (REPRO_AUTOTUNE_TABLE; "
                         "default .repro_autotune.json)")
    args = ap.parse_args()

    import os
    if args.autotune is not None:
        os.environ["REPRO_AUTOTUNE"] = args.autotune
    if args.autotune_table is not None:
        os.environ["REPRO_AUTOTUNE_TABLE"] = args.autotune_table

    s, alpha = dataset(args.dataset, args.n)
    cfg = EraConfig(memory_bytes=int(args.memory_mb * (1 << 20)),
                    build_impl="none",
                    sort_fuse=(None if args.sort is None
                               else args.sort == "fused"),
                    compaction=False if args.no_compact else None)
    if args.stream:
        budget = (None if args.device_budget_mb is None
                  else int(args.device_budget_mb * (1 << 20)))
        report = BuildReport(VerticalStats(), PrepareStats())
        t0 = time.perf_counter()
        dev, sr = EraIndexer(alpha, cfg).build_stream(
            s, report, device_budget=budget, overlap=not args.no_overlap)
        dt = time.perf_counter() - t0
        print(f"indexed {args.n} symbols in {dt:.2f}s streaming "
              f"({sr.n_chunks} chunks, overlap={'on' if sr.overlap else 'off'})")
        print(f"stream: groups={sr.groups} iterations={sr.iterations} "
              f"copied={sr.bytes_copied / 1e6:.1f}MB "
              f"copy={sr.copy_s * 1e3:.1f}ms "
              f"hidden={sr.copy_hidden_s * 1e3:.1f}ms "
              f"(overlap_frac={sr.overlap_frac:.2f})")
        print(f"leaves={dev.n_leaves} subtrees={dev.n_subtrees}")
        return
    t0 = time.perf_counter()
    idx, qstats, workers = build_distributed(
        s, alpha, cfg, n_workers=args.workers, checkpoint_path=args.checkpoint,
        groups_per_pull=args.batch_groups)
    dt = time.perf_counter() - t0
    print(f"indexed {args.n} symbols in {dt:.2f}s with {args.workers} workers")
    print(f"queue: {qstats}")
    for w in workers:
        print(f"  {w.worker}: {w.groups} groups, {w.seconds:.2f}s")
    print(f"leaves={idx.n_leaves} subtrees={len(idx.subtrees)}")


if __name__ == "__main__":
    main()
