"""Async continuous-batching serving stack (the ROADMAP's serving tier).

:mod:`repro.launch.query_serve` measures the *engine* — pre-padded batches
through ``find_batch_ranges``, one at a time, blocking on every call.  A
real serving front-end sees a stream of individual variable-length
requests and must turn them into sustained qps at bounded tail latency.
This module adds that tier on top of :class:`repro.core.query.DeviceIndex`:

* **Admission queue + continuous batch coalescing** — incoming requests
  queue up (bounded depth, rejects counted) and the server drains up to
  ``max_batch`` of them into the next padded batch.  Pad width and batch
  rows are bucketed to powers of two (``DeviceIndex.pad_batch`` with
  pinned ``m_pad``/``b_pad``), so the jit cache sees a handful of shapes
  instead of one per arrival mix.
* **Overlapped host/device pipeline** — JAX dispatch is asynchronous: the
  server pads/packs and ``jax.device_put``-dispatches batch *k+1* while
  batch *k*'s search is still executing, and only materializes (blocks
  on) a batch's device results one dispatch later.  The hot path never
  calls ``block_until_ready``; ``np.asarray`` at consume time is the only
  synchronization.  ``pipeline=False`` degrades to the synchronous
  one-batch-at-a-time baseline the benchmark compares against.
* **Hot-prefix route cache** — a :class:`repro.core.query.RouteCache`
  keyed on the dense top-trie route (:meth:`DeviceIndex.route_key`)
  resolves repeated hot patterns at admission, before they cost a batch
  row; hits skip the whole binary-search descent.  Exact-pattern keys
  make cache-on results byte-identical to cache-off.
* **Sharded backend** — hand the server a
  :class:`repro.core.fabric.ShardedIndex` and each admitted batch splits
  by route key into per-shard sub-batches (own pow2 pad/pack, own
  RouteCache, dispatched next to each shard's arrays); results merge
  bit-identical to the single-index path.  ``--shards`` turns it on;
  ``--metrics-port`` additionally exposes the live registry as a
  pull-based Prometheus endpoint (:func:`start_metrics_server`).

Config knobs follow the env-var GlobalConfig idiom the kernel selection
already uses (``REPRO_KERNELS``): every :class:`ServeConfig` field reads a
``REPRO_SERVE_*`` variable as its default, so drivers and CI legs can
retune the server without plumbing flags.

CPU example:
  PYTHONPATH=src python -m repro.launch.serving --dataset dna \
      --n 100000 --requests 4096 --mode all
"""

from __future__ import annotations

import argparse
import collections
import os
import threading
import time

import jax
import numpy as np

from repro import obs
from repro.core.api import EraConfig, EraIndexer
from repro.core.query import DeviceIndex, RouteCache
from repro.launch.warmstart import load_or_build


class ServeConfig:
    """Serving knobs; each field defaults from a ``REPRO_SERVE_*`` env var
    (the GlobalConfig idiom), keyword overrides win.

    * ``queue_depth``  — admission queue capacity; arrivals past it are
      rejected (counted, not raised) [REPRO_SERVE_QUEUE_DEPTH=1024]
    * ``max_batch``    — most requests coalesced into one padded batch
      [REPRO_SERVE_MAX_BATCH=256]
    * ``max_wait_ms``  — per-request batch aging: a non-full batch is
      held open for more arrivals until the OLDEST queued request has
      waited this long, then dispatches regardless of fill (closed-loop
      drivers keep the queue full, so this only matters under trickle
      load) [REPRO_SERVE_MAX_WAIT_MS=1.0]
    * ``cache_size``   — hot-prefix route cache entries, 0 disables
      [REPRO_SERVE_CACHE=4096]
    * ``fetch``        — text-window symbols returned per match via the
      fused probe+gather kernel; 0 = ranges only [REPRO_SERVE_FETCH=0]
    * ``pipeline``     — overlap dispatch of batch k+1 with consumption
      of batch k; 0 = synchronous baseline [REPRO_SERVE_PIPELINE=1]
    """

    def __init__(self, **overrides):
        env = os.environ.get
        self.queue_depth = int(env("REPRO_SERVE_QUEUE_DEPTH", "1024"))
        self.max_batch = int(env("REPRO_SERVE_MAX_BATCH", "256"))
        self.max_wait_ms = float(env("REPRO_SERVE_MAX_WAIT_MS", "1.0"))
        self.cache_size = int(env("REPRO_SERVE_CACHE", "4096"))
        self.fetch = int(env("REPRO_SERVE_FETCH", "0"))
        self.pipeline = bool(int(env("REPRO_SERVE_PIPELINE", "1")))
        for key, val in overrides.items():
            if not hasattr(self, key):
                raise TypeError(f"unknown ServeConfig field {key!r}")
            setattr(self, key, val)
        if self.queue_depth < 1 or self.max_batch < 1:
            raise ValueError("queue_depth and max_batch must be >= 1")
        if self.fetch and (self.fetch % 4 or self.fetch < 0):
            raise ValueError(f"fetch={self.fetch} must be 0 or a positive "
                             "multiple of 4")


class _Request:
    __slots__ = ("rid", "pattern", "pat_max", "t_admit")

    def __init__(self, rid, pattern, t_admit):
        self.rid = rid
        self.pattern = np.asarray(pattern, np.int32)
        self.pat_max = int(self.pattern.max(initial=0))
        self.t_admit = t_admit


class _InFlight:
    """One dispatched batch: device result handles + the bookkeeping to
    scatter them back to requests at consume time."""

    __slots__ = ("requests", "keys", "row_of", "handles", "n_rows")

    def __init__(self, requests, keys, row_of, handles, n_rows):
        self.requests = requests
        self.keys = keys
        self.row_of = row_of         # per-request batch row; None = cache hit
        self.handles = handles       # device arrays (NOT blocked on yet)
        self.n_rows = n_rows         # real rows before b_pad padding


class AsyncServer:
    """Continuous-batching server over a :class:`DeviceIndex`.

    Single-threaded event loop: ``submit`` admits requests; ``pump`` (or
    the :meth:`serve` convenience loop) coalesces a batch, dispatches it
    async, and consumes the PREVIOUS batch's results while the new one
    runs on device.  Results per request: ``(positions, window)`` —
    sorted int64 occurrence positions, plus the (fetch,) int32 text
    window at the first SA-order match when ``config.fetch`` > 0 (else
    ``None``).
    """

    def __init__(self, dev: DeviceIndex, config: ServeConfig | None = None):
        self.dev = dev
        self.config = config or ServeConfig()
        # a ShardedIndex (repro.core.fabric) swaps in the sharded backend:
        # each admitted batch splits by route key and every shard keeps
        # its own pow2-bucketed pad/pack and RouteCache (duck-typed so the
        # DeviceIndex path never imports the fabric)
        self.sharded = hasattr(dev, "shards") and hasattr(dev, "shard_span")
        n_caches = len(dev.shards) if self.sharded else 1
        self.caches = [RouteCache(self.config.cache_size)
                       for _ in range(n_caches)]
        self.cache = self.caches[0]
        self.queue: collections.deque[_Request] = collections.deque()
        self.inflight: _InFlight | None = None
        self.results: dict[int, tuple] = {}
        self.latency_s: list[float] = []
        self.n_admitted = 0
        self.n_rejected = 0
        self.n_batches = 0
        self.n_rows_padded = 0
        self.shapes: set[tuple[int, int]] = set()
        self.n_index_swaps = 0
        # span-link plumbing: each taken batch gets a fresh link id that is
        # stamped on BOTH its serve/queue_wait span and the device_dispatch
        # span(s) it becomes, so a trace viewer (and trace_smoke) can join
        # the admission-side wait to the device-side work it fed
        self._link_seq = 0
        self._cur_link = 0
        cap = dev.max_pattern_len - dev.max_pattern_len % 4
        self._width_cap = max(4, cap)
        self._bind_obs()

    def _bind_obs(self) -> None:
        """Bind tracer + registry instruments ONCE at construction: the
        per-batch hot path then costs an attribute access and (when obs
        is off) a no-op method call — the documented overhead budget."""
        tr, m = obs.tracer(), obs.metrics()
        self._tr = tr
        self._trace_on = tr.enabled
        self._metrics_on = m.enabled
        self._m_requests = m.counter(
            "serve_requests_total", "requests admitted")
        self._m_rejected = m.counter(
            "serve_rejected_total", "requests rejected at admission")
        self._m_batches = m.counter(
            "serve_batches_total", "padded batches dispatched")
        self._m_rows_real = m.counter(
            "serve_rows_real_total", "real (non-padding) batch rows")
        self._m_rows_padded = m.counter(
            "serve_rows_padded_total", "batch rows incl. pow2 padding")
        self._m_cache_hits = m.counter(
            "serve_cache_hits_total", "route-cache hits at admission")
        self._m_cache_misses = m.counter(
            "serve_cache_misses_total", "route-cache misses at admission")
        self._m_index_swaps = m.counter(
            "serve_index_swaps_total", "live index generation swaps")
        self._m_cache_flushes = m.counter(
            "serve_cache_flushes_total",
            "route-cache flushes forced by an index epoch change")
        self._h_queue_depth = m.histogram(
            "serve_queue_depth",
            buckets=obs.pow2_buckets(1, self.config.queue_depth),
            help="admission-queue depth sampled at each pump")
        self._h_batch_fill = m.histogram(
            "serve_batch_fill", buckets=(0.1, 0.25, 0.5, 0.75, 0.9, 1.0),
            help="real rows / padded rows per dispatched batch")
        self._h_queue_wait = m.histogram(
            "serve_queue_wait_ms",
            help="per-request wait from admission to batch dispatch")
        self._h_batch_age = m.histogram(
            "serve_batch_age_ms",
            help="oldest queued request's age at dispatch (the "
                 "max_wait_ms batch-aging signal)")
        # callback gauges read live server state at snapshot time; on
        # re-registration the newest server's callbacks win
        m.gauge("serve_cache_size",
                fn=lambda: sum(len(c) for c in self.caches),
                help="route-cache entries (all shards)")
        m.gauge("serve_cache_hit_rate", fn=lambda: self._cache_hit_rate(),
                help="route-cache lifetime hit rate (all shards)")
        m.gauge("serve_queue_depth_now", fn=lambda: len(self.queue),
                help="admission-queue depth right now")

    # ---- admission --------------------------------------------------------

    def submit(self, rid, pattern, now: float | None = None) -> bool:
        """Admit one request; False (and a counter) when the queue is full."""
        if len(self.queue) >= self.config.queue_depth:
            self.n_rejected += 1
            self._m_rejected.inc()
            return False
        self.queue.append(_Request(rid, pattern,
                                   time.perf_counter() if now is None else now))
        self.n_admitted += 1
        self._m_requests.inc()
        return True

    # ---- batching ---------------------------------------------------------

    def _bucket_width(self, m_nat: int) -> int:
        w = 4
        while w < m_nat:
            w *= 2
        return min(w, self._width_cap)

    def _bucket_rows(self, b: int) -> int:
        r = 1
        while r < b:
            r *= 2
        return min(r, self.config.max_batch)

    def _cache_hit_rate(self) -> float:
        hits = sum(c.hits for c in self.caches)
        total = hits + sum(c.misses for c in self.caches)
        return hits / total if total else 0.0

    def _cache_stats(self) -> dict:
        if not self.sharded:
            return self.cache.stats()
        agg = {"size": sum(len(c) for c in self.caches),
               "capacity": sum(c.capacity for c in self.caches),
               "hits": sum(c.hits for c in self.caches),
               "misses": sum(c.misses for c in self.caches),
               "evictions": sum(c.evictions for c in self.caches),
               "hit_rate": self._cache_hit_rate()}
        agg["per_shard"] = [c.stats() for c in self.caches]
        return agg

    def _take_batch(self) -> list[_Request] | None:
        """Pop up to ``max_batch`` requests, honoring batch aging: a
        non-full batch is held open — returns None — until the OLDEST
        queued request has waited ``max_wait_ms``, so trickle load
        coalesces without unbounded per-request staleness."""
        if not self.queue:
            return None
        cfg = self.config
        now = time.perf_counter()
        oldest_age_ms = (now - self.queue[0].t_admit) * 1e3
        if len(self.queue) < cfg.max_batch and oldest_age_ms < cfg.max_wait_ms:
            return None
        requests = [self.queue.popleft()
                    for _ in range(min(len(self.queue), cfg.max_batch))]
        self._link_seq += 1
        self._cur_link = self._link_seq
        if self._metrics_on:
            self._h_batch_age.observe(oldest_age_ms)
            for r in requests:
                self._h_queue_wait.observe((now - r.t_admit) * 1e3)
        if self._trace_on:
            self._tr.complete("serve/queue_wait",
                              int(requests[0].t_admit * 1e9),
                              int(oldest_age_ms * 1e6),
                              rows=len(requests), link=self._cur_link)
        return requests

    def _dispatch(self) -> _InFlight | None:
        """Coalesce up to ``max_batch`` queued requests into one padded
        batch and dispatch it WITHOUT blocking.  Cache hits resolve here
        (no batch row); duplicate in-batch patterns share one row."""
        requests = self._take_batch()
        if requests is None:
            return None
        if self.sharded:
            return self._dispatch_sharded(requests)
        cfg = self.config
        keys = [self.dev.route_key(r.pattern) for r in requests]

        # with the cache OFF this is the honest one-row-per-request
        # baseline (what query_serve does); the cache brings both the
        # cross-batch memo AND in-batch dedup of repeated hot patterns
        caching = cfg.cache_size > 0
        row_of: list[int | None] = []
        key_row: dict[tuple, int] = {}
        miss_req: list[_Request] = []
        hit_vals: dict[tuple, tuple] = {}
        for req, key in zip(requests, keys):
            if caching:
                if key in hit_vals:
                    row_of.append(None)
                    continue
                if key in key_row:
                    row_of.append(key_row[key])
                    continue
                val = self.cache.get(key)
                if val is not None:
                    self._m_cache_hits.inc()
                    hit_vals[key] = val
                    row_of.append(None)
                    continue
                self._m_cache_misses.inc()
                key_row[key] = len(miss_req)
            row_of.append(len(miss_req))
            miss_req.append(req)

        handles = (hit_vals,)
        n_rows = len(miss_req)
        if miss_req:
            pats = [r.pattern for r in miss_req]
            lens = [len(p) for p in pats]
            m_pad = self._bucket_width(-(-max(lens) // 4) * 4)
            b_pad = self._bucket_rows(n_rows)
            with self._tr.span("serve/pad_pack", rows=n_rows, b_pad=b_pad,
                               m_pad=m_pad):
                padded, lengths, route = self.dev.pad_batch(
                    pats, m_pad=m_pad, b_pad=b_pad)
                self.shapes.add((m_pad, b_pad))
                self.n_rows_padded += b_pad
                # host->device explicitly async, then dispatch; nothing
                # below blocks — the device chews on this batch while the
                # host consumes the previous one and pads the next
                padded = jax.device_put(padded)
                lengths = jax.device_put(lengths)
                route = jax.device_put(route)
            self._m_rows_real.inc(n_rows)
            self._m_rows_padded.inc(b_pad)
            self._h_batch_fill.observe(n_rows / b_pad)
            pat_max = max(r.pat_max for r in miss_req)
            with self._tr.span("serve/device_dispatch", rows=n_rows,
                               b_pad=b_pad, m_pad=m_pad,
                               fetch=cfg.fetch, link=self._cur_link):
                if cfg.fetch:
                    start, count, win, _ = self.dev.find_fetch_ranges(
                        padded, lengths, route, fetch=cfg.fetch,
                        pat_max=pat_max)
                    handles = (hit_vals, start, count, win)
                else:
                    start, count = self.dev.find_batch_ranges(
                        padded, lengths, route, pat_max=pat_max)
                    handles = (hit_vals, start, count)
        self.n_batches += 1
        self._m_batches.inc()
        return _InFlight(requests, keys, row_of, handles, n_rows)

    def _dispatch_sharded(self, requests: list[_Request]) -> _InFlight:
        """The ShardedIndex backend: split the batch by route key, then
        pad/pack and dispatch one pow2-bucketed sub-batch PER SHARD (each
        placed next to its shard's arrays).  Patterns shorter than
        ``k_route`` may span shards; they take one row in every covered
        shard and merge at consume time.  Cache lookups go to the primary
        (lowest covered) shard's RouteCache — route→shard is
        deterministic, so the per-shard caches partition the key space."""
        cfg = self.config
        keys = [self.dev.route_key(r.pattern) for r in requests]
        caching = cfg.cache_size > 0
        # per request: None = cache hit, else [(shard, local row), ...]
        row_of: list[list | None] = []
        key_rows: dict[tuple, list] = {}
        hit_vals: dict[tuple, tuple] = {}
        shard_req: dict[int, list[_Request]] = {}
        for req, key in zip(requests, keys):
            if caching:
                if key in hit_vals:
                    row_of.append(None)
                    continue
                if key in key_rows:  # in-batch duplicate: share the rows
                    row_of.append(key_rows[key])
                    continue
            lo, hi = self.dev.shard_span(req.pattern)
            if caching:
                val = self.caches[lo].get(key)
                if val is not None:
                    self._m_cache_hits.inc()
                    hit_vals[key] = val
                    row_of.append(None)
                    continue
                self._m_cache_misses.inc()
            rows = []
            for k in range(lo, hi + 1):
                local = shard_req.setdefault(k, [])
                rows.append((k, len(local)))
                local.append(req)
            if caching:
                key_rows[key] = rows
            row_of.append(rows)

        # shard k -> (real rows, start, count, win) device handles
        shard_handles: dict[int, tuple] = {}
        n_rows = 0
        for k, reqs in sorted(shard_req.items()):
            dev = self.dev.shards[k]
            pats = [r.pattern for r in reqs]
            m_pad = self._bucket_width(-(-max(len(p) for p in pats) // 4) * 4)
            b_pad = self._bucket_rows(len(reqs))
            with self._tr.span("serve/pad_pack", shard=k, rows=len(reqs),
                               b_pad=b_pad, m_pad=m_pad):
                padded, lengths, route = dev.pad_batch(
                    pats, m_pad=m_pad, b_pad=b_pad)
                self.shapes.add((m_pad, b_pad))
                self.n_rows_padded += b_pad
                target = next(iter(dev.ell.devices()))
                padded = jax.device_put(padded, target)
                lengths = jax.device_put(lengths, target)
                route = jax.device_put(route, target)
            self._m_rows_real.inc(len(reqs))
            self._m_rows_padded.inc(b_pad)
            self._h_batch_fill.observe(len(reqs) / b_pad)
            pat_max = max(r.pat_max for r in reqs)
            with self._tr.span("serve/device_dispatch", shard=k,
                               rows=len(reqs), b_pad=b_pad, m_pad=m_pad,
                               fetch=cfg.fetch, link=self._cur_link):
                if cfg.fetch:
                    start, count, win, _ = dev.find_fetch_ranges(
                        padded, lengths, route, fetch=cfg.fetch,
                        pat_max=pat_max)
                else:
                    start, count = dev.find_batch_ranges(
                        padded, lengths, route, pat_max=pat_max)
                    win = None
                shard_handles[k] = (len(reqs), start, count, win)
            n_rows += len(reqs)
        self.n_batches += 1
        self._m_batches.inc()
        return _InFlight(requests, keys, row_of, (hit_vals, shard_handles),
                         n_rows)

    def _consume(self, flight: _InFlight) -> None:
        """Materialize one batch's device results (the only blocking point)
        and scatter them back to requests; misses populate the cache."""
        if self.sharded:
            return self._consume_sharded(flight)
        cfg = self.config
        hit_vals = flight.handles[0]
        ell = self.dev.ell_host
        if flight.n_rows:
            with self._tr.span("serve/consume_sync", rows=flight.n_rows):
                start = np.asarray(flight.handles[1])[: flight.n_rows]
                count = np.asarray(flight.handles[2])[: flight.n_rows]
                win = (np.asarray(flight.handles[3])[: flight.n_rows]
                       if cfg.fetch else None)
        done: dict[int, tuple] = {}
        caching = cfg.cache_size > 0
        now = time.perf_counter()
        for req, key, row in zip(flight.requests, flight.keys,
                                 flight.row_of):
            if row is None:
                val = hit_vals[key]
            elif row in done:  # in-batch duplicate of a shared row
                val = done[row]
            else:
                s, c = int(start[row]), int(count[row])
                # cache the MATERIALIZED response: hot repeats skip the
                # ell slice + sort, not just the device search
                val = (np.sort(ell[s : s + c].astype(np.int64)),
                       win[row].copy() if cfg.fetch else None)
                done[row] = val
                if caching:
                    self.cache.put(key, val)
            self.results[req.rid] = val
            self.latency_s.append(now - req.t_admit)

    def _consume_sharded(self, flight: _InFlight) -> None:
        """Materialize every shard's sub-batch and merge per request:
        positions concatenate and sort (shards own disjoint leaf ranges,
        so the merge is associative and bit-identical to the unsharded
        engine); the fetch window comes from the first route-ordered
        shard with a hit — the same rule as
        :meth:`repro.core.fabric.ShardedIndex.find_fetch_batch`."""
        cfg = self.config
        hit_vals, shard_handles = flight.handles
        mats: dict[int, tuple] = {}
        for k, (n_k, start, count, win) in sorted(shard_handles.items()):
            with self._tr.span("serve/consume_sync", shard=k, rows=n_k):
                mats[k] = (np.asarray(start)[:n_k], np.asarray(count)[:n_k],
                           np.asarray(win)[:n_k] if cfg.fetch else None)
        done: dict[tuple, tuple] = {}
        caching = cfg.cache_size > 0
        now = time.perf_counter()
        for req, key, rows in zip(flight.requests, flight.keys,
                                  flight.row_of):
            if rows is None:
                val = hit_vals[key]
            elif tuple(rows) in done:
                val = done[tuple(rows)]
            else:
                parts, win_out = [], None
                for k, row in rows:
                    start, count, win = mats[k]
                    s, c = int(start[row]), int(count[row])
                    if c:
                        ell = self.dev.shards[k].ell_host
                        parts.append(ell[s : s + c].astype(np.int64))
                        if cfg.fetch and win_out is None:
                            win_out = win[row].copy()
                if cfg.fetch and win_out is None:
                    win_out = np.full(cfg.fetch, -1, np.int32)
                pos = (np.sort(np.concatenate(parts)) if parts
                       else np.empty(0, np.int64))
                val = (pos, win_out if cfg.fetch else None)
                done[tuple(rows)] = val
                if caching:
                    self.caches[rows[0][0]].put(key, val)
            self.results[req.rid] = val
            self.latency_s.append(now - req.t_admit)

    # ---- live index swap --------------------------------------------------

    def update_index(self, dev) -> dict:
        """Swap in a new index generation (e.g. the output of
        ``EraIndexer.append_device``) without dropping queued requests.

        The in-flight batch was dispatched against the OLD index, so it is
        consumed first — its device handles and row bookkeeping are only
        meaningful there; queued-but-undispatched requests simply ride
        into the next batch against the new index.  RouteCaches memoize
        materialized positions, which an append invalidates wholesale, so
        they are flushed whenever the index ``epoch`` changes (and rebuilt
        when the shard count changes); a same-epoch swap — a replica of
        the identical index, e.g. after re-placement — keeps them warm.
        """
        if self.inflight is not None:
            self._consume(self.inflight)
            self.inflight = None
        old_epoch = int(getattr(self.dev, "epoch", 0))
        new_epoch = int(getattr(dev, "epoch", 0))
        self.dev = dev
        self.sharded = hasattr(dev, "shards") and hasattr(dev, "shard_span")
        n_caches = len(dev.shards) if self.sharded else 1
        flushed = False
        if len(self.caches) != n_caches:
            self.caches = [RouteCache(self.config.cache_size)
                           for _ in range(n_caches)]
            flushed = True
        elif new_epoch != old_epoch:
            for c in self.caches:
                c.clear()
            flushed = True
        self.cache = self.caches[0]
        cap = dev.max_pattern_len - dev.max_pattern_len % 4
        self._width_cap = max(4, cap)
        self.n_index_swaps += 1
        self._m_index_swaps.inc()
        if flushed:
            self._m_cache_flushes.inc()
        if self._trace_on:
            self._tr.instant("serve/index_swap", epoch=new_epoch,
                             flushed=int(flushed), shards=n_caches)
        return {"epoch": new_epoch, "flushed": flushed, "shards": n_caches}

    # ---- the serving loop -------------------------------------------------

    def pump(self) -> bool:
        """One loop turn: dispatch the next batch, then consume the
        previous one (which overlapped with this dispatch).  Returns
        whether anything happened — False means the loop is idle (empty,
        or holding a partial batch open for aging)."""
        if self.queue:
            self._h_queue_depth.observe(len(self.queue))
        nxt = self._dispatch()
        did = nxt is not None
        if self.inflight is not None:
            self._consume(self.inflight)
            did = True
        self.inflight = nxt
        if nxt is not None and not self.config.pipeline:
            self._consume(nxt)
            self.inflight = None
        return did

    def drain(self) -> None:
        """Run the loop until queue and pipeline are empty."""
        while self.queue or self.inflight is not None:
            if not self.pump():
                time.sleep(50e-6)  # holding a partial batch for aging

    def serve(self, patterns) -> list[tuple]:
        """Closed-loop convenience: admit ``patterns`` as fast as the queue
        allows, pump until done, return results aligned with the input."""
        base = self.n_admitted + self.n_rejected
        i = 0
        while i < len(patterns) or self.queue or self.inflight is not None:
            while i < len(patterns) and self.submit(base + i, patterns[i]):
                i += 1
            if not self.pump() and i >= len(patterns):
                time.sleep(50e-6)  # only aging can unblock now
        return [self.results.pop(base + j) for j in range(len(patterns))]

    def stats(self) -> dict:
        lat = np.asarray(self.latency_s) if self.latency_s else np.zeros(1)
        return {
            "admitted": self.n_admitted,
            "rejected": self.n_rejected,
            "served": len(self.latency_s),
            "batches": self.n_batches,
            "rows_padded": self.n_rows_padded,
            "shapes": sorted(self.shapes),
            "lat_p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
            "lat_p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
            "cache": self._cache_stats(),
        }


def start_metrics_server(port: int, host: str = "127.0.0.1"):
    """A pull-based metrics endpoint on a stdlib ``http.server`` daemon
    thread: GET ``/`` or ``/metrics`` returns the live registry in the
    Prometheus text exposition format (the same payload
    ``obs.export_all`` writes to ``era_metrics.prom``), so a scraper can
    poll a long-lived serving process instead of waiting for the exit
    snapshot.  ``port=0`` binds an ephemeral port (tests); the bound port
    is ``server.server_address[1]``.  Returns the server — call
    ``shutdown()`` to stop it; off unless a driver opts in
    (``--metrics-port``)."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path.split("?", 1)[0].rstrip("/") not in ("", "/metrics"):
                self.send_error(404)
                return
            body = obs.metrics().to_prometheus().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):  # keep the serving loop's stdout clean
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    thread = threading.Thread(target=server.serve_forever,
                              name="era-metrics", daemon=True)
    thread.start()
    return server


def make_hot_workload(s: np.ndarray, rng: np.random.Generator, *,
                      n_requests: int, hot_pool: int = 32,
                      hot_frac: float = 0.8, min_len: int = 4,
                      max_len: int = 24, n_symbols: int = 4,
                      ) -> list[np.ndarray]:
    """A skewed request stream: ``hot_frac`` of requests re-ask one of
    ``hot_pool`` planted patterns (the cacheable head of the
    distribution); the rest are fresh planted-or-random patterns."""
    hot = []
    for _ in range(hot_pool):
        m = int(rng.integers(min_len, max_len + 1))
        i = int(rng.integers(0, len(s) - 1 - m))
        hot.append(np.asarray(s[i : i + m], np.int32))
    out = []
    for _ in range(n_requests):
        if rng.random() < hot_frac:
            out.append(hot[int(rng.integers(0, hot_pool))])
        else:
            m = int(rng.integers(min_len, max_len + 1))
            if rng.random() < 0.5:
                i = int(rng.integers(0, len(s) - 1 - m))
                out.append(np.asarray(s[i : i + m], np.int32))
            else:
                out.append(rng.integers(0, n_symbols, size=m,
                                        dtype=np.int32))
    return out


def run_closed_loop(dev: DeviceIndex, patterns, config: ServeConfig,
                    ) -> tuple[list[tuple], dict]:
    """Serve a whole workload closed-loop; returns (results, stats) with
    wall-clock qps added.  Warm up the jit cache first (one call per
    bucketed shape) so the measurement is steady-state serving."""
    server = AsyncServer(dev, config)
    t0 = time.perf_counter()
    results = server.serve(patterns)
    wall = time.perf_counter() - t0
    stats = server.stats()
    stats["wall_s"] = round(wall, 4)
    stats["qps"] = round(len(patterns) / max(wall, 1e-9), 1)
    return results, stats


def serve_stream(dataset_name: str = "dna", *, n: int = 100_000,
                 requests: int = 4096, hot_frac: float = 0.8,
                 hot_pool: int = 32, min_len: int = 4, max_len: int = 24,
                 memory_bytes: int = 1 << 20, seed: int = 0,
                 index_path: str | None = None, mode: str = "all",
                 shards: int = 0):
    """Build/load an index, run the serving stack, report stats per mode.

    Modes: ``sync`` (pipeline off, cache off — the one-batch-at-a-time
    baseline), ``async`` (pipeline on, cache off), ``cached`` (pipeline
    on, cache on), or ``all``.  ``shards`` > 0 serves a
    :class:`repro.core.fabric.ShardedIndex` with that many route-key
    shards (0 = the single DeviceIndex path).
    """
    max_len4 = -(-max_len // 4) * 4

    if shards > 0:
        from repro.core.fabric import ShardedIndex

        def build(s, alphabet):
            cfg = EraConfig(memory_bytes=memory_bytes, build_impl="none")
            return EraIndexer(alphabet, cfg).build_sharded(
                s, n_shards=shards, max_pattern_len=max(64, max_len4))

        dev, s, alphabet, t_build = load_or_build(
            index_path, dataset_name, n, seed, load=ShardedIndex.load,
            build=build, sharded=True)
    else:
        def build(s, alphabet):
            cfg = EraConfig(memory_bytes=memory_bytes, build_impl="none")
            return EraIndexer(alphabet, cfg).build_device(
                s, max_pattern_len=max(64, max_len4))

        dev, s, alphabet, t_build = load_or_build(
            index_path, dataset_name, n, seed, load=DeviceIndex.load,
            build=build)
    rng = np.random.default_rng(seed + 7)
    pats = make_hot_workload(s, rng, n_requests=requests, hot_pool=hot_pool,
                             hot_frac=hot_frac, min_len=min_len,
                             max_len=max_len,
                             n_symbols=len(alphabet.symbols))

    modes = {
        "sync": ServeConfig(pipeline=False, cache_size=0),
        "async": ServeConfig(pipeline=True, cache_size=0),
        "cached": ServeConfig(pipeline=True),
    }
    wanted = modes if mode == "all" else {mode: modes[mode]}
    report = {"dataset": dataset_name, "n_symbols": len(s),
              "requests": requests, "t_build_s": round(t_build, 3)}
    baseline = None
    for name, cfg in wanted.items():
        # per-mode warmup: cache-hit shrinkage changes the bucketed batch
        # shapes each mode sees, so each compiles its own jit shapes ONCE
        # before the timed steady-state pass
        run_closed_loop(dev, pats, cfg)
        _, stats = run_closed_loop(dev, pats, cfg)
        if name == "sync":
            baseline = stats["qps"]
        if baseline:
            stats["vs_sync"] = round(stats["qps"] / baseline, 2)
        report[name] = stats
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="dna")
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--requests", type=int, default=4096)
    ap.add_argument("--hot-frac", type=float, default=0.8)
    ap.add_argument("--hot-pool", type=int, default=32)
    ap.add_argument("--min-len", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=24)
    ap.add_argument("--mode", default="all",
                    choices=["all", "sync", "async", "cached"])
    ap.add_argument("--index-path", default=None,
                    help="npz cache: load the flattened index if the file "
                         "exists, else build once and save it there "
                         "(per-shard _shard{k}.npz archives with --shards)")
    ap.add_argument("--shards", type=int, default=0,
                    help="serve a ShardedIndex with this many route-key "
                         "shards (0 = single DeviceIndex)")
    ap.add_argument("--metrics-port", type=int, default=0,
                    help="expose the live metrics registry as a Prometheus "
                         "text endpoint on this port (0 = off)")
    args = ap.parse_args()
    metrics_srv = None
    if args.metrics_port:
        metrics_srv = start_metrics_server(args.metrics_port)
        print(f"metrics: http://127.0.0.1:"
              f"{metrics_srv.server_address[1]}/metrics")
    report = serve_stream(args.dataset, n=args.n, requests=args.requests,
                          hot_frac=args.hot_frac, hot_pool=args.hot_pool,
                          min_len=args.min_len, max_len=args.max_len,
                          index_path=args.index_path, mode=args.mode,
                          shards=args.shards)
    for key, val in report.items():
        print(f"{key}: {val}")
    for path in obs.export_all():
        print(f"wrote {path}")
    if metrics_srv is not None:
        metrics_srv.shutdown()


if __name__ == "__main__":
    main()
