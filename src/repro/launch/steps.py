"""Jit-able train / prefill / decode step builders shared by the training
driver, the serving driver and the multi-pod dry-run."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import adamw


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over all positions; vocab may be model-sharded (the gather
    and the logsumexp reduce become collectives under GSPMD)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return jnp.mean(logz - ll)


def make_loss_fn(cfg: ModelConfig, *, aux_weight: float = 0.01,
                 remat_policy: str = "none"):
    def loss_fn(params, batch):
        logits, aux = T.forward_train(params, batch, cfg, remat=True,
                                      remat_policy=remat_policy)
        return cross_entropy(logits, batch["labels"]) + aux_weight * aux

    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    *, remat_policy: str = "none"):
    loss_fn = make_loss_fn(cfg, remat_policy=remat_policy)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_opt, metrics = adamw.update(opt_cfg, grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, **metrics}

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch, cache):
        return T.forward_prefill(params, batch, cfg, cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, greedy: bool = True):
    def serve_step(params, tokens, cache):
        logits, cache = T.forward_decode(params, tokens, cfg, cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache

    return serve_step
