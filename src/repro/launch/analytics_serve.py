"""Sustained batched analytics serving driver (read-side, like query_serve).

Builds an ERA index over a dataset, lifts it into the device-resident
:class:`repro.core.analytics.AnalyticsEngine`, then drives a sustained loop
of matching-statistics batches (the analytics workload with a per-request
shape: one query string in, per-position longest-match lengths + witnesses
out) and reports positions/sec plus per-batch latency.  Repeat mining and
k-mer spectra are one-shot index-wide passes, so they are reported once at
startup rather than looped.

CPU example:
  PYTHONPATH=src python -m repro.launch.analytics_serve --dataset dna \
      --n 100000 --batch 512 --iters 20 --index-path /tmp/era_analytics.npz
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.analytics import AnalyticsEngine
from repro.core.api import EraConfig, EraIndexer
from repro.launch.warmstart import load_or_build


def make_query(s: np.ndarray, rng: np.random.Generator, *, batch: int,
               planted_frac: float, n_symbols: int) -> np.ndarray:
    """A query string of ``batch`` positions: planted slices of S (long
    matches) spliced with random stretches (short matches)."""
    out = np.empty(batch, np.uint8)
    i = 0
    while i < batch:
        m = int(rng.integers(8, 65))
        m = min(m, batch - i)
        if rng.random() < planted_frac:
            j = int(rng.integers(0, len(s) - 1 - m))
            out[i : i + m] = s[j : j + m]
        else:
            out[i : i + m] = rng.integers(0, n_symbols, size=m)
        i += m
    return out


def serve_analytics(dataset_name: str = "dna", *, n: int = 100_000,
                    batch: int = 512, iters: int = 20, window: int = 64,
                    planted_frac: float = 0.7, memory_bytes: int = 1 << 20,
                    seed: int = 0, index_path: str | None = None):
    if iters < 1 or batch < 1:
        raise ValueError(f"need iters >= 1 and batch >= 1, got {iters}, {batch}")
    rng = np.random.default_rng(seed + 1)

    def build(s, alphabet):
        cfg = EraConfig(memory_bytes=memory_bytes, build_impl="none")
        return EraIndexer(alphabet, cfg).build_analytics(s)[1]

    # warm start: one npz holds the flattened index AND the LCP array
    eng, s, alphabet, t_build = load_or_build(
        index_path, dataset_name, n, seed,
        load=AnalyticsEngine.load, build=build, dev_of=lambda e: e.dev)
    if len(s) <= 66:  # make_query plants slices up to 64 symbols
        raise ValueError(f"indexed string too short ({len(s)} symbols)")

    # index-wide one-shot passes (reported once, not looped)
    rep = eng.longest_repeat()
    distinct = eng.distinct_substrings()

    queries = [make_query(s, rng, batch=batch, planted_frac=planted_frac,
                          n_symbols=len(alphabet.symbols))
               for _ in range(iters)]
    ms, wit = eng.matching_stats(queries[0], window=window)  # warmup/compile

    lat = []
    matched = 0
    t0 = time.perf_counter()
    for q in queries:
        t1 = time.perf_counter()
        ms, wit = eng.matching_stats(q, window=window)
        lat.append(time.perf_counter() - t1)
        matched += int(ms.sum())
    t_serve = time.perf_counter() - t0

    lat = np.array(lat)
    return {
        "dataset": dataset_name,
        "n_symbols": eng.total,
        "n_subtrees": eng.dev.n_subtrees,
        "t_build_s": round(t_build, 3),
        "longest_repeat": None if rep is None else rep["length"],
        "distinct_substrings": distinct,
        "batches": iters,
        "batch": batch,
        "positions": iters * batch,
        "mean_match_len": round(matched / (iters * batch), 2),
        "positions_per_s": round(iters * batch / max(t_serve, 1e-9), 1),
        "batch_p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
        "batch_p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="dna")
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--batch", type=int, default=512,
                    help="query positions per batch (the query length)")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--window", type=int, default=64,
                    help="matching-statistics length cap")
    ap.add_argument("--planted-frac", type=float, default=0.7)
    ap.add_argument("--index-path", default=None,
                    help="npz cache: load index+LCP if the file exists, "
                         "else build once and save there")
    args = ap.parse_args()
    stats = serve_analytics(args.dataset, n=args.n, batch=args.batch,
                            iters=args.iters, window=args.window,
                            planted_frac=args.planted_frac,
                            index_path=args.index_path)
    print(stats)


if __name__ == "__main__":
    main()
