"""End-to-end LM training driver.

Runs on whatever mesh is available: production pods (``--mesh prod``) or
the single-device host mesh for the CPU end-to-end example (``--arch``
with ``--smoke`` reduces the config).  Features: AdamW + cosine schedule,
remat, checkpoint/restore with atomic commits, deterministic restart-safe
data pipeline, optional int8-compressed DP gradients (shard_map mode).

Example (CPU, ~100M-param smoke model, a few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 300 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.data.tokens import TokenPipelineConfig, batch_at_step
from repro.launch import steps as step_lib
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as T
from repro.models.config import smoke_config
from repro.models.registry import get_config
from repro.optim import adamw
from repro.runtime import checkpoint


def train(
    arch: str,
    *,
    smoke: bool = True,
    steps: int = 100,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-4,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    resume: bool = True,
    mesh=None,
    dtype=jnp.float32,
    log_every: int = 10,
):
    cfg = get_config(arch)
    if smoke:
        cfg = smoke_config(cfg)
    if cfg.family == "encdec" or cfg.frontend:
        raise SystemExit("train driver targets decoder-only archs; "
                         "see examples/ for the others")

    mesh = mesh or make_host_mesh()
    opt_cfg = adamw.AdamWConfig(lr=lr, total_steps=steps, warmup_steps=max(10, steps // 20))
    pipe = TokenPipelineConfig(vocab=cfg.vocab, batch=batch, seq_len=seq)

    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype)
    opt_state = adamw.init(params)
    start_step = 0

    if ckpt_dir and resume:
        latest = checkpoint.latest_step_path(ckpt_dir)
        if latest:
            (params, opt_state), meta = checkpoint.restore(latest, (params, opt_state))
            start_step = int(meta.get("step", 0))
            print(f"resumed from {latest} at step {start_step}")

    specs_tree = T.model_specs(cfg)
    p_shard = shd.param_shardings(specs_tree, mesh)
    train_step = step_lib.make_train_step(cfg, opt_cfg)
    with mesh:
        jitted = jax.jit(train_step, donate_argnums=(0, 1))

        losses = []
        t0 = time.perf_counter()
        for step in range(start_step, steps):
            batch_np = batch_at_step(pipe, step)
            batch_dev = jax.tree.map(jnp.asarray, batch_np)
            params, opt_state, metrics = jitted(params, opt_state, batch_dev)
            if (step + 1) % log_every == 0 or step == start_step:
                loss = float(metrics["loss"])
                losses.append(loss)
                tok_s = pipe.batch * pipe.seq_len * log_every / max(1e-9, time.perf_counter() - t0)
                print(f"step {step+1:5d}  loss {loss:.4f}  gnorm "
                      f"{float(metrics['grad_norm']):.3f}  tok/s {tok_s:,.0f}", flush=True)
                t0 = time.perf_counter()
            if ckpt_dir and (step + 1) % ckpt_every == 0:
                path = f"{ckpt_dir}/step_{step+1}.npz"
                checkpoint.save(path, (params, opt_state), step=step + 1,
                                meta={"arch": arch})
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", choices=["host", "prod", "multipod"], default="host")
    args = ap.parse_args()

    mesh = {"host": make_host_mesh,
            "prod": make_production_mesh,
            "multipod": lambda: make_production_mesh(multi_pod=True)}[args.mesh]()
    train(args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
          seq=args.seq, lr=args.lr, ckpt_dir=args.ckpt_dir, mesh=mesh)


if __name__ == "__main__":
    main()
