import argparse
import json
import os
import sys


def _parse_args(argv=None):
    ap = argparse.ArgumentParser(
        description="Sharded index fabric driver: simulate an N-device "
                    "mesh on CPU, run SPMD construction, optionally "
                    "benchmark it against the single-device batched "
                    "baseline or save the per-shard archives.")
    ap.add_argument("--devices", type=int, default=4,
                    help="simulated host devices (XLA_FLAGS "
                         "--xla_force_host_platform_device_count; must be "
                         "set before jax imports, which is why this driver "
                         "exists) [4]")
    ap.add_argument("--dataset", default="dna")
    ap.add_argument("--n", type=int, default=120_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--memory-bytes", type=int, default=1 << 16)
    ap.add_argument("--shards", type=int, default=0,
                    help="index route-key shards (0 = mesh size)")
    ap.add_argument("--mode", default="build",
                    choices=["build", "bench", "save"],
                    help="build: construct + verify a ShardedIndex; "
                         "bench: time sharded vs single-device baseline; "
                         "save: build and write per-shard npz archives")
    ap.add_argument("--index-path", default=None,
                    help="archive base path for --mode save "
                         "(writes {path}_shard{k}.npz)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--sort", default=None, choices=["fused", "lexsort"],
                    help="elastic-step sort engine (REPRO_SORT): fused "
                         "single-lane keys (default) or the lexsort oracle")
    ap.add_argument("--no-compact", action="store_true",
                    help="disable tail compaction (REPRO_COMPACT=off)")
    ap.add_argument("--autotune", default=None,
                    choices=["off", "table", "model"],
                    help="kernel tile selection mode (REPRO_AUTOTUNE)")
    ap.add_argument("--autotune-table", default=None,
                    help="autotune table path (REPRO_AUTOTUNE_TABLE)")
    ap.add_argument("--json", action="store_true",
                    help="emit one machine-readable JSON object on stdout "
                         "(benchmarks/bench_fabric.py subprocess mode)")
    return ap.parse_args(argv)


def run(args) -> dict:
    """The post-import body: everything that touches jax."""
    import time

    import numpy as np

    from repro.core import fabric
    from repro.core.api import EraConfig, EraIndexer
    from repro.core.prepare import subtree_prepare_batch
    from repro.data.strings import dataset

    import jax

    s, alphabet = dataset(args.dataset, args.n, seed=args.seed)
    cfg = EraConfig(memory_bytes=args.memory_bytes, r_bytes=4096,
                    build_impl="none")
    ix = EraIndexer(alphabet, cfg)
    out = {
        "dataset": args.dataset, "n": args.n, "seed": args.seed,
        "memory_bytes": args.memory_bytes,
        "devices": jax.device_count(), "backend": jax.default_backend(),
    }

    if args.mode == "bench":
        groups = ix.partition(s)
        capacity = ix._capacity(groups)
        s_padded = ix._device_text(s)
        ecfg = cfg.elastic_config()

        def best_of(fn):
            fn()  # warmup covers every (w, f_prime) program compile
            times = []
            for _ in range(args.repeats):
                t0 = time.perf_counter()
                fn()
                times.append(time.perf_counter() - t0)
            return min(times)

        t_base = best_of(
            lambda: subtree_prepare_batch(s_padded, groups, capacity, ecfg))
        t_shard = best_of(
            lambda: fabric.sharded_prepare(s_padded, groups, capacity, ecfg))
        out.update(groups=len(groups), capacity=capacity,
                   t_baseline_s=round(t_base, 4),
                   t_sharded_s=round(t_shard, 4),
                   speedup=round(t_base / t_shard, 3))
        return out

    n_shards = args.shards or jax.device_count()
    t0 = time.perf_counter()
    sh = ix.build_sharded(s, n_shards=n_shards)
    out["t_build_s"] = round(time.perf_counter() - t0, 4)
    out["shards"] = sh.stats()
    # a probe batch proves the routed query path end to end
    rng = np.random.default_rng(args.seed + 1)
    pats = [np.asarray(s[int(i) : int(i) + 12], np.int32)
            for i in rng.integers(0, len(s) - 13, size=16)]
    hits = sh.find_batch(pats)
    out["probe_hits"] = [int(len(h)) for h in hits]
    if args.mode == "save":
        if not args.index_path:
            raise SystemExit("--mode save needs --index-path")
        sh.save(args.index_path)
        out["archives"] = fabric.ShardedIndex.shard_files(args.index_path)
    return out


def main(argv=None):
    args = _parse_args(argv)
    # engine knobs travel via the env-dispatch idiom so every layer
    # (batched step, fabric shard step, kernel tile pick) sees them
    if args.sort is not None:
        os.environ["REPRO_SORT"] = args.sort
    if args.no_compact:
        os.environ["REPRO_COMPACT"] = "off"
    if args.autotune is not None:
        os.environ["REPRO_AUTOTUNE"] = args.autotune
    if args.autotune_table is not None:
        os.environ["REPRO_AUTOTUNE_TABLE"] = args.autotune_table
    # the whole point of this driver: the simulated device count must be
    # in the environment BEFORE the first jax import (same idiom as
    # launch/dryrun.py) — so argparse runs first and jax imports inside
    # run()
    if "jax" in sys.modules:
        import jax
        if jax.device_count() < args.devices:
            raise SystemExit(
                "jax is already imported with "
                f"{jax.device_count()} device(s); shard_run must own the "
                "process (python -m repro.launch.shard_run)")
    else:
        flags = os.environ.get("XLA_FLAGS", "")
        if "--xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()
    out = run(args)
    if args.json:
        print(json.dumps(out, sort_keys=True))
    else:
        for key, val in out.items():
            print(f"{key}: {val}")


if __name__ == "__main__":
    main()
