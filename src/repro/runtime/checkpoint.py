"""Checkpoint / restore for fault tolerance.

Two checkpoint families:

* **Training state** (params + optimizer + step): flat-key npz per process
  with step provenance and an atomic rename commit, so a node can die
  mid-write without corrupting the latest checkpoint.  Restore validates
  the tree structure against the abstract target.

* **ERA construction state**: each completed *virtual tree* is a natural
  recovery unit (the paper's groups are independent — §5); the scheduler
  persists one record per finished group and recovery replays only the
  remainder.  See ``runtime/scheduler.py``.
"""

from __future__ import annotations

import json
import os
import re
import tempfile

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree, *, step: int | None = None, meta: dict | None = None):
    """Atomic checkpoint write (tmp file + rename)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    blobs = _flatten_with_paths(tree)
    payload = dict(blobs)
    header = {"step": step, **(meta or {})}
    payload["__meta__"] = np.frombuffer(json.dumps(header).encode(), dtype=np.uint8)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **payload)  # file handle: numpy won't append ".npz"
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def restore(path: str, target_tree):
    """Restore into the structure of ``target_tree`` (abstract ok)."""
    data = np.load(path, allow_pickle=False)
    meta = json.loads(bytes(data["__meta__"]).decode()) if "__meta__" in data else {}
    leaves, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    out = []
    for pathk, leaf in leaves:
        key = "/".join(_path_str(p) for p in pathk)
        if key not in data:
            raise KeyError(f"checkpoint missing key {key!r}")
        arr = data[key]
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: shape {arr.shape} != expected {want}")
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target_tree), out)
    return tree, meta


def latest_step_path(ckpt_dir: str, prefix: str = "step_") -> str | None:
    if not os.path.isdir(ckpt_dir):
        return None
    best, best_step = None, -1
    for f in os.listdir(ckpt_dir):
        m = re.fullmatch(rf"{prefix}(\d+)\.npz", f)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(ckpt_dir, f), int(m.group(1))
    return best
