"""Fault-tolerant work-queue scheduler for distributed ERA construction.

The paper's parallel versions (§5) have a master distribute virtual trees
to workers "equally".  At 1000+-node scale that static split is fragile:
nodes fail, nodes straggle, group costs are skewed.  This scheduler keeps
the paper's unit of work (the virtual tree — independent, no merge phase)
and adds the production machinery around it:

* **cost-aware ordering** — groups dispatched largest-frequency-first
  (longest-processing-time heuristic ≈ paper's FFD, but online);
* **work stealing / re-dispatch** — idle workers pull from the queue; a
  group assigned to a worker that misses its deadline is re-queued
  (straggler mitigation — duplicate completions are harmless because
  group construction is deterministic and idempotent);
* **node failure** — ``mark_failed(worker)`` re-queues all of that
  worker's in-flight groups; elastic scale-up/down is just changing the
  worker set between pulls;
* **per-group checkpointing** — completed groups are persisted (one
  record each); recovery replays only the remainder (paper §5's "no
  merging phase" is what makes this exact).

The scheduler is deliberately host-side and synchronous-API (pull/complete
calls); drivers decide whether workers are threads, devices in a
``shard_map`` batch, or remote processes.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Iterable


@dataclasses.dataclass
class Task:
    task_id: int
    cost: float               # predicted cost (group total frequency)
    payload: object = None    # e.g. a VirtualTree
    assigned_to: str | None = None
    assigned_at: float = 0.0
    attempts: int = 0
    done: bool = False


class WorkQueue:
    def __init__(self, *, deadline_factor: float = 3.0,
                 min_deadline_s: float = 5.0,
                 checkpoint_path: str | None = None):
        self._tasks: dict[int, Task] = {}
        self._pending: list[int] = []   # max-heap by cost (sorted desc)
        self._inflight: dict[int, Task] = {}
        self._deadline_factor = deadline_factor
        self._min_deadline_s = min_deadline_s
        self._ema_cost_rate: float | None = None  # seconds per unit cost
        self._ckpt = checkpoint_path
        self._completed_log: list[dict] = []
        if checkpoint_path and os.path.exists(checkpoint_path):
            with open(checkpoint_path) as f:
                self._completed_log = [json.loads(l) for l in f if l.strip()]

    # ---- setup -----------------------------------------------------------

    def add_tasks(self, costs: Iterable[float], payloads=None):
        payloads = list(payloads) if payloads is not None else None
        recovered = {r["task_id"] for r in self._completed_log}
        for i, c in enumerate(costs):
            t = Task(task_id=i, cost=float(c),
                     payload=payloads[i] if payloads else None)
            if i in recovered:
                t.done = True
            self._tasks[i] = t
        self._pending = sorted(
            (i for i, t in self._tasks.items() if not t.done),
            key=lambda i: -self._tasks[i].cost)

    # ---- worker API --------------------------------------------------------

    def pull(self, worker: str) -> Task | None:
        """Next task for ``worker`` (largest-cost-first); None if drained."""
        self._requeue_stragglers()
        if not self._pending:
            return None
        tid = self._pending.pop(0)
        t = self._tasks[tid]
        t.assigned_to = worker
        t.assigned_at = time.monotonic()
        t.attempts += 1
        self._inflight[tid] = t
        return t

    def complete(self, task_id: int, *, worker: str, elapsed_s: float | None = None,
                 result_meta: dict | None = None):
        t = self._tasks[task_id]
        if t.done:
            return  # duplicate completion from a re-dispatched straggler: fine
        t.done = True
        self._inflight.pop(task_id, None)
        if elapsed_s and t.cost > 0:
            rate = elapsed_s / t.cost
            self._ema_cost_rate = (rate if self._ema_cost_rate is None
                                   else 0.7 * self._ema_cost_rate + 0.3 * rate)
        rec = {"task_id": task_id, "worker": worker,
               "elapsed_s": elapsed_s, **(result_meta or {})}
        self._completed_log.append(rec)
        if self._ckpt:
            with open(self._ckpt, "a") as f:
                f.write(json.dumps(rec) + "\n")

    # ---- failure / elasticity ---------------------------------------------

    def mark_failed(self, worker: str) -> list[int]:
        """Node loss: re-queue every in-flight task owned by ``worker``."""
        lost = [tid for tid, t in self._inflight.items() if t.assigned_to == worker]
        for tid in lost:
            self._requeue(tid)
        return lost

    def _requeue(self, tid: int):
        t = self._inflight.pop(tid, None)
        if t is None or t.done:
            return
        t.assigned_to = None
        # insert keeping cost-descending order
        lo, hi = 0, len(self._pending)
        while lo < hi:
            mid = (lo + hi) // 2
            if self._tasks[self._pending[mid]].cost >= t.cost:
                lo = mid + 1
            else:
                hi = mid
        self._pending.insert(lo, tid)

    def _requeue_stragglers(self):
        """Re-dispatch tasks that exceeded their deadline (duplicate work is
        safe: deterministic + idempotent completion)."""
        if self._ema_cost_rate is None:
            return
        now = time.monotonic()
        for tid, t in list(self._inflight.items()):
            deadline = max(self._min_deadline_s,
                           self._deadline_factor * self._ema_cost_rate * t.cost)
            if now - t.assigned_at > deadline:
                self._requeue(tid)

    # ---- introspection ------------------------------------------------------

    @property
    def drained(self) -> bool:
        return all(t.done for t in self._tasks.values())

    @property
    def remaining(self) -> int:
        return sum(1 for t in self._tasks.values() if not t.done)

    def stats(self) -> dict:
        return {
            "total": len(self._tasks),
            "done": sum(1 for t in self._tasks.values() if t.done),
            "pending": len(self._pending),
            "inflight": len(self._inflight),
            "reattempts": sum(max(0, t.attempts - 1) for t in self._tasks.values()),
            "ema_cost_rate": self._ema_cost_rate,
        }
