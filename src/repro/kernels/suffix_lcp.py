"""Pallas TPU kernel: batched suffix-pair LCP (global LCP array assembly).

The analytics engine builds the GLOBAL LCP array over the flattened leaf
array (= the suffix array): intra-subtree entries are already known — they
are the ``b_off`` divergence depths SubTreePrepare emitted — so only the
T-1 cross-subtree boundary entries remain.  Those pairs come from DIFFERENT
prefix-free vertical-partition prefixes, so their LCP is strictly less than
the shorter prefix length: a single bounded-width comparison suffices, no
iterative deepening.

Layout mirrors :mod:`repro.kernels.pattern_probe`: both position arrays are
scalar-prefetched, each grid step DMAs the two ``(2, tile)`` HBM windows
containing the reads (a read may straddle one tile boundary) and writes one
``(1, 1)`` LCP value.  The kernel compares raw symbols (an iota-min over
the first unequal position) — symbol equality needs no packing, and the
result is identical to the packed-word reference oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiles import default_interpret, stage_tiles


def _kernel(pa_ref, pb_ref, a_lo_ref, a_hi_ref, b_lo_ref, b_hi_ref, out_ref,
            *, tile: int, w: int):
    i = pl.program_id(0)
    oa = pa_ref[i]
    ob = pb_ref[i]
    flat_a = jnp.concatenate([a_lo_ref[...], a_hi_ref[...]], axis=1).reshape(2 * tile)
    flat_b = jnp.concatenate([b_lo_ref[...], b_hi_ref[...]], axis=1).reshape(2 * tile)
    sym_a = jax.lax.dynamic_slice(flat_a, (oa - (oa // tile) * tile,), (w,))
    sym_b = jax.lax.dynamic_slice(flat_b, (ob - (ob // tile) * tile,), (w,))
    neq = sym_a != sym_b
    iota = jax.lax.iota(jnp.int32, w)
    out_ref[0, 0] = jnp.min(jnp.where(neq, iota, w))


@functools.partial(jax.jit, static_argnames=("w", "tile", "interpret"))
def suffix_lcp_pairs(
    s_padded: jax.Array,
    pos_a: jax.Array,
    pos_b: jax.Array,
    w: int,
    *,
    tile: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """LCP in symbols of the suffixes at ``pos_a[i]`` and ``pos_b[i]``.

    s_padded: (n,) integer codes (terminal-padded so ``pos + w`` reads stay
    in meaningful padding); pos_a, pos_b: (B,) int32.  Returns int32[B],
    capped at ``w`` (pairs equal through ``w`` symbols report exactly ``w``).
    ``interpret=None`` compiles on TPU and interprets elsewhere.
    """
    interpret = default_interpret(interpret)
    b = pos_a.shape[0]
    assert pos_b.shape == (b,)
    assert w % 4 == 0
    tile = max(tile, w)
    s_rows, _ = stage_tiles(s_padded, tile)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i, pa, pb: (pa[i] // tile, 0)),
            pl.BlockSpec((1, tile), lambda i, pa, pb: (pa[i] // tile + 1, 0)),
            pl.BlockSpec((1, tile), lambda i, pa, pb: (pb[i] // tile, 0)),
            pl.BlockSpec((1, tile), lambda i, pa, pb: (pb[i] // tile + 1, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, pa, pb: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, tile=tile, w=w),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        interpret=interpret,
    )(pos_a.astype(jnp.int32), pos_b.astype(jnp.int32),
      s_rows, s_rows, s_rows, s_rows)
    return out[:, 0]
