"""Pallas TPU kernel: causal flash attention with GQA.

The baseline XLA attention materializes the (Sq, Sk) logits and probs in
HBM — the dominant memory-roofline term for every train/prefill cell in
EXPERIMENTS.md §Roofline.  This kernel streams K/V blocks through VMEM
with the online-softmax recurrence, so HBM traffic drops to Q+K+V+O.

Grid: (batch, q_heads, Sq/blk_q, Sk/blk_k); the last axis is sequential on
TPU, so the running max / denominator / accumulator live in VMEM scratch
across kv steps (revisiting-output pattern).  GQA: the kv-head index map
is ``h // (H // KV)`` — K/V blocks are fetched once per query-head group.

Block sizes default to (128, 512): VMEM ≈ blk_q·D (Q) + blk_k·D (K,V) +
blk_q·blk_k f32 (logits) + blk_q·D f32 (acc) ≈ 1.3MB at D=128 — well
under budget, MXU-aligned (multiples of 128 on both matmul dims).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, blk_q: int, blk_k: int, scale: float, causal: bool,
            n_k_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: kv block strictly above the diagonal contributes nothing
    diag_ok = (ki * blk_k) <= (qi * blk_q + blk_q - 1)
    run = jnp.logical_or(jnp.logical_not(causal), diag_ok)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)   # (blk_q, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)   # (blk_k, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            row = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
            col = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
            s = jnp.where(col <= row, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = corr * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k", "interpret"))
def flash_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, KV, D)
    v: jax.Array,  # (B, Sk, KV, D)
    *,
    causal: bool = True,
    blk_q: int = 128,
    blk_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    assert h % kv == 0
    group = h // kv
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, sk)
    assert sq % blk_q == 0 and sk % blk_k == 0, (sq, blk_q, sk, blk_k)
    n_k_blocks = sk // blk_k
    scale = 1.0 / np.sqrt(d)

    grid = (b, h, sq // blk_q, n_k_blocks)
    return pl.pallas_call(
        functools.partial(_kernel, blk_q=blk_q, blk_k=blk_k, scale=scale,
                          causal=causal, n_k_blocks=n_k_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, 1, d), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec((1, blk_k, 1, d), lambda b_, h_, qi, ki: (b_, ki, h_ // group, 0)),
            pl.BlockSpec((1, blk_k, 1, d), lambda b_, h_, qi, ki: (b_, ki, h_ // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, 1, d), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, d), q.dtype),
        scratch_shapes=[
            # VMEM scratch: running max, denominator, accumulator
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
