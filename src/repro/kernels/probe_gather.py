"""Fused probe+gather Pallas kernels: find-and-fetch in ONE launch.

A serving-shaped "find and fetch" query both *locates* a pattern's
suffix-array range and *returns* the matched text window.  Composed from
the existing kernel family that is two launches over the same HBM window:
a probe (:func:`repro.kernels.packed_gather.pattern_probe_words` /
``pattern_probe_packed``) followed by a gather
(:func:`repro.kernels.packed_gather.range_gather_words` /
``range_gather_packed``) at the same position — the string window is
DMA'd twice.  These kernels fuse the two: one dense read per row feeds
BOTH the comparison verdict and the gathered window, halving launches and
string traffic on the serving hot path (:mod:`repro.launch.serving`).

Two currencies, mirroring the probe family:

* :func:`probe_gather_words`  — word-compare verdict + raw shift-aligned
  substituted dense uint32 word rows (the PR-5 comparison currency);
* :func:`probe_gather_packed` — byte-key verdict + big-endian
  byte-per-symbol int32 sort-key rows (the PR-4 oracle currency).

Both are bit-identical to the two-launch composition of their family's
probe and gather kernels (the refs in :mod:`repro.kernels.ref` ARE that
composition; ``tests/test_packed.py`` pins kernel == ref == composition
under every oracle leg).  The fetch width is independent of the pattern
width: the kernel reads ``max(pattern, fetch)`` symbols once and slices.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import PackedText
from repro.kernels.packed_gather import (
    _dense_read,
    _dense_read_words,
    _first_diff,
    _repack_bytes,
)
from repro.kernels.tiles import default_interpret as _default_interpret, stage_tiles


def _fused_words_kernel(pos_ref, len_ref, limp_ref, nr_ref, s_lo_ref, s_hi_ref,
                        pat_ref, mask_ref, cmp_ref, win_ref,
                        *, tile: int, nw_pat: int, nw_out: int, bits: int,
                        terminal: int):
    i = pl.program_id(0)
    spw = 32 // bits
    nw_rd = max(nw_pat, nw_out)
    pos = pos_ref[i]
    sw = _dense_read_words(pos, nr_ref[0], s_lo_ref, s_hi_ref,
                           tile=tile, nw=nw_rd, bits=bits, terminal=terminal)
    # gather half: the first nw_out substituted words ARE what
    # range_gather_words emits (per-word substitution is independent)
    win_ref[0, :] = sw[:nw_out].astype(jnp.int32)
    # probe half: identical to packed_gather._words_probe_kernel
    big = nw_pat * spw
    mask = jax.lax.bitcast_convert_type(mask_ref[0, :], jnp.uint32)
    pat = jax.lax.bitcast_convert_type(pat_ref[0, :], jnp.uint32)
    p, aw, bw, sym = _first_diff(sw[:nw_pat] & mask, pat, nw_pat, bits)
    sh = (32 - bits * (sym + 1)).astype(jnp.uint32)
    ones = jnp.uint32((1 << bits) - 1)
    ca = ((aw >> sh) & ones).astype(jnp.int32)
    cb = ((bw >> sh) & ones).astype(jnp.int32)
    sym_sign = jnp.where(ca < cb, -1, 1)
    cmp_len = len_ref[i]
    ls = nr_ref[0] - pos
    lp = limp_ref[i]
    ls = jnp.where(ls < cmp_len, ls, big)
    lp = jnp.where(lp < cmp_len, lp, big)
    lim_sign = jnp.where(ls < lp, 1, jnp.where(lp < ls, -1, 0))
    cmp_ref[0, 0] = jnp.where(p < jnp.minimum(ls, lp), sym_sign, lim_sign)


@functools.partial(jax.jit, static_argnames=("fetch", "tile", "interpret"))
def probe_gather_words(
    pt: PackedText,
    pos: jax.Array,
    pat_dense: jax.Array,
    mask_dense: jax.Array,
    lengths: jax.Array,
    lim_p: jax.Array | None = None,
    *,
    fetch: int,
    tile: int = 2048,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused word-compare probe + word gather: one read, two results.

    Arguments match :func:`repro.kernels.packed_gather.pattern_probe_words`
    plus the static ``fetch`` width in symbols.  Returns
    ``(cmp int32[B], win uint32[B, ceil(fetch/spw)])`` — ``cmp`` equal to
    the probe kernel, ``win`` equal to ``range_gather_words(pt, pos,
    fetch)`` (oracle: :func:`repro.kernels.ref.probe_gather_words_ref`).
    """
    b, nw_pat = pat_dense.shape
    spw = pt.syms_per_word
    nw_out = -(-fetch // spw)
    nw_rd = max(nw_pat, nw_out)
    assert mask_dense.shape == (b, nw_pat) and pos.shape == (b,)
    assert nw_rd + 1 <= tile, (nw_rd, pt.bits, tile)
    if lim_p is None:
        lim_p = lengths
    s_rows, _ = stage_tiles(pt.words, tile)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, tile),
                         lambda i, pos_ref, len_ref, limp_ref, nr_ref:
                         ((pos_ref[i] // spw) // tile, 0)),
            pl.BlockSpec((1, tile),
                         lambda i, pos_ref, len_ref, limp_ref, nr_ref:
                         ((pos_ref[i] // spw) // tile + 1, 0)),
            pl.BlockSpec((1, nw_pat),
                         lambda i, pos_ref, len_ref, limp_ref, nr_ref: (i, 0)),
            pl.BlockSpec((1, nw_pat),
                         lambda i, pos_ref, len_ref, limp_ref, nr_ref: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1),
                         lambda i, pos_ref, len_ref, limp_ref, nr_ref: (i, 0)),
            pl.BlockSpec((1, nw_out),
                         lambda i, pos_ref, len_ref, limp_ref, nr_ref: (i, 0)),
        ),
    )
    cmp, win = pl.pallas_call(
        functools.partial(_fused_words_kernel, tile=tile, nw_pat=nw_pat,
                          nw_out=nw_out, bits=pt.bits, terminal=pt.terminal),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((b, 1), jnp.int32),
                   jax.ShapeDtypeStruct((b, nw_out), jnp.int32)),
        interpret=_default_interpret(interpret),
    )(pos.astype(jnp.int32), lengths.astype(jnp.int32),
      lim_p.astype(jnp.int32),
      jnp.reshape(pt.n_real, (1,)).astype(jnp.int32),
      s_rows, s_rows,
      jax.lax.bitcast_convert_type(pat_dense, jnp.int32),
      jax.lax.bitcast_convert_type(mask_dense, jnp.int32))
    return cmp[:, 0], jax.lax.bitcast_convert_type(win, jnp.uint32)


def _fused_packed_kernel(pos_ref, nr_ref, s_lo_ref, s_hi_ref, pat_ref,
                         mask_ref, cmp_ref, win_ref,
                         *, tile: int, w_pat: int, w_out: int, bits: int,
                         terminal: int):
    i = pl.program_id(0)
    w_rd = max(w_pat, w_out)
    sym = _dense_read(pos_ref[i], nr_ref[0], s_lo_ref, s_hi_ref,
                      tile=tile, w=w_rd, bits=bits, terminal=terminal)
    words = _repack_bytes(sym, w_rd)
    # gather half: first w_out // 4 byte-key words == range_gather_packed
    win_ref[0, :] = words[: w_out // 4]
    # probe half: identical to packed_gather._probe_kernel
    n_words = w_pat // 4
    pat = pat_ref[0, :]
    sw = words[:n_words] & mask_ref[0, :]
    neq = sw != pat
    iota = jax.lax.iota(jnp.int32, n_words)
    first = jnp.min(jnp.where(neq, iota, n_words))
    sel = iota == first
    sign = jnp.int32(-(1 << 31))
    a = jnp.sum(jnp.where(sel, sw, 0)) ^ sign
    b = jnp.sum(jnp.where(sel, pat, 0)) ^ sign
    cmp_ref[0, 0] = jnp.where(jnp.any(neq), jnp.where(a < b, -1, 1), 0)


@functools.partial(jax.jit, static_argnames=("fetch", "tile", "interpret"))
def probe_gather_packed(
    pt: PackedText,
    pos: jax.Array,
    pat_words: jax.Array,
    mask_words: jax.Array,
    *,
    fetch: int,
    tile: int = 2048,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused byte-key probe + byte-key gather over dense storage.

    Arguments match :func:`repro.kernels.packed_gather.pattern_probe_packed`
    plus the static ``fetch`` width (symbols, multiple of 4).  Returns
    ``(cmp int32[B], keys int32[B, fetch//4])`` — ``cmp`` equal to the
    packed probe, ``keys`` equal to ``range_gather_packed(pt, pos, fetch)``
    (oracle: :func:`repro.kernels.ref.probe_gather_packed_ref`).
    """
    assert fetch % 4 == 0, fetch
    b, n_words = pat_words.shape
    w_pat = n_words * 4
    spw = pt.syms_per_word
    nw_rd = -(-max(w_pat, fetch) // spw)
    assert mask_words.shape == (b, n_words) and pos.shape == (b,)
    assert nw_rd + 1 <= tile, (nw_rd, pt.bits, tile)
    s_rows, _ = stage_tiles(pt.words, tile)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, tile),
                         lambda i, pos_ref, nr_ref: ((pos_ref[i] // spw) // tile, 0)),
            pl.BlockSpec((1, tile),
                         lambda i, pos_ref, nr_ref: ((pos_ref[i] // spw) // tile + 1, 0)),
            pl.BlockSpec((1, n_words), lambda i, pos_ref, nr_ref: (i, 0)),
            pl.BlockSpec((1, n_words), lambda i, pos_ref, nr_ref: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1), lambda i, pos_ref, nr_ref: (i, 0)),
            pl.BlockSpec((1, fetch // 4), lambda i, pos_ref, nr_ref: (i, 0)),
        ),
    )
    cmp, win = pl.pallas_call(
        functools.partial(_fused_packed_kernel, tile=tile, w_pat=w_pat,
                          w_out=fetch, bits=pt.bits, terminal=pt.terminal),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((b, 1), jnp.int32),
                   jax.ShapeDtypeStruct((b, fetch // 4), jnp.int32)),
        interpret=_default_interpret(interpret),
    )(pos.astype(jnp.int32), jnp.reshape(pt.n_real, (1,)).astype(jnp.int32),
      s_rows, s_rows, pat_words, mask_words)
    return cmp[:, 0], win
