"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes and
dtypes and asserts exact equality (all kernels here are integer kernels)
against these functions, with the kernel run in ``interpret=True`` mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import (  # noqa: F401  (canonical shared impls)
    PACK_WEIGHTS,
    PackedText,
    extract_sym,
    flip_sign,
    gather_pack as range_gather_pack_ref,
    gather_pack_dense as range_gather_packed_ref,
    gather_words_dense as range_gather_words_ref,
    lcp_words,
    lcp_words_limited,
    pack_words as pack_words_ref,
    word_limit,
)


def probe_compare_ref(sw: jax.Array, pat_words: jax.Array) -> jax.Array:
    """Sign of masked suffix key rows vs pattern rows (shared probe tail)."""
    neq = sw != pat_words
    any_neq = jnp.any(neq, axis=1)
    first = jnp.argmax(neq, axis=1)
    a = jnp.take_along_axis(sw, first[:, None], axis=1)[:, 0]
    b = jnp.take_along_axis(pat_words, first[:, None], axis=1)[:, 0]
    lt = flip_sign(a) < flip_sign(b)  # unsigned compare (byte alphabet safe)
    return jnp.where(any_neq, jnp.where(lt, -1, 1), 0).astype(jnp.int32)


def pattern_probe_ref(s_padded: jax.Array, pos: jax.Array,
                      pat_words: jax.Array, mask_words: jax.Array) -> jax.Array:
    """Batched masked suffix-vs-pattern comparison (query binary-search probe).

    pos: (B,) int32 suffix positions; pat_words/mask_words: (B, W) int32 —
    the pattern packed big-endian with symbols beyond its length zeroed, and
    the matching 0xFF-byte mask.  Returns int32[B] in {-1, 0, +1}: the sign
    of ``S[pos:pos+m]`` vs the pattern under unsigned lexicographic order
    (0 == the suffix starts with the pattern).
    """
    w = pat_words.shape[1] * 4
    sw = range_gather_pack_ref(s_padded, pos, w) & mask_words
    return probe_compare_ref(sw, pat_words)


def pattern_probe_packed_ref(pt: PackedText, pos: jax.Array,
                             pat_words: jax.Array,
                             mask_words: jax.Array) -> jax.Array:
    """:func:`pattern_probe_ref` reading the dense k-bit packed string.

    The gather-and-repack emits byte-identical key words, so the compare
    tail is shared and results match the byte path bit-for-bit."""
    w = pat_words.shape[1] * 4
    sw = range_gather_packed_ref(pt, pos, w) & mask_words
    return probe_compare_ref(sw, pat_words)


def probe_words_ref(sw: jax.Array, pat_words: jax.Array, lim_s: jax.Array,
                    lim_p: jax.Array, cmp_len: jax.Array,
                    bits: int) -> jax.Array:
    """Word-compare probe verdict (shared tail of the word probes).

    sw / pat_words: (B, NW) uint32 substituted dense rows, BOTH masked to
    the per-row compare length ``cmp_len`` (the pattern length for the
    query probe, the window width for matching stats); lim_s / lim_p:
    per-row terminal limits — ``n_real - pos`` for the suffix side, the
    first-terminal index for a terminal-padded window.  The rules:

    * a difference below both (in-range) limits is a real symbol
      difference — its sign is the verdict;
    * otherwise the side whose limit falls INSIDE the compared region
      holds ``$`` there first and is larger;
    * limits at or past ``cmp_len`` never participate: the comparison
      ended in masked-equal region, so such rows compare equal (0).
    """
    spw = 32 // bits
    nw = sw.shape[-1]
    big = nw * spw  # past every masked difference and every in-range limit
    ls = jnp.where(lim_s < cmp_len, lim_s, big)
    lp = jnp.where(lim_p < cmp_len, lim_p, big)
    p = lcp_words(sw, pat_words, bits)
    idx = jnp.clip(p, 0, big - 1)
    ca = extract_sym(sw, idx, bits)
    cb = extract_sym(pat_words, idx, bits)
    sym_sign = jnp.where(ca < cb, -1, 1)
    lim_sign = jnp.where(ls < lp, 1, jnp.where(lp < ls, -1, 0))
    return jnp.where(p < jnp.minimum(ls, lp),
                     sym_sign, lim_sign).astype(jnp.int32)


def pattern_probe_words_ref(pt: PackedText, pos: jax.Array,
                            pat_dense: jax.Array, mask_dense: jax.Array,
                            lengths: jax.Array,
                            lim_p: jax.Array | None = None) -> jax.Array:
    """Word-parallel :func:`pattern_probe_packed_ref`: compare k-bit
    pattern words against shifted text words directly — no byte repack.

    pat_dense / mask_dense: (B, NW) uint32 dense pattern rows packed by
    :func:`repro.core.packing.pack_pattern_dense` and the matching
    all-ones-field masks (zero past each compare length); lengths: (B,)
    int32 per-row compare lengths (the pattern length for the query
    probe, the window width for matching stats); lim_p: the pattern
    side's first-terminal index when it carries a terminal-padded tail
    (matching-stats windows) — defaults to ``lengths``, i.e. no pattern
    terminal inside the compared region.  Bit-identical verdicts to the
    byte probe for real-symbol patterns (``tests/test_packed.py``).
    """
    w = pat_dense.shape[1] * (32 // pt.bits)
    sw = range_gather_words_ref(pt, pos, w) & mask_dense
    lim_s = pt.n_real - pos.astype(jnp.int32)
    if lim_p is None:
        lim_p = lengths
    return probe_words_ref(sw, pat_dense, lim_s, lim_p, lengths, pt.bits)


def probe_gather_words_ref(pt: PackedText, pos: jax.Array,
                           pat_dense: jax.Array, mask_dense: jax.Array,
                           lengths: jax.Array,
                           lim_p: jax.Array | None = None, *,
                           fetch: int) -> tuple[jax.Array, jax.Array]:
    """Fused word probe + word gather oracle: BY DEFINITION the two-launch
    composition (:func:`pattern_probe_words_ref` then
    :func:`range_gather_words_ref` at the same positions) the fused kernel
    (:mod:`repro.kernels.probe_gather`) must match bit-for-bit."""
    cmp = pattern_probe_words_ref(pt, pos, pat_dense, mask_dense,
                                  lengths, lim_p)
    win = range_gather_words_ref(pt, pos, fetch)
    return cmp, win


def probe_gather_packed_ref(pt: PackedText, pos: jax.Array,
                            pat_words: jax.Array, mask_words: jax.Array, *,
                            fetch: int) -> tuple[jax.Array, jax.Array]:
    """Fused byte-key probe + gather oracle: the two-launch composition
    (:func:`pattern_probe_packed_ref` then :func:`range_gather_packed_ref`)
    the fused packed kernel must match bit-for-bit."""
    cmp = pattern_probe_packed_ref(pt, pos, pat_words, mask_words)
    win = range_gather_packed_ref(pt, pos, fetch)
    return cmp, win


def suffix_lcp_words_ref(pt: PackedText, pos_a: jax.Array,
                         pos_b: jax.Array, w: int) -> jax.Array:
    """Word-parallel suffix-pair LCP: first differing dense word via XOR,
    symbol offset via count-leading-zeros, capped by both terminal
    limits.  Equals the byte symbol scan for distinct suffixes."""
    a = range_gather_words_ref(pt, pos_a, w)
    b = range_gather_words_ref(pt, pos_b, w)
    la = word_limit(pt.n_real, pos_a, w)
    lb = word_limit(pt.n_real, pos_b, w)
    return lcp_words_limited(a, b, la, lb, w, pt.bits)


def kmer_histogram_ref(s: jax.Array, n: int, k: int, base: int) -> jax.Array:
    """Counts of every base-``base`` k-mer code over windows 0..n-1.

    ``s`` must be terminal-padded to at least ``n + k - 1`` symbols.
    Returns int32[base**k].
    """
    codes = jnp.zeros(n, jnp.int32)
    for d in range(k):
        codes = codes * base + s[d : d + n].astype(jnp.int32)
    return jnp.zeros(base**k, jnp.int32).at[codes].add(1)


def suffix_lcp_pairs_ref(s_padded: jax.Array, pos_a: jax.Array,
                         pos_b: jax.Array, w: int) -> jax.Array:
    """Batched suffix-pair LCP in symbols, capped at ``w``.

    The oracle runs on the shared packed-word machinery: gather + pack both
    reads, then take the per-row first-divergent-byte of the word rows —
    byte order inside a big-endian packed word IS symbol order, so the
    result equals a symbol-by-symbol scan.
    """
    a = range_gather_pack_ref(s_padded, pos_a, w)
    b = range_gather_pack_ref(s_padded, pos_b, w)
    return lcp_pairs_ref(a, b, w)[0]


# ---------------------------------------------------------------------------
# Literal §6.1 2-bit DNA path (historical reference): dense uint32 words of
# 16 big-endian 2-bit symbols compared as 4x-narrower DENSE keys.  The
# production pipeline instead generalizes density to the alphabet and
# repacks gathers into the common byte-key currency (core.packing.PackedText
# + kernels.packed_gather), which keeps every sort/LCP/probe bit-identical
# across representations; these functions remain as the §6.1 worked form
# and its property tests (tests/test_flash_and_packed.py).
# ---------------------------------------------------------------------------

SYMS_PER_WORD = 16


def pack_string_2bit(s: jax.Array) -> jax.Array:
    """uint8 symbols (codes 0..3) -> uint32 words, 16 symbols big-endian."""
    n = s.shape[0]
    pad = (-n) % SYMS_PER_WORD
    sp = jnp.concatenate([s.astype(jnp.uint32), jnp.zeros(pad, jnp.uint32)])
    grp = sp.reshape(-1, SYMS_PER_WORD)
    shifts = (30 - 2 * jnp.arange(SYMS_PER_WORD, dtype=jnp.uint32))
    return jnp.sum(grp << shifts[None, :], axis=1).astype(jnp.uint32)


def packed_gather_ref(s_words: jax.Array, offs: jax.Array, w: int) -> jax.Array:
    """Gather ``w`` symbols per offset from the 2-bit packed string.

    Returns (F, w // 16) uint32 key words, shift-aligned so that unsigned
    integer order == lexicographic symbol order.
    """
    assert w % SYMS_PER_WORD == 0
    nw = w // SYMS_PER_WORD
    word0 = (offs // SYMS_PER_WORD).astype(jnp.int32)
    idx = word0[:, None] + jnp.arange(nw + 1, dtype=jnp.int32)[None, :]
    idx = jnp.minimum(idx, s_words.shape[0] - 1)
    words = jnp.take(s_words, idx, axis=0).astype(jnp.uint32)  # (F, nw+1)
    sh = (2 * (offs % SYMS_PER_WORD)).astype(jnp.uint32)[:, None]
    hi = jnp.where(sh > 0, words[:, :-1] << sh, words[:, :-1])
    lo = jnp.where(sh > 0, words[:, 1:] >> (32 - sh), 0)
    return (hi | lo).astype(jnp.uint32)


def lcp_pairs_packed_ref(a: jax.Array, b: jax.Array, w: int):
    """Row-wise LCP in SYMBOLS over 2-bit packed key rows (uint32)."""
    f, nw = a.shape
    x = a ^ b
    neq = x != 0
    iota = jnp.arange(nw, dtype=jnp.int32)[None, :]
    first_w = jnp.min(jnp.where(neq, iota, nw), axis=1)
    sel = iota == first_w[:, None]
    xw = jnp.sum(jnp.where(sel, x, 0), axis=1).astype(jnp.uint32)
    aw = jnp.sum(jnp.where(sel, a, 0), axis=1).astype(jnp.uint32)
    bw = jnp.sum(jnp.where(sel, b, 0), axis=1).astype(jnp.uint32)
    # leading zero bits of the xor -> first divergent 2-bit symbol
    y = xw
    y = y | (y >> 1); y = y | (y >> 2); y = y | (y >> 4)
    y = y | (y >> 8); y = y | (y >> 16)
    clz = 32 - jax.lax.population_count(y).astype(jnp.int32)
    sym_in_word = clz // 2
    any_neq = jnp.any(neq, axis=1)
    lcp = jnp.where(any_neq, first_w * SYMS_PER_WORD + sym_in_word, w)
    shift = (30 - 2 * jnp.minimum(sym_in_word, SYMS_PER_WORD - 1)).astype(jnp.uint32)
    c1 = (aw >> shift) & 3
    c2 = (bw >> shift) & 3
    return (jnp.minimum(lcp, w).astype(jnp.int32),
            c1.astype(jnp.int32), c2.astype(jnp.int32))


def lcp_pairs_ref(a: jax.Array, b: jax.Array, w: int):
    """Per-row LCP (symbols) and first divergent symbols of packed rows.

    a, b: (F, W) int32 packed words (W = w // 4).
    Returns (lcp, c1, c2): int32[F] each; rows that are fully equal get
    lcp == w and c1 == c2 == 0.
    """
    f, n_words = a.shape
    shifts = jnp.array([24, 16, 8, 0], jnp.int32)
    ab = ((a[:, :, None] >> shifts[None, None, :]) & 0xFF).reshape(f, n_words * 4)
    bb = ((b[:, :, None] >> shifts[None, None, :]) & 0xFF).reshape(f, n_words * 4)
    neq = ab != bb
    iota = jnp.arange(n_words * 4, dtype=jnp.int32)[None, :]
    first = jnp.min(jnp.where(neq, iota, n_words * 4), axis=1)
    sel = iota == first[:, None]
    c1 = jnp.sum(jnp.where(sel, ab, 0), axis=1)
    c2 = jnp.sum(jnp.where(sel, bb, 0), axis=1)
    lcp = jnp.minimum(first, w)
    return lcp.astype(jnp.int32), c1.astype(jnp.int32), c2.astype(jnp.int32)
