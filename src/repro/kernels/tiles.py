"""Shared HBM tile staging for the gather-style Pallas kernels.

Every kernel that walks S through ``(1, tile)`` BlockSpec windows
(``range_gather``, ``pattern_probe``, ``suffix_lcp``, ``kmer_histogram``)
stages the string the same way: pad to a whole number of tiles PLUS one
halo row — so a read straddling a tile boundary can always fetch rows
``(r, r + 1)`` — filling with the last element (the terminal code, which
by convention continues past the end of S) and reshaping to
``(n_tiles, tile)`` int32 rows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def default_interpret(interpret: bool | None) -> bool:
    """Resolve a kernel's ``interpret=None`` default: compiled on TPU,
    interpreter elsewhere (a hard-coded True would leave real TPU runs
    interpreting forever).  One shared policy site for every kernel."""
    return jax.default_backend() != "tpu" if interpret is None else interpret


def pick_tile(kernel: str, *, n: int, dtype_bits: int = 32,
              w_cap: int = 0) -> int:
    """The tile shape for one kernel dispatch, resolved through the
    roofline autotuner (on-disk table entry → VMEM/HBM model pick →
    the kernel's static default).  Always ≥ ``w_cap`` so the kernels'
    ``w <= tile`` assertion holds; rounding ``n`` into pow2 buckets
    happens inside the table so jit program counts stay bounded."""
    from repro.roofline import autotune

    return autotune.tile_for(kernel, backend=jax.default_backend(),
                             bits=dtype_bits, n=n, w_cap=w_cap)


def stage_tiles(s_padded: jax.Array, tile: int) -> tuple[jax.Array, int]:
    """Reshape S into ``(n_tiles, tile)`` int32 rows with one halo row.

    Returns ``(s_rows, n_tiles)``; ``n_tiles`` includes the halo row.
    """
    n = s_padded.shape[0]
    n_tiles = -(-n // tile) + 1  # +1 halo row so (row, row+1) always exists
    pad_val = s_padded[-1]  # terminal padding continues the last element
    s_rows = jnp.full((n_tiles * tile,), pad_val, s_padded.dtype)
    s_rows = jax.lax.dynamic_update_slice(s_rows, s_padded, (0,))
    return s_rows.reshape(n_tiles, tile).astype(jnp.int32), n_tiles
