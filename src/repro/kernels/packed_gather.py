"""Pallas TPU kernels over the DENSE k-bit packed string (paper §6.1).

Five kernels share one in-kernel dense-read recipe.  The byte-key family
(PR 4) repacks dense reads into byte-per-symbol sort keys:

* :func:`range_gather_packed` — the packed realization of
  :mod:`repro.kernels.range_gather`: gather ``w`` symbols per offset from
  the ``bits``-bit packed word stream and emit the SAME big-endian
  byte-per-symbol int32 sort keys the unpacked path produces, so every
  downstream lexsort / LCP runs unchanged while the HBM string read
  shrinks by ``8/bits`` (4x for DNA).
* :func:`pattern_probe_packed` — the packed probe-gather-compare step of
  the batched query binary search (:mod:`repro.kernels.pattern_probe`).

The word-compare family keeps the dense words AS the comparison currency
(no byte repack in-kernel, ``bits/8`` of the compare lanes — the ERA §6.1
packing argument taken to its end; terminal semantics live in
:mod:`repro.core.packing`'s word-comparison rules):

* :func:`range_gather_words` — raw shift-aligned uint32 word rows with
  the virtual terminal substituted (:func:`repro.core.packing.sub_code`);
* :func:`pattern_probe_words` — compares k-bit pattern words against the
  shifted text words directly, verdict via XOR + first-word + clz +
  terminal-limit rules;
* :func:`suffix_lcp_words` — suffix-pair LCP as first-differing-word +
  count-leading-zeros, capped by both terminal limits.

Dense-read recipe: offsets are scalar-prefetched; each grid step DMAs the
``(2, tile)`` uint32-word window containing the read (a read may straddle
one tile boundary), slices the ``nw + 1`` words covering the symbols,
shift-aligns across the sub-word bit offset (``off % syms_per_word``),
expands the ``bits``-bit fields to one byte per symbol, substitutes the
virtual terminal for positions ``>= n_real`` (dense storage holds only
REAL symbols — see :class:`repro.core.packing.PackedText`), and repacks
big-endian 4-symbols/int32.

The pure-jnp oracles are :func:`repro.core.packing.gather_pack_dense` /
``repro.kernels.ref.pattern_probe_packed_ref``; ``tests/test_packed.py``
asserts exact equality in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import PackedText, _sub_word, clz32
from repro.kernels.tiles import default_interpret as _default_interpret, stage_tiles


def _dense_read(off, n_real, s_lo_ref, s_hi_ref, *, tile: int, w: int,
                bits: int, terminal: int):
    """Read ``w`` byte-expanded symbols at ``off`` from a 2-tile window."""
    spw = 32 // bits
    nw = -(-w // spw)
    word0 = off // spw
    local = word0 - (word0 // tile) * tile  # word offset within the window
    flat = jnp.concatenate([s_lo_ref[...], s_hi_ref[...]], axis=1).reshape(2 * tile)
    u = jax.lax.dynamic_slice(flat, (local,), (nw + 1,)).astype(jnp.uint32)
    sh = (bits * (off - word0 * spw)).astype(jnp.uint32)
    hi = u[:-1] << sh
    # funnel low half: (x >> 1) >> (31 - sh) == x >> (32 - sh) for sh > 0
    # and 0 at sh == 0, keeping every shift amount in-range select-free
    lo = (u[1:] >> 1) >> (31 - sh)
    aligned = hi | lo  # (nw,) each holding spw big-endian symbols
    shifts = 32 - bits * (jax.lax.iota(jnp.uint32, spw) + 1)
    sym = (aligned[:, None] >> shifts[None, :]) & jnp.uint32((1 << bits) - 1)
    sym = sym.reshape(nw * spw)[:w].astype(jnp.int32)
    past_end = off + jax.lax.iota(jnp.int32, w) >= n_real
    return jnp.where(past_end, jnp.int32(terminal), sym)


def _repack_bytes(sym, w: int):
    grp = sym.reshape(w // 4, 4)
    # unrolled big-endian pack (pallas kernels cannot capture array consts)
    return (grp[:, 0] * (1 << 24) + grp[:, 1] * (1 << 16)
            + grp[:, 2] * (1 << 8) + grp[:, 3])


def _gather_kernel(offs_ref, nr_ref, s_lo_ref, s_hi_ref, out_ref,
                   *, tile: int, w: int, bits: int, terminal: int):
    i = pl.program_id(0)
    sym = _dense_read(offs_ref[i], nr_ref[0], s_lo_ref, s_hi_ref,
                      tile=tile, w=w, bits=bits, terminal=terminal)
    out_ref[0, :] = _repack_bytes(sym, w)


@functools.partial(jax.jit, static_argnames=("w", "tile", "interpret"))
def range_gather_packed(
    pt: PackedText,
    offs: jax.Array,
    w: int,
    *,
    tile: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """Gather ``w`` symbols per offset from dense storage; emit byte keys.

    pt: the dense-packed string (its word tail must cover every read —
    the ``extra`` contract of :func:`repro.core.packing.pack_text`);
    offs: (F,) int32.  Returns (F, w//4) int32, bit-identical to
    :func:`repro.kernels.range_gather.range_gather_pack` on the
    terminal-padded byte string.
    """
    assert w % 4 == 0, w
    spw = pt.syms_per_word
    nw = -(-w // spw)
    assert nw + 1 <= tile, (w, pt.bits, tile)
    f = offs.shape[0]
    s_rows, _ = stage_tiles(pt.words, tile)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(f,),
        in_specs=[
            # the word window may straddle one tile boundary: fetch tiles
            # r and r+1 as two (1, tile) blocks (halo row exists by staging)
            pl.BlockSpec((1, tile),
                         lambda i, offs_ref, nr_ref: ((offs_ref[i] // spw) // tile, 0)),
            pl.BlockSpec((1, tile),
                         lambda i, offs_ref, nr_ref: ((offs_ref[i] // spw) // tile + 1, 0)),
        ],
        out_specs=pl.BlockSpec((1, w // 4), lambda i, offs_ref, nr_ref: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_gather_kernel, tile=tile, w=w, bits=pt.bits,
                          terminal=pt.terminal),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((f, w // 4), jnp.int32),
        interpret=_default_interpret(interpret),
    )(offs.astype(jnp.int32), jnp.reshape(pt.n_real, (1,)).astype(jnp.int32),
      s_rows, s_rows)


def _probe_kernel(pos_ref, nr_ref, s_lo_ref, s_hi_ref, pat_ref, mask_ref,
                  out_ref, *, tile: int, w: int, bits: int, terminal: int):
    i = pl.program_id(0)
    sym = _dense_read(pos_ref[i], nr_ref[0], s_lo_ref, s_hi_ref,
                      tile=tile, w=w, bits=bits, terminal=terminal)
    words = _repack_bytes(sym, w)
    pat = pat_ref[0, :]
    sw = words & mask_ref[0, :]
    neq = sw != pat
    n_words = w // 4
    iota = jax.lax.iota(jnp.int32, n_words)
    first = jnp.min(jnp.where(neq, iota, n_words))
    sel = iota == first
    sign = jnp.int32(-(1 << 31))
    a = jnp.sum(jnp.where(sel, sw, 0)) ^ sign
    b = jnp.sum(jnp.where(sel, pat, 0)) ^ sign
    out_ref[0, 0] = jnp.where(jnp.any(neq), jnp.where(a < b, -1, 1), 0)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def pattern_probe_packed(
    pt: PackedText,
    pos: jax.Array,
    pat_words: jax.Array,
    mask_words: jax.Array,
    *,
    tile: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """Packed-storage probe: compare each suffix against its pattern row.

    pos: (B,) int32 suffix positions; pat_words/mask_words: (B, W) int32
    byte-packed + masked pattern rows (the same host-side packing the byte
    probe uses).  Returns int32[B] in {-1, 0, +1}; bit-identical to
    :func:`repro.kernels.pattern_probe.pattern_probe` on the byte string.
    """
    b, n_words = pat_words.shape
    w = n_words * 4
    assert mask_words.shape == (b, n_words) and pos.shape == (b,)
    spw = pt.syms_per_word
    nw = -(-w // spw)
    assert nw + 1 <= tile, (w, pt.bits, tile)
    s_rows, _ = stage_tiles(pt.words, tile)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, tile),
                         lambda i, pos_ref, nr_ref: ((pos_ref[i] // spw) // tile, 0)),
            pl.BlockSpec((1, tile),
                         lambda i, pos_ref, nr_ref: ((pos_ref[i] // spw) // tile + 1, 0)),
            pl.BlockSpec((1, n_words), lambda i, pos_ref, nr_ref: (i, 0)),
            pl.BlockSpec((1, n_words), lambda i, pos_ref, nr_ref: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, pos_ref, nr_ref: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_probe_kernel, tile=tile, w=w, bits=pt.bits,
                          terminal=pt.terminal),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        interpret=_default_interpret(interpret),
    )(pos.astype(jnp.int32), jnp.reshape(pt.n_real, (1,)).astype(jnp.int32),
      s_rows, s_rows, pat_words, mask_words)
    return out[:, 0]


# ---------------------------------------------------------------------------
# Word-compare kernels: dense uint32 words are the comparison currency
# ---------------------------------------------------------------------------


def _dense_read_words(off, n_real, s_lo_ref, s_hi_ref, *, tile: int, nw: int,
                      bits: int, terminal: int):
    """Read ``nw`` shift-aligned SUBSTITUTED dense words at symbol ``off``
    from a 2-tile uint32 window (the in-kernel form of
    :func:`repro.core.packing.gather_words_dense`)."""
    spw = 32 // bits
    word0 = off // spw
    local = word0 - (word0 // tile) * tile
    flat = jnp.concatenate([s_lo_ref[...], s_hi_ref[...]], axis=1).reshape(2 * tile)
    u = jax.lax.dynamic_slice(flat, (local,), (nw + 1,)).astype(jnp.uint32)
    sh = (bits * (off - word0 * spw)).astype(jnp.uint32)
    hi = u[:-1] << sh
    lo = (u[1:] >> 1) >> (31 - sh)  # funnel low half, shift always in-range
    aligned = hi | lo
    # virtual terminal: keep the first v = clip(n_real - start, 0, spw)
    # fields of each word, substitute sub_code for the rest
    starts = off + spw * jax.lax.iota(jnp.int32, nw)
    v = jnp.clip(n_real - starts, 0, spw)
    full = jnp.uint32(0xFFFFFFFF)
    keep = jnp.where(
        v > 0,
        full << ((spw - jnp.maximum(v, 1)) * bits).astype(jnp.uint32),
        jnp.uint32(0))
    sub_w = jnp.uint32(_sub_word(bits, terminal))
    return (aligned & keep) | (sub_w & ~keep)


def _first_diff(a, b, nw: int, bits: int):
    """(p, aw, bw): first differing symbol index of two word vectors plus
    the words holding it (p == nw * spw when equal)."""
    spw = 32 // bits
    x = a ^ b
    neq = x != 0
    iota = jax.lax.iota(jnp.int32, nw)
    first = jnp.min(jnp.where(neq, iota, nw))
    sel = iota == first
    xw = jnp.sum(jnp.where(sel, x, jnp.uint32(0)))
    aw = jnp.sum(jnp.where(sel, a, jnp.uint32(0)))
    bw = jnp.sum(jnp.where(sel, b, jnp.uint32(0)))
    sym = clz32(xw) // bits
    p = jnp.where(jnp.any(neq), first * spw + sym, nw * spw)
    return p, aw, bw, jnp.minimum(sym, spw - 1)


def _words_gather_kernel(offs_ref, nr_ref, s_lo_ref, s_hi_ref, out_ref,
                         *, tile: int, nw: int, bits: int, terminal: int):
    i = pl.program_id(0)
    words = _dense_read_words(offs_ref[i], nr_ref[0], s_lo_ref, s_hi_ref,
                              tile=tile, nw=nw, bits=bits, terminal=terminal)
    out_ref[0, :] = words.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("w", "tile", "interpret"))
def range_gather_words(
    pt: PackedText,
    offs: jax.Array,
    w: int,
    *,
    tile: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """Gather the ``ceil(w / spw)`` dense uint32 words covering ``w``
    symbols at each offset — shift-aligned, terminal-substituted, never
    spread to bytes.  Returns (F, nw) uint32, bit-identical to
    :func:`repro.core.packing.gather_words_dense`.
    """
    spw = pt.syms_per_word
    nw = -(-w // spw)
    assert nw + 1 <= tile, (w, pt.bits, tile)
    f = offs.shape[0]
    s_rows, _ = stage_tiles(pt.words, tile)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(f,),
        in_specs=[
            pl.BlockSpec((1, tile),
                         lambda i, offs_ref, nr_ref: ((offs_ref[i] // spw) // tile, 0)),
            pl.BlockSpec((1, tile),
                         lambda i, offs_ref, nr_ref: ((offs_ref[i] // spw) // tile + 1, 0)),
        ],
        out_specs=pl.BlockSpec((1, nw), lambda i, offs_ref, nr_ref: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_words_gather_kernel, tile=tile, nw=nw, bits=pt.bits,
                          terminal=pt.terminal),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((f, nw), jnp.int32),
        interpret=_default_interpret(interpret),
    )(offs.astype(jnp.int32), jnp.reshape(pt.n_real, (1,)).astype(jnp.int32),
      s_rows, s_rows)
    return jax.lax.bitcast_convert_type(out, jnp.uint32)


def _words_probe_kernel(pos_ref, len_ref, limp_ref, nr_ref, s_lo_ref, s_hi_ref,
                        pat_ref, mask_ref, out_ref,
                        *, tile: int, nw: int, bits: int, terminal: int):
    i = pl.program_id(0)
    spw = 32 // bits
    big = nw * spw
    pos = pos_ref[i]
    sw = _dense_read_words(pos, nr_ref[0], s_lo_ref, s_hi_ref,
                           tile=tile, nw=nw, bits=bits, terminal=terminal)
    mask = jax.lax.bitcast_convert_type(mask_ref[0, :], jnp.uint32)
    pat = jax.lax.bitcast_convert_type(pat_ref[0, :], jnp.uint32)
    p, aw, bw, sym = _first_diff(sw & mask, pat, nw, bits)
    sh = (32 - bits * (sym + 1)).astype(jnp.uint32)
    ones = jnp.uint32((1 << bits) - 1)
    ca = ((aw >> sh) & ones).astype(jnp.int32)
    cb = ((bw >> sh) & ones).astype(jnp.int32)
    sym_sign = jnp.where(ca < cb, -1, 1)
    # terminal-limit rules (core.packing module docstring): limits at or
    # past the compare length saturate out of the comparison
    cmp_len = len_ref[i]
    ls = nr_ref[0] - pos
    lp = limp_ref[i]
    ls = jnp.where(ls < cmp_len, ls, big)
    lp = jnp.where(lp < cmp_len, lp, big)
    lim_sign = jnp.where(ls < lp, 1, jnp.where(lp < ls, -1, 0))
    out_ref[0, 0] = jnp.where(p < jnp.minimum(ls, lp), sym_sign, lim_sign)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def pattern_probe_words(
    pt: PackedText,
    pos: jax.Array,
    pat_dense: jax.Array,
    mask_dense: jax.Array,
    lengths: jax.Array,
    lim_p: jax.Array | None = None,
    *,
    tile: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """Word-compare probe: k-bit pattern words vs shifted text words.

    pat_dense / mask_dense: (B, NW) uint32 dense rows from
    :func:`repro.core.packing.pack_pattern_dense` (zero / all-ones fields
    past each compare length); lengths: (B,) int32 compare lengths;
    lim_p: the pattern side's first-terminal index for terminal-padded
    windows (defaults to ``lengths`` — no pattern terminal).  Returns
    int32[B] in {-1, 0, +1}; bit-identical to the byte probe for
    real-symbol patterns (oracle:
    :func:`repro.kernels.ref.pattern_probe_words_ref`).
    """
    b, nw = pat_dense.shape
    spw = pt.syms_per_word
    assert mask_dense.shape == (b, nw) and pos.shape == (b,)
    assert nw + 1 <= tile, (nw, pt.bits, tile)
    if lim_p is None:
        lim_p = lengths
    s_rows, _ = stage_tiles(pt.words, tile)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, tile),
                         lambda i, pos_ref, len_ref, limp_ref, nr_ref:
                         ((pos_ref[i] // spw) // tile, 0)),
            pl.BlockSpec((1, tile),
                         lambda i, pos_ref, len_ref, limp_ref, nr_ref:
                         ((pos_ref[i] // spw) // tile + 1, 0)),
            pl.BlockSpec((1, nw),
                         lambda i, pos_ref, len_ref, limp_ref, nr_ref: (i, 0)),
            pl.BlockSpec((1, nw),
                         lambda i, pos_ref, len_ref, limp_ref, nr_ref: (i, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1), lambda i, pos_ref, len_ref, limp_ref, nr_ref: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_words_probe_kernel, tile=tile, nw=nw, bits=pt.bits,
                          terminal=pt.terminal),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        interpret=_default_interpret(interpret),
    )(pos.astype(jnp.int32), lengths.astype(jnp.int32),
      lim_p.astype(jnp.int32),
      jnp.reshape(pt.n_real, (1,)).astype(jnp.int32),
      s_rows, s_rows,
      jax.lax.bitcast_convert_type(pat_dense, jnp.int32),
      jax.lax.bitcast_convert_type(mask_dense, jnp.int32))
    return out[:, 0]


def _words_lcp_kernel(pa_ref, pb_ref, nr_ref, a_lo_ref, a_hi_ref,
                      b_lo_ref, b_hi_ref, out_ref,
                      *, tile: int, nw: int, w: int, bits: int, terminal: int):
    i = pl.program_id(0)
    oa = pa_ref[i]
    ob = pb_ref[i]
    a = _dense_read_words(oa, nr_ref[0], a_lo_ref, a_hi_ref,
                          tile=tile, nw=nw, bits=bits, terminal=terminal)
    b = _dense_read_words(ob, nr_ref[0], b_lo_ref, b_hi_ref,
                          tile=tile, nw=nw, bits=bits, terminal=terminal)
    p, _, _, _ = _first_diff(a, b, nw, bits)
    la = jnp.clip(nr_ref[0] - oa, 0, w)
    lb = jnp.clip(nr_ref[0] - ob, 0, w)
    out_ref[0, 0] = jnp.minimum(jnp.minimum(jnp.minimum(p, la), lb), w)


@functools.partial(jax.jit, static_argnames=("w", "tile", "interpret"))
def suffix_lcp_words(
    pt: PackedText,
    pos_a: jax.Array,
    pos_b: jax.Array,
    w: int,
    *,
    tile: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """Word-compare suffix-pair LCP over dense storage, capped at ``w``.

    Finds the first differing dense word by XOR, resolves the symbol
    offset with count-leading-zeros, and caps at both terminal limits —
    equal to the byte symbol scan for distinct suffix pairs (oracle:
    :func:`repro.kernels.ref.suffix_lcp_words_ref`).
    """
    spw = pt.syms_per_word
    nw = -(-w // spw)
    assert nw + 1 <= tile, (w, pt.bits, tile)
    b = pos_a.shape[0]
    assert pos_b.shape == (b,)
    s_rows, _ = stage_tiles(pt.words, tile)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, tile),
                         lambda i, pa, pb, nr: ((pa[i] // spw) // tile, 0)),
            pl.BlockSpec((1, tile),
                         lambda i, pa, pb, nr: ((pa[i] // spw) // tile + 1, 0)),
            pl.BlockSpec((1, tile),
                         lambda i, pa, pb, nr: ((pb[i] // spw) // tile, 0)),
            pl.BlockSpec((1, tile),
                         lambda i, pa, pb, nr: ((pb[i] // spw) // tile + 1, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, pa, pb, nr: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_words_lcp_kernel, tile=tile, nw=nw, w=w,
                          bits=pt.bits, terminal=pt.terminal),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        interpret=_default_interpret(interpret),
    )(pos_a.astype(jnp.int32), pos_b.astype(jnp.int32),
      jnp.reshape(pt.n_real, (1,)).astype(jnp.int32),
      s_rows, s_rows, s_rows, s_rows)
    return out[:, 0]
