"""Pallas TPU kernels over the DENSE k-bit packed string (paper §6.1).

Two kernels share one in-kernel dense-read recipe:

* :func:`range_gather_packed` — the packed realization of
  :mod:`repro.kernels.range_gather`: gather ``w`` symbols per offset from
  the ``bits``-bit packed word stream and emit the SAME big-endian
  byte-per-symbol int32 sort keys the unpacked path produces, so every
  downstream lexsort / LCP runs unchanged while the HBM string read
  shrinks by ``8/bits`` (4x for DNA).
* :func:`pattern_probe_packed` — the packed probe-gather-compare step of
  the batched query binary search (:mod:`repro.kernels.pattern_probe`).

Dense-read recipe: offsets are scalar-prefetched; each grid step DMAs the
``(2, tile)`` uint32-word window containing the read (a read may straddle
one tile boundary), slices the ``nw + 1`` words covering the symbols,
shift-aligns across the sub-word bit offset (``off % syms_per_word``),
expands the ``bits``-bit fields to one byte per symbol, substitutes the
virtual terminal for positions ``>= n_real`` (dense storage holds only
REAL symbols — see :class:`repro.core.packing.PackedText`), and repacks
big-endian 4-symbols/int32.

The pure-jnp oracles are :func:`repro.core.packing.gather_pack_dense` /
``repro.kernels.ref.pattern_probe_packed_ref``; ``tests/test_packed.py``
asserts exact equality in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import PackedText
from repro.kernels.tiles import default_interpret as _default_interpret, stage_tiles


def _dense_read(off, n_real, s_lo_ref, s_hi_ref, *, tile: int, w: int,
                bits: int, terminal: int):
    """Read ``w`` byte-expanded symbols at ``off`` from a 2-tile window."""
    spw = 32 // bits
    nw = -(-w // spw)
    word0 = off // spw
    local = word0 - (word0 // tile) * tile  # word offset within the window
    flat = jnp.concatenate([s_lo_ref[...], s_hi_ref[...]], axis=1).reshape(2 * tile)
    u = jax.lax.dynamic_slice(flat, (local,), (nw + 1,)).astype(jnp.uint32)
    sh = (bits * (off - word0 * spw)).astype(jnp.uint32)
    hi = u[:-1] << sh
    # funnel low half: (x >> 1) >> (31 - sh) == x >> (32 - sh) for sh > 0
    # and 0 at sh == 0, keeping every shift amount in-range select-free
    lo = (u[1:] >> 1) >> (31 - sh)
    aligned = hi | lo  # (nw,) each holding spw big-endian symbols
    shifts = 32 - bits * (jax.lax.iota(jnp.uint32, spw) + 1)
    sym = (aligned[:, None] >> shifts[None, :]) & jnp.uint32((1 << bits) - 1)
    sym = sym.reshape(nw * spw)[:w].astype(jnp.int32)
    past_end = off + jax.lax.iota(jnp.int32, w) >= n_real
    return jnp.where(past_end, jnp.int32(terminal), sym)


def _repack_bytes(sym, w: int):
    grp = sym.reshape(w // 4, 4)
    # unrolled big-endian pack (pallas kernels cannot capture array consts)
    return (grp[:, 0] * (1 << 24) + grp[:, 1] * (1 << 16)
            + grp[:, 2] * (1 << 8) + grp[:, 3])


def _gather_kernel(offs_ref, nr_ref, s_lo_ref, s_hi_ref, out_ref,
                   *, tile: int, w: int, bits: int, terminal: int):
    i = pl.program_id(0)
    sym = _dense_read(offs_ref[i], nr_ref[0], s_lo_ref, s_hi_ref,
                      tile=tile, w=w, bits=bits, terminal=terminal)
    out_ref[0, :] = _repack_bytes(sym, w)


@functools.partial(jax.jit, static_argnames=("w", "tile", "interpret"))
def range_gather_packed(
    pt: PackedText,
    offs: jax.Array,
    w: int,
    *,
    tile: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """Gather ``w`` symbols per offset from dense storage; emit byte keys.

    pt: the dense-packed string (its word tail must cover every read —
    the ``extra`` contract of :func:`repro.core.packing.pack_text`);
    offs: (F,) int32.  Returns (F, w//4) int32, bit-identical to
    :func:`repro.kernels.range_gather.range_gather_pack` on the
    terminal-padded byte string.
    """
    assert w % 4 == 0, w
    spw = pt.syms_per_word
    nw = -(-w // spw)
    assert nw + 1 <= tile, (w, pt.bits, tile)
    f = offs.shape[0]
    s_rows, _ = stage_tiles(pt.words, tile)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(f,),
        in_specs=[
            # the word window may straddle one tile boundary: fetch tiles
            # r and r+1 as two (1, tile) blocks (halo row exists by staging)
            pl.BlockSpec((1, tile),
                         lambda i, offs_ref, nr_ref: ((offs_ref[i] // spw) // tile, 0)),
            pl.BlockSpec((1, tile),
                         lambda i, offs_ref, nr_ref: ((offs_ref[i] // spw) // tile + 1, 0)),
        ],
        out_specs=pl.BlockSpec((1, w // 4), lambda i, offs_ref, nr_ref: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_gather_kernel, tile=tile, w=w, bits=pt.bits,
                          terminal=pt.terminal),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((f, w // 4), jnp.int32),
        interpret=_default_interpret(interpret),
    )(offs.astype(jnp.int32), jnp.reshape(pt.n_real, (1,)).astype(jnp.int32),
      s_rows, s_rows)


def _probe_kernel(pos_ref, nr_ref, s_lo_ref, s_hi_ref, pat_ref, mask_ref,
                  out_ref, *, tile: int, w: int, bits: int, terminal: int):
    i = pl.program_id(0)
    sym = _dense_read(pos_ref[i], nr_ref[0], s_lo_ref, s_hi_ref,
                      tile=tile, w=w, bits=bits, terminal=terminal)
    words = _repack_bytes(sym, w)
    pat = pat_ref[0, :]
    sw = words & mask_ref[0, :]
    neq = sw != pat
    n_words = w // 4
    iota = jax.lax.iota(jnp.int32, n_words)
    first = jnp.min(jnp.where(neq, iota, n_words))
    sel = iota == first
    sign = jnp.int32(-(1 << 31))
    a = jnp.sum(jnp.where(sel, sw, 0)) ^ sign
    b = jnp.sum(jnp.where(sel, pat, 0)) ^ sign
    out_ref[0, 0] = jnp.where(jnp.any(neq), jnp.where(a < b, -1, 1), 0)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def pattern_probe_packed(
    pt: PackedText,
    pos: jax.Array,
    pat_words: jax.Array,
    mask_words: jax.Array,
    *,
    tile: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """Packed-storage probe: compare each suffix against its pattern row.

    pos: (B,) int32 suffix positions; pat_words/mask_words: (B, W) int32
    byte-packed + masked pattern rows (the same host-side packing the byte
    probe uses).  Returns int32[B] in {-1, 0, +1}; bit-identical to
    :func:`repro.kernels.pattern_probe.pattern_probe` on the byte string.
    """
    b, n_words = pat_words.shape
    w = n_words * 4
    assert mask_words.shape == (b, n_words) and pos.shape == (b,)
    spw = pt.syms_per_word
    nw = -(-w // spw)
    assert nw + 1 <= tile, (w, pt.bits, tile)
    s_rows, _ = stage_tiles(pt.words, tile)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, tile),
                         lambda i, pos_ref, nr_ref: ((pos_ref[i] // spw) // tile, 0)),
            pl.BlockSpec((1, tile),
                         lambda i, pos_ref, nr_ref: ((pos_ref[i] // spw) // tile + 1, 0)),
            pl.BlockSpec((1, n_words), lambda i, pos_ref, nr_ref: (i, 0)),
            pl.BlockSpec((1, n_words), lambda i, pos_ref, nr_ref: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, pos_ref, nr_ref: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_probe_kernel, tile=tile, w=w, bits=pt.bits,
                          terminal=pt.terminal),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        interpret=_default_interpret(interpret),
    )(pos.astype(jnp.int32), jnp.reshape(pt.n_real, (1,)).astype(jnp.int32),
      s_rows, s_rows, pat_words, mask_words)
    return out[:, 0]
