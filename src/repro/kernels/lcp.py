"""Pallas TPU kernel: adjacent-row LCP + divergence symbols (ERA branching).

SubTreePrepare derives each ``B[i] = (c1, c2, offset)`` from the common
prefix of two adjacent sorted reads (paper lines 16-23).  The kernel
expands packed int32 words to bytes with shifts, finds the first unequal
byte with an iota-min reduction, and extracts the divergent symbols with a
one-hot sum — all VPU-shaped (no gathers, no scalar loops).

The caller supplies the shifted pair ``(a, b) = (rows[i-1], rows[i])``; the
shift-by-one is a cheap roll done in XLA where it fuses with the sort's
output layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiles import default_interpret


def _kernel(a_ref, b_ref, lcp_ref, c1_ref, c2_ref, *, w: int, n_words: int, blk: int):
    a = a_ref[...]
    b = b_ref[...]

    def to_bytes(x):  # unrolled byte expansion (no captured array consts)
        parts = [(x >> s) & 0xFF for s in (24, 16, 8, 0)]
        return jnp.stack(parts, axis=-1).reshape(blk, n_words * 4)

    ab = to_bytes(a)
    bb = to_bytes(b)
    neq = ab != bb
    iota = jax.lax.broadcasted_iota(jnp.int32, (blk, n_words * 4), 1)
    first = jnp.min(jnp.where(neq, iota, n_words * 4), axis=1)
    sel = iota == first[:, None]
    c1 = jnp.sum(jnp.where(sel, ab, 0), axis=1)
    c2 = jnp.sum(jnp.where(sel, bb, 0), axis=1)
    lcp_ref[...] = jnp.minimum(first, w)[:, None]
    c1_ref[...] = c1[:, None]
    c2_ref[...] = c2[:, None]


@functools.partial(jax.jit, static_argnames=("w", "blk", "interpret"))
def lcp_pairs(
    a: jax.Array,
    b: jax.Array,
    w: int,
    *,
    blk: int = 256,
    interpret: bool | None = None,
):
    """Row-wise LCP of packed key rows.  a, b: (F, W) int32; returns
    (lcp, c1, c2) int32[F] (fully-equal rows get lcp == w, c1 == c2 == 0).
    ``interpret=None`` compiles on TPU and interprets elsewhere."""
    interpret = default_interpret(interpret)
    f, n_words = a.shape
    assert b.shape == (f, n_words) and n_words * 4 >= w
    blk = min(blk, f)
    pad = (-f) % blk
    if pad:
        a = jnp.concatenate([a, jnp.zeros((pad, n_words), a.dtype)])
        b = jnp.concatenate([b, jnp.zeros((pad, n_words), b.dtype)])
    fp = f + pad

    outs = pl.pallas_call(
        functools.partial(_kernel, w=w, n_words=n_words, blk=blk),
        grid=(fp // blk,),
        in_specs=[
            pl.BlockSpec((blk, n_words), lambda i: (i, 0)),
            pl.BlockSpec((blk, n_words), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((fp, 1), jnp.int32),
            jax.ShapeDtypeStruct((fp, 1), jnp.int32),
            jax.ShapeDtypeStruct((fp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(a, b)
    lcp, c1, c2 = (o[:f, 0] for o in outs)
    return lcp, c1, c2
