"""Jit'd dispatch wrappers for the ERA Pallas kernels.

On a real TPU the kernels run compiled (``interpret=False``); on CPU they
run in interpret mode for validation, and the pure-jnp reference path is
the default for speed.  Selection:

* ``REPRO_KERNELS=pallas``    — always use the Pallas kernels (interpret
                                 mode off-TPU);
* ``REPRO_KERNELS=jnp`` (default on CPU) — pure-jnp reference path;
* on TPU platforms the Pallas path is the default.

String-representation dispatch: every wrapper that reads the string
accepts EITHER the terminal-padded byte array (uint8 codes) OR a dense
k-bit :class:`repro.core.packing.PackedText`; the packed variants emit
byte-identical sort keys / verdicts (see :mod:`repro.kernels.packed_gather`),
so callers switch representation without touching results.

Comparison-currency dispatch: for a PackedText the hot comparisons
(suffix LCP, probe, the elastic-range sort keys) default to WORD-compare
— k-bit dense uint32 words compared directly, ``8/bits``x fewer compare
lanes — with the PR-4 byte-repack path kept as the oracle.
``REPRO_WORD_COMPARE=byte`` forces the byte-key path (bit-identical
results either way; tests pin it).
"""

from __future__ import annotations

import os
import threading

import jax

from repro import obs
from repro.core.packing import PackedText
from repro.kernels import ref as _ref
from repro.kernels.kmer_histogram import kmer_histogram as _kmer_pallas
from repro.kernels.lcp import lcp_pairs as _lcp_pallas
from repro.kernels.packed_gather import (
    pattern_probe_packed as _packed_probe_pallas,
    pattern_probe_words as _words_probe_pallas,
    range_gather_packed as _packed_gather_pallas,
    range_gather_words as _words_gather_pallas,
    suffix_lcp_words as _words_lcp_pallas,
)
from repro.kernels.pattern_probe import pattern_probe as _probe_pallas
from repro.kernels.probe_gather import (
    probe_gather_packed as _fused_packed_pallas,
    probe_gather_words as _fused_words_pallas,
)
from repro.kernels.range_gather import range_gather_pack as _gather_pallas
from repro.kernels.suffix_lcp import suffix_lcp_pairs as _suffix_lcp_pallas
from repro.kernels.tiles import pick_tile as _pick_tile
from repro.roofline.analysis import HBM_BW as _HBM_BW


# ---------------------------------------------------------------------------
# Kernel-dispatch telemetry (REPRO_METRICS).  The record helper runs in the
# impl closures' Python bodies: under jit that is TRACE time, so the counters
# count (re)compilations per distinct padded shape — exactly the jit-cache
# pressure signal the serving/bench layers need — while eager callers count
# every call.  ``kernel_distinct_shapes_total`` is the recompile proxy: it
# grows only when a (kernel, currency, shape) triple is first seen.
# ---------------------------------------------------------------------------

_SHAPES_SEEN: set[tuple] = set()
_SHAPES_LOCK = threading.Lock()


def _record(kernel: str, use_pallas: bool, currency: str, *arrays,
            tile: int = 0, w: int = 0) -> None:
    impl = "pallas" if use_pallas else "ref"
    if obs.trace_enabled():
        # Roofline prediction on the dispatch marker: every row DMAs a
        # two-tile halo window, and the compare work is ~w symbol lanes
        # per row.  Perfetto viewers divide the enclosing span's wall
        # time by these to read achieved-vs-predicted throughput.
        rows = int(arrays[0].shape[0]) if arrays else 0
        eff_tile = tile or 2048
        pred_bytes = rows * 2 * eff_tile * 4
        obs.tracer().instant(
            f"kernel/{kernel}/dispatch", kernel=kernel, impl=impl,
            currency=currency, rows=rows, tile=eff_tile,
            roofline_pred_bytes=pred_bytes,
            roofline_pred_flops=rows * max(w, 1),
            roofline_hbm_us=pred_bytes / _HBM_BW * 1e6)
    if not obs.metrics_enabled():
        return
    m = obs.metrics()
    m.counter("kernel_dispatch_total",
              "kernel impl dispatches (trace-time under jit: counts "
              "compilations per padded shape)",
              kernel=kernel, impl=impl, currency=currency).inc()
    shape = tuple(tuple(getattr(a, "shape", ())) for a in arrays)
    key = (kernel, currency, shape)
    with _SHAPES_LOCK:
        new = key not in _SHAPES_SEEN
        if new:
            _SHAPES_SEEN.add(key)
    if new:
        m.counter("kernel_distinct_shapes_total",
                  "distinct padded argument shapes per kernel "
                  "(jit-recompile proxy)",
                  kernel=kernel, currency=currency).inc()


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_pallas() -> bool:
    env = os.environ.get("REPRO_KERNELS", "")
    if env == "pallas":
        return True
    if env == "jnp":
        return False
    return _on_tpu()


def _use_word_compare() -> bool:
    """Word-compare is the default for dense-packed strings;
    ``REPRO_WORD_COMPARE=byte`` pins the PR-4 byte-repack oracle path.
    Resolved OUTSIDE jitted traces (a static arg), like ``_use_pallas``."""
    env = os.environ.get("REPRO_WORD_COMPARE", "")
    if env == "byte":
        return False
    if env in ("", "word"):
        return True
    raise ValueError(
        f"unknown REPRO_WORD_COMPARE={env!r}; choose 'word' or 'byte'")


def _use_sort_fuse() -> bool:
    """Fused single-lane sort keys are the default construction currency
    (PR-8 promoted engine); ``REPRO_SORT=lexsort`` pins the three-lane
    lexsort oracle path.  Resolved OUTSIDE jitted traces (a static arg),
    like ``_use_pallas``/``_use_word_compare``."""
    env = os.environ.get("REPRO_SORT", "")
    if env == "lexsort":
        return False
    if env in ("", "fused"):
        return True
    raise ValueError(
        f"unknown REPRO_SORT={env!r}; choose 'fused' or 'lexsort'")


def _use_compaction() -> bool:
    """Tail compaction (sort only still-active rows) is the default for
    the batched/streaming/append host loops; ``REPRO_COMPACT=off`` pins
    the full-width oracle path.  Resolved OUTSIDE jitted traces."""
    env = os.environ.get("REPRO_COMPACT", "")
    if env == "off":
        return False
    if env in ("", "tail"):
        return True
    raise ValueError(
        f"unknown REPRO_COMPACT={env!r}; choose 'tail' or 'off'")


def _tile(kernel: str, s_text, w: int = 0) -> int:
    """Autotuned tile for one dispatch — resolved at trace time from
    STATIC shapes only (``PackedText.words``/byte-array length), so the
    choice is a jit-cache key, never a traced value."""
    if isinstance(s_text, PackedText):
        n = s_text.words.shape[0] * (32 // s_text.bits)
        bits = s_text.bits
    else:
        n = int(s_text.shape[0])
        bits = 32
    return _pick_tile(kernel, n=n, dtype_bits=bits, w_cap=w)


def range_gather_impl(use_pallas: bool):
    """Gather-and-pack implementation for a STATIC ``use_pallas`` —
    returns ``fn(s_text, offs, w) -> (F, w//4) int32`` byte sort keys,
    dispatching on the string representation inside the trace."""
    def fn(s_text, offs, w: int):
        tile = _tile("range_gather", s_text, w)
        if isinstance(s_text, PackedText):
            _record("range_gather", use_pallas, "packed", offs,
                    tile=tile, w=w)
            if use_pallas:
                return _packed_gather_pallas(s_text, offs, w, tile=tile,
                                             interpret=not _on_tpu())
            return _ref.range_gather_packed_ref(s_text, offs, w)
        _record("range_gather", use_pallas, "byte", offs, tile=tile, w=w)
        if use_pallas:
            return _gather_pallas(s_text, offs, w, tile=tile,
                                  interpret=not _on_tpu())
        return _ref.range_gather_pack_ref(s_text, offs, w)
    return fn


def range_gather_pack(s_text, offs, w: int):
    return range_gather_impl(_use_pallas())(s_text, offs, w)


def kmer_histogram(s_padded, n: int, k: int, base: int):
    if _use_pallas():
        tile = _tile("kmer_histogram", s_padded, k)
        return _kmer_pallas(s_padded, n, k, base, tile=tile,
                            interpret=not _on_tpu())
    return _ref.kmer_histogram_ref(s_padded, n, k, base)


def range_gather_words_impl(use_pallas: bool):
    """Word-key gather for a STATIC ``use_pallas``: ``fn(pt, offs, w) ->
    (F, ceil(w/spw)) uint32`` substituted dense word rows (PackedText
    only — the word currency has no byte-string form)."""
    def fn(pt: PackedText, offs, w: int):
        tile = _tile("range_gather_words", pt, w)
        _record("range_gather", use_pallas, "word", offs, tile=tile, w=w)
        if use_pallas:
            return _words_gather_pallas(pt, offs, w, tile=tile,
                                        interpret=not _on_tpu())
        return _ref.range_gather_words_ref(pt, offs, w)
    return fn


def range_gather_words(pt: PackedText, offs, w: int):
    return range_gather_words_impl(_use_pallas())(pt, offs, w)


def suffix_lcp_pairs(s_text, pos_a, pos_b, w: int):
    tile = _tile("suffix_lcp", s_text, w)
    if isinstance(s_text, PackedText):
        if _use_word_compare():
            # word path: first differing dense word + clz, no byte repack
            _record("suffix_lcp", _use_pallas(), "word", pos_a,
                    tile=tile, w=w)
            if _use_pallas():
                return _words_lcp_pallas(s_text, pos_a, pos_b, w, tile=tile,
                                         interpret=not _on_tpu())
            return _ref.suffix_lcp_words_ref(s_text, pos_a, pos_b, w)
        # byte-key oracle path: two byte-key gathers feed the shared
        # row-LCP — identical to the byte kernel's symbol scan.
        gather = range_gather_impl(_use_pallas())
        a = gather(s_text, pos_a, w)
        b = gather(s_text, pos_b, w)
        return lcp_pairs(a, b, w)[0]
    _record("suffix_lcp", _use_pallas(), "byte", pos_a, tile=tile, w=w)
    if _use_pallas():
        return _suffix_lcp_pallas(s_text, pos_a, pos_b, w, tile=tile,
                                  interpret=not _on_tpu())
    return _ref.suffix_lcp_pairs_ref(s_text, pos_a, pos_b, w)


def lcp_pairs(a, b, w: int):
    if _use_pallas():
        return _lcp_pallas(a, b, w, interpret=not _on_tpu())
    return _ref.lcp_pairs_ref(a, b, w)


def pattern_probe_impl(use_pallas: bool):
    """Probe implementation for a STATIC ``use_pallas`` — jitted callers
    (repro.core.query / analytics) resolve the env var once outside the
    trace so flipping REPRO_KERNELS between calls cannot hit a stale
    trace; the byte-vs-packed branch dispatches on the s_text type."""
    def fn(s_text, pos, pat_words, mask_words):
        w = pat_words.shape[1] * 4
        tile = _tile("pattern_probe", s_text, w)
        if isinstance(s_text, PackedText):
            _record("pattern_probe", use_pallas, "packed", pos, pat_words,
                    tile=tile, w=w)
            if use_pallas:
                return _packed_probe_pallas(s_text, pos, pat_words,
                                            mask_words, tile=tile,
                                            interpret=not _on_tpu())
            return _ref.pattern_probe_packed_ref(s_text, pos, pat_words,
                                                 mask_words)
        _record("pattern_probe", use_pallas, "byte", pos, pat_words,
                tile=tile, w=w)
        if use_pallas:
            return _probe_pallas(s_text, pos, pat_words, mask_words,
                                 tile=tile, interpret=not _on_tpu())
        return _ref.pattern_probe_ref(s_text, pos, pat_words, mask_words)
    return fn


def pattern_probe(s_text, pos, pat_words, mask_words):
    return pattern_probe_impl(_use_pallas())(s_text, pos, pat_words, mask_words)


def pattern_probe_words_impl(use_pallas: bool):
    """Word-compare probe for a STATIC ``use_pallas``:
    ``fn(pt, pos, pat_dense, mask_dense, lengths, lim_p=None) -> int32[B]``
    verdicts (PackedText only; patterns must be real-symbol apart from a
    terminal-padded tail described by ``lim_p`` — callers fall back to
    :func:`pattern_probe_impl` for other terminal-bearing batches)."""
    def fn(pt: PackedText, pos, pat_dense, mask_dense, lengths, lim_p=None):
        w = pat_dense.shape[1] * (32 // pt.bits)
        tile = _tile("pattern_probe_words", pt, w)
        _record("pattern_probe", use_pallas, "word", pos, pat_dense,
                tile=tile, w=w)
        if use_pallas:
            return _words_probe_pallas(pt, pos, pat_dense, mask_dense,
                                       lengths, lim_p, tile=tile,
                                       interpret=not _on_tpu())
        return _ref.pattern_probe_words_ref(pt, pos, pat_dense, mask_dense,
                                            lengths, lim_p)
    return fn


def pattern_probe_words(pt: PackedText, pos, pat_dense, mask_dense, lengths,
                        lim_p=None):
    return pattern_probe_words_impl(_use_pallas())(pt, pos, pat_dense,
                                                   mask_dense, lengths, lim_p)


def probe_gather_words_impl(use_pallas: bool):
    """Fused find-and-fetch (word currency) for a STATIC ``use_pallas``:
    ``fn(pt, pos, pat_dense, mask_dense, lengths, fetch, lim_p=None) ->
    (cmp int32[B], win uint32[B, ceil(fetch/spw)])`` — one launch for the
    probe verdict AND the gathered dense word window (PackedText only)."""
    def fn(pt: PackedText, pos, pat_dense, mask_dense, lengths, fetch: int,
           lim_p=None):
        w = max(pat_dense.shape[1] * (32 // pt.bits), fetch)
        tile = _tile("probe_gather_words", pt, w)
        _record("probe_gather", use_pallas, "word", pos, pat_dense,
                tile=tile, w=w)
        if use_pallas:
            return _fused_words_pallas(pt, pos, pat_dense, mask_dense,
                                       lengths, lim_p, fetch=fetch,
                                       tile=tile, interpret=not _on_tpu())
        return _ref.probe_gather_words_ref(pt, pos, pat_dense, mask_dense,
                                           lengths, lim_p, fetch=fetch)
    return fn


def probe_gather_words(pt: PackedText, pos, pat_dense, mask_dense, lengths,
                       fetch: int, lim_p=None):
    return probe_gather_words_impl(_use_pallas())(pt, pos, pat_dense,
                                                  mask_dense, lengths, fetch,
                                                  lim_p)


def probe_gather_impl(use_pallas: bool):
    """Fused find-and-fetch (byte-key currency) for a STATIC ``use_pallas``:
    ``fn(s_text, pos, pat_words, mask_words, fetch) ->
    (cmp int32[B], keys int32[B, fetch//4])``.

    Dense strings run the fused packed kernel / ref; a plain byte string
    has no fused kernel — it runs the literal two-launch probe→gather
    composition (which is also the fused kernels' semantic definition, so
    results are interchangeable across representations)."""
    def fn(s_text, pos, pat_words, mask_words, fetch: int):
        if isinstance(s_text, PackedText):
            w = max(pat_words.shape[1] * 4, fetch)
            tile = _tile("probe_gather", s_text, w)
            _record("probe_gather", use_pallas, "packed", pos, pat_words,
                    tile=tile, w=w)
            if use_pallas:
                return _fused_packed_pallas(s_text, pos, pat_words,
                                            mask_words, fetch=fetch,
                                            tile=tile,
                                            interpret=not _on_tpu())
            return _ref.probe_gather_packed_ref(s_text, pos, pat_words,
                                                mask_words, fetch=fetch)
        cmp = pattern_probe_impl(use_pallas)(s_text, pos, pat_words,
                                             mask_words)
        win = range_gather_impl(use_pallas)(s_text, pos, fetch)
        return cmp, win
    return fn


def probe_gather(s_text, pos, pat_words, mask_words, fetch: int):
    return probe_gather_impl(_use_pallas())(s_text, pos, pat_words,
                                            mask_words, fetch)
