"""Jit'd dispatch wrappers for the ERA Pallas kernels.

On a real TPU the kernels run compiled (``interpret=False``); on CPU they
run in interpret mode for validation, and the pure-jnp reference path is
the default for speed.  Selection:

* ``REPRO_KERNELS=pallas``    — always use the Pallas kernels (interpret
                                 mode off-TPU);
* ``REPRO_KERNELS=jnp`` (default on CPU) — pure-jnp reference path;
* on TPU platforms the Pallas path is the default.
"""

from __future__ import annotations

import os

import jax

from repro.kernels import ref as _ref
from repro.kernels.kmer_histogram import kmer_histogram as _kmer_pallas
from repro.kernels.lcp import lcp_pairs as _lcp_pallas
from repro.kernels.pattern_probe import pattern_probe as _probe_pallas
from repro.kernels.range_gather import range_gather_pack as _gather_pallas
from repro.kernels.suffix_lcp import suffix_lcp_pairs as _suffix_lcp_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_pallas() -> bool:
    env = os.environ.get("REPRO_KERNELS", "")
    if env == "pallas":
        return True
    if env == "jnp":
        return False
    return _on_tpu()


def range_gather_pack(s_padded, offs, w: int):
    if _use_pallas():
        return _gather_pallas(s_padded, offs, w, interpret=not _on_tpu())
    return _ref.range_gather_pack_ref(s_padded, offs, w)


def kmer_histogram(s_padded, n: int, k: int, base: int):
    if _use_pallas():
        return _kmer_pallas(s_padded, n, k, base, interpret=not _on_tpu())
    return _ref.kmer_histogram_ref(s_padded, n, k, base)


def suffix_lcp_pairs(s_padded, pos_a, pos_b, w: int):
    if _use_pallas():
        return _suffix_lcp_pallas(s_padded, pos_a, pos_b, w,
                                  interpret=not _on_tpu())
    return _ref.suffix_lcp_pairs_ref(s_padded, pos_a, pos_b, w)


def lcp_pairs(a, b, w: int):
    if _use_pallas():
        return _lcp_pallas(a, b, w, interpret=not _on_tpu())
    return _ref.lcp_pairs_ref(a, b, w)


def pattern_probe_impl(use_pallas: bool):
    """Probe implementation for a STATIC ``use_pallas`` — jitted callers
    (repro.core.query) resolve the env var once outside the trace so
    flipping REPRO_KERNELS between calls cannot hit a stale trace."""
    if use_pallas:
        return lambda s, p, pw, mw: _probe_pallas(s, p, pw, mw,
                                                  interpret=not _on_tpu())
    return _ref.pattern_probe_ref


def pattern_probe(s_padded, pos, pat_words, mask_words):
    return pattern_probe_impl(_use_pallas())(s_padded, pos, pat_words, mask_words)
