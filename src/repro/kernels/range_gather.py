"""Pallas TPU kernel: elastic-range gather + pack (ERA's string read).

This is the TPU realization of the paper's "fill R by scanning S" step
(SubTreePrepare lines 9-12).  On disk the paper streams S sequentially; in
HBM the natural analogue is a *paged gather*: the per-leaf offset array is
scalar-prefetched (the same pattern as paged-attention block tables), the
``index_map`` selects the HBM tile containing each read, and the kernel
packs ``w`` symbols into big-endian int32 words in VMEM so that integer
comparisons equal lexicographic symbol comparisons.

Tiling: S is reshaped to ``(n_tiles, tile)``; each grid step DMAs a
``(2, tile)`` window (the read may straddle one tile boundary; ``w <=
tile`` is enforced) and writes one ``(1, w//4)`` output row.  VMEM per
step = ``2*tile*4 + w`` bytes — tile=2048 keeps it ~16KB, far under VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import PACK_WEIGHTS
from repro.kernels.tiles import default_interpret, stage_tiles


def _kernel(offs_ref, s_lo_ref, s_hi_ref, out_ref, *, tile: int, w: int):
    i = pl.program_id(0)
    off = offs_ref[i]
    local = off - (off // tile) * tile  # offset within the 2-tile window
    flat = jnp.concatenate([s_lo_ref[...], s_hi_ref[...]], axis=1).reshape(2 * tile)
    sym = jax.lax.dynamic_slice(flat, (local,), (w,))
    grp = sym.reshape(w // 4, 4).astype(jnp.int32)
    # unrolled big-endian pack (pallas kernels cannot capture array consts)
    words = (grp[:, 0] * (1 << 24) + grp[:, 1] * (1 << 16)
             + grp[:, 2] * (1 << 8) + grp[:, 3])
    out_ref[0, :] = words


@functools.partial(jax.jit, static_argnames=("w", "tile", "interpret"))
def range_gather_pack(
    s_padded: jax.Array,
    offs: jax.Array,
    w: int,
    *,
    tile: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """Gather ``w`` symbols per offset from S (terminal-padded) and pack.

    s_padded: (n,) integer codes;  offs: (F,) int32;  returns (F, w//4) int32.
    ``interpret=None`` compiles on TPU and interprets elsewhere.
    """
    interpret = default_interpret(interpret)
    assert w % 4 == 0 and w <= tile, (w, tile)
    f = offs.shape[0]
    s_rows, _ = stage_tiles(s_padded, tile)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(f,),
        in_specs=[
            # the read window may straddle one tile boundary: fetch tiles
            # r and r+1 as two (1, tile) blocks (halo row exists by padding)
            pl.BlockSpec((1, tile), lambda i, offs_ref: (offs_ref[i] // tile, 0)),
            pl.BlockSpec((1, tile), lambda i, offs_ref: (offs_ref[i] // tile + 1, 0)),
        ],
        out_specs=pl.BlockSpec((1, w // 4), lambda i, offs_ref: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, tile=tile, w=w),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((f, w // 4), jnp.int32),
        interpret=interpret,
    )(offs.astype(jnp.int32), s_rows, s_rows)
