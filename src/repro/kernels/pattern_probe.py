"""Pallas TPU kernel: batched probe-gather-compare (ERA substring queries).

The device-resident query engine (:mod:`repro.core.query`) resolves a batch
of patterns by vectorized lower/upper-bound binary search over the leaf
array ``L`` (= the suffix array restricted to each sub-tree's prefix).  The
inner step of that search is this kernel: for each probe position, gather
``w`` symbols of the suffix from S, pack them big-endian into int32 words,
mask past the pattern length, and emit the sign of the comparison with the
pre-packed pattern row.

Layout mirrors :mod:`repro.kernels.range_gather`: probe positions are
scalar-prefetched (paged-gather block-table style), each grid step DMAs the
``(2, tile)`` HBM window containing the read plus the pattern/mask rows,
and writes one ``(1, 1)`` comparison verdict.  Comparisons run on the
sign-flipped words so signed int32 order equals unsigned (lexicographic)
order — required for the byte alphabet whose codes reach the top bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiles import default_interpret, stage_tiles


def _kernel(pos_ref, s_lo_ref, s_hi_ref, pat_ref, mask_ref, out_ref,
            *, tile: int, w: int):
    i = pl.program_id(0)
    off = pos_ref[i]
    local = off - (off // tile) * tile  # offset within the 2-tile window
    flat = jnp.concatenate([s_lo_ref[...], s_hi_ref[...]], axis=1).reshape(2 * tile)
    sym = jax.lax.dynamic_slice(flat, (local,), (w,))
    grp = sym.reshape(w // 4, 4).astype(jnp.int32)
    # unrolled big-endian pack (pallas kernels cannot capture array consts)
    words = (grp[:, 0] * (1 << 24) + grp[:, 1] * (1 << 16)
             + grp[:, 2] * (1 << 8) + grp[:, 3])
    pat = pat_ref[0, :]
    sw = words & mask_ref[0, :]
    neq = sw != pat
    n_words = w // 4
    iota = jax.lax.iota(jnp.int32, n_words)
    first = jnp.min(jnp.where(neq, iota, n_words))
    sel = iota == first
    sign = jnp.int32(-(1 << 31))
    a = jnp.sum(jnp.where(sel, sw, 0)) ^ sign
    b = jnp.sum(jnp.where(sel, pat, 0)) ^ sign
    cmp = jnp.where(jnp.any(neq), jnp.where(a < b, -1, 1), 0)
    out_ref[0, 0] = cmp


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def pattern_probe(
    s_padded: jax.Array,
    pos: jax.Array,
    pat_words: jax.Array,
    mask_words: jax.Array,
    *,
    tile: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """Compare the suffix at each probe position against its pattern row.

    s_padded: (n,) integer codes (terminal-padded past every read);
    pos: (B,) int32; pat_words/mask_words: (B, W) int32 packed+masked.
    Returns int32[B] in {-1, 0, +1} (0 == suffix starts with pattern).
    ``interpret=None`` compiles on TPU and interprets elsewhere.
    """
    interpret = default_interpret(interpret)
    b, n_words = pat_words.shape
    w = n_words * 4
    assert mask_words.shape == (b, n_words) and pos.shape == (b,)
    tile = max(tile, w)  # long patterns (to_device(max_pattern_len=...)) grow the window
    s_rows, _ = stage_tiles(s_padded, tile)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            # the read window may straddle one tile boundary: fetch tiles
            # r and r+1 as two (1, tile) blocks (halo row exists by padding)
            pl.BlockSpec((1, tile), lambda i, pos_ref: (pos_ref[i] // tile, 0)),
            pl.BlockSpec((1, tile), lambda i, pos_ref: (pos_ref[i] // tile + 1, 0)),
            pl.BlockSpec((1, n_words), lambda i, pos_ref: (i, 0)),
            pl.BlockSpec((1, n_words), lambda i, pos_ref: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, pos_ref: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, tile=tile, w=w),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        interpret=interpret,
    )(pos.astype(jnp.int32), s_rows, s_rows, pat_words, mask_words)
    return out[:, 0]
