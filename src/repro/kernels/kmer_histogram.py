"""Pallas TPU kernel: k-mer histogram (ERA vertical-partition counting).

The paper's VerticalPartitioning scans S once per working-set iteration and
counts the frequency of every candidate S-prefix.  On TPU this is a
streaming histogram: tiles of S flow HBM→VMEM, rolling base-``|Σ|+1`` codes
are built with ``k`` shifted adds (the ``(2, tile)`` window provides the
``k-1`` lookahead across the tile boundary), and counts accumulate into a
VMEM-resident histogram via a one-hot compare-and-sum (VPU-friendly; there
is no scatter on TPU).

The output block index is constant, so the histogram stays in VMEM across
all grid steps and is written back once — the revisiting-output pattern.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiles import default_interpret, stage_tiles


def _kernel(s_lo_ref, s_hi_ref, out_ref, *, tile: int, k: int, base: int, n: int, nbins: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    flat = jnp.concatenate([s_lo_ref[...], s_hi_ref[...]], axis=1).reshape(2 * tile)
    codes = jnp.zeros((tile,), jnp.int32)
    for d in range(k):  # k is small & static: unrolled shifted adds
        codes = codes * base + jax.lax.dynamic_slice(flat, (d,), (tile,)).astype(jnp.int32)
    # mask windows that start past the last suffix
    pos = i * tile + jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)
    codes = jnp.where(pos < n, codes, -1)
    bins = jax.lax.broadcasted_iota(jnp.int32, (tile, nbins), 1)
    onehot = (codes[:, None] == bins).astype(jnp.int32)
    out_ref[...] += jnp.sum(onehot, axis=0)


@functools.partial(jax.jit, static_argnames=("n", "k", "base", "tile", "interpret"))
def kmer_histogram(
    s_padded: jax.Array,
    n: int,
    k: int,
    base: int,
    *,
    tile: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Counts of every base-``base`` k-mer over windows starting at 0..n-1.

    ``s_padded`` must be terminal-padded to >= n + k - 1 symbols.  Returns
    int32[base**k].  ``base**k`` must stay VMEM-resident (<= 2**16 bins).
    ``interpret=None`` compiles on TPU and interprets elsewhere.
    """
    interpret = default_interpret(interpret)
    nbins = base**k
    assert nbins <= (1 << 16), "histogram too wide for VMEM residency"
    assert k <= tile
    s_rows, n_tiles = stage_tiles(s_padded, tile)

    return pl.pallas_call(
        functools.partial(_kernel, tile=tile, k=k, base=base, n=n, nbins=nbins),
        grid=(n_tiles - 1,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (i + 1, 0)),  # k-1 lookahead halo
        ],
        out_specs=pl.BlockSpec((nbins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nbins,), jnp.int32),
        interpret=interpret,
    )(s_rows, s_rows)
