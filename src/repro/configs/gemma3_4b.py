"""gemma3-4b — dense, GQA, 5:1 local:global sliding window.
[hf:google/gemma-3-1b-pt; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=10_240,
    vocab=262_144,
    sliding_window=1024,
    global_every=6,  # layer (i+1) % 6 == 0 is global: 5 local : 1 global
    rope_theta=1_000_000.0,
)
