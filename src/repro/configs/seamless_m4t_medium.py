"""seamless-m4t-medium — encoder-decoder audio backbone; the modality
frontend is a STUB (precomputed frame embeddings).  [arXiv:2308.11596; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=24,        # 12 enc + 12 dec
    n_enc_layers=12,
    n_dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab=256_206,
    frontend="frames",
    frontend_dim=160,   # stub fbank-embedding width
    frontend_len=1024,  # default encoder frames (overridden by shape)
)
