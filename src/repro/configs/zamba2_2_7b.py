"""zamba2-2.7b — Mamba-2 backbone + ONE shared attention block applied every
6 layers.  [arXiv:2411.15242; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_head=80,
    d_ff=10_240,
    vocab=32_000,
    ssm="mamba2",
    d_state=64,
    d_conv=4,
    expand=2,
    ssm_heads=32,
    attn_every=6,
)
