"""deepseek-v2-236b — MLA (kv_lora=512) + 160 routed experts top-6 + 2 shared.
First layer dense FFN.  [arXiv:2405.04434; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_head=128,
    d_ff=12_288,        # dense first-layer FFN
    vocab=102_400,
    mla=True,
    kv_lora=512,
    q_lora=1536,
    rope_dims=64,
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    d_ff_expert=1536,
    n_dense_layers=1,
)
