"""internvl2-2b — InternLM2 LM backbone; InternViT frontend is a STUB
(precomputed patch embeddings).  [arXiv:2404.16821; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=92_553,
    frontend="patches",
    frontend_dim=1024,  # stub InternViT embedding width
    frontend_len=256,   # patches per image
)
