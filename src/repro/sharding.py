"""Logical-axis → mesh PartitionSpec rules (DP / TP / EP / SP).

Every parameter Spec carries logical axis names (see ``models/nn.py``);
this module maps them onto the physical mesh:

* ``vocab / heads / kv_heads / mlp / experts / inner`` → the ``model`` axis
  (TP for dense projections, EP for expert stacks, vocab-parallel embeddings)
* batch dims of activations/caches → the data axes ``("pod", "data")``
* long-context decode (batch=1) → KV-cache *sequence* dim over ``data`` (SP)

A logical axis is only sharded when its size divides the mesh axis size —
e.g. qwen3's 8 KV heads on a 16-way model axis stay replicated while its
16 query heads shard.  This divisibility resolution is what lets one rule
table serve all ten architectures.
"""

from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axis (first match that divides wins)
LOGICAL_RULES: dict[str | None, tuple[str, ...]] = {
    "vocab": ("model",),
    "embed": (),          # replicated: rows of weight matrices
    "heads": ("model",),
    "kv_heads": ("model",),
    "head": (),
    "mlp": ("model",),
    "experts": ("model",),
    "kv_lora": (),
    "inner": ("model",),
    "layers": (),         # scan dim
    None: (),
}


def _mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 0


def spec_for(shape: tuple, axes: tuple, mesh: Mesh) -> P:
    parts = []
    used: set[str] = set()  # a mesh axis may appear at most once per spec
    for dim, ax in zip(shape, axes):
        chosen = None
        for cand in LOGICAL_RULES.get(ax, ()):
            sz = _mesh_axis_size(mesh, cand)
            if sz and dim % sz == 0 and cand not in used:
                chosen = cand
                used.add(cand)
                break
        parts.append(chosen)
    return P(*parts)


def param_shardings(specs_tree, mesh: Mesh):
    """Spec tree -> NamedSharding tree (same structure as params)."""
    from repro.models.nn import Spec

    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_for(s.shape, s.axes, mesh)),
        specs_tree,
        is_leaf=lambda x: isinstance(x, Spec),
    )


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_sharding(mesh: Mesh, batch_size: int, ndim: int) -> NamedSharding:
    """Shard the leading batch dim over the data axes (DP)."""
    dp = dp_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    lead = dp if total and batch_size % total == 0 else ()
    return NamedSharding(mesh, P(lead if lead else None, *([None] * (ndim - 1))))


def batch_shardings(mesh: Mesh, batch_tree):
    """Sharding tree for an input batch (dict of ShapeDtypeStructs)."""
    return jax.tree.map(
        lambda x: batch_sharding(mesh, x.shape[0], len(x.shape)), batch_tree
    )


def cache_shardings(cfg, mesh: Mesh, cache_tree, *, seq_parallel: bool = False):
    """Shardings for a decode cache.

    Layout conventions (see transformer.init_cache):
      attention KV   (L, B, S, KV, hd)   -> B→data, KV→model (if divisible)
      MLA latents    (L, B, S, lora)     -> B→data
      ssm conv state (L, B, K-1, di)     -> B→data, di→model
      ssm h state    (L, B, …, N)        -> B→data, inner/heads→model
      enc memory     (B, T, d)           -> B→data

    ``seq_parallel=True`` (long_500k, batch=1): the cache *sequence* dim is
    sharded over ``data`` instead (context/sequence parallelism); GSPMD
    turns the decode attention into partial-softmax + all-reduce.
    """
    dp = dp_axes(mesh)
    model_sz = _mesh_axis_size(mesh, "model")
    dp_sz = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def one(x):
        shp = x.shape
        if len(shp) == 0:  # pos scalar
            return NamedSharding(mesh, P())
        if len(shp) == 3 and shp[-1] == cfg.d_model:  # enc memory (B,T,d)
            b_ax = dp if shp[0] % max(dp_sz, 1) == 0 and dp_sz > 1 else None
            return NamedSharding(mesh, P(b_ax, None, None))
        parts = [None] * len(shp)
        # dim 1 is batch for stacked (L, B, ...) caches
        if len(shp) >= 2:
            if shp[1] % max(dp_sz, 1) == 0 and dp_sz > 1 and not seq_parallel:
                parts[1] = dp
            elif seq_parallel and len(shp) >= 3 and shp[2] % max(dp_sz, 1) == 0:
                parts[2] = dp  # sequence dim of (L,B,S,…) caches
        # last-but-one dim: KV heads / ssm channels; last dim: head/state
        if len(shp) >= 4 and model_sz:
            if shp[-2] % model_sz == 0:
                parts[-2] = "model"
            elif shp[-1] % model_sz == 0:
                parts[-1] = "model"
        elif len(shp) == 3 and model_sz and shp[-1] % model_sz == 0:
            parts[-1] = "model"  # (L, B, lora) etc.
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(one, cache_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
