"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

  compute    = HLO_FLOPs      / (chips × 197e12  bf16 FLOP/s)
  memory     = HLO_bytes      / (chips × 819e9   B/s HBM)
  collective = coll_bytes     / (chips × n_links × 50e9 B/s ICI)

``cost_analysis()`` supplies FLOPs and bytes for the whole SPMD module
(per-device program × chips is how XLA reports post-partitioning — we
normalize per chip).  Collective bytes are NOT in cost_analysis: we parse
the optimized HLO (``compiled.as_text()``) and sum, for every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, the bytes a
device moves over the wire:

  all-reduce       2·(g-1)/g · result     (ring)
  all-gather       (g-1)/g · result       (result = gathered buffer)
  reduce-scatter   (g-1)/g · operand      (operand = g × result)
  all-to-all       (g-1)/g · result
  collective-permute  result

with g = replica-group size parsed from ``replica_groups``.
"""

from __future__ import annotations

import dataclasses
import re

# TPU v5e-class hardware constants (assignment-specified)
PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link
ICI_LINKS = 4            # usable links per chip on a 2D torus (x± / y±)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict
    wire_bytes: float  # per-device bytes moved over ICI

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))


def parse_collectives(hlo_text: str) -> CollectiveStats:
    bytes_by_kind: dict[str, float] = {}
    count_by_kind: dict[str, int] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_txt, kind = m.group(1), m.group(2)
        result_bytes = _shape_bytes(shape_txt)
        g = _group_size(line)
        frac = (g - 1) / g if g > 1 else 0.0
        if kind == "all-reduce":
            w = 2.0 * frac * result_bytes
        elif kind == "all-gather":
            w = frac * result_bytes
        elif kind == "reduce-scatter":
            w = frac * result_bytes * g  # operand = g × result
        elif kind == "all-to-all":
            w = frac * result_bytes
        else:  # collective-permute
            w = float(result_bytes)
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0.0) + result_bytes
        count_by_kind[kind] = count_by_kind.get(kind, 0) + 1
        wire += w
    return CollectiveStats(bytes_by_kind, count_by_kind, wire)


def _group_size(line: str) -> int:
    m = _GROUPS_ITOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


@dataclasses.dataclass
class RooflineTerms:
    flops: float        # per-device (XLA cost_analysis reports the SPMD program)
    hbm_bytes: float    # per-device
    wire_bytes: float   # per-device ICI traffic
    chips: int
    model_flops: float = 0.0  # GLOBAL useful flops (6·N·D)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / (ICI_LINKS * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """No-overlap roofline estimate (upper bound on achievable)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_upper_bound(self) -> float:
        """Model-flops utilization at the roofline step time."""
        denom = self.step_time * self.chips * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "wire_bytes": self.wire_bytes, "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_upper_bound": self.mfu_upper_bound,
        }


def terms_from_compiled(compiled, chips: int, model_flops: float,
                        hlo_text: str | None = None) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)
    return RooflineTerms(
        flops=flops, hbm_bytes=hbm, wire_bytes=coll.wire_bytes,
        chips=chips, model_flops=model_flops,
    ), coll
