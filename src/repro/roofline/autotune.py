"""Roofline-driven tile autotuning for the gather-style Pallas kernels.

Every kernel in :mod:`repro.kernels` walks the string through ``(1,
tile)`` BlockSpec windows (see :func:`repro.kernels.tiles.stage_tiles`)
and takes ``tile`` as a static argument that never changes results —
only how much HBM each grid step DMAs and how much VMEM the two-tile
halo window occupies.  Historically every call used a hard-coded
``tile=2048``.  This module picks the tile per
``(backend, kernel, dtype-bits, n-bucket)`` instead:

* **Model pick** — the VMEM/HBM roofline model of
  :mod:`repro.roofline.analysis`: each grid step moves ``2 * tile *
  4`` bytes HBM→VMEM (two int32 halo rows) plus its output row, so the
  per-step time model is ``max(t_dispatch, dma_bytes / HBM_BW)``.  The
  DMA term only reaches the fixed dispatch overhead at tiles far larger
  than the VMEM budget allows, so the model selects the SMALLEST
  feasible candidate: ``tile >= w_cap`` (kernels assert ``w <= tile``),
  ``tile`` large enough that the per-step DMA amortizes the issue
  overhead (``tile * 4 >= DMA_MIN_BYTES``), and the two-tile window
  under the per-step VMEM budget.  Same histogram-bucket idiom as
  :func:`repro.core.build.bucket_pad_widths` — ``n`` buckets to powers
  of two so one table entry covers a whole workload size class.
* **Measured fallback** — :func:`measured_sweep` times a caller-supplied
  thunk per candidate and keeps the argmin; used where the model's
  constants are wrong (e.g. interpret mode, exotic hosts) and by the
  ``--autotune`` driver flags.

Chosen tiles persist to a small JSON table (:class:`AutotuneTable`) that
:mod:`repro.kernels.ops` consults at dispatch via :func:`tile_for`.
Resolution order per key: explicit on-disk table entry → roofline model
(when ``REPRO_AUTOTUNE=model`` or a table is active) → the kernel's
static default.  The table path comes from ``REPRO_AUTOTUNE_TABLE``
(default ``.repro_autotune.json`` in the working directory); dispatch
only ever READS the table — writing happens solely through
:meth:`AutotuneTable.save` (driver flags / sweeps), so imports never
touch disk.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading

from repro.roofline.analysis import HBM_BW

# Per-step VMEM budget for the two-tile halo window + output row.  VMEM
# is ~16 MB/core (pallas guide); the double-buffered pipeline wants many
# steps in flight, so one step gets a conservative slice.
VMEM_STEP_BUDGET = 1 << 20          # 1 MiB
DMA_MIN_BYTES = 2048                # below this a DMA is issue-bound
DISPATCH_OVERHEAD_S = 1e-6          # fixed per-grid-step cost model

# Candidate tiles: powers of two spanning the kernels' historical
# defaults (512 for kmer_histogram, 2048 everywhere else).
TILE_CANDIDATES = (512, 1024, 2048, 4096, 8192)

# Static per-kernel defaults — what dispatch used before autotuning.
DEFAULT_TILES = {"kmer_histogram": 512}
DEFAULT_TILE = 2048


def n_bucket(n: int) -> int:
    """Power-of-two workload-size bucket for a string of ``n`` symbols
    (one table entry covers the whole class; same idiom as the
    node-build pad-width buckets)."""
    return 1 << max(int(n) - 1, 1).bit_length()


@dataclasses.dataclass(frozen=True)
class TileScore:
    """Roofline terms for one candidate tile."""

    tile: int
    vmem_bytes: int      # two-tile int32 halo window per grid step
    dma_bytes: int       # HBM bytes moved per grid step
    t_step: float        # modeled per-step seconds

    @property
    def feasible(self) -> bool:
        return self.vmem_bytes <= VMEM_STEP_BUDGET


def score_tile(tile: int, *, out_bytes: int = 256) -> TileScore:
    vmem = 2 * tile * 4 + out_bytes
    dma = 2 * tile * 4
    t = max(DISPATCH_OVERHEAD_S, dma / HBM_BW)
    return TileScore(tile=tile, vmem_bytes=vmem, dma_bytes=dma, t_step=t)


def model_pick(kernel: str, *, w_cap: int = 0,
               candidates=TILE_CANDIDATES) -> int:
    """The VMEM/HBM-model tile choice: smallest candidate that (a) fits
    the per-step VMEM budget, (b) covers the kernel's read width
    (``w <= tile`` is asserted by every kernel), and (c) moves enough
    bytes per DMA to amortize the issue overhead.  Falls back to the
    kernel's static default when nothing qualifies."""
    feas = [score_tile(t) for t in sorted(candidates)
            if t >= max(w_cap, 1) and t * 4 >= DMA_MIN_BYTES]
    feas = [s for s in feas if s.feasible]
    if not feas:
        return max(DEFAULT_TILES.get(kernel, DEFAULT_TILE), w_cap)
    best = min(feas, key=lambda s: (s.t_step, s.tile))
    return best.tile


def measured_sweep(run_fn, candidates=TILE_CANDIDATES, *, w_cap: int = 0,
                   repeats: int = 3):
    """Measured fallback: time ``run_fn(tile)`` per feasible candidate
    and return ``(best_tile, {tile: seconds})``.  ``run_fn`` must block
    until the device result is ready (callers wrap with
    ``jax.block_until_ready``)."""
    import time

    timings: dict[int, float] = {}
    for tile in sorted(candidates):
        if tile < max(w_cap, 1) or not score_tile(tile).feasible:
            continue
        run_fn(tile)  # warmup / compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            run_fn(tile)
            best = min(best, time.perf_counter() - t0)
        timings[tile] = best
    if not timings:
        raise ValueError("no feasible tile candidate for "
                         f"w_cap={w_cap} among {candidates}")
    return min(timings, key=timings.get), timings


def table_key(backend: str, kernel: str, bits: int, nb: int) -> str:
    return f"{backend}/{kernel}/b{bits}/n{nb}"


class AutotuneTable:
    """The small on-disk tile table: ``key -> {"tile": int, "source":
    "model" | "measured"}`` plus free-form metadata per entry."""

    def __init__(self, entries: dict | None = None,
                 path: str | None = None):
        self.entries: dict[str, dict] = dict(entries or {})
        self.path = path

    # ---- persistence -------------------------------------------------------

    @classmethod
    def load(cls, path: str) -> "AutotuneTable":
        with open(path) as f:
            payload = json.load(f)
        entries = payload.get("entries", payload)
        return cls(entries=entries, path=path)

    def save(self, path: str | None = None) -> str:
        path = path or self.path
        if not path:
            raise ValueError("AutotuneTable.save needs a path")
        with open(path, "w") as f:
            json.dump({"version": 1, "entries": self.entries}, f,
                      indent=2, sort_keys=True)
        self.path = path
        return path

    # ---- population --------------------------------------------------------

    def put(self, backend: str, kernel: str, bits: int, n: int, tile: int,
            *, source: str = "model", **meta) -> None:
        entry = {"tile": int(tile), "source": source}
        entry.update(meta)
        self.entries[table_key(backend, kernel, bits, n_bucket(n))] = entry

    def get(self, backend: str, kernel: str, bits: int, n: int):
        e = self.entries.get(table_key(backend, kernel, bits, n_bucket(n)))
        return int(e["tile"]) if e else None

    def fill_model(self, backend: str, kernels_w: dict[str, int],
                   bits: int, n: int) -> None:
        """Model-pick an entry per kernel for one workload class.
        ``kernels_w``: kernel name -> read-width cap."""
        for kernel, w_cap in kernels_w.items():
            self.put(backend, kernel, bits, n,
                     model_pick(kernel, w_cap=w_cap), source="model",
                     w_cap=int(w_cap))


# ---------------------------------------------------------------------------
# Dispatch-side resolution (consulted by repro.kernels.ops)
# ---------------------------------------------------------------------------

_LOCK = threading.Lock()
_ACTIVE: AutotuneTable | None = None
_LOADED_FROM: str | None = None


def default_table_path() -> str:
    return os.environ.get("REPRO_AUTOTUNE_TABLE", ".repro_autotune.json")


def set_active_table(table: AutotuneTable | None) -> None:
    """Install (or clear) the process-wide table — the driver-flag hook;
    also used by tests to pin a choice without touching disk."""
    global _ACTIVE, _LOADED_FROM
    with _LOCK:
        _ACTIVE = table
        _LOADED_FROM = getattr(table, "path", None) if table else None


def active_table() -> AutotuneTable | None:
    """The installed table, lazily loading the on-disk default once.  A
    missing file is remembered as 'no table' — dispatch stays one dict
    probe, no per-call stat."""
    global _ACTIVE, _LOADED_FROM
    with _LOCK:
        if _ACTIVE is not None:
            return _ACTIVE
        path = default_table_path()
        if _LOADED_FROM == path:  # already probed and missing
            return None
        _LOADED_FROM = path
        if os.path.exists(path):
            _ACTIVE = AutotuneTable.load(path)
        return _ACTIVE


def tile_for(kernel: str, *, backend: str, bits: int, n: int,
             w_cap: int = 0) -> int:
    """The tile :mod:`repro.kernels.ops` uses for one dispatch.

    Table entry → model pick (when ``REPRO_AUTOTUNE=model`` or a table
    is active) → static default.  The result always satisfies the
    kernels' ``w <= tile`` contract."""
    table = active_table()
    if table is not None:
        tile = table.get(backend, kernel, bits, n)
        if tile is not None:
            return max(tile, w_cap)
    mode = os.environ.get("REPRO_AUTOTUNE", "")
    if mode == "model" or table is not None:
        return model_pick(kernel, w_cap=w_cap)
    if mode not in ("", "off", "table"):
        raise ValueError(f"unknown REPRO_AUTOTUNE={mode!r}; "
                         "choose 'off', 'table' or 'model'")
    return max(DEFAULT_TILES.get(kernel, DEFAULT_TILE), w_cap)
