"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
experiments/dryrun.json.

    PYTHONPATH=src python -m repro.roofline.report [--json experiments/dryrun.json]
"""

from __future__ import annotations

import argparse
import json


def _fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def _fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.1f}us"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | status | per-dev peak mem | compile | collectives |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "ok":
            mem = _fmt_bytes(r["memory"]["peak_estimate_bytes"])
            colls = ",".join(f"{k}×{v}" for k, v in
                             sorted(r["collectives"]["counts"].items())) or "none"
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {mem} "
                f"| {r.get('t_compile_s', '-')}s | {colls} |")
        else:
            reason = r.get("reason", r.get("error", ""))[:60]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} "
                f"| - | - | {reason} |")
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | t_compute | t_memory | t_collective | "
        "bottleneck | MODEL_FLOPS/HLO | MFU@roofline | one-line diagnosis |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] != "ok":
            continue
        t = r["roofline"]
        diag = _diagnose(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {_fmt_s(t['t_compute_s'])} | {_fmt_s(t['t_memory_s'])} "
            f"| {_fmt_s(t['t_collective_s'])} | **{t['bottleneck']}** "
            f"| {t['useful_flops_ratio']:.2f} | {t['mfu_upper_bound'] * 100:.1f}% "
            f"| {diag} |")
    return "\n".join(lines)


def _diagnose(r) -> str:
    t = r["roofline"]
    bt = t["bottleneck"]
    shape = r["shape"]
    if r["arch"].startswith("era"):
        return "string gather + key sort traffic; zero-collective step proves no-merge parallelism"
    if bt == "memory":
        if shape.startswith("train") or shape.startswith("prefill"):
            return "S² attention logits/probs HBM traffic dominates → flash-attention kernel"
        return "KV-cache streaming is the floor; raise batch or quantize cache"
    if bt == "collective":
        return "vocab-sharded CE gather + TP all-reduces → local one-hot CE, overlap"
    if t["useful_flops_ratio"] < 0.6:
        return "full-remat recompute wastes FLOPs → dots-saveable policy"
    return "near compute roofline"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="experiments/dryrun.json")
    args = ap.parse_args()
    with open(args.json) as f:
        recs = json.load(f)
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skipped"]
    err = [r for r in recs if r["status"] == "error"]
    print(f"## Dry-run summary: {len(ok)} ok / {len(skip)} skipped / {len(err)} errors\n")
    print(dryrun_table(recs))
    print()
    print("## Roofline\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
