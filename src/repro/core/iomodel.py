"""I/O cost model (paper §4.4 and Figs. 9-10).

The container has no disk-backed strings, and on TPU the string lives in
HBM; this module reproduces the *paper's* I/O accounting analytically so
the benchmarks can report the quantities the paper optimizes:

* ``wavefront_scan_bytes`` — the WaveFront baseline reads all of S once per
  iteration per (virtual) tree.
* ``era_scan_bytes``       — ERA reads the same sequential stream but skips
  blocks with no active offset (the disk-seek heuristic, §4.4); with the
  elastic range the iteration count shrinks as leaves resolve.
* grouping amortization    — one stream shared by all sub-trees of a group.

All byte counts are per construction unit; multiply by groups / divide by
workers for the parallel projections (Table 3 / Fig. 13 benchmarks).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class IoReport:
    iterations: int
    seq_bytes_full: int        # full sequential scans (WaveFront discipline)
    seq_bytes_skip: int        # with the block-skip heuristic
    gathered_symbols: int      # what the TPU gather path actually fetches
    blocks_touched: int


def model_prepare_io(
    active_offsets: list[np.ndarray],
    ranges: list[int],
    n: int,
    block_bytes: int = 1 << 20,
) -> IoReport:
    """Model one group's SubTreePrepare I/O from its per-iteration state.

    ``active_offsets[t]`` = string offsets read at iteration t;
    ``ranges[t]`` = elastic range (symbols per offset) at iteration t.
    """
    seq_full = 0
    seq_skip = 0
    gathered = 0
    blocks_total = 0
    for offs, w in zip(active_offsets, ranges):
        seq_full += n
        if len(offs) == 0:
            continue
        gathered += len(offs) * w
        lo = offs // block_bytes
        hi = (offs + w - 1) // block_bytes
        # blocks covered by each read, then dedup across reads
        touched = set()
        for a, b in zip(lo.tolist(), hi.tolist()):
            touched.update(range(a, b + 1))
        blocks_total += len(touched)
        seq_skip += len(touched) * block_bytes
    return IoReport(
        iterations=len(ranges),
        seq_bytes_full=seq_full,
        seq_bytes_skip=min(seq_skip, seq_full),
        gathered_symbols=gathered,
        blocks_touched=blocks_total,
    )


def amortization_factor(n_prefixes: int, n_groups: int) -> float:
    """How many sub-trees share each scan of S thanks to virtual trees."""
    return n_prefixes / max(1, n_groups)
