"""I/O cost model (paper §4.4 and Figs. 9-10).

The container has no disk-backed strings, and on TPU the string lives in
HBM; this module reproduces the *paper's* I/O accounting analytically so
the benchmarks can report the quantities the paper optimizes:

* ``wavefront_scan_bytes`` — the WaveFront baseline reads all of S once per
  iteration per (virtual) tree.
* ``era_scan_bytes``       — ERA reads the same sequential stream but skips
  blocks with no active offset (the disk-seek heuristic, §4.4); with the
  elastic range the iteration count shrinks as leaves resolve.
* grouping amortization    — one stream shared by all sub-trees of a group.

All byte counts are per construction unit; multiply by groups / divide by
workers for the parallel projections (Table 3 / Fig. 13 benchmarks).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class IoReport:
    iterations: int
    seq_bytes_full: int        # full sequential scans (WaveFront discipline)
    seq_bytes_skip: int        # with the block-skip heuristic
    gathered_symbols: int      # what the TPU gather path actually fetches
    blocks_touched: int


def model_prepare_io(
    active_offsets: list[np.ndarray],
    ranges: list[int],
    n: int,
    block_bytes: int = 1 << 20,
) -> IoReport:
    """Model one group's SubTreePrepare I/O from its per-iteration state.

    ``active_offsets[t]`` = string offsets read at iteration t;
    ``ranges[t]`` = elastic range (symbols per offset) at iteration t.
    """
    seq_full = 0
    seq_skip = 0
    gathered = 0
    blocks_total = 0
    for offs, w in zip(active_offsets, ranges):
        seq_full += n
        if len(offs) == 0:
            continue
        gathered += len(offs) * w
        lo = offs // block_bytes
        hi = (offs + w - 1) // block_bytes
        # blocks covered by each read, then dedup across reads
        touched = set()
        for a, b in zip(lo.tolist(), hi.tolist()):
            touched.update(range(a, b + 1))
        blocks_total += len(touched)
        seq_skip += len(touched) * block_bytes
    return IoReport(
        iterations=len(ranges),
        seq_bytes_full=seq_full,
        seq_bytes_skip=min(seq_skip, seq_full),
        gathered_symbols=gathered,
        blocks_touched=blocks_total,
    )


def amortization_factor(n_prefixes: int, n_groups: int) -> float:
    """How many sub-trees share each scan of S thanks to virtual trees."""
    return n_prefixes / max(1, n_groups)


# ---------------------------------------------------------------------------
# Device-memory side of the model (paper §4.1: ERA sizes the construction
# unit to the memory budget; here the budget is *device* memory and the
# unit is a chunk of vertical-partition groups).

# One (group, leaf-slot) cell of PrepareState is six int32 fields:
# L, start, area, b_off, b_c1, b_c2.
STATE_FIELDS = 6
STATE_CELL_BYTES = STATE_FIELDS * 4


def state_bytes_per_group(capacity: int) -> int:
    """Device bytes of elastic-range state for one vertical-partition
    group at leaf capacity F (padded, so every group costs the same)."""
    return STATE_CELL_BYTES * capacity


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """How the streaming builder slices the group list into device-sized
    chunks.

    ``chunks`` are contiguous ``[lo, hi)`` ranges over the *original* group
    order, so flattening results back into the one-shot layout is a plain
    concatenation.  ``buffers`` is 2 when the pipeline double-buffers (the
    standby chunk's state is resident while the active chunk iterates) and
    1 for the synchronous copy-then-compute mode.
    """

    chunks: tuple[tuple[int, int], ...]
    capacity: int
    budget_bytes: int | None       # None = unbounded -> one chunk
    buffers: int = 2
    reserved_bytes: int = 0        # string + misc resident device bytes

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    @property
    def groups_per_chunk(self) -> int:
        return max((hi - lo) for lo, hi in self.chunks) if self.chunks else 0

    @property
    def chunk_state_bytes(self) -> int:
        """Worst-case device bytes of one chunk's PrepareState."""
        return self.groups_per_chunk * state_bytes_per_group(self.capacity)

    @property
    def peak_bytes(self) -> int:
        """Modeled peak device footprint: resident string + the active
        chunk's state + (when double-buffered) the standby chunk."""
        return self.reserved_bytes + self.buffers * self.chunk_state_bytes

    def describe(self) -> dict:
        return {
            "n_chunks": self.n_chunks,
            "groups_per_chunk": self.groups_per_chunk,
            "capacity": self.capacity,
            "chunk_state_bytes": self.chunk_state_bytes,
            "peak_bytes": self.peak_bytes,
            "budget_bytes": self.budget_bytes,
            "buffers": self.buffers,
        }


def plan_stream(
    n_groups: int,
    capacity: int,
    *,
    budget_bytes: int | None = None,
    reserved_bytes: int = 0,
    double_buffer: bool = True,
) -> StreamPlan:
    """Slice ``n_groups`` vertical-partition groups into contiguous chunks
    whose double-buffered PrepareState fits ``budget_bytes`` of device
    memory.

    ``reserved_bytes`` models device allocations that stay resident for
    the whole build (the packed string, routing tables) and is subtracted
    from the budget before sizing chunks.  Degenerate budgets are honored
    rather than rejected: an unbounded (``None``) or huge budget collapses
    to one chunk — the streaming build then *is* the one-shot batched
    build — and a budget too small for even one double-buffered group
    still yields one-group chunks (the floor of the planner; the model's
    ``peak_bytes`` then reports the overshoot honestly).
    """
    if n_groups <= 0:
        return StreamPlan(chunks=(), capacity=capacity,
                          budget_bytes=budget_bytes,
                          buffers=2 if double_buffer else 1,
                          reserved_bytes=reserved_bytes)
    buffers = 2 if double_buffer else 1
    per_group = state_bytes_per_group(capacity)
    if budget_bytes is None:
        gpc = n_groups
    else:
        avail = max(0, budget_bytes - reserved_bytes)
        gpc = max(1, min(n_groups, avail // (buffers * per_group)))
    chunks = tuple((lo, min(lo + gpc, n_groups))
                   for lo in range(0, n_groups, gpc))
    return StreamPlan(chunks=chunks, capacity=capacity,
                      budget_bytes=budget_bytes, buffers=buffers,
                      reserved_bytes=reserved_bytes)
