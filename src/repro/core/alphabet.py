"""Alphabet handling for ERA suffix-tree construction.

Symbols are encoded as small integer codes ``0..|Σ|-1``; the end-of-string
terminal ``$`` is always the LARGEST code ``|Σ|`` so that it sorts after
every real symbol — this matches the paper's traces (Example 2 sorts
``CGGT`` before ``C$`` and emits ``B = (G, $, 3)``).  Out-of-range gathers
read padding equal to the terminal code, which behaves like a run of
terminals: any two distinct suffixes diverge at or before the earlier
``$`` (the terminal is unique), so padding never affects a comparison that
matters.
"""

from __future__ import annotations

import dataclasses

import numpy as np

TERMINAL = "$"


@dataclasses.dataclass(frozen=True)
class Alphabet:
    """A finite symbol set plus the implicit terminal ``$`` (largest code)."""

    name: str
    symbols: str  # real symbols, codes 0..len(symbols)-1

    @property
    def terminal_code(self) -> int:
        return len(self.symbols)

    @property
    def base(self) -> int:
        """Radix for integer k-mer codes (``|Σ| + 1`` including ``$``)."""
        return len(self.symbols) + 1

    @property
    def bits_per_symbol(self) -> int:
        return max(1, int(np.ceil(np.log2(self.base))))

    @property
    def dense_bits(self) -> int:
        """Dense-packing width in bits per symbol (paper §6.1, generalized).

        Covers the REAL symbols only — the terminal is virtual in the dense
        representation (it exists only at the end of the string, so packed
        gathers substitute it by position instead of storing it; see
        :mod:`repro.core.packing`).  Rounded up to a power of two dividing
        32 so symbols never straddle word boundaries: 2-bit DNA, 4-bit
        reduced-protein-class alphabets, 8-bit fallback (= byte passthrough
        density) for protein/english/byte.
        """
        need = max(1, int(np.ceil(np.log2(max(2, len(self.symbols))))))
        for bits in (2, 4, 8):
            if bits >= need:
                return bits
        return 8

    def char_of(self, code: int) -> str:
        if code == self.terminal_code:
            return TERMINAL
        return self.symbols[code]

    def encode(self, text: str, *, terminate: bool = True) -> np.ndarray:
        """Encode ``text`` to uint8 codes, appending the terminal."""
        lut = np.full(256, 255, dtype=np.uint8)
        for i, ch in enumerate(self.symbols):
            lut[ord(ch)] = i
        arr = lut[np.frombuffer(text.encode("latin-1"), dtype=np.uint8)]
        if (arr == 255).any():
            bad = sorted({text[i] for i in np.nonzero(arr == 255)[0][:8]})
            raise ValueError(f"symbols {bad!r} not in alphabet {self.name!r}")
        if terminate:
            arr = np.concatenate([arr, np.array([self.terminal_code], np.uint8)])
        return arr

    def decode(self, codes: np.ndarray) -> str:
        return "".join(self.char_of(int(c)) for c in codes)

    def random_string(self, n: int, seed: int = 0) -> np.ndarray:
        """Random terminated string of ``n`` real symbols (n+1 codes)."""
        rng = np.random.default_rng(seed)
        arr = rng.integers(0, len(self.symbols), size=n, dtype=np.uint8)
        return np.concatenate([arr, np.array([self.terminal_code], np.uint8)])

    def pad_string(self, codes: np.ndarray, extra: int, pad_to_multiple: int = 1) -> np.ndarray:
        """Terminal-pad so gathers up to ``extra`` past the end are safe."""
        n = len(codes)
        target = n + extra
        if pad_to_multiple > 1:
            target = -(-target // pad_to_multiple) * pad_to_multiple
        out = np.full(target, self.terminal_code, dtype=np.uint8)
        out[:n] = codes
        return out


DNA = Alphabet("dna", "ACGT")
PROTEIN = Alphabet("protein", "ACDEFGHIKLMNPQRSTVWY")
ENGLISH = Alphabet("english", "abcdefghijklmnopqrstuvwxyz")
# Murphy-10 reduced protein classes (one representative letter per class:
# LVIM, C, A, G, ST, P, FYW, EDNQ, KR, H) — 10 symbols fit 4-bit dense
# packing, the "protein-class" tier between 2-bit DNA and the 8-bit
# fallback that full 20-letter protein needs.
PROTEIN_CLASS = Alphabet("protein_class", "LCAGSPFEKH")
# Raw bytes 0..254 (terminal = 255): indexes arbitrary binary data.  Codes
# above 127 reach the sign bit of packed int32 words, which is why every
# packed-word sort/comparison runs unsigned (see repro.core.packing).
BYTE = Alphabet("byte", "".join(chr(i) for i in range(255)))

ALPHABETS = {a.name: a for a in (DNA, PROTEIN, PROTEIN_CLASS, ENGLISH, BYTE)}
