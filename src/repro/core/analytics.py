"""Device-resident LCP + analytics engine over the flattened ERA index.

The ERA paper motivates suffix trees by their applications (bioinformatics,
time-series mining, compression); exact-occurrence lookup is only the first
of them.  This module turns the flattened index (:class:`DeviceIndex`, whose
concatenated leaf array IS the suffix array of S) into the classic SA + LCP
analytics stack, entirely device-resident:

* **Global LCP array** — ``lcp[i] = LCP(suffix ell[i-1], suffix ell[i])``.
  Intra-subtree entries are free: they are exactly the ``b_off`` divergence
  depths SubTreePrepare already computed (paper lines 16-23).  Only the
  T-1 cross-subtree boundary entries are missing, and because the vertical
  partition prefixes are prefix-free, each boundary LCP is strictly smaller
  than the shorter prefix — one bounded-width pass of the
  :func:`repro.kernels.ops.suffix_lcp_pairs` kernel fills them all.
* **Sparse-table RMQ** (:mod:`repro.core.rmq`, shared with the parallel
  tree builder) — O(1) LCP-interval queries ``LCP(ell[i], ell[j]) =
  min(lcp[i+1..j])`` and O(log n) maximal-interval expansion.

Four batched workloads ride on top, each cross-checked against naive numpy
oracles in ``tests/test_analytics.py``:

* :meth:`AnalyticsEngine.matching_stats` — per-position longest-match
  length + witness of a query string vs the index, one fused lower-bound
  binary-search/probe pass reusing the ``pattern_probe`` kernel;
* :meth:`AnalyticsEngine.top_repeats` / :meth:`longest_repeat` — maximal
  repeated substrings via top-k over the LCP array + interval expansion;
* :meth:`AnalyticsEngine.distinct_substrings` — n(n+1)/2 − ΣLCP;
* :meth:`AnalyticsEngine.kmer_spectrum` / :meth:`top_kmers` — k-mer
  frequencies as an LCP<k boundary sweep (cross-checked against the
  ``kmer_histogram`` kernel).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing, rmq
from repro.core import query as query_mod
from repro.core.query import DeviceIndex
from repro.kernels import ops as kops
from repro.kernels import ref as kref

_MS_BATCH_PAD = 64  # query positions round up to this (bounds recompiles)


# ---------------------------------------------------------------------------
# jitted cores (module-level so tracing caches across engine instances)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k_route", "n_iter", "use_pallas",
                                             "w", "word"))
def _matching_stats(s_text, ell, win_lo, win_hi, pows, q_ext, n_q,
                    *, k_route: int, n_iter: int, use_pallas: bool, w: int,
                    word: bool = False):
    """Matching statistics of query positions 0..B-1 vs the suffix array.

    s_text: the served string (byte array or dense PackedText — probe and
    neighbor gathers dispatch, results identical).  q_ext: (B + w,) int32
    query codes, terminal-padded past ``n_q``.  Each position's window
    ``q[i:i+w]`` is routed and lower-bounded exactly like a ``find_batch``
    pattern (the probe kernel is the only gather in the search); the
    max-LCP suffix is then one of the two lexicographic neighbors of the
    insertion point.  ``word`` (PackedText, terminal-free queries) packs
    the whole window batch to k-bit dense words once and runs the
    word-compare probe + word-LCP neighbor resolution — the window's
    terminal padding enters the comparison as its first-terminal limit
    (``n_q - i``).  Returns (ms, witness): int32[B].
    """
    b = q_ext.shape[0] - w
    total = ell.shape[0]

    idx = jnp.arange(b, dtype=jnp.int32)
    windows = q_ext[idx[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]]
    if word:
        bits = s_text.bits
        pat_words = packing.pack_pattern_dense(windows, bits, s_text.terminal)
        mask_words = jnp.broadcast_to(
            packing.pack_dense(
                jnp.full((1, w), (1 << bits) - 1, jnp.int32), bits),
            pat_words.shape)
        # the window holds real query symbols then terminal padding: its
        # comparison limit is the first terminal (== n_q - i, clipped)
        lim_p = jnp.clip(n_q - idx, 0, w)
        w_arr = jnp.full((b,), w, jnp.int32)
        probe_w = kops.pattern_probe_words_impl(use_pallas)
        probe = lambda st, pos, pat, mask: probe_w(st, pos, pat, mask,
                                                   w_arr, lim_p)
        gather = kops.range_gather_words_impl(use_pallas)
    else:
        probe = kops.pattern_probe_impl(use_pallas)
        gather = kops.range_gather_impl(use_pallas)
        pat_words = packing.pack_words(windows)
        mask_words = jnp.full_like(pat_words, -1)  # full-width comparison

    # routing: the window is always k_route symbols deep (terminal-padded),
    # so its depth-k_route code owns exactly one cell.
    c = jnp.sum(windows[:, :k_route] * pows[None, :], axis=1)
    lo0 = win_lo[c]
    hi0 = jnp.maximum(win_hi[c], lo0)

    def body(_, st):
        lo, hi = st
        mid = (lo + hi) // 2
        pos = ell[jnp.clip(mid, 0, total - 1)]
        cmp = probe(s_text, pos, pat_words, mask_words)
        act = lo < hi
        lo = jnp.where(act & (cmp < 0), mid + 1, lo)
        hi = jnp.where(act & (cmp >= 0), mid, hi)
        return lo, hi

    pos, _ = jax.lax.fori_loop(0, n_iter, body, (lo0, hi0))

    # the suffix maximizing LCP with the window is a lex neighbor of the
    # insertion point; compare both neighbors' packed reads with the window.
    left_row = jnp.clip(pos - 1, 0, total - 1)
    right_row = jnp.clip(pos, 0, total - 1)
    lw = gather(s_text, ell[left_row], w)
    rw = gather(s_text, ell[right_row], w)
    if word:
        def window_lcp(sw, la):
            # min(first-diff, limits) — except when suffix and window hit
            # their terminals at the SAME index with no earlier real
            # difference: there the byte rows continue matching through
            # the equal terminal padding, so the byte LCP is exactly w
            p = packing.lcp_words(sw, pat_words, bits)
            capped = jnp.minimum(jnp.minimum(jnp.minimum(p, la), lim_p), w)
            return jnp.where((la == lim_p) & (p >= la), w, capped)

        la_l = packing.word_limit(s_text.n_real, ell[left_row], w)
        la_r = packing.word_limit(s_text.n_real, ell[right_row], w)
        raw_l = window_lcp(lw, la_l)
        raw_r = window_lcp(rw, la_r)
    else:
        raw_l = kref.lcp_pairs_ref(lw, pat_words, w)[0]
        raw_r = kref.lcp_pairs_ref(rw, pat_words, w)[0]
    lcp_l = jnp.where(pos > 0, raw_l, 0)
    lcp_r = jnp.where(pos < total, raw_r, 0)
    best = jnp.maximum(lcp_l, lcp_r)
    # window symbols past the query end are terminal padding: clipping to
    # the remaining query length makes the padded computation exact.
    ms = jnp.clip(jnp.minimum(best, n_q - idx), 0)
    wit_row = jnp.where(lcp_l >= lcp_r, left_row, right_row)
    witness = jnp.where(ms > 0, ell[wit_row], -1)
    return jnp.stack([ms, witness])  # one array -> one host sync


@functools.partial(jax.jit, static_argnames=("k",))
def _top_repeats(vals, vals_rev, lcp, ell, *, k: int):
    """Top-k LCP entries expanded to maximal repeat intervals.

    For row i with v = lcp[i] >= 1, the maximal run jl < i <= jn with
    ``lcp[jl] < v``, ``lcp[jn] < v`` (walls exist: lcp[0] = 0) spans the
    suffix rows jl..jn-1 that all share the length-v prefix — so the repeat
    occurs exactly ``jn - jl`` times.  Returns (v, count, witness, jl, jn).
    """
    total = lcp.shape[0]
    v, i = jax.lax.top_k(lcp, k)
    target = jnp.maximum(v, 1)  # v == 0 rows are filtered by the caller
    jl = rmq.prev_less(list(vals), i, target)
    jn = total - rmq.prev_less(list(vals_rev), total - i, target)
    return v, jn - jl, ell[i], jl, jn


@functools.partial(jax.jit, static_argnames=("k", "topk"))
def _kmer_spectrum(ell, lcp, *, k: int, topk: int):
    """k-mer groups as maximal runs of lcp >= k; counts skip suffixes
    shorter than k (they are always singleton groups: lcp <= length < k)."""
    total = ell.shape[0]
    rows = jnp.arange(total, dtype=jnp.int32)
    valid = (ell + k) <= total  # suffix long enough to host a full k-mer
    gid = jnp.cumsum((lcp < k).astype(jnp.int32)) - 1  # lcp[0]=0 -> gid[0]=0
    counts = jnp.zeros(total, jnp.int32).at[gid].add(valid.astype(jnp.int32))
    rep = jnp.full(total, total, jnp.int32).at[gid].min(
        jnp.where(valid, rows, total))
    top_c, top_g = jax.lax.top_k(counts, topk)
    top_pos = ell[jnp.clip(rep[top_g], 0, total - 1)]
    return counts, rep, top_c, top_pos


@jax.jit
def _lcp_rows(vals, lcp, ell, i, j):
    """LCP of the suffixes at SA rows i and j (batched, i == j allowed)."""
    total = lcp.shape[0]
    lo = jnp.minimum(i, j)
    hi = jnp.maximum(i, j)
    pair = rmq.range_min(list(vals), jnp.minimum(lo + 1, total - 1), hi)
    return jnp.where(lo == hi, total - ell[lo], pair)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AnalyticsEngine:
    """LCP array + RMQ + batched analytics over a :class:`DeviceIndex`."""

    dev: DeviceIndex
    lcp: jax.Array                      # int32[total]; lcp[0] == 0
    lcp_host: np.ndarray
    vals: tuple                         # forward range-min sparse table
    vals_rev: tuple                     # table over [-1] + lcp[::-1] (NSV)

    @property
    def total(self) -> int:
        return int(self.lcp_host.shape[0])

    # ---- construction -----------------------------------------------------

    @classmethod
    def from_index(cls, index, dev: DeviceIndex | None = None,
                   **device_kwargs) -> "AnalyticsEngine":
        """Build from a :class:`SuffixTreeIndex`: seed intra-subtree LCPs
        from the stored ``b_off`` divergence depths, fill the cross-subtree
        boundaries with the batched suffix-LCP kernel."""
        if dev is None:
            dev = DeviceIndex.from_index(index, **device_kwargs)
        prefixes = sorted(index.subtrees)
        parts = []
        for p in prefixes:
            b = np.asarray(index.subtrees[p].b_off, np.int32).copy()
            if len(b):
                b[0] = 0
            parts.append(b)
        lcp = np.concatenate(parts).astype(np.int32)
        if len(prefixes) > 1:
            bnd = np.asarray(dev.sub_off)[1:].astype(np.int64)
            ell = dev.ell_host
            # prefix-freeness bounds every boundary LCP below the shorter
            # prefix length; one fixed-width kernel pass covers them all.
            max_plen = max(len(p) for p in prefixes)
            w = -(-(max_plen + 1) // 4) * 4
            if w <= dev.max_pattern_len:  # dev padding already covers w;
                s_pad = dev.s_text       # packed or byte — kernel dispatches
            else:
                s_pad = jnp.asarray(index.alphabet.pad_string(
                    np.asarray(index.s), extra=w + 8))
            cross = kops.suffix_lcp_pairs(
                s_pad, jnp.asarray(ell[bnd - 1], jnp.int32),
                jnp.asarray(ell[bnd], jnp.int32), w)
            lcp[bnd] = np.asarray(cross)
        return cls.from_device(dev, lcp)

    @classmethod
    def from_device(cls, dev: DeviceIndex, lcp) -> "AnalyticsEngine":
        lcp_host = np.asarray(lcp, np.int32)
        total = int(lcp_host.shape[0])
        if total != dev.n_leaves:
            raise ValueError(f"lcp length {total} != n_leaves {dev.n_leaves}")
        h = jnp.asarray(lcp_host)
        n_levels = rmq.log2_ceil(max(total, 2)) + 2
        vals, _ = rmq.sparse_table(h, n_levels)
        h_rev_ext = jnp.concatenate([jnp.array([-1], jnp.int32), h[::-1]])
        vals_rev, _ = rmq.sparse_table(h_rev_ext, n_levels)
        return cls(dev=dev, lcp=h, lcp_host=lcp_host,
                   vals=tuple(vals), vals_rev=tuple(vals_rev))

    # ---- persistence ------------------------------------------------------

    def save(self, path: str) -> None:
        """One npz holding the flattened index AND the LCP array, so
        ``analytics_serve`` restarts skip both build and flatten."""
        blobs = self.dev.to_blobs()
        blobs["lcp"] = self.lcp_host
        np.savez_compressed(query_mod.npz_path(path), **blobs)

    @classmethod
    def load(cls, path: str) -> "AnalyticsEngine":
        with np.load(query_mod.npz_path(path)) as data:
            if "lcp" not in data:
                raise ValueError(
                    f"{path} has no 'lcp' array — it is a DeviceIndex "
                    f"(query_serve) cache, not an analytics cache; rebuild "
                    f"with AnalyticsEngine.save")
            dev = DeviceIndex.from_blobs(data)
            lcp = np.asarray(data["lcp"])
        return cls.from_device(dev, lcp)

    # ---- LCP-interval queries --------------------------------------------

    def lcp_rows(self, i, j) -> np.ndarray:
        """Batched LCP of the suffixes at SA rows ``i`` and ``j`` (any
        order; equal rows return the full suffix length)."""
        i = jnp.asarray(i, jnp.int32)
        j = jnp.asarray(j, jnp.int32)
        return np.asarray(_lcp_rows(self.vals, self.lcp, self.dev.ell, i, j))

    # ---- matching statistics ---------------------------------------------

    def matching_stats(self, q, *, window: int | None = None):
        """Per-position longest match of ``q`` against the indexed string.

        Returns ``(ms, witness)``: for each i, ``ms[i]`` is the length of
        the longest prefix of ``q[i:]`` occurring somewhere in S and
        ``witness[i]`` one position where it occurs (-1 when ms == 0).
        Lengths are capped at ``window`` (default: the index's
        ``max_pattern_len``, the same cap ``find_batch`` has).
        """
        q = np.asarray(q)
        if q.ndim != 1 or len(q) < 1:
            raise ValueError("query must be a non-empty 1-D code array")
        if q.min() < 0 or q.max() >= self.dev.base:
            raise ValueError(f"query has codes outside [0, {self.dev.base})")
        w_cap = (self.dev.max_pattern_len // 4) * 4  # stay within pad_batch's cap
        w_req = int(window) if window is not None else w_cap
        if w_req < 1:
            raise ValueError("window must be >= 1")
        w = -(-max(w_req, self.dev.k_route, 4) // 4) * 4  # packing granularity
        if w > w_cap:
            raise ValueError(
                f"window {w} exceeds max_pattern_len={self.dev.max_pattern_len} "
                f"(rounded to {w_cap})")
        b_pad = -(-len(q) // _MS_BATCH_PAD) * _MS_BATCH_PAD
        q_ext = np.full(b_pad + w, self.dev.base - 1, np.int32)
        q_ext[: len(q)] = q
        # dense-packed indexes default to word-compare; a query embedding
        # the terminal sentinel falls back to the byte-key path, whose
        # comparison semantics are defined for it
        word = (self.dev.packed and kops._use_word_compare()
                and int(q.max()) < self.dev.s_text.terminal)
        out = np.asarray(_matching_stats(
            self.dev.s_text, self.dev.ell, self.dev.win_lo, self.dev.win_hi,
            self.dev.pows, q_ext, np.int32(len(q)),
            k_route=self.dev.k_route, n_iter=self.dev.n_iter,
            use_pallas=kops._use_pallas(), w=w, word=word))
        # re-apply the caller's exact cap (w was rounded up to whole words;
        # a witness matching >= ms symbols stays valid after clipping)
        return np.minimum(out[0, : len(q)], w_req), out[1, : len(q)]

    # ---- repeats ----------------------------------------------------------

    def top_repeats(self, k: int = 10) -> list[dict]:
        """Up to ``k`` deepest maximal repeat intervals, longest first.

        Each entry: ``length`` (symbols), ``count`` (occurrences),
        ``witness`` (one start position), ``rows`` (the SA row interval
        [lo, hi) of all occurrences).  Ties on the same interval dedupe.
        """
        if k < 1:
            raise ValueError("k must be >= 1")
        # a high-multiplicity repeat contributes MANY equal LCP rows that
        # dedupe to one interval, so the candidate pool grows (recompiling
        # _top_repeats at most a few times) until k distinct intervals are
        # found or the LCP array is exhausted.
        kk = min(self.total, 4 * k)
        while True:
            v, count, wit, jl, jn = _top_repeats(
                self.vals, self.vals_rev, self.lcp, self.dev.ell, k=kk)
            out, seen = [], set()
            exhausted = False
            for vi, ci, wi, li, ni in zip(
                    np.asarray(v), np.asarray(count), np.asarray(wit),
                    np.asarray(jl), np.asarray(jn)):
                if vi <= 0:
                    exhausted = True  # no repeats beyond this point
                    break
                key = (int(li), int(ni))
                if key in seen:
                    continue
                seen.add(key)
                out.append({"length": int(vi), "count": int(ci),
                            "witness": int(wi), "rows": (int(li), int(ni))})
                if len(out) == k:
                    break
            if len(out) == k or exhausted or kk == self.total:
                return out
            kk = min(self.total, 4 * kk)

    def longest_repeat(self) -> dict | None:
        """The longest substring occurring >= 2 times (None if all suffixes
        diverge immediately, i.e. every LCP entry is zero)."""
        top = self.top_repeats(1)
        return top[0] if top else None

    # ---- counting ---------------------------------------------------------

    def distinct_substrings(self, *, include_terminal: bool = False) -> int:
        """Number of distinct non-empty substrings: n(n+1)/2 − ΣLCP over the
        n = |S| suffixes.  By default the n substrings containing the
        terminal ``$`` (one per suffix ending) are excluded."""
        n = self.total
        full = n * (n + 1) // 2 - int(self.lcp_host.astype(np.int64).sum())
        return full - n if not include_terminal else full

    # ---- k-mer spectrum ---------------------------------------------------

    def kmer_spectrum(self, k: int):
        """All distinct k-mers of S as ``(starts, counts)``: one witness
        start position and the occurrence count per k-mer (suffixes shorter
        than ``k`` never contribute)."""
        if not 1 <= k <= self.total:
            raise ValueError(f"need 1 <= k <= {self.total}")
        counts, rep, _, _ = _kmer_spectrum(self.dev.ell, self.lcp, k=k, topk=1)
        counts = np.asarray(counts)
        rep = np.asarray(rep)
        mask = counts > 0
        starts = self.dev.ell_host[rep[mask]].astype(np.int64)
        return starts, counts[mask].astype(np.int64)

    def top_kmers(self, k: int, topk: int = 10) -> list[dict]:
        """The ``topk`` most frequent k-mers: ``kmer`` (code array),
        ``count``, ``witness`` (one start position)."""
        if not 1 <= k <= self.total:
            raise ValueError(f"need 1 <= k <= {self.total}")
        tk = min(int(topk), self.total)
        _, _, top_c, top_pos = _kmer_spectrum(self.dev.ell, self.lcp,
                                              k=k, topk=tk)
        # gather the (topk, k) windows on device; transferring the whole
        # string to read topk*k symbols would be an O(n) copy per call
        # (read_symbols decodes dense storage in-register)
        wins = np.asarray(self.dev.read_symbols(top_pos, k))
        out = []
        for c, p, w in zip(np.asarray(top_c), np.asarray(top_pos), wins):
            if c <= 0:
                break
            out.append({"kmer": w.copy(), "count": int(c), "witness": int(p)})
        return out
