"""Vertical partitioning (paper §4.1, Alg. VerticalPartitioning).

Splits the suffix tree of ``S`` into sub-trees ``T_p`` indexed by
variable-length S-prefixes ``p`` with frequency ``0 < f_p <= F_M``, then
packs sub-trees into *virtual trees* (groups) by first-fit-decreasing so a
single pass over ``S`` is amortized across a full memory budget of work.

Two counting strategies:

* ``histogram`` (paper-faithful): iteration ``t`` makes one vectorized pass
  over S computing rolling base-``|Σ|+1`` codes of every length-``t`` window
  and histograms them against the working set.  This mirrors the paper's
  "scan S once per iteration" I/O behaviour; when the Pallas kernels are
  selected (``REPRO_KERNELS=pallas`` or a TPU backend — see
  :mod:`repro.kernels.ops`) and ``base**t`` fits VMEM, the counting pass is
  the ``kmer_histogram`` kernel and the host only materializes positions
  for surviving prefixes (one stable argsort + group slicing per
  iteration, not one O(n) scan per survivor).
* ``positions`` (beyond-paper): once a prefix overflows, its occurrence list
  is materialized and children are counted by gathering ``S[pos + t]`` —
  O(f_p) work instead of an O(n) scan.  Also used automatically when
  ``base**t`` would overflow int64.

Frequencies count *window occurrences* which equal suffix counts because the
terminal ``$`` (the LARGEST code, ``base - 1``) makes every suffix distinct
and windows are padded with the terminal code beyond the end of the string.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SubTreePrefix:
    """A vertical-partition unit: the sub-tree T_p for S-prefix ``p``."""

    symbols: tuple[int, ...]  # symbol codes of p
    freq: int
    positions: np.ndarray  # int64 occurrence positions of p in S

    @property
    def length(self) -> int:
        return len(self.symbols)


@dataclasses.dataclass
class VirtualTree:
    """A group of sub-trees processed as one unit (shared scans of S)."""

    prefixes: list[SubTreePrefix]

    @property
    def total_freq(self) -> int:
        return sum(p.freq for p in self.prefixes)


@dataclasses.dataclass
class VerticalStats:
    scans: int = 0  # full passes over S (histogram iterations)
    refine_steps: int = 0  # position-refinement rounds
    bytes_scanned: int = 0  # modeled sequential I/O


def _window_codes(s_padded: np.ndarray, n: int, t: int, base: int,
                  prev: np.ndarray | None) -> np.ndarray:
    """Rolling base-``base`` codes of all length-t windows starting at 0..n-1."""
    if prev is None:
        codes = s_padded[:n].astype(np.int64)
        for j in range(1, t):
            codes = codes * base + s_padded[j : j + n]
        return codes
    return prev * base + s_padded[t - 1 : t - 1 + n].astype(np.int64)


_KERNEL_NBINS_MAX = 1 << 16  # kmer_histogram VMEM-residency bound


def _candidate_counts(s_padded: np.ndarray, codes: np.ndarray, n: int,
                      t: int, base: int,
                      cand: np.ndarray) -> np.ndarray:
    """Frequency of each candidate depth-``t`` prefix code.

    Dispatches to the ``kmer_histogram`` Pallas kernel (full base**t
    histogram in VMEM, indexed at the candidate codes) when the kernel path
    is selected and the bin count fits; otherwise counts on the host via
    searchsorted + bincount against the sorted candidate set.
    """
    from repro.kernels import ops as kops  # local: keep numpy path jax-free

    if kops._use_pallas() and base**t <= _KERNEL_NBINS_MAX:
        import jax.numpy as jnp

        hist = np.asarray(kops.kmer_histogram(
            jnp.asarray(s_padded[: n + max(t, 2)]), n, t, base))
        return hist[cand].astype(np.int64)
    order = np.argsort(cand)
    cand_sorted = cand[order]
    idx = np.searchsorted(cand_sorted, codes)
    idx_clipped = np.minimum(idx, len(cand_sorted) - 1)
    hit = cand_sorted[idx_clipped] == codes
    counts = np.bincount(idx_clipped[hit], minlength=len(cand_sorted))
    freq = np.zeros(len(cand), dtype=np.int64)
    freq[order] = counts  # map sorted index back to candidate order
    return freq


class _PositionIndex:
    """One stable argsort of the window codes, sliced per survivor.

    Replaces the former ``np.nonzero(codes == code)`` per survivor —
    O(n · #survivors) — with one O(n log n) grouping pass per iteration;
    stable sort keeps each group's positions already ascending.
    """

    def __init__(self, codes: np.ndarray):
        self.order = np.argsort(codes, kind="stable").astype(np.int64)
        self.sorted_codes = codes[self.order]

    def positions_of(self, code: int) -> np.ndarray:
        lo = np.searchsorted(self.sorted_codes, code, side="left")
        hi = np.searchsorted(self.sorted_codes, code, side="right")
        return self.order[lo:hi].copy()


def vertical_partition(
    s: np.ndarray,
    base: int,
    f_max: int,
    *,
    strategy: str = "histogram",
    stats: VerticalStats | None = None,
) -> list[SubTreePrefix]:
    """Alg. VerticalPartitioning lines 1–11: the sub-tree prefix set."""
    if f_max < 1:
        raise ValueError("f_max must be >= 1")
    n = len(s)
    t_max_code = int(63 // np.ceil(np.log2(base)))  # int64 overflow guard
    stats = stats if stats is not None else VerticalStats()

    # ---- phase 1: histogram scans (paper-faithful) -----------------------
    survivors: list[tuple[tuple[int, ...], int]] = []  # (symbols, freq)
    survivor_positions: dict[tuple[int, ...], np.ndarray] = {}
    overflow: list[tuple[int, ...]] = []  # prefixes needing refinement

    terminal = base - 1  # terminal is the largest code; pad continues it
    pad = np.full(max(t_max_code, 2), terminal, dtype=np.uint8)
    s_padded = np.concatenate([s, pad])

    if strategy == "histogram":
        work = [(c,) for c in range(base)]
        codes = None
        t = 0
        while work:
            t += 1
            if t > t_max_code:
                overflow.extend(work)
                break
            codes = _window_codes(s_padded, n, t, base, codes)
            stats.scans += 1
            stats.bytes_scanned += n
            cand = np.array(
                [sum(c * base ** (t - 1 - j) for j, c in enumerate(p)) for p in work],
                dtype=np.int64,
            )
            freq_by_work = _candidate_counts(s_padded, codes, n, t, base, cand)
            nxt: list[tuple[int, ...]] = []
            pos_index: _PositionIndex | None = None
            for w_i, p in enumerate(work):
                f = int(freq_by_work[w_i])
                if 0 < f <= f_max:
                    if pos_index is None:  # one grouping pass per iteration
                        pos_index = _PositionIndex(codes)
                    survivors.append((p, f))
                    survivor_positions[p] = pos_index.positions_of(int(cand[w_i]))
                elif f > f_max:
                    nxt.extend(p + (c,) for c in range(base))
            work = nxt
    else:
        overflow = [(c,) for c in range(base)]

    # ---- phase 2: position refinement (beyond-paper / overflow) ----------
    if overflow:
        # materialize positions for the overflow roots
        pending: list[tuple[tuple[int, ...], np.ndarray]] = []
        for p in overflow:
            t = len(p)
            if t == 1:
                pos = np.nonzero(s == p[0])[0].astype(np.int64)
            else:
                # parent positions are unknown here only in pure-positions
                # strategy for t==1; histogram phase always breaks at t_max
                # with full working sets, so recompute by scanning once.
                mask = np.ones(n, dtype=bool)
                for j, c in enumerate(p):
                    mask &= s_padded[j : j + n] == c
                pos = np.nonzero(mask)[0].astype(np.int64)
                stats.bytes_scanned += n
            pending.append((p, pos))
        while pending:
            stats.refine_steps += 1
            nxt_pending = []
            for p, pos in pending:
                f = len(pos)
                if f == 0:
                    continue
                if f <= f_max:
                    survivors.append((p, f))
                    survivor_positions[p] = pos
                    continue
                t = len(p)
                nxt_sym = s_padded[pos + t]
                for c in range(base):
                    child_pos = pos[nxt_sym == c]
                    if len(child_pos):
                        nxt_pending.append((p + (c,), child_pos))
            pending = nxt_pending

    return [
        SubTreePrefix(symbols=p, freq=f, positions=survivor_positions[p])
        for p, f in survivors
    ]


def group_prefixes(prefixes: list[SubTreePrefix], f_max: int) -> list[VirtualTree]:
    """Alg. VerticalPartitioning lines 12–22: first-fit-decreasing grouping."""
    todo = sorted(prefixes, key=lambda p: -p.freq)
    groups: list[VirtualTree] = []
    while todo:
        group = [todo.pop(0)]
        total = group[0].freq
        rest = []
        for p in todo:
            if total + p.freq <= f_max:
                group.append(p)
                total += p.freq
            else:
                rest.append(p)
        todo = rest
        groups.append(VirtualTree(prefixes=group))
    return groups


def vertical_partition_grouped(
    s: np.ndarray,
    base: int,
    f_max: int,
    *,
    strategy: str = "histogram",
    group: bool = True,
    stats: VerticalStats | None = None,
) -> list[VirtualTree]:
    """Full vertical partitioning: prefix set + (optional) grouping.

    ``group=False`` reproduces the paper's "no virtual trees" ablation
    (each sub-tree its own unit — Fig. 9a baseline).
    """
    prefixes = vertical_partition(s, base, f_max, strategy=strategy, stats=stats)
    if group:
        return group_prefixes(prefixes, f_max)
    return [VirtualTree(prefixes=[p]) for p in prefixes]
