"""Numpy reference oracles for the ERA pipeline.

Everything in here is deliberately simple and independent of the JAX
implementation: prefix-doubling suffix array, Kasai LCP, brute-force
S-prefix frequency counting, the reference ``(L, B)`` construction of the
paper's ``SubTreePrepare``, and a canonical interval-form suffix (sub-)tree
used to check ``BuildSubTree`` output for isomorphism.

Conventions match :mod:`repro.core.alphabet`: ``S`` is a uint8 code array
whose last element is the terminal code ``|Σ|`` (the largest code, sorting
after all real symbols, as in the paper's Example 2 traces).
"""

from __future__ import annotations

import numpy as np


# ---------------------------------------------------------------------------
# Suffix array / LCP oracles
# ---------------------------------------------------------------------------

def suffix_array(s: np.ndarray) -> np.ndarray:
    """Manber–Myers prefix doubling via ``np.lexsort``; O(n log^2 n)."""
    s = np.asarray(s)
    n = len(s)
    rank = s.astype(np.int64)
    sa = np.argsort(rank, kind="stable")
    k = 1
    while k < n:
        # key = (rank[i], rank[i+k]) with -1 past the end
        rank2 = np.full(n, -1, dtype=np.int64)
        rank2[:-k] = rank[k:]
        sa = np.lexsort((rank2, rank))
        # recompute ranks
        prev = (rank[sa[1:]] != rank[sa[:-1]]) | (rank2[sa[1:]] != rank2[sa[:-1]])
        new_rank = np.zeros(n, dtype=np.int64)
        new_rank[sa[1:]] = np.cumsum(prev)
        if new_rank[sa[-1]] == n - 1:
            return sa
        rank = new_rank
        k *= 2
    return sa


def lcp_array(s: np.ndarray, sa: np.ndarray) -> np.ndarray:
    """Kasai: ``lcp[i] = LCP(suffix sa[i-1], suffix sa[i])``; lcp[0] = 0."""
    n = len(s)
    rank = np.zeros(n, dtype=np.int64)
    rank[sa] = np.arange(n)
    lcp = np.zeros(n, dtype=np.int64)
    h = 0
    for i in range(n):
        if rank[i] > 0:
            j = sa[rank[i] - 1]
            while i + h < n and j + h < n and s[i + h] == s[j + h]:
                h += 1
            lcp[rank[i]] = h
            if h > 0:
                h -= 1
        else:
            h = 0
    return lcp


def suffix_lcp(s: np.ndarray, i: int, j: int) -> int:
    """Direct LCP of suffixes i and j (small-input oracle)."""
    n = len(s)
    h = 0
    while i + h < n and j + h < n and s[i + h] == s[j + h]:
        h += 1
    return h


# ---------------------------------------------------------------------------
# Vertical partitioning oracles
# ---------------------------------------------------------------------------

def prefix_frequency(s: np.ndarray, prefix: np.ndarray) -> int:
    """Number of suffixes of ``s`` whose S-prefix equals ``prefix``."""
    t = len(prefix)
    n = len(s)
    count = 0
    for i in range(n):
        if i + t <= n and np.array_equal(s[i : i + t], prefix):
            count += 1
    return count


def prefix_positions(s: np.ndarray, prefix: np.ndarray) -> np.ndarray:
    t = len(prefix)
    n = len(s)
    return np.array(
        [i for i in range(n) if i + t <= n and np.array_equal(s[i : i + t], prefix)],
        dtype=np.int64,
    )


def vertical_partition_ref(s: np.ndarray, base: int, f_max: int):
    """Paper Alg. VerticalPartitioning lines 1-11 (no grouping), brute force.

    Returns a list of ``(prefix_tuple, frequency)`` with 0 < f <= f_max.
    """
    out = []
    work = [(c,) for c in range(base)]
    while work:
        nxt = []
        for p in work:
            f = prefix_frequency(s, np.array(p, dtype=np.uint8))
            if 0 < f <= f_max:
                out.append((p, f))
            elif f > f_max:
                nxt.extend(p + (c,) for c in range(base))
        work = nxt
    return out


# ---------------------------------------------------------------------------
# (L, B) reference — the paper's SubTreePrepare output
# ---------------------------------------------------------------------------

def era_reference_lb(s: np.ndarray, prefix: np.ndarray):
    """Reference ``(L, B)`` arrays for sub-tree T_p (paper §4.2.2).

    ``L[i]`` are occurrence positions of ``prefix`` in lexicographic suffix
    order; ``B[i] = (c1, c2, offset)`` where ``offset`` is the LCP (counted
    from the suffix start, i.e. including ``|p|``) of suffixes ``L[i-1]`` and
    ``L[i]`` and ``c1, c2`` the first symbols after the divergence.
    """
    pos = prefix_positions(s, prefix)
    order = sorted(pos, key=lambda i: tuple(int(x) for x in s[i:]))
    ell = np.array(order, dtype=np.int64)
    b = []
    for k in range(1, len(ell)):
        off = suffix_lcp(s, int(ell[k - 1]), int(ell[k]))
        c1 = int(s[ell[k - 1] + off]) if ell[k - 1] + off < len(s) else 0
        c2 = int(s[ell[k] + off]) if ell[k] + off < len(s) else 0
        b.append((c1, c2, off))
    return ell, b


# ---------------------------------------------------------------------------
# Canonical suffix sub-tree from (L, B_off): interval form
# ---------------------------------------------------------------------------

def tree_intervals(b_off: np.ndarray, f: int):
    """Canonical internal-node intervals of the sub-tree described by (L, B).

    The suffix sub-tree over leaves ``0..F-1`` (in lexicographic order) is
    uniquely determined by the adjacent-divergence depths ``b_off[1..F-1]``:
    each internal node is an interval ``(l, r, depth)`` meaning "the lowest
    common ancestor of leaves l..r-1 has string-depth ``depth``".  This is
    the classic SA+LCP interval enumeration (Abouelhoda-style bottom-up
    traversal); it is the isomorphism oracle for BuildSubTree outputs.

    Returns a sorted list of ``(l, r, depth)`` with r exclusive, one entry
    per internal node.
    """
    if f <= 1:
        return []
    out = []
    stack = [(0, 0)]  # (depth, left_boundary); depth-0 sentinel
    for i in range(1, f):
        h = int(b_off[i])
        lb = i - 1
        while stack and stack[-1][0] > h:
            d, l = stack.pop()
            out.append((l, i, d))
            lb = l
        if not stack or stack[-1][0] < h:
            stack.append((h, lb))
    min_h = int(min(int(b_off[i]) for i in range(1, f)))
    while stack:
        d, l = stack.pop()
        if d >= min_h:  # drop the artificial depth-0 sentinel root
            out.append((l, f, d))
    return sorted(out)


def occurrences(s: np.ndarray, pattern: np.ndarray) -> np.ndarray:
    """Brute-force substring search oracle."""
    n, m = len(s), len(pattern)
    return np.array(
        [i for i in range(n - m + 1) if np.array_equal(s[i : i + m], pattern)],
        dtype=np.int64,
    )
