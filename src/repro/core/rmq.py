"""Shared sparse-table range-minimum machinery (device-resident, O(1) query).

Originally private to :mod:`repro.core.build` (the parallel Cartesian-tree
builder computes all-nearest-smaller-values with it); the analytics engine
(:mod:`repro.core.analytics`) needs the same structure over the GLOBAL LCP
array for LCP-interval queries and maximal-repeat expansion, so the table
lives here and both import it.

Layout: ``sparse_table(h, L)`` returns ``(vals, args)`` — lists of
``L + 1`` arrays where ``vals[k][i] = min(h[i : i + 2**k])`` (clipped to the
array end) and ``args[k][i]`` the LEFTMOST index attaining it.  All queries
are closed intervals ``[lo, hi]`` and fully vectorized (no data-dependent
shapes), so they trace cleanly under ``jax.jit``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import clz32


def log2_ceil(x: int) -> int:
    return max(1, int(np.ceil(np.log2(max(2, x)))))


def sparse_table(h: jax.Array, n_levels: int):
    """Leftmost-argmin sparse table over ``h``. Returns (vals, args) lists."""
    n = h.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    big = jnp.iinfo(jnp.int32).max
    vals = [h]
    args = [idx]
    span = 1
    for _ in range(n_levels):
        src = jnp.minimum(idx + span, n - 1)
        valid = (idx + span) < n
        shifted_v = jnp.where(valid, vals[-1][src], big)
        shifted_a = jnp.where(valid, args[-1][src], n)
        take_left = vals[-1] <= shifted_v  # ties -> leftmost
        vals.append(jnp.where(take_left, vals[-1], shifted_v))
        args.append(jnp.where(take_left, args[-1], shifted_a))
        span *= 2
    return vals, args


def _level_of(length: jax.Array, n_levels: int) -> jax.Array:
    """floor(log2(length)) clipped into the table's level range."""
    k = jnp.maximum(0, 31 - clz32(length))
    return jnp.minimum(k, n_levels)


def range_min(vals, lo: jax.Array, hi: jax.Array):
    """min over h[lo..hi] inclusive, vectorized; requires lo <= hi."""
    k = _level_of(hi - lo + 1, len(vals) - 1)
    stacked = jnp.stack(vals)  # (levels+1, n)
    left = stacked[k, lo]
    right = stacked[k, jnp.maximum(hi - (1 << k) + 1, lo)]
    return jnp.minimum(left, right)


def range_argmin(vals, args, lo: jax.Array, hi: jax.Array):
    """Leftmost argmin over h[lo..hi] inclusive; requires lo <= hi."""
    k = _level_of(hi - lo + 1, len(vals) - 1)
    sv = jnp.stack(vals)
    sa = jnp.stack(args)
    l_v, l_a = sv[k, lo], sa[k, lo]
    hi2 = jnp.maximum(hi - (1 << k) + 1, lo)
    r_v, r_a = sv[k, hi2], sa[k, hi2]
    take_left = l_v <= r_v
    return jnp.where(take_left, l_a, r_a)


def prev_less(vals, init_pos: jax.Array, target: jax.Array) -> jax.Array:
    """Largest ``j < init_pos`` with ``h[j] < target``, via block skipping.

    Requires ``h[0] < target`` for every queried target (a sentinel wall),
    so the result is always >= 0.  O(n_levels) fixed-trip loop, vectorized
    over arbitrarily-shaped ``init_pos``/``target``.
    """
    n_levels = len(vals) - 1

    def body(k, pos):
        step = 1 << (n_levels - 1 - k)
        cand = pos - step
        lo = jnp.maximum(cand, 0)
        blockmin = range_min(vals, lo, jnp.maximum(pos - 1, lo))
        jump = (cand >= 1) & (blockmin >= target) & (pos - 1 >= lo)
        return jnp.where(jump, cand, pos)

    pos = jax.lax.fori_loop(0, n_levels, body, init_pos)
    return pos - 1
