"""Shared packed-word machinery for construction AND querying.

Two representations live here:

**Sort keys** — one byte per symbol code, packed big-endian
4-symbols/int32 so that the UNSIGNED integer order of the packed words
equals the lexicographic order of the symbol sequence.  This is the single
comparison currency of the whole pipeline:

* :mod:`repro.core.prepare`  — elastic-range sort keys (SubTreePrepare),
* :mod:`repro.core.build`    — clz-based log2 in the parallel builder,
* :mod:`repro.core.query`    — batched pattern/suffix comparisons,
* :mod:`repro.kernels.ref`   — the pure-jnp kernel oracles.

**Storage** (:class:`PackedText`) — the string itself held DENSE at
``Alphabet.dense_bits`` bits per symbol (paper §6.1 generalized beyond
DNA: 2-bit DNA, 4-bit reduced-protein classes, 8-bit fallback), big-endian
inside uint32 words.  Gathers read the dense words and REPACK in-register
into the exact byte-per-symbol sort keys above (:func:`gather_pack_dense`),
so every downstream lexsort / LCP / probe is bit-identical between the
dense and byte paths while HBM string traffic shrinks by ``8/bits``.  The
terminal is *virtual* in dense storage: it only ever occurs at the end of
the string, so a gather substitutes the terminal code for every position
``>= n_real`` instead of spending a code point on it (codes ``0..|Σ|-1``
must fit ``bits``; the terminal ``|Σ|`` need not).

Signedness: codes up to 127 keep every packed key word non-negative, so
signed int32 comparisons coincide with lexicographic order (the original
DNA / protein assumption).  The byte alphabet (codes up to 255) sets the
int32 sign bit via the top byte; every sort or comparison on packed key
words must therefore run on the uint32 bit pattern — use :func:`as_u32`
(bitcast) or :func:`flip_sign` (order-preserving int32 remap) at the
comparison site.

**Word comparison** — the packed words themselves are ALSO a comparison
currency (ERA §6.1 taken to its conclusion: 16 DNA symbols per uint32
compare instead of 4 byte-codes per int32).  The subtlety is the virtual
terminal: a bits-saturated alphabet (DNA: 4 codes fill 2 bits exactly)
has no spare bit pattern for ``$``, so dense word reads SUBSTITUTE the
largest representable code (:func:`sub_code`) for every position past
``n_real`` and carry a per-row *limit* — the symbol index of the first
terminal (``n_real - off``).  Every word-level comparison then follows
one rule set, exact for all four alphabets:

* first difference ``p`` (XOR + count-leading-zeros, :func:`lcp_words`)
  below both limits → a real symbol difference, sign/LCP taken directly;
* otherwise the side whose limit comes first holds ``$`` there — it is
  LARGER (the terminal is the largest code) and the LCP is the smaller
  limit (:func:`lcp_words_limited`, :func:`probe_words_ref` in
  ``kernels.ref``);
* rows equal through the window with both limits beyond it are equal —
  the elastic-range sort appends ``w - limit`` as a least-significant
  tiebreak key so equal substituted keys order exactly like the byte
  keys (:func:`word_sort_keys`).

When the terminal fits ``bits`` (4-bit protein classes, 8-bit byte) the
substitution is the identity and the limit rules reduce to no-ops, so one
code path serves every alphabet.  The byte-key path remains the oracle:
both paths emit bit-identical construction arrays, query results and
analytics (``tests/test_packed.py``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

PACK_WEIGHTS = (1 << 24, 1 << 16, 1 << 8, 1)

_SIGN = jnp.int32(-(1 << 31))


def pack_words(sym: jax.Array) -> jax.Array:
    """(…, w) symbol codes → (…, w//4) int32 big-endian packed words."""
    *lead, w = sym.shape
    assert w % 4 == 0, "pack width must be a multiple of 4"
    grp = sym.astype(jnp.int32).reshape(*lead, w // 4, 4)
    weights = jnp.asarray(PACK_WEIGHTS, jnp.int32)
    return jnp.sum(grp * weights, axis=-1)


def gather_pack(s_padded: jax.Array, offs: jax.Array, w: int) -> jax.Array:
    """Gather ``w`` symbols at each offset and pack; pure-jnp fallback path.

    The TPU path is ``repro.kernels.range_gather`` (scalar-prefetch paged
    gather); this fallback is used on CPU and as the kernel oracle.
    """
    idx = offs[:, None].astype(jnp.int32) + jnp.arange(w, dtype=jnp.int32)[None, :]
    # S must be pre-padded with the terminal code (Alphabet.pad_string);
    # clip is only a safety net for the final over-reads of resolved areas.
    idx = jnp.minimum(idx, s_padded.shape[0] - 1)
    sym = jnp.take(s_padded, idx, axis=0)
    return pack_words(sym)


def as_u32(words: jax.Array) -> jax.Array:
    """Bitcast packed int32 words to uint32 (unsigned sort/compare keys)."""
    if words.dtype == jnp.uint32:
        return words
    return jax.lax.bitcast_convert_type(words.astype(jnp.int32), jnp.uint32)


def flip_sign(words: jax.Array) -> jax.Array:
    """XOR the sign bit: signed int32 order of the result == unsigned
    order of the input.  Usable inside Pallas kernels (no bitcast)."""
    return words ^ _SIGN


def clz32(x: jax.Array) -> jax.Array:
    """Count leading zeros of an int32 OR uint32 via bit smear + popcount.

    int32's arithmetic right shifts only over-smear below the highest set
    bit, so the result is exact for negative inputs too (clz == 0);
    uint32's logical shifts are the textbook form.  Plain jnp ops, so it
    is usable inside Pallas kernel bodies."""
    x = x | (x >> 1)
    x = x | (x >> 2)
    x = x | (x >> 4)
    x = x | (x >> 8)
    x = x | (x >> 16)
    return 32 - jax.lax.population_count(x.astype(jnp.uint32)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Dense k-bit text storage (paper §6.1, generalized to the alphabet)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedText:
    """The string stored dense at ``bits`` bits/symbol in uint32 words.

    ``words[k]`` holds symbols ``k*spw .. k*spw + spw - 1`` big-endian
    (``spw = 32 // bits``), so the bit pattern of a word run IS the
    lexicographic order of the symbols it covers.  Only the ``n_real``
    REAL symbols are stored; the terminal (and the terminal padding past
    it) is virtual — readers substitute ``terminal`` for every position
    ``>= n_real``.  ``words`` carries enough zero tail that any gather a
    caller is contracted to make (``n_real + extra`` symbols, see
    :func:`pack_text`) stays in bounds.

    Registered as a pytree with ``bits``/``terminal`` static, so a
    PackedText flows through ``jax.jit`` boundaries and abstract
    ``ShapeDtypeStruct`` lowering (the dry-run) like any array.
    """

    words: jax.Array   # uint32[n_words]; big-endian ``bits``-bit symbols
    n_real: jax.Array  # int32 scalar: symbols stored before the terminal
    bits: int          # static: 2 | 4 | 8
    terminal: int      # static: the (virtual) terminal code

    def tree_flatten(self):
        return (self.words, self.n_real), (self.bits, self.terminal)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(words=children[0], n_real=children[1],
                   bits=aux[0], terminal=aux[1])

    @property
    def syms_per_word(self) -> int:
        return 32 // self.bits

    @property
    def nbytes(self) -> int:
        return int(self.words.shape[0]) * 4


def resolve_dense(mode: str, alphabet) -> bool:
    """Does packing ``mode`` select dense storage for ``alphabet``?

    ``auto`` goes dense exactly when density buys traffic (< 8 bits);
    ``dense`` forces the packed machinery even at 8 bits (byte-equivalent
    density, useful for exercising the generic path); ``bytes`` never."""
    if mode == "bytes":
        return False
    if mode == "dense":
        return True
    if mode == "auto":
        return alphabet.dense_bits < 8
    raise ValueError(f"unknown packing mode {mode!r}; "
                     "choose 'auto', 'dense' or 'bytes'")


def pack_text(codes: np.ndarray, alphabet, *, extra: int = 8) -> PackedText:
    """Dense-pack a TERMINATED code string for device-resident gathers.

    ``codes``: uint8 codes whose last element is the terminal (the form
    :meth:`Alphabet.encode` produces).  ``extra``: how many symbols past
    the end gathers may read (the same contract as
    :meth:`Alphabet.pad_string`) — the word tail is sized to cover it plus
    one halo word for sub-word shift alignment.
    """
    codes = np.asarray(codes, np.uint8)
    if codes.size == 0 or codes[-1] != alphabet.terminal_code:
        raise ValueError("pack_text needs a terminated code string")
    bits = alphabet.dense_bits
    n_real = codes.size - 1
    real = codes[:n_real].astype(np.uint32)
    if real.size and real.max() >= (1 << bits):
        raise ValueError(
            f"codes exceed {bits}-bit dense range for alphabet "
            f"{alphabet.name!r} (max code {int(real.max())})")
    spw = 32 // bits
    n_words = -(-(n_real + extra) // spw) + 1  # +1 halo for shift alignment
    grp = np.zeros(n_words * spw, np.uint32)
    grp[:n_real] = real
    shifts = (32 - bits * (np.arange(spw, dtype=np.uint32) + 1))
    words = (grp.reshape(n_words, spw) << shifts[None, :]).sum(
        axis=1, dtype=np.uint32)
    return PackedText(words=jnp.asarray(words),
                      n_real=jnp.asarray(n_real, jnp.int32),
                      bits=bits, terminal=alphabet.terminal_code)


def pack_text_stream(chunks, alphabet, *, extra: int = 8) -> PackedText:
    """Dense-pack a terminated code string delivered in CHUNKS.

    ``chunks`` is any iterable of uint8 code arrays whose concatenation is
    a terminated code string (the :func:`pack_text` input contract); the
    chunks may have arbitrary sizes and are consumed one at a time, so the
    peak host footprint is one chunk plus a ``< syms_per_word`` carry —
    this is what lets :mod:`repro.launch.warmstart` migrate legacy byte
    archives to dense storage without materializing the decoded string.

    Bit-identical to ``pack_text`` on the concatenated string: symbols are
    committed to words only on ``syms_per_word``-aligned boundaries, the
    final symbol of the stream is held back one step (it must be the
    terminal, which is virtual and never stored), and the zero tail is
    sized by the same ``n_real + extra`` formula.
    """
    bits = alphabet.dense_bits
    spw = 32 // bits
    shifts = (32 - bits * (np.arange(spw, dtype=np.uint32) + 1))
    word_parts: list[np.ndarray] = []
    carry = np.zeros(0, np.uint32)   # committed symbols short of a word
    pending = None                   # last symbol seen; terminal candidate
    n_real = 0

    def commit(sym: np.ndarray) -> None:
        nonlocal carry, n_real
        if sym.size and sym.max() >= (1 << bits):
            raise ValueError(
                f"codes exceed {bits}-bit dense range for alphabet "
                f"{alphabet.name!r} (max code {int(sym.max())})")
        n_real += sym.size
        buf = np.concatenate([carry, sym]) if carry.size else sym
        n_full = buf.size // spw
        if n_full:
            head = buf[:n_full * spw].reshape(n_full, spw)
            word_parts.append(
                (head << shifts[None, :]).sum(axis=1, dtype=np.uint32))
        carry = buf[n_full * spw:]

    for chunk in chunks:
        c = np.asarray(chunk, np.uint8).astype(np.uint32)
        if c.size == 0:
            continue
        if pending is not None:
            c = np.concatenate([[pending], c])
        pending = int(c[-1])
        commit(c[:-1])
    if pending is None or pending != alphabet.terminal_code:
        raise ValueError("pack_text_stream needs a terminated code string")

    n_words = -(-(n_real + extra) // spw) + 1  # same formula as pack_text
    tail = np.zeros(n_words * spw - n_real, np.uint32)
    commit_real = n_real                       # commit() would double-count
    commit(tail)
    n_real = commit_real
    assert carry.size == 0
    words = (np.concatenate(word_parts) if word_parts
             else np.zeros(0, np.uint32))
    return PackedText(words=jnp.asarray(words),
                      n_real=jnp.asarray(n_real, jnp.int32),
                      bits=bits, terminal=alphabet.terminal_code)


def gather_symbols_dense(pt: PackedText, offs: jax.Array, w: int) -> jax.Array:
    """Read ``w`` symbol codes at each offset from dense storage.

    Returns (F, w) int32 codes with the virtual terminal substituted for
    positions ``>= n_real`` — element-for-element what a byte-path
    ``jnp.take`` from the terminal-padded string returns.  Pure-jnp; the
    Pallas realization is :mod:`repro.kernels.packed_gather`.
    """
    bits, spw = pt.bits, pt.syms_per_word
    offs = offs.astype(jnp.int32)
    aligned = _aligned_words(pt, offs, w)                       # (F, nw)
    shifts = (32 - bits * (jnp.arange(spw, dtype=jnp.uint32) + 1))
    sym = ((aligned[:, :, None] >> shifts[None, None, :]) & ((1 << bits) - 1))
    sym = sym.reshape(offs.shape[0], -1)[:, :w].astype(jnp.int32)
    past_end = (offs[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
                >= pt.n_real)
    return jnp.where(past_end, jnp.int32(pt.terminal), sym)


def _aligned_words(pt: PackedText, offs: jax.Array, w: int) -> jax.Array:
    """(F, ceil(w/spw)) uint32 dense words, shift-aligned to each offset."""
    bits, spw = pt.bits, pt.syms_per_word
    nw = -(-w // spw)
    word0 = offs // spw
    idx = word0[:, None] + jnp.arange(nw + 1, dtype=jnp.int32)[None, :]
    idx = jnp.minimum(idx, pt.words.shape[0] - 1)  # safety net (cf. gather_pack)
    words = jnp.take(pt.words, idx, axis=0).astype(jnp.uint32)  # (F, nw+1)
    sh = (bits * (offs % spw)).astype(jnp.uint32)[:, None]
    hi = words[:, :-1] << sh
    # funnel low half as (x >> 1) >> (31 - sh): equals x >> (32 - sh) for
    # sh > 0 and 0 for sh == 0, with every shift amount in-range — no
    # select needed (selects + masked shifts dominate this path on CPU).
    lo = (words[:, 1:] >> 1) >> (31 - sh)
    return hi | lo


def _spread_to_bytes(chunk: jax.Array, bits: int) -> jax.Array:
    """Spread 4 right-aligned ``bits``-bit fields of a uint32 lane into the
    4 big-endian bytes of the lane (classic bit-interleave deposit)."""
    if bits == 8:
        return chunk
    if bits == 4:
        t = (chunk | (chunk << 8)) & jnp.uint32(0x00FF00FF)
        return (t | (t << 4)) & jnp.uint32(0x0F0F0F0F)
    if bits == 2:
        t = (chunk | (chunk << 12)) & jnp.uint32(0x000F000F)
        return (t | (t << 6)) & jnp.uint32(0x03030303)
    raise ValueError(f"unsupported dense bits {bits}")


def gather_pack_dense(pt: PackedText, offs: jax.Array, w: int) -> jax.Array:
    """Gather ``w`` symbols from dense storage and emit byte sort keys.

    Bit-identical to :func:`gather_pack` on the terminal-padded byte
    string — the invariant the whole dense pipeline rests on (asserted in
    ``tests/test_packed.py``) — while moving ``bits/8`` of the bytes.

    The repack never materializes individual symbols: each output int32
    carries 4 symbols = ``4*bits`` consecutive dense bits, so it is one
    chunk-extract + bit-spread per OUTPUT word (4x fewer lanes than the
    per-symbol route), and the virtual-terminal tail is patched per word
    through a 5-entry keep/terminal mask table.
    """
    bits, spw = pt.bits, pt.syms_per_word
    assert w % 4 == 0, w
    offs = offs.astype(jnp.int32)
    f = offs.shape[0]
    n_out = w // 4
    aligned = _aligned_words(pt, offs, w)  # (F, ceil(w/spw))
    cpw = spw // 4  # output chunks per dense word
    if cpw > 1:
        csh = (32 - (4 * bits) * (jnp.arange(cpw, dtype=jnp.uint32) + 1))
        chunks = ((aligned[:, :, None] >> csh[None, None, :])
                  & jnp.uint32((1 << (4 * bits)) - 1))
        chunks = chunks.reshape(f, aligned.shape[1] * cpw)[:, :n_out]
    else:
        chunks = aligned[:, :n_out]
    out = _spread_to_bytes(chunks, bits)  # (F, n_out) big-endian byte words

    # virtual terminal: word j holds symbols off+4j .. off+4j+3; keep the
    # first v = clip(n_real - (off+4j), 0, 4) and overwrite the tail with
    # terminal bytes (= t_word on the dropped bytes: term == t_word & ~keep)
    t_word = jnp.uint32((pt.terminal & 0xFF) * 0x01010101)
    keep_tab = jnp.asarray(
        np.array([0, 0xFF000000, 0xFFFF0000, 0xFFFFFF00, 0xFFFFFFFF],
                 np.uint32))
    v = jnp.clip(pt.n_real - (offs[:, None]
                              + 4 * jnp.arange(n_out, dtype=jnp.int32)[None, :]),
                 0, 4)
    keep = keep_tab[v]
    out = (out & keep) | (t_word & ~keep)
    return jax.lax.bitcast_convert_type(out, jnp.int32)


# ---------------------------------------------------------------------------
# Word-parallel comparison primitives (dense words AS the compare currency)
# ---------------------------------------------------------------------------


def syms_per_word(bits: int) -> int:
    return 32 // bits


def sub_code(bits: int, terminal: int) -> int:
    """The code substituted for the virtual terminal in dense word reads.

    The largest representable code: when the terminal itself fits ``bits``
    (4-bit protein classes, 8-bit byte) this IS the terminal and word
    reads are faithful; a saturated alphabet (2-bit DNA, terminal code 4)
    substitutes the largest real code and relies on the per-row limit to
    keep comparisons exact (see the module docstring)."""
    return min(terminal, (1 << bits) - 1)


def _sub_word(bits: int, terminal: int) -> int:
    """``sub_code`` replicated across every field of a uint32 word."""
    sub = sub_code(bits, terminal)
    return sum(sub << (bits * k) for k in range(syms_per_word(bits)))


def pack_dense(sym: jax.Array, bits: int) -> jax.Array:
    """(…, m) symbol codes (< 2**bits) → (…, ceil(m/spw)) uint32 dense
    big-endian words, zero-padded past ``m`` — the pattern-side packing
    that mirrors what :func:`pack_text` stores for the string."""
    *lead, m = sym.shape
    spw = syms_per_word(bits)
    m_pad = -(-m // spw) * spw
    sym = sym.astype(jnp.uint32)
    if m_pad != m:
        pad = jnp.zeros((*lead, m_pad - m), jnp.uint32)
        sym = jnp.concatenate([sym, pad], axis=-1)
    grp = sym.reshape(*lead, m_pad // spw, spw)
    shifts = (32 - bits * (jnp.arange(spw, dtype=jnp.uint32) + 1))
    return jnp.sum(grp << shifts, axis=-1).astype(jnp.uint32)


def pack_pattern_dense(sym: jax.Array, bits: int, terminal: int) -> jax.Array:
    """Pack a (…, m) pattern/window batch to dense words, substituting the
    terminal code (``jnp.minimum`` with :func:`sub_code` — the identity
    for every code a valid pattern may hold except a too-wide terminal)."""
    sub = jnp.uint32(sub_code(bits, terminal))
    return pack_dense(jnp.minimum(sym.astype(jnp.uint32), sub), bits)


def gather_words_dense(pt: PackedText, offs: jax.Array, w: int) -> jax.Array:
    """(F, ceil(w/spw)) uint32 dense words, shift-aligned to each offset,
    with :func:`sub_code` substituted for every position ``>= n_real``.

    This is the word-compare analogue of :func:`gather_pack_dense`: the
    raw comparison keys, never spread back to bytes.  Pure-jnp; the
    Pallas realization is ``repro.kernels.packed_gather.range_gather_words``.
    """
    bits, spw = pt.bits, pt.syms_per_word
    offs = offs.astype(jnp.int32)
    aligned = _aligned_words(pt, offs, w)                        # (F, nw)
    nw = aligned.shape[1]
    # keep the first v = clip(n_real - word_start, 0, spw) fields of each
    # word; overwrite the tail with the substituted terminal pattern
    starts = offs[:, None] + spw * jnp.arange(nw, dtype=jnp.int32)[None, :]
    v = jnp.clip(pt.n_real - starts, 0, spw)
    full = jnp.uint32(0xFFFFFFFF)
    # shift stays in-range: v >= 1 rows shift by <= 32 - bits; v == 0 is
    # overridden by the where
    keep = jnp.where(
        v > 0,
        full << ((spw - jnp.maximum(v, 1)) * bits).astype(jnp.uint32),
        jnp.uint32(0))
    sub_w = jnp.uint32(_sub_word(bits, pt.terminal))
    return (aligned & keep) | (sub_w & ~keep)


def word_limit(n_real, offs: jax.Array, w: int) -> jax.Array:
    """Symbol index of the first (virtual) terminal in a width-``w`` read
    at each offset, clipped to [0, w] — the per-row comparison limit."""
    return jnp.clip(n_real - offs.astype(jnp.int32), 0, w)


def lcp_words(a: jax.Array, b: jax.Array, bits: int) -> jax.Array:
    """First differing SYMBOL index of (F, NW) uint32 dense word rows:
    XOR, first non-zero word, count-leading-zeros → field index.  Rows
    equal through all NW words return ``NW * spw``."""
    spw = syms_per_word(bits)
    nw = a.shape[-1]
    x = a ^ b
    neq = x != 0
    any_neq = jnp.any(neq, axis=-1)
    wi = jnp.argmax(neq, axis=-1).astype(jnp.int32)
    xw = jnp.take_along_axis(x, wi[..., None], axis=-1)[..., 0]
    sym = clz32(xw) // bits
    return jnp.where(any_neq, wi * spw + sym, nw * spw)


def extract_sym(words: jax.Array, idx: jax.Array, bits: int) -> jax.Array:
    """The ``bits``-wide field at symbol index ``idx`` of each word row."""
    spw = syms_per_word(bits)
    wv = jnp.take_along_axis(words, (idx // spw)[..., None], axis=-1)[..., 0]
    sh = (32 - bits * (idx % spw + 1)).astype(jnp.uint32)
    return ((wv >> sh) & ((1 << bits) - 1)).astype(jnp.int32)


def lcp_words_limited(a: jax.Array, b: jax.Array, lim_a: jax.Array,
                      lim_b: jax.Array, w: int, bits: int) -> jax.Array:
    """Row LCP in symbols, capped at ``w``, of substituted dense word rows
    with per-row terminal limits: ``min(first_diff, lim_a, lim_b, w)``.

    Exact vs the byte scan whenever ``lim_a != lim_b`` or the rows carry
    matching all-terminal tails past a common limit (suffix-vs-suffix
    always; window-vs-suffix for embedded-terminal-free queries)."""
    p = lcp_words(a, b, bits)
    return jnp.minimum(jnp.minimum(jnp.minimum(p, lim_a), lim_b),
                       w).astype(jnp.int32)


def lcp_adjacent_words(prev: jax.Array, cur: jax.Array, lim_prev: jax.Array,
                       lim_cur: jax.Array, w: int, bits: int, terminal: int):
    """Word-key analogue of ``prepare.lcp_adjacent``: (lcp, c1, c2) per
    row, with the true terminal code restored at a divergence that falls
    ON a row's limit (the substituted field there is :func:`sub_code`,
    but the suffix really holds ``$``).  Fully-equal rows (lcp == w)
    report c1 == c2 == 0, matching the byte oracle."""
    spw = syms_per_word(bits)
    nw = cur.shape[-1]
    lcp = lcp_words_limited(prev, cur, lim_prev, lim_cur, w, bits)
    idx = jnp.clip(lcp, 0, nw * spw - 1)
    ca = extract_sym(prev, idx, bits)
    cb = extract_sym(cur, idx, bits)
    diverged = lcp < w
    c1 = jnp.where(diverged, jnp.where(lim_prev == lcp, terminal, ca), 0)
    c2 = jnp.where(diverged, jnp.where(lim_cur == lcp, terminal, cb), 0)
    return lcp, c1.astype(jnp.int32), c2.astype(jnp.int32)


def word_sort_keys(pt: PackedText, offs: jax.Array, w: int,
                   gather_words=None) -> tuple[jax.Array, jax.Array]:
    """(keys, tie) for the elastic-range sort on dense word keys.

    keys: (F, ceil(w/spw)) uint32 substituted dense words; tie: (F,)
    int32 ``w - limit``, the LEAST significant sort key.  Substituted
    keys that compare equal through ``w`` symbols differ from the byte
    keys only where a terminal was substituted — and there the row whose
    terminal comes FIRST is lexicographically larger, which is exactly
    ascending ``w - limit``.  Rows with no terminal in the window tie at
    0, preserving the stable order the byte path keeps."""
    gather = gather_words or gather_words_dense
    keys = gather(pt, offs, w)
    tie = (w - word_limit(pt.n_real, offs, w)).astype(jnp.int32)
    return keys, tie


def unpack_text(pt: PackedText, n: int | None = None) -> np.ndarray:
    """Decode dense storage back to uint8 codes (terminal included).

    ``n``: total symbols to materialize (default ``n_real + 1``, i.e. the
    original terminated string)."""
    n_real = int(pt.n_real)
    n = n_real + 1 if n is None else int(n)
    spw = pt.syms_per_word
    words = np.asarray(pt.words)
    shifts = (32 - pt.bits * (np.arange(spw, dtype=np.uint32) + 1))
    sym = ((words[:, None] >> shifts[None, :]) & ((1 << pt.bits) - 1))
    sym = sym.reshape(-1)[:n].astype(np.uint8)
    sym[n_real:] = pt.terminal
    return sym
