"""Shared packed-word machinery for construction AND querying.

One byte per symbol code, packed big-endian 4-symbols/int32 so that the
UNSIGNED integer order of the packed words equals the lexicographic order
of the symbol sequence.  This module is the single implementation behind

* :mod:`repro.core.prepare`  — elastic-range sort keys (SubTreePrepare),
* :mod:`repro.core.build`    — clz-based log2 in the parallel builder,
* :mod:`repro.core.query`    — batched pattern/suffix comparisons,
* :mod:`repro.kernels.ref`   — the pure-jnp kernel oracles.

Signedness: codes up to 127 keep every packed word non-negative, so signed
int32 comparisons coincide with lexicographic order (the original DNA /
protein assumption).  The byte alphabet (codes up to 255) sets the int32
sign bit via the top byte; every sort or comparison on packed words must
therefore run on the uint32 bit pattern — use :func:`as_u32` (bitcast) or
:func:`flip_sign` (order-preserving int32 remap) at the comparison site.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

PACK_WEIGHTS = (1 << 24, 1 << 16, 1 << 8, 1)

_SIGN = jnp.int32(-(1 << 31))


def pack_words(sym: jax.Array) -> jax.Array:
    """(…, w) symbol codes → (…, w//4) int32 big-endian packed words."""
    *lead, w = sym.shape
    assert w % 4 == 0, "pack width must be a multiple of 4"
    grp = sym.astype(jnp.int32).reshape(*lead, w // 4, 4)
    weights = jnp.asarray(PACK_WEIGHTS, jnp.int32)
    return jnp.sum(grp * weights, axis=-1)


def gather_pack(s_padded: jax.Array, offs: jax.Array, w: int) -> jax.Array:
    """Gather ``w`` symbols at each offset and pack; pure-jnp fallback path.

    The TPU path is ``repro.kernels.range_gather`` (scalar-prefetch paged
    gather); this fallback is used on CPU and as the kernel oracle.
    """
    idx = offs[:, None].astype(jnp.int32) + jnp.arange(w, dtype=jnp.int32)[None, :]
    # S must be pre-padded with the terminal code (Alphabet.pad_string);
    # clip is only a safety net for the final over-reads of resolved areas.
    idx = jnp.minimum(idx, s_padded.shape[0] - 1)
    sym = jnp.take(s_padded, idx, axis=0)
    return pack_words(sym)


def as_u32(words: jax.Array) -> jax.Array:
    """Bitcast packed int32 words to uint32 (unsigned sort/compare keys)."""
    if words.dtype == jnp.uint32:
        return words
    return jax.lax.bitcast_convert_type(words.astype(jnp.int32), jnp.uint32)


def flip_sign(words: jax.Array) -> jax.Array:
    """XOR the sign bit: signed int32 order of the result == unsigned
    order of the input.  Usable inside Pallas kernels (no bitcast)."""
    return words ^ _SIGN


def clz32(x: jax.Array) -> jax.Array:
    """Count leading zeros of int32 via bit smear + popcount.

    Arithmetic right shifts only over-smear below the highest set bit, so
    the result is exact for negative inputs too (clz == 0)."""
    x = x | (x >> 1)
    x = x | (x >> 2)
    x = x | (x >> 4)
    x = x | (x >> 8)
    x = x | (x >> 16)
    return 32 - jax.lax.population_count(x.astype(jnp.uint32)).astype(jnp.int32)
