"""Assembled suffix-tree index: trie-on-top + per-prefix sub-trees.

The final index (paper §4, Figure 3) is a small top trie over the vertical-
partition prefixes plus one sub-tree per prefix.  Sub-trees are stored in
structure-of-arrays form (``build.SubTreeNodes``) together with the leaf
array ``L`` — which is precisely the suffix array restricted to the prefix,
so substring queries can run either as tree walks or as binary searches
over ``L``.  Both are implemented; they are cross-checked in tests.

Three query paths, slowest to fastest:

* ``find``       — per-pattern numpy binary search (the reference oracle);
* ``find_walk``  — per-pattern tree walk (validates the built topology);
* ``find_batch`` — device-resident batched engine (:mod:`repro.core.query`):
  the index is flattened once via :meth:`SuffixTreeIndex.to_device` and a
  whole batch resolves with one routing gather plus a vectorized binary
  search over packed words (Pallas ``pattern_probe`` kernel on TPU).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.alphabet import Alphabet
from repro.core.build import SubTreeNodes, nodes_to_host


@dataclasses.dataclass
class SubTree:
    prefix: tuple[int, ...]
    ell: np.ndarray          # int32[f] leaf positions, lexicographic order
    b_off: np.ndarray        # int32[f]
    b_c1: np.ndarray
    b_c2: np.ndarray
    nodes: SubTreeNodes | None = None  # filled by BuildSubTree

    @property
    def freq(self) -> int:
        return len(self.ell)


def _cmp_suffix(s: np.ndarray, pos: int, pattern: np.ndarray) -> int:
    """-1/0/+1: compare suffix at ``pos`` against ``pattern`` (prefix match = 0)."""
    n = len(s)
    m = len(pattern)
    chunk = s[pos : pos + m]
    if len(chunk) < m:
        pad = np.full(m - len(chunk), np.iinfo(np.int32).max, dtype=np.int64)
        chunk = np.concatenate([chunk.astype(np.int64), pad])
    diff = np.nonzero(chunk.astype(np.int64) - pattern.astype(np.int64))[0]
    if len(diff) == 0:
        return 0
    d = diff[0]
    return -1 if chunk[d] < pattern[d] else 1


@dataclasses.dataclass
class SuffixTreeIndex:
    s: np.ndarray            # the indexed string (codes incl. terminal)
    alphabet: Alphabet
    subtrees: dict[tuple[int, ...], SubTree]
    _device: object = dataclasses.field(default=None, repr=False, compare=False)
    _analytics: object = dataclasses.field(default=None, repr=False, compare=False)

    # ---- top trie ---------------------------------------------------------

    def route(self, pattern: np.ndarray) -> list[tuple[int, ...]]:
        """Prefixes whose sub-tree may contain occurrences of ``pattern``."""
        m = len(pattern)
        out = []
        for p in self.subtrees:
            k = min(len(p), m)
            if tuple(pattern[:k]) == p[:k]:
                out.append(p)
        return out

    # ---- queries ----------------------------------------------------------

    def find(self, pattern: np.ndarray) -> np.ndarray:
        """All occurrence positions of ``pattern`` in S (suffix-array search
        within the routed sub-trees; O(|route| * log f * |P|))."""
        hits = []
        m = len(pattern)
        for p in self.route(pattern):
            st = self.subtrees[p]
            if len(p) >= m:
                hits.append(st.ell)  # whole sub-tree matches
                continue
            lo, hi = 0, st.freq  # binary search boundaries in L
            # lower bound: first suffix >= pattern
            while lo < hi:
                mid = (lo + hi) // 2
                if _cmp_suffix(self.s, int(st.ell[mid]), pattern) < 0:
                    lo = mid + 1
                else:
                    hi = mid
            first = lo
            lo, hi = first, st.freq
            while lo < hi:
                mid = (lo + hi) // 2
                if _cmp_suffix(self.s, int(st.ell[mid]), pattern) == 0:
                    lo = mid + 1
                else:
                    hi = mid
            hits.append(st.ell[first:lo])
        if not hits:
            return np.empty(0, dtype=np.int64)
        return np.sort(np.concatenate(hits).astype(np.int64))

    def find_walk(self, pattern: np.ndarray) -> np.ndarray:
        """Tree-walk search (paper's O(|P|) descent) — validates the built
        tree topology; requires ``nodes`` on the routed sub-trees."""
        hits = []
        m = len(pattern)
        for p in self.route(pattern):
            st = self.subtrees[p]
            if len(p) >= m:
                hits.append(st.ell)
                continue
            if st.nodes is None:
                raise ValueError("sub-tree not built; call with build_impl set")
            node = self._descend(st, pattern)
            if node is not None:
                hits.append(st.ell[node[0] : node[1]])
        if not hits:
            return np.empty(0, dtype=np.int64)
        return np.sort(np.concatenate(hits).astype(np.int64))

    def _descend(self, st: SubTree, pattern: np.ndarray):
        """Walk the sub-tree matching ``pattern``; return (lo, hi) leaf span."""
        # one up-front host conversion, written back so repeated queries
        # never re-copy: the walks below touch the arrays element-wise,
        # which must never sync a device array per element
        nodes = st.nodes = nodes_to_host(st.nodes)
        parent = nodes.parent
        depth = nodes.depth
        f = nodes.n_leaves
        # children lists + leaf spans computed lazily and cached on the obj
        if not hasattr(st, "_children"):
            cap = len(parent)
            wit = nodes.witness
            kids: list[list[int]] = [[] for _ in range(cap)]
            root = -1
            for v in range(cap):
                pv = int(parent[v])
                if pv >= 0:
                    kids[pv].append(v)
                elif v >= f and wit[v] >= 0:
                    root = v
            lo = np.full(cap, 10**9)
            hi = np.full(cap, -1)
            for leaf in range(f):
                v = leaf
                while v != -1:
                    lo[v] = min(lo[v], leaf)
                    hi[v] = max(hi[v], leaf)
                    v = int(parent[v])
            st._children = kids
            st._span = (lo, hi)
            st._root = root
        kids = st._children
        lo, hi = st._span
        witness = nodes.witness

        v = st._root
        if v < 0:
            return None
        matched = 0
        m = len(pattern)
        while matched < m:
            nxt = None
            for c in kids[v]:
                # edge label = S[witness[c]+depth[v] : witness[c]+depth[c]]
                e0 = int(witness[c]) + int(depth[v])
                if self.s[e0] == pattern[matched]:
                    nxt = c
                    break
            if nxt is None:
                return None
            elen = int(depth[nxt]) - int(depth[v])
            take = min(elen, m - matched)
            lbl = self.s[int(witness[nxt]) + int(depth[v]) : int(witness[nxt]) + int(depth[v]) + take]
            if not np.array_equal(lbl, pattern[matched : matched + take]):
                return None
            matched += take
            v = nxt
        return int(lo[v]), int(hi[v]) + 1

    # ---- batched device fast path -----------------------------------------

    def to_device(self, **kwargs):
        """Flatten into a :class:`repro.core.query.DeviceIndex` (kwargs:
        ``route_cap``, ``max_pattern_len``).  The result is immutable and
        independent of this object."""
        from repro.core.query import DeviceIndex  # local: avoid import cycle

        return DeviceIndex.from_index(self, **kwargs)

    def find_batch(self, patterns) -> list[np.ndarray]:
        """Batched ``find``: one device round-trip for a whole list of
        patterns.  Results exactly match per-pattern ``find`` (sorted
        int64 occurrence positions); the flattened device form is built
        lazily on first use and cached."""
        if self._device is None:
            self._device = self.to_device()
        return self._device.find_batch(patterns)

    def analytics(self, **kwargs):
        """Build the LCP + analytics engine
        (:class:`repro.core.analytics.AnalyticsEngine`) over this index:
        matching statistics, maximal repeats, distinct-substring counts and
        k-mer spectra.  Without flattening kwargs, the engine AND its
        flattened device form are shared with ``find_batch`` (built
        lazily, cached once)."""
        from repro.core.analytics import AnalyticsEngine  # avoid import cycle

        if kwargs:
            return AnalyticsEngine.from_index(self, **kwargs)
        if self._analytics is None:
            if self._device is None:
                self._device = self.to_device()
            self._analytics = AnalyticsEngine.from_index(self, dev=self._device)
        return self._analytics

    # ---- stats / io -------------------------------------------------------

    @property
    def n_leaves(self) -> int:
        return sum(st.freq for st in self.subtrees.values())

    @property
    def n_internal(self) -> int:
        tot = 0
        for st in self.subtrees.values():
            if st.nodes is not None:
                tot += int(st.nodes.n_nodes) - int(st.nodes.n_leaves)
        return tot

    def save(self, path: str) -> None:
        blobs = {"s": self.s, "alphabet": np.frombuffer(self.alphabet.name.encode(), dtype=np.uint8)}
        for i, (p, st) in enumerate(sorted(self.subtrees.items())):
            blobs[f"p{i}_prefix"] = np.array(p, dtype=np.int32)
            blobs[f"p{i}_ell"] = np.asarray(st.ell)
            blobs[f"p{i}_boff"] = np.asarray(st.b_off)
            blobs[f"p{i}_bc1"] = np.asarray(st.b_c1)
            blobs[f"p{i}_bc2"] = np.asarray(st.b_c2)
            if st.nodes is not None:
                # persist built node arrays so a loaded index can find_walk;
                # normalize once (device arrays -> numpy, scalars -> int)
                nodes = nodes_to_host(st.nodes)
                blobs[f"p{i}_nparent"] = nodes.parent
                blobs[f"p{i}_ndepth"] = nodes.depth
                blobs[f"p{i}_nwitness"] = nodes.witness
                blobs[f"p{i}_ncounts"] = np.array(
                    [nodes.n_nodes, nodes.n_leaves], np.int64)
        np.savez_compressed(path, **blobs)

    @classmethod
    def load(cls, path: str, alphabet: Alphabet) -> "SuffixTreeIndex":
        data = np.load(path)
        subtrees = {}
        i = 0
        while f"p{i}_prefix" in data:
            p = tuple(int(x) for x in data[f"p{i}_prefix"])
            nodes = None
            if f"p{i}_nparent" in data:
                counts = data[f"p{i}_ncounts"]
                nodes = SubTreeNodes(
                    parent=data[f"p{i}_nparent"],
                    depth=data[f"p{i}_ndepth"],
                    witness=data[f"p{i}_nwitness"],
                    n_nodes=int(counts[0]),
                    n_leaves=int(counts[1]),
                )
            subtrees[p] = SubTree(
                prefix=p,
                ell=data[f"p{i}_ell"],
                b_off=data[f"p{i}_boff"],
                b_c1=data[f"p{i}_bc1"],
                b_c2=data[f"p{i}_bc2"],
                nodes=nodes,
            )
            i += 1
        return cls(s=data["s"], alphabet=alphabet, subtrees=subtrees)
