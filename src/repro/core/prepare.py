"""SubTreePrepare (paper §4.2.2) — elastic-range batched construction in JAX.

The paper's algorithm maintains, for one virtual tree, arrays ``L`` (leaf
positions, progressively reordered into lexicographic suffix order), ``A``
(active areas), ``B`` (branching triplets) and a read buffer ``R``.  Each
iteration reads ``range`` symbols after every *active* leaf, sorts active
areas lexicographically, and emits ``B[i] = (c1, c2, offset)`` where two
adjacent branches diverge.  ``range = |R| / |active|`` grows as leaves
resolve — the *elastic range*.

TPU-native formulation implemented here:

* the per-leaf read becomes a batched gather (``range_gather_pack``): ``w``
  symbols per active leaf, packed big-endian 4-symbols/int32 so that integer
  order == lexicographic order (terminal ``$`` = largest code, matching the
  paper's traces; S is terminal-padded so overruns are safe — two distinct
  suffixes always diverge at or before the earlier ``$``);
* the per-area reorder becomes ONE stable ``jnp.lexsort`` over the whole
  state with the area id as the major key.  Done elements get a unique
  singleton major key (their own index) so they never move — this preserves
  the paper's invariant that resolved positions are frozen;
* divergence detection becomes a vectorized adjacent-row LCP on the packed
  words (``lcp_adjacent``);
* areas / done flags are recomputed with a cumulative-max segment sweep.

``B`` entries are attached to *positions* (boundaries), which is sound
because areas only ever split in place: once positions ``i-1 | i`` are
separated, the boundary index never moves again.

Shapes are static per jitted step; the elastic range ``w`` is bucketed to
powers of two so at most ``log2(w_max/w_min)`` distinct compilations occur.
The host loop drives steps until every area is resolved.

Two drivers share the step: :func:`subtree_prepare` runs one virtual tree
(the reference / worked-example path) and :func:`subtree_prepare_batch`
stacks every group into one padded (G, F) state and drives a single
vmapped, buffer-donated loop — the default construction engine (paper §5:
virtual trees are independent, so the batch axis is free parallelism and
``shard_map`` over G distributes it across devices).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import packing
from repro.core.packing import (  # noqa: F401  (re-exported; shared with build/query)
    PackedText,
    as_u32,
    clz32 as _clz32,
    gather_pack,
    pack_words,
)
from repro.core.vertical import VirtualTree
from repro.kernels import ops as kops

DONE = jnp.int32(-1)
UNDEF = jnp.int32(-1)


class PrepareState(NamedTuple):
    """Per-virtual-tree state; all arrays have static length F (padded)."""

    L: jax.Array       # int32[F]  leaf positions (suffix offsets), -1 pad
    start: jax.Array   # int32[F]  symbols consumed so far per element
    area: jax.Array    # int32[F]  active-area id (= index of first element), -1 done
    b_off: jax.Array   # int32[F]  B offset, -1 undefined (b_*[0] unused)
    b_c1: jax.Array    # int32[F]  first divergent symbol of left branch
    b_c2: jax.Array    # int32[F]  first divergent symbol of right branch


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Memory-budget knobs (paper §4.4)."""

    r_budget_symbols: int = 1 << 20  # |R|: total symbols fetched per scan
    w_min: int = 4
    w_max: int = 256
    elastic: bool = True  # False = static range (paper Fig. 9b ablation)
    static_w: int = 16


def _init_arrays(group: VirtualTree, capacity: int):
    """Host-side (L, start, area) arrays for one group (padded to capacity)."""
    total = sum(p.freq for p in group.prefixes)
    if total > capacity:
        raise ValueError(f"group frequency {total} exceeds capacity {capacity}")
    L = np.full(capacity, -1, dtype=np.int32)
    start = np.zeros(capacity, dtype=np.int32)
    area = np.full(capacity, -1, dtype=np.int32)
    off = 0
    for p in group.prefixes:
        f = p.freq
        L[off : off + f] = p.positions
        start[off : off + f] = p.length
        if f > 1:
            area[off : off + f] = off
        off += f
    return L, start, area


def init_state(group: VirtualTree, capacity: int) -> PrepareState:
    """Concatenate the group's occurrence lists into padded state arrays.

    Each prefix's segment gets its own initial area (id = segment start);
    frequency-1 prefixes are born resolved (a single leaf is a complete
    sub-tree).
    """
    L, start, area = _init_arrays(group, capacity)
    return PrepareState(
        L=jnp.asarray(L),
        start=jnp.asarray(start),
        area=jnp.asarray(area),
        b_off=jnp.full(capacity, -1, jnp.int32),
        b_c1=jnp.zeros(capacity, jnp.int32),
        b_c2=jnp.zeros(capacity, jnp.int32),
    )


def _host_init_batch(groups: list[VirtualTree], capacity: int) -> PrepareState:
    """Host-side (numpy) stacked (G, F) state — the unit the streaming
    pipeline stages through pinned buffers before ``jax.device_put``."""
    if not groups:
        raise ValueError("init_batch needs at least one group")
    cols = [_init_arrays(g, capacity) for g in groups]
    g = len(groups)
    return PrepareState(
        L=np.stack([c[0] for c in cols]),
        start=np.stack([c[1] for c in cols]),
        area=np.stack([c[2] for c in cols]),
        b_off=np.full((g, capacity), -1, np.int32),
        b_c1=np.zeros((g, capacity), np.int32),
        b_c2=np.zeros((g, capacity), np.int32),
    )


def init_batch(groups: list[VirtualTree], capacity: int) -> PrepareState:
    """Stack ALL groups into one padded (G, F) state for the batched engine."""
    host = _host_init_batch(groups, capacity)
    return PrepareState(*(jnp.asarray(a) for a in host))


# ---------------------------------------------------------------------------
# Packed-key helpers — one shared implementation in core.packing, re-exported
# here (``pack_words`` / ``gather_pack``) for existing importers.
# ---------------------------------------------------------------------------


def lcp_adjacent(keys: jax.Array, w: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """LCP (in symbols) + first divergent symbols between adjacent rows.

    keys: (F, W) int32 packed words.  Returns (lcp, c1, c2) each (F,) where
    entry i compares rows i-1 and i (entry 0 is garbage, callers mask it).
    """
    a = jnp.concatenate([keys[:1], keys[:-1]], axis=0)  # row i-1
    b = keys
    neq = a != b
    any_neq = jnp.any(neq, axis=1)
    word = jnp.argmax(neq, axis=1).astype(jnp.int32)  # first differing word
    aw = jnp.take_along_axis(a, word[:, None], axis=1)[:, 0]
    bw = jnp.take_along_axis(b, word[:, None], axis=1)[:, 0]
    x = aw ^ bw
    byte = _clz32(x) // 8  # byte index from the top (0..3); x>0 when any_neq
    lcp = jnp.where(any_neq, word * 4 + byte, w).astype(jnp.int32)
    shift = (3 - byte) * 8
    c1 = (aw >> shift) & 0xFF
    c2 = (bw >> shift) & 0xFF
    return lcp, c1.astype(jnp.int32), c2.astype(jnp.int32)


# ---------------------------------------------------------------------------
# One elastic-range step (jitted per static w)
# ---------------------------------------------------------------------------

def _kernel_impls(use_pallas: bool):
    """Select kernel implementations; a STATIC jit arg so switching the
    REPRO_KERNELS env var between builds cannot hit a stale trace cache.

    The returned gather dispatches on the string representation: a dense
    :class:`repro.core.packing.PackedText` (paper §6.1 generalized —
    ``8/bits``x less gather traffic) or the terminal-padded byte array.
    Both emit identical byte-per-symbol sort keys, so the LCP stage is
    shared and construction output is representation-independent."""
    if use_pallas:
        from repro.kernels.lcp import lcp_pairs as lcp_k

        interp = jax.default_backend() != "tpu"
        return (
            kops.range_gather_impl(True),
            lambda a, b, w: lcp_k(a, b, w, interpret=interp),
        )
    from repro.kernels import ref as kref

    return kops.range_gather_impl(False), kref.lcp_pairs_ref


def _fused_sort_order(major, keys, tie, *, w: int, bits: int,
                      f: int) -> jax.Array | None:
    """Stable sort order on (major, window, tie) packed into the fewest
    uint32 lanes — the fabric engine's sort-key fusion.

    The lexsort path compares ``2 + n_words`` operands (tie + every dense
    word + the area major).  But the triple is just one big integer:
    ``major`` needs ceil(log2 F) bits, the window exactly ``w*bits``
    meaningful bits (top-aligned in the words), ``tie`` ceil(log2(w+1)).
    Bit-concatenating them yields ceil(total/32) lanes — ONE lane for the
    hot small-``w`` iterations of a 2-bit alphabet, and always at least
    one fewer comparator operand than lexsort.

    The fused key drops each word's bits BEYOND ``w`` symbols, which the
    lexsort path does feed to the comparator; by the step's documented
    invariant those extra bits only reorder rows INSIDE still-active
    equal-window blocks, which later iterations re-sort before anything
    observable is emitted — final construction arrays are bit-identical
    (pinned by tests/test_fabric.py).

    Returns None when the packing cannot beat lexsort (major + tie alone
    overflow one lane — F beyond ~2^26 with w = 64).
    """
    mb = max(1, int(np.ceil(np.log2(max(f, 2)))))
    tb = max(1, int(np.ceil(np.log2(w + 2))))
    if mb + tb > 32:
        return None
    kw = w * bits
    total = mb + kw + tb
    n_lanes = -(-total // 32)
    lanes = [jnp.zeros(major.shape, jnp.uint32) for _ in range(n_lanes)]

    def place(value, pos, width):
        # OR a right-aligned ``width``-bit field into the conceptual
        # bitstring at MSB-offset ``pos`` (lane bitrange [32j, 32j+32))
        end = pos + width
        lane0, lane1 = pos // 32, (end - 1) // 32
        if lane0 == lane1:
            lanes[lane0] = lanes[lane0] | (value << (32 * (lane0 + 1) - end))
        else:  # field straddles a lane boundary: split high/low
            lanes[lane0] = lanes[lane0] | (value >> (end - 32 * (lane0 + 1)))
            lanes[lane1] = lanes[lane1] | (value << (32 * (lane1 + 1) - end))

    place(major.astype(jnp.uint32), 0, mb)
    for j in range(keys.shape[1]):
        m_j = min(32, kw - 32 * j)  # meaningful top bits of word j
        place(keys[:, j] >> (32 - m_j), mb + 32 * j, m_j)
    place(tie.astype(jnp.uint32), mb + kw, tb)
    return jnp.lexsort(tuple(lanes[::-1]))


def prepare_step(s_padded, state: PrepareState, *, w: int,
                 use_pallas: bool = False,
                 word_keys: bool | None = None,
                 sort_fuse: bool = False,
                 gather_fn=None) -> tuple[PrepareState, jax.Array]:
    """One iteration of SubTreePrepare for static range ``w``.

    ``s_padded``: the terminal-padded byte string OR a dense
    :class:`repro.core.packing.PackedText` — results are bit-identical.
    For a PackedText the sort runs on the dense uint32 WORD keys by
    default (``word_keys``; env ``REPRO_WORD_COMPARE=byte`` or an
    explicit ``False`` pins the byte-key oracle): ``8/bits``x fewer sort
    key words plus one ``w - limit`` tiebreak lane, identical final
    construction arrays (intermediate orders may differ only INSIDE
    still-active equal-key blocks, which the segmented sort re-orders
    before anything observable is emitted).

    ``sort_fuse`` (the sharded fabric's default) packs the whole
    (major, window, tie) triple into the fewest uint32 sort lanes
    (:func:`_fused_sort_order`) — same final arrays, fewer comparator
    operands; it applies only on the word-key path and silently falls
    back to lexsort elsewhere.
    Returns (new_state, n_active).
    """
    f = state.L.shape[0]
    iota = jnp.arange(f, dtype=jnp.int32)
    active = state.area >= 0
    if word_keys is None:
        word_keys = kops._use_word_compare()
    word_keys = (word_keys and isinstance(s_padded, PackedText)
                 and gather_fn is None)

    offs = jnp.where(active, state.L + state.start, 0)
    major = jnp.where(active, state.area, iota)

    if word_keys:
        # 1w. read the dense word keys directly (no byte repack): the
        #     substituted words plus the w - limit tiebreak ARE the
        #     comparison currency (see core.packing's word-compare rules).
        keys, tie = packing.word_sort_keys(
            s_padded, offs, w,
            gather_words=kops.range_gather_words_impl(use_pallas))
        keys = jnp.where(active[:, None], keys, jnp.uint32(0))
        tie = jnp.where(active, tie, 0)

        # 2w. segmented stable sort on ``8/bits``x fewer minor words; the
        #     tiebreak lane is the LEAST significant key.
        order = None
        if sort_fuse:
            order = _fused_sort_order(major, keys, tie, w=w,
                                      bits=s_padded.bits, f=f)
        if order is None:
            n_words = keys.shape[1]
            minor_keys = (tie,) + tuple(keys[:, j]
                                        for j in range(n_words - 1, -1, -1))
            order = jnp.lexsort(minor_keys + (major,))
        L = state.L[order]
        start = state.start[order]
        keys = keys[order]

        # 3w. adjacent divergence: XOR + clz + terminal-limit rules give
        #     the same (lcp, c1, c2) the byte rows would.
        lim = packing.word_limit(s_padded.n_real, L + start, w)
        prev_rows = jnp.concatenate([keys[:1], keys[:-1]], axis=0)
        prev_lim = jnp.concatenate([lim[:1], lim[:-1]])
        lcp, c1, c2 = packing.lcp_adjacent_words(
            prev_rows, keys, prev_lim, lim, w, s_padded.bits,
            s_padded.terminal)
    else:
        # 1. read ``w`` symbols after every active leaf (paper lines 9-12);
        #    Pallas paged-gather on TPU, pure-jnp fallback elsewhere.
        default_gather, lcp_fn = _kernel_impls(use_pallas)
        gather_fn = gather_fn or default_gather
        keys = gather_fn(s_padded, offs, w)
        keys = jnp.where(active[:, None], keys, 0)

        # 2. segmented stable sort (paper lines 13-15): major key = area
        #    id; done elements get singleton majors (their index) so they
        #    stay put.  Minor keys compare as uint32: byte-alphabet codes
        #    >= 128 set the int32 sign bit of the top packed byte, so
        #    signed order would break.
        sort_keys = as_u32(keys) if keys.dtype == jnp.int32 else keys
        n_words = keys.shape[1]
        minor_keys = tuple(sort_keys[:, j] for j in range(n_words - 1, -1, -1))
        order = jnp.lexsort(minor_keys + (major,))
        L = state.L[order]
        start = state.start[order]
        keys = keys[order]
        # area / b_* are position-attached: within-area sorting leaves
        # them fixed.

        # 3. adjacent divergence → B entries (paper lines 16-23)
        prev_rows = jnp.concatenate([keys[:1], keys[:-1]], axis=0)
        lcp, c1, c2 = lcp_fn(prev_rows, keys, w)

    same_area = (state.area == jnp.roll(state.area, 1)) & active & (iota > 0)
    new_split = same_area & (lcp < w)
    b_off = jnp.where(new_split, start + lcp, state.b_off)
    b_c1 = jnp.where(new_split, c1, state.b_c1)
    b_c2 = jnp.where(new_split, c2, state.b_c2)

    # 4. recompute areas: a run starts where the old area changes or a new
    #    split landed; singleton runs are done (leaf found, Prop. 1 case 1).
    run_start = active & (
        (iota == 0)
        | (state.area != jnp.roll(state.area, 1))
        | ~jnp.roll(active, 1)
        | new_split
    )
    seg = jax.lax.cummax(jnp.where(run_start, iota, -1))
    nxt_start = jnp.concatenate([run_start[1:], jnp.array([True])])
    nxt_active = jnp.concatenate([active[1:], jnp.array([False])])
    right_bound = nxt_start | ~nxt_active
    singleton = run_start & right_bound
    area = jnp.where(active & ~singleton, seg, DONE)

    # 5. elastic advance for survivors
    start = jnp.where(area >= 0, start + w, start)

    new_state = PrepareState(L=L, start=start, area=area,
                             b_off=b_off, b_c1=b_c1, b_c2=b_c2)
    return new_state, jnp.sum(area >= 0)


@functools.partial(jax.jit, static_argnames=("w", "use_pallas", "word_keys"))
def _jit_step(s_padded, state, w, use_pallas=False, word_keys=None):
    return prepare_step(s_padded, state, w=w, use_pallas=use_pallas,
                        word_keys=word_keys)


def prepare_step_batch(s_padded, states: PrepareState, *, w: int,
                       use_pallas: bool = False,
                       word_keys: bool | None = None,
                       sort_fuse: bool = False):
    """One elastic-range iteration for a (G, F) batch of virtual trees.

    Groups are independent, so the step is a plain vmap over the leading
    axis; converged groups have no active areas, make zeroed gathers and
    are exact fixed points of the step.  Callers may shard_map G over the
    mesh — the only cross-device data is the replicated string read
    (byte array or dense PackedText; the latter replicates ``8/bits``x
    fewer bytes per device); :func:`repro.core.fabric.sharded_prepare`
    is that driver.

    Returns (new_states, n_active) with ``n_active`` int32[G].
    """
    step = lambda st: prepare_step(s_padded, st, w=w, use_pallas=use_pallas,
                                   word_keys=word_keys, sort_fuse=sort_fuse)
    return jax.vmap(step)(states)


@functools.partial(jax.jit,
                   static_argnames=("w", "use_pallas", "word_keys",
                                    "sort_fuse"),
                   donate_argnums=(1,))
def _jit_step_batch(s_padded, states, w, use_pallas=False, word_keys=None,
                    sort_fuse=False):
    # donated state buffers: the host loop re-binds the result, so the
    # whole elastic loop runs in-place on device.
    return prepare_step_batch(s_padded, states, w=w, use_pallas=use_pallas,
                              word_keys=word_keys, sort_fuse=sort_fuse)


def compact_step_batch(s_padded, states: PrepareState, *, f_prime: int,
                       w: int, use_pallas: bool, word_keys: bool,
                       sort_fuse: bool):
    """One elastic iteration on only the ACTIVE rows of each group.

    Tail iterations sort a (G, F) state in which most rows are long done;
    the sort is the whole step cost, so the engine gathers each group's
    active rows (ascending, so contiguous area blocks stay contiguous and
    in order) into a (G, f_prime) buffer, runs the UNMODIFIED
    :func:`prepare_step` there, and scatters the results back.  Exactness:
    the step's only position-dependent quantity is ``area`` (the run-start
    position), which translates through the gather index map both ways;
    ``b_off`` is a string offset, not a position; and every
    adjacency-based rule (``same_area``/``run_start``/``right_bound``)
    sees the same neighbor pairs because done rows only ever SEPARATE
    blocks, never join them.  ``f_prime`` must be >= every group's active
    count (:func:`compaction_width` buckets the global max to a power of
    two).  Proven in the sharded fabric (PR 8); now the shared batched
    step every driver — batched, streaming, append, fabric — compacts
    through.
    """
    f = states.area.shape[1]

    def one_group(st):
        active = st.area >= 0
        idx = jnp.nonzero(active, size=f_prime, fill_value=f)[0]
        valid = idx < f
        safe = jnp.minimum(idx, f - 1).astype(jnp.int32)
        take = lambda x, fill: jnp.where(valid, x[safe], fill)
        # run-start positions -> compacted positions (run starts are
        # themselves active rows, so searchsorted finds them exactly)
        carea = jnp.where(
            valid,
            jnp.searchsorted(idx, take(st.area, 0).clip(0)).astype(
                st.area.dtype),
            DONE)
        cst = PrepareState(L=take(st.L, -1), start=take(st.start, 0),
                           area=carea, b_off=take(st.b_off, -1),
                           b_c1=take(st.b_c1, 0), b_c2=take(st.b_c2, 0))
        new, _ = prepare_step(s_padded, cst, w=w, use_pallas=use_pallas,
                              word_keys=word_keys, sort_fuse=sort_fuse)
        # compacted run starts -> full-layout positions
        narea = jnp.where(
            new.area >= 0,
            idx[jnp.maximum(new.area, 0)].astype(new.area.dtype), DONE)
        scat = jnp.where(valid, idx, f)  # out-of-bounds pads drop
        put = lambda full, vals: full.at[scat].set(vals, mode="drop")
        return PrepareState(L=put(st.L, new.L),
                            start=put(st.start, new.start),
                            area=put(st.area, narea),
                            b_off=put(st.b_off, new.b_off),
                            b_c1=put(st.b_c1, new.b_c1),
                            b_c2=put(st.b_c2, new.b_c2))

    new_states = jax.vmap(one_group)(states)
    return new_states, jnp.sum(new_states.area >= 0, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("w", "use_pallas", "word_keys",
                                    "sort_fuse", "f_prime"),
                   donate_argnums=(1,))
def _jit_compact_step_batch(s_padded, states, w, use_pallas=False,
                            word_keys=None, sort_fuse=False, f_prime=32):
    return compact_step_batch(s_padded, states, f_prime=f_prime, w=w,
                              use_pallas=use_pallas, word_keys=word_keys,
                              sort_fuse=sort_fuse)


def compaction_width(maxact: int, capacity: int) -> int | None:
    """The compacted row width for a global max active count — the pow2
    bucket keeps jit program variants to ~log2(F) per w — or None while
    compaction cannot beat the full-width step (active rows still fill
    more than half the state)."""
    f_prime = max(32, 1 << max(maxact - 1, 0).bit_length())
    return None if f_prime * 2 > capacity else f_prime


def elastic_range(cfg: ElasticConfig, n_active: int) -> int:
    """range = |R| / |L'| (paper §4.4), bucketed to a power of two."""
    if not cfg.elastic:
        return max(4, (cfg.static_w + 3) // 4 * 4)
    w = max(cfg.w_min, min(cfg.w_max, cfg.r_budget_symbols // max(1, n_active)))
    return 1 << int(np.floor(np.log2(w)))


@dataclasses.dataclass
class PrepareStats:
    iterations: int = 0
    ranges: list = dataclasses.field(default_factory=list)
    active_history: list = dataclasses.field(default_factory=list)
    symbols_fetched: int = 0
    record_offsets: bool = False  # keep per-iteration offsets for iomodel
    offsets_history: list = dataclasses.field(default_factory=list)


def _record_prepare_metrics(group_iters: list, wall_s: float,
                            cfg: ElasticConfig) -> None:
    """Registry rows for one completed prepare run: per-group elastic
    iteration counts (the paper's convergence constant, a histogram so
    skew is visible) plus total convergence wall time."""
    if not obs.metrics_enabled():
        return
    m = obs.metrics()
    h = m.histogram("prepare_group_iterations",
                    buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256),
                    help="elastic-range iterations until each virtual "
                         "tree converged")
    for it in group_iters:
        h.observe(it)
    m.counter("prepare_convergence_seconds_total",
              "wall time spent in elastic-range loops").inc(wall_s)
    m.counter("prepare_runs_total",
              "completed SubTreePrepare loops").inc()
    m.gauge("prepare_r_budget_symbols",
            "|R| read-buffer budget of the last run").set(
        cfg.r_budget_symbols)


def subtree_prepare(
    s_padded,
    group: VirtualTree,
    capacity: int,
    cfg: ElasticConfig = ElasticConfig(),
    stats: PrepareStats | None = None,
    max_iters: int = 10_000,
    group_index: int | None = None,
) -> PrepareState:
    """Run SubTreePrepare to completion for one virtual tree."""
    state = init_state(group, capacity)
    use_pallas = kops._use_pallas()
    word_keys = kops._use_word_compare()
    n_active = int(jnp.sum(state.area >= 0))
    it = 0
    t0 = time.perf_counter()
    with obs.tracer().span("prepare/group",
                           group=-1 if group_index is None else group_index,
                           capacity=capacity) as sp:
        while n_active > 0:
            w = elastic_range(cfg, n_active)
            if it >= max_iters:
                raise RuntimeError(
                    "SubTreePrepare failed to converge after "
                    f"{it} iterations: group={group_index if group_index is not None else '?'} "
                    f"({len(group.prefixes)} prefixes, total_freq={group.total_freq}), "
                    f"w={w}, n_active={n_active}")
            if stats is not None and stats.record_offsets:
                act = np.asarray(state.area) >= 0
                offs = (np.asarray(state.L) + np.asarray(state.start))[act]
                stats.offsets_history.append(offs.astype(np.int64))
            with obs.tracer().span("prepare/step", w=w, n_active=n_active):
                state, n_active_dev = _jit_step(s_padded, state, w,
                                                use_pallas, word_keys)
            if stats is not None:
                stats.iterations += 1
                stats.ranges.append(w)
                stats.active_history.append(n_active)
                stats.symbols_fetched += n_active * w
            n_active = int(n_active_dev)
            it += 1
        sp.set(iterations=it)
    _record_prepare_metrics([it], time.perf_counter() - t0, cfg)
    return state


def subtree_prepare_batch(
    s_padded,
    groups: list[VirtualTree],
    capacity: int,
    cfg: ElasticConfig = ElasticConfig(),
    stats: PrepareStats | None = None,
    max_iters: int = 10_000,
    sort_fuse: bool | None = None,
    compact: bool | None = None,
) -> PrepareState:
    """Run SubTreePrepare to completion for ALL virtual trees at once.

    The whole working set is one padded (G, F) state driven by a single
    jitted vmapped elastic-range loop: per-group active counts shrink
    independently, converged groups are fixed points (they mask out of the
    gather and the sort leaves them in place), and the state buffers are
    donated so the loop runs in-place on device.  The elastic range is
    shared across the batch, keyed to the busiest group — range choice
    never changes results (Fig. 9b invariant), only I/O.

    ``sort_fuse``/``compact`` default to the promoted engine (fused
    single-lane sort keys + tail compaction, both proven bit-identical in
    the fabric); ``REPRO_SORT=lexsort`` / ``REPRO_COMPACT=off`` — or the
    explicit arguments — pin the oracle paths.

    Returns the final (G, F) state; slice per group/prefix with
    :func:`segments_of`.
    """
    states = init_batch(groups, capacity)
    use_pallas = kops._use_pallas()
    word_keys = kops._use_word_compare()
    if sort_fuse is None:
        sort_fuse = kops._use_sort_fuse()
    if compact is None:
        compact = kops._use_compaction()
    n_active = np.asarray(jnp.sum(states.area >= 0, axis=1))
    group_iters = np.zeros(len(groups), np.int64)
    it = 0
    t0 = time.perf_counter()
    with obs.tracer().span("prepare/batch_loop", groups=len(groups),
                           capacity=capacity) as sp:
        while int(n_active.max()) > 0:
            w = elastic_range(cfg, int(n_active.max()))
            if it >= max_iters:
                live = np.nonzero(n_active > 0)[0]
                detail = "; ".join(
                    f"group {g}: {len(groups[g].prefixes)} prefixes, "
                    f"total_freq={groups[g].total_freq}, n_active={int(n_active[g])}"
                    for g in live[:8])
                raise RuntimeError(
                    f"SubTreePrepare failed to converge after {it} iterations "
                    f"(w={w}, {len(live)}/{len(groups)} groups active): {detail}")
            if stats is not None and stats.record_offsets:
                act = np.asarray(states.area) >= 0
                offs = (np.asarray(states.L) + np.asarray(states.start))[act]
                stats.offsets_history.append(offs.astype(np.int64))
            group_iters += n_active > 0
            f_prime = (compaction_width(int(n_active.max()), capacity)
                       if compact else None)
            with obs.tracer().span("prepare/step", w=w,
                                   n_active=int(n_active.sum()),
                                   groups_active=int((n_active > 0).sum()),
                                   f_prime=f_prime or capacity):
                if f_prime is not None:
                    states, n_active_dev = _jit_compact_step_batch(
                        s_padded, states, w, use_pallas, word_keys,
                        sort_fuse, f_prime)
                else:
                    states, n_active_dev = _jit_step_batch(
                        s_padded, states, w, use_pallas, word_keys,
                        sort_fuse)
            if stats is not None:
                total_active = int(n_active.sum())
                stats.iterations += 1
                stats.ranges.append(w)
                stats.active_history.append(total_active)
                stats.symbols_fetched += total_active * w
            n_active = np.asarray(n_active_dev)
            it += 1
        sp.set(iterations=it)
    _record_prepare_metrics(group_iters.tolist(),
                            time.perf_counter() - t0, cfg)
    return states


@dataclasses.dataclass
class StreamReport:
    """Accounting for one out-of-core streaming build (paper §4.1 scaled
    to device memory): how many chunks the planner cut, how much
    host→device traffic the pipeline moved, and how much of it was hidden
    behind the elastic-range loop of the previous chunk."""

    n_chunks: int = 0
    overlap: bool = True
    groups: int = 0
    iterations: int = 0            # summed over chunk loops
    bytes_copied: int = 0          # host->device state traffic
    copy_s: float = 0.0            # estimated total copy wall time
    copy_hidden_s: float = 0.0     # portion overlapped with compute
    copy_wait_s: float = 0.0       # blocking remainder actually observed
    chunk_iters: list = dataclasses.field(default_factory=list)

    @property
    def overlap_frac(self) -> float:
        """Fraction of host→device transfer hidden behind compute."""
        return self.copy_hidden_s / self.copy_s if self.copy_s > 0 else 0.0


def _state_nbytes(state: PrepareState) -> int:
    return sum(int(np.asarray(a).nbytes) for a in state)


def subtree_prepare_stream(
    s_padded,
    groups: list[VirtualTree],
    capacity: int,
    cfg: ElasticConfig = ElasticConfig(),
    *,
    plan=None,
    device_budget: int | None = None,
    overlap: bool = True,
    stats: PrepareStats | None = None,
    report: StreamReport | None = None,
    max_iters: int = 10_000,
    sort_fuse: bool | None = None,
    compact: bool | None = None,
) -> tuple[PrepareState, StreamReport]:
    """Out-of-core SubTreePrepare: pipeline group chunks through a device
    memory budget with double-buffered host→device copies.

    The planner (:func:`repro.core.iomodel.plan_stream`, or an explicit
    ``plan``) slices the group list into contiguous chunks whose
    double-buffered (G_chunk, F) state fits ``device_budget``.  Each chunk
    runs the same donated elastic-range loop as
    :func:`subtree_prepare_batch`; while chunk k iterates, chunk k+1's
    host-initialized state is ``jax.device_put`` into a standby buffer
    right after the first step is dispatched, so the copy proceeds behind
    the in-flight compute — the construction-side mirror of the serving
    tier hiding pad/pack behind dispatch.  ``overlap=False`` degrades to
    synchronous copy-then-compute (the benchmark baseline).

    The elastic range is keyed per chunk to the chunk's busiest group.
    Range choice never changes results (Fig. 9b invariant), so the final
    arrays are bit-identical to the one-shot batched build; with the
    default budget (``r_budget_symbols >= w_max * F``) the schedule is
    moreover the same constant ``w_max`` both ways.

    Returns ``(state, report)`` where ``state`` is the full host-resident
    (G, F) :class:`PrepareState` (numpy arrays, original group order) and
    ``report`` carries the copy-overlap accounting.
    """
    from repro.core import iomodel

    if not groups:
        raise ValueError("subtree_prepare_stream needs at least one group")
    if plan is None:
        plan = iomodel.plan_stream(len(groups), capacity,
                                   budget_bytes=device_budget,
                                   double_buffer=overlap)
    rep = report if report is not None else StreamReport()
    rep.n_chunks = plan.n_chunks
    rep.overlap = overlap
    rep.groups = len(groups)

    use_pallas = kops._use_pallas()
    word_keys = kops._use_word_compare()
    if sort_fuse is None:
        sort_fuse = kops._use_sort_fuse()
    if compact is None:
        compact = kops._use_compaction()
    g_total = len(groups)
    out = PrepareState(*(np.empty((g_total, capacity), np.int32)
                         for _ in range(6)))
    chunks = list(plan.chunks)
    group_iters = np.zeros(g_total, np.int64)
    copy_rate = None  # bytes/s, calibrated by the chunk-0 synchronous copy
    t0 = time.perf_counter()

    def _copy_sync(host_state: PrepareState) -> PrepareState:
        nonlocal copy_rate
        nb = _state_nbytes(host_state)
        t = time.perf_counter()
        dev = jax.device_put(host_state)
        dev = jax.block_until_ready(dev)
        dt = max(time.perf_counter() - t, 1e-9)
        rep.copy_s += dt
        rep.bytes_copied += nb
        if copy_rate is None:
            copy_rate = nb / dt
        return dev

    with obs.tracer().span("stream/pipeline", chunks=plan.n_chunks,
                           groups=g_total, capacity=capacity,
                           overlap=overlap) as sp_pipe:
        # chunk 0 has no in-flight compute to hide behind: copy it
        # synchronously, which also calibrates the copy-rate estimate
        # used for the prefetched chunks.
        lo0, hi0 = chunks[0]
        states = _copy_sync(_host_init_batch(groups[lo0:hi0], capacity))
        for ci, (lo, hi) in enumerate(chunks):
            nxt = chunks[ci + 1] if ci + 1 < len(chunks) else None
            host_next = (_host_init_batch(groups[nxt[0]:nxt[1]], capacity)
                         if nxt is not None else None)
            standby = None
            t_issue = 0.0
            n_active = np.asarray(jnp.sum(states.area >= 0, axis=1))
            it = 0
            with obs.tracer().span("stream/chunk", chunk=ci,
                                   groups=hi - lo) as sp:
                while int(n_active.max()) > 0:
                    w = elastic_range(cfg, int(n_active.max()))
                    if it >= max_iters:
                        raise RuntimeError(
                            f"SubTreePrepare (stream chunk {ci}, groups "
                            f"[{lo}, {hi})) failed to converge after {it} "
                            f"iterations (w={w})")
                    group_iters[lo:hi] += n_active > 0
                    f_prime = (compaction_width(int(n_active.max()),
                                                capacity)
                               if compact else None)
                    with obs.tracer().span(
                            "prepare/step", w=w,
                            n_active=int(n_active.sum()),
                            groups_active=int((n_active > 0).sum()),
                            f_prime=f_prime or capacity):
                        if f_prime is not None:
                            states, n_active_dev = _jit_compact_step_batch(
                                s_padded, states, w, use_pallas, word_keys,
                                sort_fuse, f_prime)
                        else:
                            states, n_active_dev = _jit_step_batch(
                                s_padded, states, w, use_pallas, word_keys,
                                sort_fuse)
                    if overlap and standby is None and host_next is not None:
                        # the step above is dispatched asynchronously —
                        # issue the standby copy now so it transfers
                        # behind the chunk's in-flight elastic loop
                        t_issue = time.perf_counter()
                        standby = jax.device_put(host_next)
                    if stats is not None:
                        total_active = int(n_active.sum())
                        stats.iterations += 1
                        stats.ranges.append(w)
                        stats.active_history.append(total_active)
                        stats.symbols_fetched += total_active * w
                    n_active = np.asarray(n_active_dev)
                    it += 1
                sp.set(iterations=it)
            rep.iterations += it
            rep.chunk_iters.append(it)
            # drain this chunk's results to the host output slice (blocks
            # on the chunk's compute, NOT on the standby copy)
            for o, d in zip(out, states):
                o[lo:hi] = np.asarray(d)
            if host_next is None:
                continue
            if not overlap or standby is None:
                # synchronous mode, or a chunk that converged at init
                # (zero iterations -> nothing to hide the copy behind)
                states = _copy_sync(host_next)
                continue
            nb = _state_nbytes(host_next)
            t_wait = time.perf_counter()
            states = jax.block_until_ready(standby)
            wait = time.perf_counter() - t_wait
            est = max(nb / copy_rate, wait)  # >= observed blocking time
            rep.bytes_copied += nb
            rep.copy_s += est
            rep.copy_wait_s += wait
            rep.copy_hidden_s += est - wait
            obs.tracer().complete(
                "stream/standby_copy", int(t_issue * 1e9),
                int(max(time.perf_counter() - t_issue, 1e-9) * 1e9),
                chunk=ci + 1, bytes=nb, wait_ms=round(wait * 1e3, 3),
                hidden_frac=round((est - wait) / est, 4) if est > 0 else 1.0)
        sp_pipe.set(iterations=rep.iterations,
                    copy_ms=round(rep.copy_s * 1e3, 3),
                    hidden_ms=round(rep.copy_hidden_s * 1e3, 3),
                    overlap_frac=round(rep.overlap_frac, 4))
    _record_prepare_metrics(group_iters.tolist(),
                            time.perf_counter() - t0, cfg)
    return out, rep


def segments_of(group: VirtualTree) -> list[tuple[int, int]]:
    """(offset, length) of each prefix's slice in the packed state arrays."""
    segs = []
    off = 0
    for p in group.prefixes:
        segs.append((off, p.freq))
        off += p.freq
    return segs
