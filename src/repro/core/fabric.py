"""Sharded index fabric: SPMD construction + routed multi-shard serving.

This is ERA's shared-nothing parallel version (paper §7) reborn as a JAX
SPMD program over a device mesh, in two halves:

**Sharded construction** (:func:`sharded_prepare`).  Virtual-tree groups
are embarrassingly parallel, so the batched (G, F) elastic-range loop
shards its G axis: a 1-D ``("shard",)`` mesh, the string replicated
(``P()`` — a dense PackedText replicates ``8/bits``x fewer bytes), the
per-shard ``(G_shard, F)`` state donated in place.  Each ``shard_map``
step wraps the vmapped :func:`repro.core.prepare.prepare_step` in a
``lax.cond`` on the shard's OWN active count — a converged shard's
devices skip the gather/sort/sweep entirely and exit the loop
independently (the per-shard convergence mask) while the host keeps
driving until the globally busiest shard finishes.  The elastic range
``w`` stays keyed to the globally busiest group, exactly the schedule the
single-device engine uses, so results are bit-identical (range choice
never changes results — the Fig. 9b invariant).  The fabric step also
enables the fused sort-key path (``sort_fuse``): the (major, window,
tie) sort triple packs into the fewest uint32 lanes, which is where the
fabric's single-core speedup comes from when the mesh is simulated on
one CPU (see ``benchmarks/bench_fabric.py`` for the attribution).

**ShardedIndex** — the flattened :class:`repro.core.query.DeviceIndex`
leaf arrays sharded by the dense top-trie route key.  Sub-trees sort
lexicographically, so contiguous runs of sub-trees are contiguous route
code intervals; shards cut ONLY between sub-trees whose depth-``k_route``
intervals do not overlap (sub-trees deeper than the routing table share a
cell and must stay together).  Every shard is a self-contained
DeviceIndex (same global ``k_route``, replicated string) placed on its
own mesh device, plus a replicated host-side route→shard table:
``find_batch`` / ``find_fetch_batch`` split each query batch by route
key, run each sub-batch against ONLY its owning shard's
``pattern_probe_words`` descent, and gather just the small verdicts —
no all-gather on the hot path.  Patterns shorter than ``k_route`` may
span a shard boundary; they fan out to every covered shard and the
sorted position lists concatenate associatively, so results stay
bit-identical to the single-device engine.  Per-shard npz archives
(``{path}_shard{k}.npz``) let a multi-host job warm-start each shard
locally.

CPU testing: simulate the mesh with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set BEFORE
importing jax — ``repro.launch.shard_run`` does this for you).
"""

from __future__ import annotations

import glob
import re
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.core.prepare import (
    ElasticConfig,
    PrepareState,
    PrepareStats,
    compact_step_batch,
    compaction_width,
    elastic_range,
    init_batch,
    prepare_step_batch,
)
from repro.core import packing as packing_mod
from repro.core.query import DeviceIndex, route_depth, shard_npz_path
from repro.kernels import ops as kops

SHARD_AXIS = "shard"


def fabric_mesh(n_shards: int | None = None) -> jax.sharding.Mesh:
    """A 1-D ``("shard",)`` mesh over the first ``n_shards`` devices
    (default: all of them)."""
    devices = jax.devices()
    n = len(devices) if n_shards is None else n_shards
    if not 1 <= n <= len(devices):
        raise ValueError(f"n_shards={n} needs 1..{len(devices)} devices")
    from repro.launch.mesh import make_fabric_mesh
    return make_fabric_mesh(n)


# ---- sharded construction --------------------------------------------------

_STEP_CACHE: dict = {}


def _shard_step(mesh, w: int, use_pallas: bool, word_keys: bool,
                sort_fuse: bool, use_cond: bool, f_prime: int | None):
    """The jitted SPMD elastic step for one ``(w, f_prime)`` bucket.

    Per shard: with ``use_cond``, a ``lax.cond`` on the shard's own
    active count — converged shards are exact fixed points and skip the
    work entirely (their predicate is device-local, so the branch is a
    REAL skip, not a select).  The cond boundary costs ~2ms/step in
    buffer copies, so the host only requests it once some shard has
    actually converged; while every shard is live the cond would take
    the same branch everywhere and the plain step is identical.  With
    ``f_prime``, the step runs compacted — the shared
    :func:`repro.core.prepare.compact_step_batch`, the same path the
    batched/streaming/append drivers now default through.
    State buffers are donated; the string is replicated.
    """
    key = (mesh, w, use_pallas, word_keys, sort_fuse, use_cond, f_prime)
    cached = _STEP_CACHE.get(key)
    if cached is not None:
        return cached

    def one_shard(s_padded, states):
        def live(sts):
            if f_prime is not None:
                new, _ = compact_step_batch(
                    s_padded, sts, f_prime=f_prime, w=w,
                    use_pallas=use_pallas, word_keys=word_keys,
                    sort_fuse=sort_fuse)
            else:
                new, _ = prepare_step_batch(
                    s_padded, sts, w=w, use_pallas=use_pallas,
                    word_keys=word_keys, sort_fuse=sort_fuse)
            return new
        if use_cond:
            states = jax.lax.cond(jnp.sum(states.area >= 0) > 0,
                                  live, lambda sts: sts, states)
        else:
            states = live(states)
        return states, jnp.sum(states.area >= 0, axis=1)

    fn = shard_map(one_shard, mesh=mesh,
                   in_specs=(P(), P(SHARD_AXIS, None)),
                   out_specs=(P(SHARD_AXIS, None), P(SHARD_AXIS)),
                   check_rep=False)
    jitted = jax.jit(fn, donate_argnums=(1,))
    _STEP_CACHE[key] = jitted
    return jitted


def _pad_group_axis(states: PrepareState, g_pad: int) -> PrepareState:
    """Pad the G axis with born-converged dummy groups (area = -1
    everywhere) so it divides evenly across the mesh."""
    g = states.L.shape[0]
    if g_pad == g:
        return states

    def pad(x, fill):
        extra = jnp.full((g_pad - g,) + x.shape[1:], fill, x.dtype)
        return jnp.concatenate([x, extra], axis=0)

    return PrepareState(L=pad(states.L, -1), start=pad(states.start, 0),
                        area=pad(states.area, -1), b_off=pad(states.b_off, -1),
                        b_c1=pad(states.b_c1, 0), b_c2=pad(states.b_c2, 0))


def sharded_prepare(
    s_padded,
    groups,
    capacity: int,
    cfg: ElasticConfig = ElasticConfig(),
    *,
    mesh: jax.sharding.Mesh | None = None,
    stats: PrepareStats | None = None,
    max_iters: int = 10_000,
    sort_fuse: bool | None = None,
) -> PrepareState:
    """:func:`repro.core.prepare.subtree_prepare_batch` over a device
    mesh: groups split into contiguous per-shard blocks, one SPMD step
    per elastic iteration, per-shard convergence mask.

    Returns the final (G, F) state (sliced back to the real group count;
    dummy padding groups never reach the caller) — bit-identical to the
    single-device batched engine.
    """
    mesh = mesh or fabric_mesh()
    n_shards = mesh.devices.size
    g = len(groups)
    g_pad = -(-g // n_shards) * n_shards
    use_pallas = kops._use_pallas()
    word_keys = kops._use_word_compare()
    if sort_fuse is None:
        sort_fuse = kops._use_sort_fuse()

    states = _pad_group_axis(init_batch(groups, capacity), g_pad)
    states = jax.device_put(
        states, NamedSharding(mesh, P(SHARD_AXIS, None)))
    n_active = np.asarray(jnp.sum(states.area >= 0, axis=1))
    it = 0
    t0 = time.perf_counter()
    with obs.tracer().span("fabric/shard_loop", groups=g, shards=n_shards,
                           capacity=capacity) as sp:
        while int(n_active.max()) > 0:
            # the GLOBAL busiest group keys the range — the same schedule
            # (and therefore the same per-iteration states) as the
            # single-device engine; per-shard schedules would also be
            # valid (Fig. 9b) but would break step-for-step comparability
            w = elastic_range(cfg, int(n_active.max()))
            if it >= max_iters:
                raise RuntimeError(
                    f"sharded SubTreePrepare failed to converge after {it} "
                    f"iterations (w={w}, "
                    f"{int((n_active > 0).sum())}/{g} groups active)")
            shards_active = n_active.reshape(n_shards, -1).max(axis=1) > 0
            # tail compaction: once every group's active count fits in
            # half the state width, sort only the active rows (the
            # pow2 bucket keeps program variants to ~log2(F) per w)
            f_prime = compaction_width(int(n_active.max()), capacity)
            with obs.tracer().span("fabric/step", w=w,
                                   n_active=int(n_active.sum()),
                                   shards_active=int(shards_active.sum()),
                                   f_prime=f_prime or capacity):
                # the convergence mask (lax.cond) only enters the program
                # once a shard has actually converged — before that every
                # shard takes the live branch and the cond boundary is
                # pure copy overhead
                step = _shard_step(mesh, w, use_pallas, word_keys,
                                   sort_fuse,
                                   not bool(shards_active.all()), f_prime)
                states, n_active_dev = step(s_padded, states)
            if stats is not None:
                stats.iterations += 1
                stats.ranges.append(w)
                stats.active_history.append(int(n_active.sum()))
                stats.symbols_fetched += int(n_active.sum()) * w
            n_active = np.asarray(n_active_dev)
            it += 1
        sp.set(iterations=it)
    return PrepareState(*(x[:g] for x in states))


# ---- shard planning --------------------------------------------------------


def _entry_code_intervals(prefixes, base: int, k_route: int):
    """Per sub-tree depth-``k_route`` route-code interval [clo, chi] —
    the same intervals ``DeviceIndex.from_prepare`` routes with."""
    clo = np.zeros(len(prefixes), np.int64)
    chi = np.zeros(len(prefixes), np.int64)
    for t, p in enumerate(prefixes):
        kk = min(len(p), k_route)
        c = 0
        for j in range(kk):
            c = c * base + p[j]
        clo[t] = c * base ** (k_route - kk)
        chi[t] = clo[t] + base ** (k_route - kk) - 1
    return clo, chi


def plan_shards(prefixes, freqs, base: int, k_route: int,
                n_shards: int) -> list[slice]:
    """Split the sorted sub-tree list into ≤ ``n_shards`` contiguous,
    leaf-balanced chunks, cutting ONLY where adjacent route intervals do
    not overlap (sub-trees deeper than ``k_route`` share a cell and must
    stay on one shard).  Returns per-shard entry slices."""
    n = len(prefixes)
    if n_shards < 1:
        raise ValueError(f"n_shards={n_shards} must be >= 1")
    clo, chi = _entry_code_intervals(prefixes, base, k_route)
    # legal cut AFTER entry t: the next entry starts a fresh route cell
    cuts = np.nonzero(chi[:-1] < clo[1:])[0] + 1  # entry indices
    cum = np.concatenate([[0], np.cumsum(np.asarray(freqs, np.int64))])
    total = cum[-1]
    bounds = [0]
    for k in range(1, n_shards):
        target = total * k // n_shards
        if not len(cuts):
            break
        j = int(np.argmin(np.abs(cum[cuts] - target)))
        cut = int(cuts[j])
        if cut > bounds[-1]:
            bounds.append(cut)
            cuts = cuts[cuts > cut]
    bounds.append(n)
    return [slice(a, b) for a, b in zip(bounds[:-1], bounds[1:])]


# ---- the sharded index -----------------------------------------------------


class ShardedIndex:
    """A :class:`DeviceIndex` per route-key shard + the replicated
    route→shard table.  Query results are bit-identical to one
    DeviceIndex over the whole string (pinned by tests/test_fabric.py).
    """

    def __init__(self, shards: list[DeviceIndex], cell_lo: np.ndarray):
        if not shards:
            raise ValueError("ShardedIndex needs at least one shard")
        self.shards = shards
        self.cell_lo = np.asarray(cell_lo, np.int64)  # first owned cell
        dev = shards[0]
        self.base = dev.base
        self.k_route = dev.k_route
        self.max_pattern_len = dev.max_pattern_len
        n_cells = self.base ** self.k_route
        # the replicated route→shard table: every cell's owning shard
        # (cells before shard 0 resolve there and simply miss)
        self.route2shard = (np.searchsorted(
            self.cell_lo, np.arange(n_cells, dtype=np.int64),
            side="right") - 1).clip(0).astype(np.int32)

    # ---- construction ------------------------------------------------------

    @classmethod
    def from_flat(cls, *, alphabet, s, prefixes, freqs, ell,
                  n_shards: int, route_cap: int = 1 << 18,
                  max_pattern_len: int = 512, packing: str = "auto",
                  place: bool | None = None,
                  epoch: int = 0) -> "ShardedIndex":
        """Build from flattened construction output (the same inputs as
        :meth:`DeviceIndex.from_prepare`) split into ≤ ``n_shards``
        route-contiguous shards.  ``place`` distributes shard arrays
        round-robin over the local devices (default: only when there is
        more than one)."""
        freqs = np.asarray(freqs, np.int32)
        max_plen = max(len(p) for p in prefixes)
        k_route = route_depth(alphabet.base, max_plen, route_cap)
        slices = plan_shards(prefixes, freqs, alphabet.base, k_route,
                             n_shards)
        offs = np.concatenate([[0], np.cumsum(freqs)]).astype(np.int64)
        devices = jax.devices()
        if place is None:
            place = len(devices) > 1
        shards, cell_lo = [], []
        ell = jnp.asarray(ell)
        for k, sl in enumerate(slices):
            dev = DeviceIndex.from_prepare(
                alphabet=alphabet, s=s, prefixes=prefixes[sl],
                freqs=freqs[sl], ell=ell[offs[sl.start]:offs[sl.stop]],
                route_cap=route_cap, max_pattern_len=max_pattern_len,
                packing=packing, k_route=k_route, epoch=epoch)
            if place:
                dev = _place_index(dev, devices[k % len(devices)])
            shards.append(dev)
            clo, _ = _entry_code_intervals(prefixes[sl.start:sl.start + 1],
                                           alphabet.base, k_route)
            cell_lo.append(int(clo[0]))
        return cls(shards, np.asarray(cell_lo, np.int64))

    # ---- routing -----------------------------------------------------------

    def route_key(self, pattern):
        """Global cache key (route code, length, bytes) — identical
        across shards because ``k_route`` is shared."""
        return self.shards[0].route_key(pattern)

    def shard_span(self, pattern) -> tuple[int, int]:
        """(lo, hi) inclusive shard range a pattern's route covers.
        Patterns of length >= k_route hit exactly one shard; shorter
        ones cover a cell interval that may cross a boundary."""
        arr = np.asarray(pattern, np.int32)
        kk = min(arr.size, self.k_route)
        c = 0
        for j in range(kk):
            c = c * self.base + int(arr[j])
        span = self.base ** (self.k_route - kk)
        c_lo = c * span
        lo = int(self.route2shard[c_lo])
        hi = int(self.route2shard[c_lo + span - 1])
        return lo, hi

    def _split_batch(self, patterns):
        """shard id → list of pattern indices (fan-out for short spans)."""
        per_shard: dict[int, list[int]] = {}
        for i, p in enumerate(patterns):
            lo, hi = self.shard_span(p)
            for k in range(lo, hi + 1):
                per_shard.setdefault(k, []).append(i)
        return per_shard

    # ---- queries -----------------------------------------------------------

    def find_batch(self, patterns) -> list[np.ndarray]:
        """Per-pattern sorted occurrence positions; each sub-batch runs
        only against its owning shard (route → local probe → verdicts)."""
        out: list = [None] * len(patterns)
        for k, idxs in sorted(self._split_batch(patterns).items()):
            with obs.tracer().span("fabric/find_batch", shard=k,
                                   rows=len(idxs)):
                hits = self.shards[k].find_batch([patterns[i] for i in idxs])
            for i, h in zip(idxs, hits):
                out[i] = h if out[i] is None else np.sort(
                    np.concatenate([out[i], h]))
        return out

    def find_fetch_batch(self, patterns, *, fetch: int = 32):
        """Positions + a (fetch,) context window at the first SA-order
        match.  Shards are route-ordered, so the first shard (ascending)
        with a hit owns the globally first match's window."""
        out: list = [None] * len(patterns)
        wins = np.full((len(patterns), fetch), -1, np.int32)
        filled = [False] * len(patterns)
        for k, idxs in sorted(self._split_batch(patterns).items()):
            with obs.tracer().span("fabric/find_fetch", shard=k,
                                   rows=len(idxs)):
                hits, win = self.shards[k].find_fetch_batch(
                    [patterns[i] for i in idxs], fetch=fetch)
            for j, i in enumerate(idxs):
                out[i] = hits[j] if out[i] is None else np.sort(
                    np.concatenate([out[i], hits[j]]))
                if not filled[i] and len(hits[j]):
                    wins[i] = win[j]
                    filled[i] = True
        return out, wins

    # ---- introspection -----------------------------------------------------

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def n_leaves(self) -> int:
        return sum(int(d.ell.shape[0]) for d in self.shards)

    @property
    def epoch(self) -> int:
        """Mutation generation (uniform across shards — every append
        rebuilds all shards from the merged flat layout)."""
        return self.shards[0].epoch

    def flat_table(self):
        """The global flattened view ``(prefixes, freqs, ell)``.

        Shards are route-ordered and each shard's sub-trees are sorted, so
        concatenating the per-shard tables reproduces EXACTLY the layout
        :meth:`DeviceIndex.from_prepare` flattens — this is what the
        incremental-append merge consumes to reuse unaffected leaf
        segments before re-sharding with :meth:`from_flat`."""
        prefixes: list[tuple] = []
        freq_parts, ell_parts = [], []
        for dev in self.shards:
            plen = np.asarray(dev.sub_plen)
            pref = np.asarray(dev.sub_prefix)
            prefixes += [tuple(int(c) for c in pref[t, :plen[t]])
                         for t in range(len(plen))]
            freq_parts.append(np.asarray(dev.sub_freq))
            ell_parts.append(dev.ell_host)
        return (prefixes, np.concatenate(freq_parts).astype(np.int32),
                np.concatenate(ell_parts).astype(np.int32))

    def string_codes(self) -> np.ndarray:
        # every shard replicates the FULL string in s_text, but a shard's
        # own n_leaves is only its leaf-slice count — |S| is the total
        sh0 = self.shards[0]
        n = self.n_leaves
        if sh0.packed:
            return packing_mod.unpack_text(sh0.s_text, n=n)
        return np.asarray(sh0.s_text)[:n]

    def stats(self) -> dict:
        return {
            "shards": self.n_shards,
            "k_route": self.k_route,
            "leaves": [int(d.ell.shape[0]) for d in self.shards],
            "cell_lo": self.cell_lo.tolist(),
        }

    # ---- persistence -------------------------------------------------------

    def save(self, path: str) -> None:
        """One self-contained npz PER SHARD (``{path}_shard{k}.npz``) so
        each host of a multi-host job warm-starts its shard locally."""
        for k, dev in enumerate(self.shards):
            dev.save(shard_npz_path(path, k))

    @classmethod
    def shard_files(cls, path: str) -> list[str]:
        """The per-shard archives for ``path``, in shard order."""
        pat = shard_npz_path(path, 0).replace("_shard0.npz", "_shard*.npz")
        def shard_no(p):
            m = re.search(r"_shard(\d+)\.npz$", p)
            return int(m.group(1)) if m else -1
        return sorted((p for p in glob.glob(pat) if shard_no(p) >= 0),
                      key=shard_no)

    @classmethod
    def load(cls, path: str) -> "ShardedIndex":
        files = cls.shard_files(path)
        if not files:
            raise FileNotFoundError(f"no shard archives match "
                                    f"{shard_npz_path(path, 0)!r} siblings")
        shards = [DeviceIndex.load(f) for f in files]
        # the route table reconstructs from each shard's first prefix —
        # no separate manifest to keep in sync
        cell_lo = []
        for dev in shards:
            plen = int(np.asarray(dev.sub_plen)[0])
            prefix = tuple(int(c) for c in np.asarray(dev.sub_prefix)[0][:plen])
            clo, _ = _entry_code_intervals([prefix], dev.base, dev.k_route)
            cell_lo.append(int(clo[0]))
        return cls(shards, np.asarray(cell_lo, np.int64))


def _place_index(dev: DeviceIndex, device) -> DeviceIndex:
    """Pin one shard's device arrays to its mesh device (host mirrors
    like ``ell_host`` stay put)."""
    import dataclasses
    put = lambda x: jax.device_put(x, device)
    return dataclasses.replace(
        dev, s_text=put(dev.s_text), ell=put(dev.ell),
        sub_off=put(dev.sub_off), sub_freq=put(dev.sub_freq),
        sub_prefix=put(dev.sub_prefix), sub_plen=put(dev.sub_plen),
        win_lo=put(dev.win_lo), win_hi=put(dev.win_hi),
        pows=put(dev.pows), spans=put(dev.spans))
