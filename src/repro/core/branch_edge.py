"""ERA-str: Algorithms ComputeSuffixSubTree + BranchEdge (paper §4.2.1).

The paper's FIRST horizontal-partitioning variant: breadth-first edge
refinement driven by Proposition 1, with the level-amortized scan
optimization (one pass over S per level, shared by all active edges) but
WITHOUT the (L, B) memory-access optimization of §4.2.2.  It serves as

* the Fig. 7 comparison baseline (ERA-str vs ERA-str+mem), and
* an independent oracle for the optimized pipeline (same trees out).

WaveFront-style construction (the paper's main competitor) is this same
level-by-level discipline but with per-node tree insertion and a fixed
range of 1 symbol per scan; ``wavefront_build`` models it for Fig. 10.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class EdgeNode:
    """Pointer-style node (deliberately the paper's §4.2.1 representation)."""

    depth: int                 # symbols from root to the END of this edge
    occs: np.ndarray           # occurrence positions of pathlabel(e)
    children: dict             # first symbol -> EdgeNode
    parent_depth: int          # depth at the START of this edge
    leaf_pos: int = -1


@dataclasses.dataclass
class StrStats:
    scans: int = 0
    levels: int = 0
    nodes: int = 0


def compute_suffix_subtree(s: np.ndarray, positions: np.ndarray, p_len: int,
                           stats: StrStats | None = None,
                           range_sym: int = 16) -> EdgeNode:
    """Build T_p by level-by-level BranchEdge (occurrence-list refinement).

    ``range_sym`` models optimization 2 of §4.2.1: each scan of S fetches a
    range of symbols, so one pass serves ``range_sym`` refinement levels.
    """
    n = len(s)
    stats = stats if stats is not None else StrStats()
    root = EdgeNode(depth=p_len, occs=np.asarray(positions, np.int64),
                    children={}, parent_depth=0)
    active = [root]
    while active:
        stats.levels += 1
        if (stats.levels - 1) % max(1, range_sym) == 0:
            stats.scans += 1  # one amortized pass serves range_sym levels
        nxt: list[EdgeNode] = []
        for e in active:
            if len(e.occs) == 1:
                e.leaf_pos = int(e.occs[0])
                continue
            idx = e.occs + e.depth
            syms = np.where(idx < n, s[np.minimum(idx, n - 1)], -1)
            uniq = np.unique(syms)
            if len(uniq) == 1:
                e.depth += 1  # Prop. 1 case 2: extend the label
                nxt.append(e)
                continue
            for c in uniq:  # Prop. 1 case 3: branch
                occ_c = e.occs[syms == c]
                child = EdgeNode(depth=e.depth + 1, occs=occ_c, children={},
                                 parent_depth=e.depth)
                e.children[int(c)] = child
                stats.nodes += 1
                nxt.append(child)
        active = nxt
    return root


def tree_to_intervals(root: EdgeNode, s: np.ndarray):
    """Canonical (l, r, depth) intervals — comparable with build.nodes_to_intervals.

    Leaves are ordered by DFS with children visited in symbol order, which
    equals lexicographic order of the suffixes.
    """
    out = []
    counter = [0]

    def walk(e: EdgeNode):
        if e.leaf_pos >= 0 and not e.children:
            i = counter[0]
            counter[0] += 1
            return i, i + 1
        lo, hi = None, None
        for c in sorted(e.children):
            l, r = walk(e.children[c])
            lo = l if lo is None else lo
            hi = r
        if hi - lo >= 2:
            out.append((lo, hi, e.depth))
        return lo, hi

    walk(root)
    return sorted(out)


def wavefront_build(s: np.ndarray, positions: np.ndarray, p_len: int,
                    stats: StrStats | None = None) -> EdgeNode:
    """WaveFront-discipline baseline: same tree, but one SYMBOL per scan
    (range=1; no elastic growth) and per-level full passes — the I/O and
    iteration profile the paper beats (Figs. 9b/10)."""
    return compute_suffix_subtree(s, positions, p_len, stats, range_sym=1)
