"""Device-resident batched query engine (the read side of the ERA index).

The assembled index (:class:`repro.core.suffix_tree.SuffixTreeIndex`) stores
each sub-tree's leaf array ``L`` as the suffix array restricted to its
prefix.  Because the vertical-partition prefixes are prefix-free and cover
every suffix, concatenating the ``L`` arrays in lexicographic prefix order
yields the full suffix array of ``S`` — so a substring query is a routing
step (which contiguous slice of the concatenation can contain matches?)
plus a bounded lower/upper-bound binary search (paper §2, §4).

:class:`DeviceIndex` flattens the whole index into device arrays:

* ``ell``              — the concatenated leaf arrays (int32[total]);
* ``sub_off/sub_freq`` + padded ``sub_prefix`` — per-subtree tables;
* ``win_lo/win_hi``    — a dense top-trie routing table keyed on packed
  base-|Σ|+1 prefix codes at depth ``k_route`` (capped so the table stays
  small): cell ``c`` maps to the slice of ``ell`` owned by sub-trees whose
  code range touches ``c``.

``find_batch`` then resolves a whole ``(B, m)`` batch of padded patterns
with ONE routing gather and a fixed-trip vectorized binary search whose
inner probe-gather-compare step is the :func:`repro.kernels.ops.pattern_probe`
kernel (Pallas on TPU, pure-jnp oracle elsewhere).  Comparisons run on the
packed big-endian words of :mod:`repro.core.packing` — the same machinery
the construction path sorts with — under unsigned order, so results are
exact for every alphabet including the byte alphabet.

The served string itself (``s_text``) is stored DENSE by default (paper
§6.1 generalized): ``Alphabet.dense_bits`` bits per symbol inside uint32
words (2-bit DNA, 4-bit protein classes) with the byte array as fallback /
reference.  Probe gathers repack in-register to the same byte sort keys,
so results are bit-identical across representations while the serving
index and its per-probe HBM traffic shrink ~``8/bits``x.

The per-pattern numpy path (``SuffixTreeIndex.find``) remains the oracle;
``tests/test_query.py`` / ``tests/test_packed.py`` cross-check the paths
on randomized workloads.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing as packing_mod
from repro.kernels import ops as kops


def npz_path(path: str) -> str:
    """The path ``np.savez_compressed`` actually writes (it appends
    ``.npz`` when missing); every save/load here normalizes through this
    so bare paths round-trip."""
    return path if path.endswith(".npz") else path + ".npz"


@functools.partial(jax.jit, static_argnames=("k_route", "n_iter", "use_pallas",
                                             "word"))
def _find_batch_ranges(s_text, ell, win_lo, win_hi, pows, spans,
                       patterns, lengths, route_syms,
                       *, k_route: int, n_iter: int, use_pallas: bool,
                       word: bool = False):
    """Route + vectorized lower/upper-bound binary search for one batch.

    s_text: byte string or dense PackedText (the probe dispatches);
    patterns: (B, m_pad) int32, zero-padded; lengths: (B,) int32 >= 1;
    route_syms: (B, k_route) int32 (first symbols, zero-padded).
    ``word`` (PackedText only, real-symbol patterns only) packs the batch
    to k-bit dense words ONCE and runs the word-compare probe — ``bits/8``
    of the pattern key words and compare lanes, identical verdicts.
    Returns (start, count): int32[B] slices into ``ell``.
    """
    b, m_pad = patterns.shape
    total = ell.shape[0]

    # pattern packing (once per batch): zero symbols past each length in
    # both the pattern and the all-ones mask, so masked suffix words
    # compare against exactly the first ``m`` symbols (prefix match ==
    # equality).  Byte path: 0xFF-byte masks over 4-symbol int32 words;
    # word path: bits-wide fields over 32/bits-symbol uint32 words.
    in_pat = jnp.arange(m_pad, dtype=jnp.int32)[None, :] < lengths[:, None]
    if word:
        bits = s_text.bits
        pat_words = packing_mod.pack_pattern_dense(
            jnp.where(in_pat, patterns, 0), bits, s_text.terminal)
        mask_words = packing_mod.pack_dense(
            jnp.where(in_pat, (1 << bits) - 1, 0), bits)
        probe_w = kops.pattern_probe_words_impl(use_pallas)
        len2 = jnp.concatenate([lengths, lengths])
        probe = lambda st, pos, pat, mask: probe_w(st, pos, pat, mask, len2)
    else:
        pat_words = packing_mod.pack_words(jnp.where(in_pat, patterns, 0))
        mask_words = packing_mod.pack_words(jnp.where(in_pat, 0xFF, 0))
        probe = kops.pattern_probe_impl(use_pallas)

    # routing: the pattern's depth-k_route code interval [c_lo, c_hi] covers
    # every suffix that can match; one gather into the dense table bounds
    # the binary search to the owning sub-tree slice of ``ell``.
    k = jnp.minimum(lengths, k_route)
    in_route = jnp.arange(k_route, dtype=jnp.int32)[None, :] < k[:, None]
    c_lo = jnp.sum(jnp.where(in_route, route_syms, 0) * pows[None, :], axis=1)
    c_hi = c_lo + spans[k]
    lo0 = win_lo[c_lo]
    hi0 = jnp.maximum(win_hi[c_hi], lo0)

    # fixed-trip binary search; lower and upper bound run fused as one
    # 2B-row probe per iteration (the probe kernel is the only gather).
    pat2 = jnp.concatenate([pat_words, pat_words], axis=0)
    mask2 = jnp.concatenate([mask_words, mask_words], axis=0)

    def body(_, st):
        llo, lhi, ulo, uhi = st
        lmid = (llo + lhi) // 2
        umid = (ulo + uhi) // 2
        mids = jnp.concatenate([lmid, umid])
        pos = ell[jnp.clip(mids, 0, total - 1)]
        cmp = probe(s_text, pos, pat2, mask2)
        lcmp, ucmp = cmp[:b], cmp[b:]
        lact = llo < lhi
        uact = ulo < uhi
        # lower bound: first suffix >= pattern (prefix match counts as >=)
        llo = jnp.where(lact & (lcmp < 0), lmid + 1, llo)
        lhi = jnp.where(lact & (lcmp >= 0), lmid, lhi)
        # upper bound: first suffix > pattern
        ulo = jnp.where(uact & (ucmp <= 0), umid + 1, ulo)
        uhi = jnp.where(uact & (ucmp > 0), umid, uhi)
        return llo, lhi, ulo, uhi

    llo, _, ulo, _ = jax.lax.fori_loop(0, n_iter, body, (lo0, hi0, lo0, hi0))
    return llo, jnp.maximum(ulo - llo, 0)


@dataclasses.dataclass(frozen=True)
class DeviceIndex:
    """Flattened, device-resident form of a :class:`SuffixTreeIndex`."""

    base: int                 # |Σ| + 1 including the terminal
    k_route: int              # routing-trie depth (base**k_route cells)
    n_iter: int               # binary-search trip count (covers ``total``)
    max_pattern_len: int      # padding guarantee baked into ``s_text``
    s_text: object            # the served string: dense PackedText (k-bit
    #                           uint32 words, the default for sub-byte
    #                           alphabets) or uint8[n + pad] terminal-padded
    ell: jax.Array            # int32[total] concatenated leaf arrays (= SA)
    ell_host: np.ndarray      # host copy of ell (result materialization)
    sub_off: jax.Array        # int32[T] slice start of sub-tree t in ell
    sub_freq: jax.Array       # int32[T]
    sub_prefix: jax.Array     # int32[T, max_plen] prefix symbols, -1 pad
    sub_plen: jax.Array       # int32[T]
    win_lo: jax.Array         # int32[base**k_route] routing slice starts
    win_hi: jax.Array         # int32[base**k_route] routing slice ends
    pows: jax.Array           # int32[k_route] base**(k_route-1-j)
    spans: jax.Array          # int32[k_route+1] base**(k_route-k) - 1

    @property
    def n_leaves(self) -> int:
        return int(self.ell.shape[0])

    @property
    def n_subtrees(self) -> int:
        return int(self.sub_off.shape[0])

    @property
    def packed(self) -> bool:
        """True when the string is stored dense (k-bit PackedText)."""
        return isinstance(self.s_text, packing_mod.PackedText)

    @property
    def s_bits(self) -> int:
        """Stored bits per symbol (8 on the byte path)."""
        return self.s_text.bits if self.packed else 8

    @property
    def s_padded(self) -> jax.Array:
        """The terminal-padded byte string (byte-path indexes only; packed
        indexes read through :meth:`read_symbols` / the probe kernels)."""
        if self.packed:
            raise AttributeError(
                "this DeviceIndex stores the string dense-packed; use "
                "s_text / read_symbols / string_codes")
        return self.s_text

    @property
    def string_nbytes(self) -> int:
        """Bytes the served string representation occupies."""
        return (self.s_text.nbytes if self.packed
                else int(self.s_text.shape[0]))

    def read_symbols(self, pos, k: int) -> jax.Array:
        """(B, k) int32 symbol codes starting at each position (device);
        representation-independent (dense storage decodes in-register)."""
        pos = jnp.asarray(pos, jnp.int32)
        if self.packed:
            return packing_mod.gather_symbols_dense(self.s_text, pos, k)
        idx = pos[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
        idx = jnp.minimum(idx, self.s_text.shape[0] - 1)
        return jnp.take(self.s_text, idx, axis=0).astype(jnp.int32)

    def string_codes(self) -> np.ndarray:
        """The indexed string back as uint8 codes (terminal included) —
        ``n_leaves`` symbols, whatever the storage representation."""
        if self.packed:
            return packing_mod.unpack_text(self.s_text, n=self.n_leaves)
        return np.asarray(self.s_text)[: self.n_leaves]

    # ---- construction -----------------------------------------------------

    @classmethod
    def from_index(cls, index, *, route_cap: int = 1 << 18,
                   max_pattern_len: int = 512,
                   packing: str = "auto") -> "DeviceIndex":
        """Flatten ``index`` (a SuffixTreeIndex) into device arrays.

        ``route_cap`` bounds the dense routing table (cells <= route_cap);
        ``max_pattern_len`` fixes how far past |S| gathers may read;
        ``packing`` picks the served string representation
        (auto | dense | bytes — ``auto`` stores DNA at 2 and protein
        classes at 4 bits per symbol).
        """
        prefixes = sorted(index.subtrees)
        if not prefixes:
            raise ValueError("cannot flatten an empty index")
        subs = [index.subtrees[p] for p in prefixes]
        freqs = np.array([st.freq for st in subs], np.int32)
        ell = np.concatenate([np.asarray(st.ell, np.int32) for st in subs])
        return cls.from_prepare(alphabet=index.alphabet, s=np.asarray(index.s),
                                prefixes=prefixes, freqs=freqs, ell=ell,
                                route_cap=route_cap,
                                max_pattern_len=max_pattern_len,
                                packing=packing)

    @classmethod
    def from_prepare(cls, *, alphabet, s: np.ndarray, prefixes, freqs,
                     ell, route_cap: int = 1 << 18,
                     max_pattern_len: int = 512,
                     packing: str = "auto") -> "DeviceIndex":
        """Assemble directly from construction output — no SubTree dict.

        ``prefixes``: sorted (lexicographic) prefix tuples; ``freqs``: the
        aligned leaf counts; ``ell``: the concatenated leaf arrays in the
        same order (a device array from the batched engine stays on device;
        only the routing tables are computed host-side from the prefix
        metadata).  This is the ``EraIndexer.build_device`` fast path.
        """
        base = alphabet.base
        if not prefixes:
            raise ValueError("cannot flatten an empty index")
        freqs = np.asarray(freqs, np.int32)
        offs = np.concatenate([[0], np.cumsum(freqs)[:-1]]).astype(np.int32)
        total = int(freqs.sum())

        max_plen = max(len(p) for p in prefixes)
        plen = np.array([len(p) for p in prefixes], np.int32)
        pref = np.full((len(prefixes), max_plen), -1, np.int32)
        for t, p in enumerate(prefixes):
            pref[t, : len(p)] = p

        k_route = 1
        while base ** (k_route + 1) <= route_cap and k_route < max_plen:
            k_route += 1
        n_cells = base**k_route

        # each sub-tree owns the depth-k_route code interval [clo, chi] of
        # its (truncated) prefix; prefix-freeness makes the intervals sorted
        # and non-overlapping (equal only for sub-trees deeper than k_route).
        clo = np.zeros(len(prefixes), np.int64)
        chi = np.zeros(len(prefixes), np.int64)
        for t, p in enumerate(prefixes):
            kk = min(len(p), k_route)
            c = 0
            for j in range(kk):
                c = c * base + p[j]
            clo[t] = c * base ** (k_route - kk)
            chi[t] = clo[t] + base ** (k_route - kk) - 1
        codes = np.arange(n_cells, dtype=np.int64)
        off_ext = np.concatenate([offs, [total]]).astype(np.int32)
        win_lo = off_ext[np.searchsorted(chi, codes, side="left")]
        t_last = np.searchsorted(clo, codes, side="right") - 1
        win_hi = np.where(t_last >= 0, offs[np.maximum(t_last, 0)]
                          + freqs[np.maximum(t_last, 0)], 0).astype(np.int32)

        n_iter = int(np.ceil(np.log2(total + 1))) + 1
        pows = (base ** np.arange(k_route - 1, -1, -1)).astype(np.int32)
        spans = (base ** (k_route - np.arange(k_route + 1)) - 1).astype(np.int32)
        if packing_mod.resolve_dense(packing, alphabet):
            s_text = packing_mod.pack_text(np.asarray(s), alphabet,
                                           extra=max_pattern_len + 8)
        else:
            s_text = jnp.asarray(alphabet.pad_string(s, extra=max_pattern_len + 8))
        return cls(
            base=base,
            k_route=k_route,
            n_iter=n_iter,
            max_pattern_len=max_pattern_len,
            s_text=s_text,
            ell=jnp.asarray(ell),  # no-op for a device array from the batched engine
            ell_host=np.asarray(ell),
            sub_off=jnp.asarray(offs),
            sub_freq=jnp.asarray(freqs),
            sub_prefix=jnp.asarray(pref),
            sub_plen=jnp.asarray(plen),
            win_lo=jnp.asarray(win_lo),
            win_hi=jnp.asarray(win_hi),
            pows=jnp.asarray(pows),
            spans=jnp.asarray(spans),
        )

    # ---- persistence ------------------------------------------------------
    # The flattened form round-trips through npz so serving drivers
    # (query_serve / analytics_serve) can start without re-building and
    # re-flattening the index.  AnalyticsEngine reuses the blob helpers to
    # store its LCP array alongside the same fields in one file.
    #
    # Two string encodings coexist: byte-path saves keep the ORIGINAL
    # 4-entry-meta + ``s_padded`` layout (so pre-packing archives load
    # unchanged and byte saves stay readable by older code); dense saves
    # write ``s_words`` (uint32) and extend ``meta`` with
    # ``[s_bits, n_real]``.

    _BLOB_FIELDS = ("ell", "sub_off", "sub_freq", "sub_prefix",
                    "sub_plen", "win_lo", "win_hi", "pows", "spans")

    def to_blobs(self) -> dict[str, np.ndarray]:
        meta = [self.base, self.k_route, self.n_iter, self.max_pattern_len]
        if self.packed:
            meta += [self.s_text.bits, int(self.s_text.n_real)]
            blobs = {"s_words": np.asarray(self.s_text.words)}
        else:
            blobs = {"s_padded": np.asarray(self.s_text)}
        blobs["meta"] = np.array(meta, np.int64)
        for name in self._BLOB_FIELDS:
            blobs[name] = np.asarray(getattr(self, name))
        return blobs

    @classmethod
    def from_blobs(cls, data) -> "DeviceIndex":
        meta = np.asarray(data["meta"])
        ell = np.asarray(data["ell"], np.int32)
        if "s_words" in data:
            s_text = packing_mod.PackedText(
                words=jnp.asarray(np.asarray(data["s_words"], np.uint32)),
                n_real=jnp.asarray(int(meta[5]), jnp.int32),
                bits=int(meta[4]), terminal=int(meta[0]) - 1)
        else:  # byte-format archive (including every pre-packing save)
            s_text = jnp.asarray(data["s_padded"])
        fields = {name: jnp.asarray(data[name]) for name in cls._BLOB_FIELDS}
        return cls(base=int(meta[0]), k_route=int(meta[1]), n_iter=int(meta[2]),
                   max_pattern_len=int(meta[3]), s_text=s_text, ell_host=ell,
                   **fields)

    def save(self, path: str) -> None:
        """Persist the flattened index (npz); ``load`` restores it exactly."""
        np.savez_compressed(npz_path(path), **self.to_blobs())

    @classmethod
    def load(cls, path: str) -> "DeviceIndex":
        with np.load(npz_path(path)) as data:
            return cls.from_blobs(data)

    # ---- queries ----------------------------------------------------------

    def pad_batch(self, patterns) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Pad a list of 1-D code arrays to (B, m_pad) + lengths + route rows."""
        if not len(patterns):
            raise ValueError("empty batch")
        lengths = np.array([len(p) for p in patterns], np.int32)
        if (lengths < 1).any():
            raise ValueError("patterns must have length >= 1")
        m_max = int(lengths.max())
        m_pad = -(-m_max // 4) * 4
        if m_pad > self.max_pattern_len:
            raise ValueError(
                f"pattern length {m_max} exceeds max_pattern_len="
                f"{self.max_pattern_len}; rebuild with to_device(max_pattern_len=...)")
        padded = np.zeros((len(patterns), m_pad), np.int32)
        route = np.zeros((len(patterns), self.k_route), np.int32)
        for i, p in enumerate(patterns):
            arr = np.asarray(p, np.int32)
            if arr.size and (arr.min() < 0 or arr.max() >= self.base):
                raise ValueError(f"pattern {i} has codes outside [0, {self.base})")
            padded[i, : len(arr)] = arr
            route[i, : min(len(arr), self.k_route)] = arr[: self.k_route]
        return padded, lengths, route

    def find_batch_ranges(self, patterns, lengths, route_syms):
        """Jitted core: (B, m_pad)/(B,)/(B, k_route) → (start, count) slices
        of ``ell`` (device arrays; matches are ``ell[start:start+count]``).

        Dense-packed indexes default to the word-compare probe
        (``REPRO_WORD_COMPARE``); a batch carrying the terminal sentinel
        as a pattern code (degenerate but accepted) falls back to the
        byte-key probe, whose verdicts are defined for it."""
        word = self.packed and kops._use_word_compare()
        if word:
            # the gate is a STATIC jit arg, so the max code must reach the
            # host; reduce on device for device-resident batches (one
            # scalar sync) instead of pulling the whole batch back
            if isinstance(patterns, jax.Array):
                pat_max = int(jnp.max(patterns, initial=0))
            else:
                pat_max = int(np.asarray(patterns).max(initial=0))
            word = pat_max < self.s_text.terminal
        return _find_batch_ranges(
            self.s_text, self.ell, self.win_lo, self.win_hi,
            self.pows, self.spans,
            jnp.asarray(patterns, jnp.int32), jnp.asarray(lengths, jnp.int32),
            jnp.asarray(route_syms, jnp.int32),
            k_route=self.k_route, n_iter=self.n_iter,
            use_pallas=kops._use_pallas(), word=word,
        )

    def find_batch(self, patterns) -> list[np.ndarray]:
        """All occurrence positions for each pattern (sorted, int64) —
        the batched device analogue of ``SuffixTreeIndex.find``."""
        padded, lengths, route = self.pad_batch(patterns)
        start, count = self.find_batch_ranges(padded, lengths, route)
        start = np.asarray(start)
        count = np.asarray(count)
        ell = self.ell_host  # avoid a full device->host copy per batch
        return [np.sort(ell[s : s + c].astype(np.int64))
                for s, c in zip(start, count)]
