"""Device-resident batched query engine (the read side of the ERA index).

The assembled index (:class:`repro.core.suffix_tree.SuffixTreeIndex`) stores
each sub-tree's leaf array ``L`` as the suffix array restricted to its
prefix.  Because the vertical-partition prefixes are prefix-free and cover
every suffix, concatenating the ``L`` arrays in lexicographic prefix order
yields the full suffix array of ``S`` — so a substring query is a routing
step (which contiguous slice of the concatenation can contain matches?)
plus a bounded lower/upper-bound binary search (paper §2, §4).

:class:`DeviceIndex` flattens the whole index into device arrays:

* ``ell``              — the concatenated leaf arrays (int32[total]);
* ``sub_off/sub_freq`` + padded ``sub_prefix`` — per-subtree tables;
* ``win_lo/win_hi``    — a dense top-trie routing table keyed on packed
  base-|Σ|+1 prefix codes at depth ``k_route`` (capped so the table stays
  small): cell ``c`` maps to the slice of ``ell`` owned by sub-trees whose
  code range touches ``c``.

``find_batch`` then resolves a whole ``(B, m)`` batch of padded patterns
with ONE routing gather and a fixed-trip vectorized binary search whose
inner probe-gather-compare step is the :func:`repro.kernels.ops.pattern_probe`
kernel (Pallas on TPU, pure-jnp oracle elsewhere).  Comparisons run on the
packed big-endian words of :mod:`repro.core.packing` — the same machinery
the construction path sorts with — under unsigned order, so results are
exact for every alphabet including the byte alphabet.

The served string itself (``s_text``) is stored DENSE by default (paper
§6.1 generalized): ``Alphabet.dense_bits`` bits per symbol inside uint32
words (2-bit DNA, 4-bit protein classes) with the byte array as fallback /
reference.  Probe gathers repack in-register to the same byte sort keys,
so results are bit-identical across representations while the serving
index and its per-probe HBM traffic shrink ~``8/bits``x.

The per-pattern numpy path (``SuffixTreeIndex.find``) remains the oracle;
``tests/test_query.py`` / ``tests/test_packed.py`` cross-check the paths
on randomized workloads.
"""

from __future__ import annotations

import collections
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing as packing_mod
from repro.kernels import ops as kops


def npz_path(path: str) -> str:
    """The path ``np.savez_compressed`` actually writes (it appends
    ``.npz`` when missing); every save/load here normalizes through this
    so bare paths round-trip."""
    return path if path.endswith(".npz") else path + ".npz"


def shard_npz_path(path: str, k: int) -> str:
    """Per-shard archive path: the ``_shard{k}`` suffix goes BEFORE the
    ``.npz`` extension (``idx.npz`` → ``idx_shard2.npz``), so sharded
    saves never collide with the base archive or each other."""
    base = npz_path(path)
    return f"{base[:-4]}_shard{k}.npz"


def route_depth(base: int, max_plen: int, route_cap: int) -> int:
    """Depth of the dense top-trie routing table: the deepest ``k`` with
    ``base**k`` cells under ``route_cap`` (and within the shallowest
    prefix).  Shared by :meth:`DeviceIndex.from_prepare` and the sharded
    fabric, which must pin ONE global depth across every shard."""
    k_route = 1
    while base ** (k_route + 1) <= route_cap and k_route < max_plen:
        k_route += 1
    return k_route


def _pack_query_batch(s_text, patterns, lengths, word: bool):
    """Pattern packing (once per batch): zero symbols past each length in
    both the pattern and the all-ones mask, so masked suffix words compare
    against exactly the first ``m`` symbols (prefix match == equality).
    Byte path: 0xFF-byte masks over 4-symbol int32 words; word path:
    bits-wide fields over 32/bits-symbol uint32 words."""
    m_pad = patterns.shape[1]
    in_pat = jnp.arange(m_pad, dtype=jnp.int32)[None, :] < lengths[:, None]
    if word:
        bits = s_text.bits
        pat_words = packing_mod.pack_pattern_dense(
            jnp.where(in_pat, patterns, 0), bits, s_text.terminal)
        mask_words = packing_mod.pack_dense(
            jnp.where(in_pat, (1 << bits) - 1, 0), bits)
    else:
        pat_words = packing_mod.pack_words(jnp.where(in_pat, patterns, 0))
        mask_words = packing_mod.pack_words(jnp.where(in_pat, 0xFF, 0))
    return pat_words, mask_words


def _route_window(win_lo, win_hi, pows, spans, lengths, route_syms,
                  k_route: int):
    """Routing: the pattern's depth-k_route code interval [c_lo, c_hi]
    covers every suffix that can match; one gather into the dense table
    bounds the binary search to the owning sub-tree slice of ``ell``."""
    k = jnp.minimum(lengths, k_route)
    in_route = jnp.arange(k_route, dtype=jnp.int32)[None, :] < k[:, None]
    c_lo = jnp.sum(jnp.where(in_route, route_syms, 0) * pows[None, :], axis=1)
    c_hi = c_lo + spans[k]
    lo0 = win_lo[c_lo]
    hi0 = jnp.maximum(win_hi[c_hi], lo0)
    return lo0, hi0


def _search_bounds(s_text, ell, pat_words, mask_words, lengths, lo0, hi0,
                   *, n_iter: int, use_pallas: bool, word: bool):
    """Fixed-trip binary search; lower and upper bound run fused as one
    2B-row probe per iteration (the probe kernel is the only gather).
    Returns (llo, ulo): the lower/upper bound indices into ``ell``."""
    b = pat_words.shape[0]
    total = ell.shape[0]
    if word:
        probe_w = kops.pattern_probe_words_impl(use_pallas)
        len2 = jnp.concatenate([lengths, lengths])
        probe = lambda st, pos, pat, mask: probe_w(st, pos, pat, mask, len2)
    else:
        probe = kops.pattern_probe_impl(use_pallas)

    pat2 = jnp.concatenate([pat_words, pat_words], axis=0)
    mask2 = jnp.concatenate([mask_words, mask_words], axis=0)

    def body(_, st):
        llo, lhi, ulo, uhi = st
        lmid = (llo + lhi) // 2
        umid = (ulo + uhi) // 2
        mids = jnp.concatenate([lmid, umid])
        pos = ell[jnp.clip(mids, 0, total - 1)]
        cmp = probe(s_text, pos, pat2, mask2)
        lcmp, ucmp = cmp[:b], cmp[b:]
        lact = llo < lhi
        uact = ulo < uhi
        # lower bound: first suffix >= pattern (prefix match counts as >=)
        llo = jnp.where(lact & (lcmp < 0), lmid + 1, llo)
        lhi = jnp.where(lact & (lcmp >= 0), lmid, lhi)
        # upper bound: first suffix > pattern
        ulo = jnp.where(uact & (ucmp <= 0), umid + 1, ulo)
        uhi = jnp.where(uact & (ucmp > 0), umid, uhi)
        return llo, lhi, ulo, uhi

    llo, _, ulo, _ = jax.lax.fori_loop(0, n_iter, body, (lo0, hi0, lo0, hi0))
    return llo, ulo


@functools.partial(jax.jit, static_argnames=("k_route", "n_iter", "use_pallas",
                                             "word"))
def _find_batch_ranges(s_text, ell, win_lo, win_hi, pows, spans,
                       patterns, lengths, route_syms,
                       *, k_route: int, n_iter: int, use_pallas: bool,
                       word: bool = False):
    """Route + vectorized lower/upper-bound binary search for one batch.

    s_text: byte string or dense PackedText (the probe dispatches);
    patterns: (B, m_pad) int32, zero-padded; lengths: (B,) int32 >= 1;
    route_syms: (B, k_route) int32 (first symbols, zero-padded).
    ``word`` (PackedText only, real-symbol patterns only) packs the batch
    to k-bit dense words ONCE and runs the word-compare probe — ``bits/8``
    of the pattern key words and compare lanes, identical verdicts.
    Returns (start, count): int32[B] slices into ``ell``.
    """
    pat_words, mask_words = _pack_query_batch(s_text, patterns, lengths, word)
    lo0, hi0 = _route_window(win_lo, win_hi, pows, spans, lengths, route_syms,
                             k_route)
    llo, ulo = _search_bounds(s_text, ell, pat_words, mask_words, lengths,
                              lo0, hi0, n_iter=n_iter, use_pallas=use_pallas,
                              word=word)
    return llo, jnp.maximum(ulo - llo, 0)


def _window_symbols(s_text, win, pos0, fetch: int, word: bool):
    """Decode a fused-gather window back to (B, fetch) int32 symbol codes.

    word rows are ``bits``-bit fields inside uint32 words; byte-key rows
    are 4 big-endian bytes per int32.  Dense storage substitutes
    :func:`repro.core.packing.sub_code` past ``n_real`` on the word path,
    so the true terminal is patched back in by position — making the
    decoded window identical across every representation and oracle leg.
    """
    b = win.shape[0]
    if word:
        bits, spw = s_text.bits, s_text.syms_per_word
        shifts = (32 - bits * (jnp.arange(spw, dtype=jnp.uint32) + 1))
        sym = ((win[:, :, None] >> shifts[None, None, :])
               & ((1 << bits) - 1))
        sym = sym.reshape(b, -1)[:, :fetch].astype(jnp.int32)
    else:
        shifts = jnp.array([24, 16, 8, 0], jnp.int32)
        sym = ((win[:, :, None] >> shifts[None, None, :]) & 0xFF)
        sym = sym.reshape(b, -1)[:, :fetch].astype(jnp.int32)
    if isinstance(s_text, packing_mod.PackedText):
        past = (pos0[:, None] + jnp.arange(fetch, dtype=jnp.int32)[None, :]
                >= s_text.n_real)
        sym = jnp.where(past, jnp.int32(s_text.terminal), sym)
    return sym


@functools.partial(jax.jit, static_argnames=("k_route", "n_iter", "use_pallas",
                                             "word", "fetch"))
def _find_fetch_batch(s_text, ell, win_lo, win_hi, pows, spans,
                      patterns, lengths, route_syms,
                      *, k_route: int, n_iter: int, use_pallas: bool,
                      word: bool, fetch: int):
    """:func:`_find_batch_ranges` plus a fused find-and-fetch epilogue.

    After the search converges, ONE fused probe+gather launch at the
    lower-bound suffix (``ell[start]``) re-verifies the match and returns
    the ``fetch``-symbol text window there — where the two-launch form
    would probe and then gather the same HBM window twice.  Returns
    ``(start, count, window, verified)``: window is (B, fetch) int32
    symbol codes (-1 rows for patterns with no match), ``verified`` the
    fused probe's verdict (0 exactly where count > 0).
    """
    total = ell.shape[0]
    pat_words, mask_words = _pack_query_batch(s_text, patterns, lengths, word)
    lo0, hi0 = _route_window(win_lo, win_hi, pows, spans, lengths, route_syms,
                             k_route)
    llo, ulo = _search_bounds(s_text, ell, pat_words, mask_words, lengths,
                              lo0, hi0, n_iter=n_iter, use_pallas=use_pallas,
                              word=word)
    count = jnp.maximum(ulo - llo, 0)
    pos0 = ell[jnp.clip(llo, 0, total - 1)]
    if word:
        cmp, win = kops.probe_gather_words_impl(use_pallas)(
            s_text, pos0, pat_words, mask_words, lengths, fetch)
    else:
        cmp, win = kops.probe_gather_impl(use_pallas)(
            s_text, pos0, pat_words, mask_words, fetch)
    sym = _window_symbols(s_text, win, pos0, fetch, word)
    sym = jnp.where((count > 0)[:, None], sym, jnp.int32(-1))
    return llo, count, sym, cmp


@dataclasses.dataclass(frozen=True)
class DeviceIndex:
    """Flattened, device-resident form of a :class:`SuffixTreeIndex`."""

    base: int                 # |Σ| + 1 including the terminal
    k_route: int              # routing-trie depth (base**k_route cells)
    n_iter: int               # binary-search trip count (covers ``total``)
    max_pattern_len: int      # padding guarantee baked into ``s_text``
    s_text: object            # the served string: dense PackedText (k-bit
    #                           uint32 words, the default for sub-byte
    #                           alphabets) or uint8[n + pad] terminal-padded
    ell: jax.Array            # int32[total] concatenated leaf arrays (= SA)
    ell_host: np.ndarray      # host copy of ell (result materialization)
    sub_off: jax.Array        # int32[T] slice start of sub-tree t in ell
    sub_freq: jax.Array       # int32[T]
    sub_prefix: jax.Array     # int32[T, max_plen] prefix symbols, -1 pad
    sub_plen: jax.Array       # int32[T]
    win_lo: jax.Array         # int32[base**k_route] routing slice starts
    win_hi: jax.Array         # int32[base**k_route] routing slice ends
    pows: jax.Array           # int32[k_route] base**(k_route-1-j)
    spans: jax.Array          # int32[k_route+1] base**(k_route-k) - 1
    epoch: int = 0            # mutation generation: bumped by incremental
    #                           append; serving flushes RouteCaches on change

    @property
    def n_leaves(self) -> int:
        return int(self.ell.shape[0])

    @property
    def n_subtrees(self) -> int:
        return int(self.sub_off.shape[0])

    @property
    def packed(self) -> bool:
        """True when the string is stored dense (k-bit PackedText)."""
        return isinstance(self.s_text, packing_mod.PackedText)

    @property
    def s_bits(self) -> int:
        """Stored bits per symbol (8 on the byte path)."""
        return self.s_text.bits if self.packed else 8

    @property
    def s_padded(self) -> jax.Array:
        """The terminal-padded byte string (byte-path indexes only; packed
        indexes read through :meth:`read_symbols` / the probe kernels)."""
        if self.packed:
            raise AttributeError(
                "this DeviceIndex stores the string dense-packed; use "
                "s_text / read_symbols / string_codes")
        return self.s_text

    @property
    def string_nbytes(self) -> int:
        """Bytes the served string representation occupies."""
        return (self.s_text.nbytes if self.packed
                else int(self.s_text.shape[0]))

    def read_symbols(self, pos, k: int) -> jax.Array:
        """(B, k) int32 symbol codes starting at each position (device);
        representation-independent (dense storage decodes in-register)."""
        pos = jnp.asarray(pos, jnp.int32)
        if self.packed:
            return packing_mod.gather_symbols_dense(self.s_text, pos, k)
        idx = pos[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
        idx = jnp.minimum(idx, self.s_text.shape[0] - 1)
        return jnp.take(self.s_text, idx, axis=0).astype(jnp.int32)

    def string_codes(self) -> np.ndarray:
        """The indexed string back as uint8 codes (terminal included) —
        ``n_leaves`` symbols, whatever the storage representation."""
        if self.packed:
            return packing_mod.unpack_text(self.s_text, n=self.n_leaves)
        return np.asarray(self.s_text)[: self.n_leaves]

    # ---- construction -----------------------------------------------------

    @classmethod
    def from_index(cls, index, *, route_cap: int = 1 << 18,
                   max_pattern_len: int = 512,
                   packing: str = "auto") -> "DeviceIndex":
        """Flatten ``index`` (a SuffixTreeIndex) into device arrays.

        ``route_cap`` bounds the dense routing table (cells <= route_cap);
        ``max_pattern_len`` fixes how far past |S| gathers may read;
        ``packing`` picks the served string representation
        (auto | dense | bytes — ``auto`` stores DNA at 2 and protein
        classes at 4 bits per symbol).
        """
        prefixes = sorted(index.subtrees)
        if not prefixes:
            raise ValueError("cannot flatten an empty index")
        subs = [index.subtrees[p] for p in prefixes]
        freqs = np.array([st.freq for st in subs], np.int32)
        ell = np.concatenate([np.asarray(st.ell, np.int32) for st in subs])
        return cls.from_prepare(alphabet=index.alphabet, s=np.asarray(index.s),
                                prefixes=prefixes, freqs=freqs, ell=ell,
                                route_cap=route_cap,
                                max_pattern_len=max_pattern_len,
                                packing=packing)

    @classmethod
    def from_prepare(cls, *, alphabet, s: np.ndarray, prefixes, freqs,
                     ell, route_cap: int = 1 << 18,
                     max_pattern_len: int = 512,
                     packing: str = "auto",
                     k_route: int | None = None,
                     epoch: int = 0) -> "DeviceIndex":
        """Assemble directly from construction output — no SubTree dict.

        ``prefixes``: sorted (lexicographic) prefix tuples; ``freqs``: the
        aligned leaf counts; ``ell``: the concatenated leaf arrays in the
        same order (a device array from the batched engine stays on device;
        only the routing tables are computed host-side from the prefix
        metadata).  This is the ``EraIndexer.build_device`` fast path.

        ``k_route`` overrides the routing-table depth: the sharded fabric
        builds one DeviceIndex per shard over a SUBSET of the sub-trees
        and every shard must share the GLOBAL depth (else route codes
        would not be comparable across shards).
        """
        base = alphabet.base
        if not prefixes:
            raise ValueError("cannot flatten an empty index")
        freqs = np.asarray(freqs, np.int32)
        offs = np.concatenate([[0], np.cumsum(freqs)[:-1]]).astype(np.int32)
        total = int(freqs.sum())

        max_plen = max(len(p) for p in prefixes)
        plen = np.array([len(p) for p in prefixes], np.int32)
        pref = np.full((len(prefixes), max_plen), -1, np.int32)
        for t, p in enumerate(prefixes):
            pref[t, : len(p)] = p

        if k_route is None:
            k_route = route_depth(base, max_plen, route_cap)
        n_cells = base**k_route

        # each sub-tree owns the depth-k_route code interval [clo, chi] of
        # its (truncated) prefix; prefix-freeness makes the intervals sorted
        # and non-overlapping (equal only for sub-trees deeper than k_route).
        clo = np.zeros(len(prefixes), np.int64)
        chi = np.zeros(len(prefixes), np.int64)
        for t, p in enumerate(prefixes):
            kk = min(len(p), k_route)
            c = 0
            for j in range(kk):
                c = c * base + p[j]
            clo[t] = c * base ** (k_route - kk)
            chi[t] = clo[t] + base ** (k_route - kk) - 1
        codes = np.arange(n_cells, dtype=np.int64)
        off_ext = np.concatenate([offs, [total]]).astype(np.int32)
        win_lo = off_ext[np.searchsorted(chi, codes, side="left")]
        t_last = np.searchsorted(clo, codes, side="right") - 1
        win_hi = np.where(t_last >= 0, offs[np.maximum(t_last, 0)]
                          + freqs[np.maximum(t_last, 0)], 0).astype(np.int32)

        n_iter = int(np.ceil(np.log2(total + 1))) + 1
        pows = (base ** np.arange(k_route - 1, -1, -1)).astype(np.int32)
        spans = (base ** (k_route - np.arange(k_route + 1)) - 1).astype(np.int32)
        if packing_mod.resolve_dense(packing, alphabet):
            s_text = packing_mod.pack_text(np.asarray(s), alphabet,
                                           extra=max_pattern_len + 8)
        else:
            s_text = jnp.asarray(alphabet.pad_string(s, extra=max_pattern_len + 8))
        return cls(
            base=base,
            k_route=k_route,
            n_iter=n_iter,
            max_pattern_len=max_pattern_len,
            s_text=s_text,
            ell=jnp.asarray(ell),  # no-op for a device array from the batched engine
            ell_host=np.asarray(ell),
            sub_off=jnp.asarray(offs),
            sub_freq=jnp.asarray(freqs),
            sub_prefix=jnp.asarray(pref),
            sub_plen=jnp.asarray(plen),
            win_lo=jnp.asarray(win_lo),
            win_hi=jnp.asarray(win_hi),
            pows=jnp.asarray(pows),
            spans=jnp.asarray(spans),
            epoch=int(epoch),
        )

    # ---- persistence ------------------------------------------------------
    # The flattened form round-trips through npz so serving drivers
    # (query_serve / analytics_serve) can start without re-building and
    # re-flattening the index.  AnalyticsEngine reuses the blob helpers to
    # store its LCP array alongside the same fields in one file.
    #
    # Two string encodings coexist: byte-path saves keep the ORIGINAL
    # 4-entry-meta + ``s_padded`` layout (so pre-packing archives load
    # unchanged and byte saves stay readable by older code); dense saves
    # write ``s_words`` (uint32) and extend ``meta`` with
    # ``[s_bits, n_real]``.  The mutation ``epoch`` rides as ONE trailing
    # meta entry on both layouts — archives written before the append era
    # are shorter and load as epoch 0.

    _BLOB_FIELDS = ("ell", "sub_off", "sub_freq", "sub_prefix",
                    "sub_plen", "win_lo", "win_hi", "pows", "spans")

    def to_blobs(self) -> dict[str, np.ndarray]:
        meta = [self.base, self.k_route, self.n_iter, self.max_pattern_len]
        if self.packed:
            meta += [self.s_text.bits, int(self.s_text.n_real)]
            blobs = {"s_words": np.asarray(self.s_text.words)}
        else:
            blobs = {"s_padded": np.asarray(self.s_text)}
        meta.append(self.epoch)
        blobs["meta"] = np.array(meta, np.int64)
        for name in self._BLOB_FIELDS:
            blobs[name] = np.asarray(getattr(self, name))
        return blobs

    @classmethod
    def from_blobs(cls, data) -> "DeviceIndex":
        meta = np.asarray(data["meta"])
        ell = np.asarray(data["ell"], np.int32)
        if "s_words" in data:
            s_text = packing_mod.PackedText(
                words=jnp.asarray(np.asarray(data["s_words"], np.uint32)),
                n_real=jnp.asarray(int(meta[5]), jnp.int32),
                bits=int(meta[4]), terminal=int(meta[0]) - 1)
            epoch = int(meta[6]) if meta.size > 6 else 0
        else:  # byte-format archive (including every pre-packing save)
            s_text = jnp.asarray(data["s_padded"])
            epoch = int(meta[4]) if meta.size > 4 else 0
        fields = {name: jnp.asarray(data[name]) for name in cls._BLOB_FIELDS}
        return cls(base=int(meta[0]), k_route=int(meta[1]), n_iter=int(meta[2]),
                   max_pattern_len=int(meta[3]), s_text=s_text, ell_host=ell,
                   epoch=epoch, **fields)

    def save(self, path: str) -> None:
        """Persist the flattened index (npz); ``load`` restores it exactly."""
        np.savez_compressed(npz_path(path), **self.to_blobs())

    @classmethod
    def load(cls, path: str) -> "DeviceIndex":
        with np.load(npz_path(path)) as data:
            return cls.from_blobs(data)

    # ---- queries ----------------------------------------------------------

    def pad_batch(self, patterns, *, m_pad: int | None = None,
                  b_pad: int | None = None,
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Pad a list of 1-D code arrays to (B, m_pad) + lengths + route rows.

        ``m_pad`` / ``b_pad`` optionally pin the padded width / batch rows
        to caller-chosen bucket sizes (the serving loop buckets both to
        powers of two so recompiles stay bounded); width must be a
        multiple of 4 and at least the natural padded width.  Dummy rows
        (length 1, code 0) fill the batch out to ``b_pad`` — callers slice
        results back to the real row count."""
        if not len(patterns):
            raise ValueError("empty batch")
        lengths = np.array([len(p) for p in patterns], np.int32)
        if (lengths < 1).any():
            raise ValueError("patterns must have length >= 1")
        m_max = int(lengths.max())
        m_nat = -(-m_max // 4) * 4
        if m_pad is None:
            m_pad = m_nat
        elif m_pad % 4 or m_pad < m_nat:
            raise ValueError(
                f"m_pad={m_pad} must be a multiple of 4 and >= {m_nat}")
        if m_pad > self.max_pattern_len:
            raise ValueError(
                f"pattern length {m_max} exceeds max_pattern_len="
                f"{self.max_pattern_len}; rebuild with to_device(max_pattern_len=...)")
        b = len(patterns)
        if b_pad is None:
            b_pad = b
        elif b_pad < b:
            raise ValueError(f"b_pad={b_pad} < batch size {b}")
        padded = np.zeros((b_pad, m_pad), np.int32)
        route = np.zeros((b_pad, self.k_route), np.int32)
        for i, p in enumerate(patterns):
            arr = np.asarray(p, np.int32)
            if arr.size and (arr.min() < 0 or arr.max() >= self.base):
                raise ValueError(f"pattern {i} has codes outside [0, {self.base})")
            padded[i, : len(arr)] = arr
            route[i, : min(len(arr), self.k_route)] = arr[: self.k_route]
        if b_pad > b:
            lengths = np.concatenate(
                [lengths, np.ones(b_pad - b, np.int32)])
        return padded, lengths, route

    def _word_gate(self, patterns, pat_max: int | None) -> bool:
        """Resolve the word-vs-byte probe gate (a STATIC jit arg).

        A batch carrying the terminal sentinel as a pattern code
        (degenerate but accepted) must fall back to the byte-key probe,
        whose verdicts are defined for it.  The max code must reach the
        host; serving passes the ``pat_max`` it already tracks at
        admission so device-resident batches avoid even the one scalar
        sync of the device reduce."""
        if not (self.packed and kops._use_word_compare()):
            return False
        if pat_max is None:
            if isinstance(patterns, jax.Array):
                pat_max = int(jnp.max(patterns, initial=0))
            else:
                pat_max = int(np.asarray(patterns).max(initial=0))
        return pat_max < self.s_text.terminal

    def find_batch_ranges(self, patterns, lengths, route_syms,
                          *, pat_max: int | None = None):
        """Jitted core: (B, m_pad)/(B,)/(B, k_route) → (start, count) slices
        of ``ell`` (device arrays; matches are ``ell[start:start+count]``).

        Dense-packed indexes default to the word-compare probe
        (``REPRO_WORD_COMPARE``); batches carrying the terminal sentinel
        fall back to the byte-key probe (see :meth:`_word_gate` — pass
        the already-known ``pat_max`` to keep the call sync-free)."""
        return _find_batch_ranges(
            self.s_text, self.ell, self.win_lo, self.win_hi,
            self.pows, self.spans,
            jnp.asarray(patterns, jnp.int32), jnp.asarray(lengths, jnp.int32),
            jnp.asarray(route_syms, jnp.int32),
            k_route=self.k_route, n_iter=self.n_iter,
            use_pallas=kops._use_pallas(),
            word=self._word_gate(patterns, pat_max),
        )

    def find_fetch_ranges(self, patterns, lengths, route_syms, *, fetch: int,
                          pat_max: int | None = None):
        """Find-and-fetch: :meth:`find_batch_ranges` plus the text window.

        One extra FUSED probe+gather launch (:mod:`repro.kernels.probe_gather`)
        at the lower-bound suffix returns ``fetch`` symbols of context per
        match.  Returns device arrays ``(start, count, window, verified)``;
        ``window`` is (B, fetch) int32 codes (-1 rows where count == 0),
        ``verified`` the fused probe's verdict (0 wherever count > 0).
        """
        if fetch % 4 or fetch <= 0:
            raise ValueError(f"fetch={fetch} must be a positive multiple of 4")
        if fetch > self.max_pattern_len:
            raise ValueError(
                f"fetch={fetch} exceeds max_pattern_len={self.max_pattern_len}"
                " (the gather-past-|S| padding guarantee)")
        return _find_fetch_batch(
            self.s_text, self.ell, self.win_lo, self.win_hi,
            self.pows, self.spans,
            jnp.asarray(patterns, jnp.int32), jnp.asarray(lengths, jnp.int32),
            jnp.asarray(route_syms, jnp.int32),
            k_route=self.k_route, n_iter=self.n_iter,
            use_pallas=kops._use_pallas(),
            word=self._word_gate(patterns, pat_max), fetch=fetch,
        )

    def find_batch(self, patterns) -> list[np.ndarray]:
        """All occurrence positions for each pattern (sorted, int64) —
        the batched device analogue of ``SuffixTreeIndex.find``."""
        padded, lengths, route = self.pad_batch(patterns)
        start, count = self.find_batch_ranges(padded, lengths, route)
        start = np.asarray(start)
        count = np.asarray(count)
        ell = self.ell_host  # avoid a full device->host copy per batch
        return [np.sort(ell[s : s + c].astype(np.int64))
                for s, c in zip(start, count)]

    def find_fetch_batch(self, patterns, *, fetch: int = 32):
        """Host-convenience find-and-fetch over a list of code arrays.

        Returns ``(ranges, windows)``: ``ranges`` the list of sorted
        occurrence-position arrays (as :meth:`find_batch`), ``windows`` a
        (B, fetch) int32 array of text context at the first (SA-order)
        match of each pattern, -1 rows for patterns with no match."""
        padded, lengths, route = self.pad_batch(patterns)
        start, count, win, _ = self.find_fetch_ranges(padded, lengths, route,
                                                      fetch=fetch)
        start = np.asarray(start)
        count = np.asarray(count)
        ell = self.ell_host
        ranges = [np.sort(ell[s : s + c].astype(np.int64))
                  for s, c in zip(start, count)]
        return ranges, np.asarray(win)

    # ---- hot-prefix route cache -------------------------------------------

    def route_key(self, pattern) -> tuple[int, int, bytes]:
        """Cache key for one pattern: (top-trie route code, length, bytes).

        The leading component is the dense depth-``k_route`` route code
        ``c_lo`` — the same cell :func:`_route_window` gathers — so keys
        cluster by the top-trie route the query would take and cache
        introspection can report per-route hit concentrations.  The full
        pattern bytes keep lookups exact: a hit returns (start, count)
        bounds that are byte-identical to running the search, because
        probe verdicts do not depend on the batch's padded width."""
        arr = np.asarray(pattern, np.int32)
        kk = min(arr.size, self.k_route)
        c = 0
        for j in range(kk):
            c = c * self.base + int(arr[j])
        c *= self.base ** (self.k_route - kk)
        return c, arr.size, arr.astype(np.int32).tobytes()

    def find_batch_cached(self, patterns, cache: "RouteCache") -> list[np.ndarray]:
        """:meth:`find_batch` through a :class:`RouteCache`.

        Hits resolve to their memoized (start, count) without touching the
        device; misses run as ONE smaller batch and populate the cache.
        Results are byte-identical to :meth:`find_batch` (exact-pattern
        keys; see :meth:`route_key`)."""
        keys = [self.route_key(p) for p in patterns]
        bounds: list[tuple[int, int] | None] = [cache.get(k) for k in keys]
        # dedupe misses by key: a hot pattern repeated inside one batch
        # costs one search row, and every repeat resolves from that row
        miss: dict[tuple, int] = {}
        for i, bnd in enumerate(bounds):
            if bnd is None and keys[i] not in miss:
                miss[keys[i]] = i
        if miss:
            padded, lengths, route = self.pad_batch(
                [patterns[i] for i in miss.values()])
            start, count = self.find_batch_ranges(padded, lengths, route)
            start = np.asarray(start)
            count = np.asarray(count)
            solved = {k: (int(start[j]), int(count[j]))
                      for j, k in enumerate(miss)}
            for k, bnd in solved.items():
                cache.put(k, bnd)
            for i, bnd in enumerate(bounds):
                if bnd is None:
                    bounds[i] = solved[keys[i]]
        ell = self.ell_host
        return [np.sort(ell[s : s + c].astype(np.int64))
                for s, c in bounds]


class RouteCache:
    """LRU memo of (route-keyed pattern → (start, count) bounds in ``ell``).

    Keyed by :meth:`DeviceIndex.route_key` — exact pattern identity under a
    top-trie route prefix — so the head of a skewed query distribution
    skips the whole binary-search descent; the memoized bounds are exactly
    what the search returns (verdicts are padded-width-independent), which
    is what makes cache-on/off serving byte-identical.  Plain OrderedDict
    LRU with hit/miss/eviction counters for the serving driver's stats."""

    def __init__(self, capacity: int = 4096):
        if capacity < 0:
            raise ValueError(f"capacity={capacity} must be >= 0")
        self.capacity = capacity
        self._map: collections.OrderedDict[tuple, tuple[int, int]] = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._map)

    def get(self, key) -> tuple[int, int] | None:
        if self.capacity == 0:
            self.misses += 1
            return None
        got = self._map.get(key)
        if got is None:
            self.misses += 1
            return None
        self._map.move_to_end(key)
        self.hits += 1
        return got

    def put(self, key, bounds: tuple[int, int]) -> None:
        if self.capacity == 0:
            return
        if key in self._map:
            self._map.move_to_end(key)
        self._map[key] = bounds
        while len(self._map) > self.capacity:
            self._map.popitem(last=False)
            self.evictions += 1

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"size": len(self._map), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "hit_rate": self.hit_rate}

    def clear(self) -> None:
        self._map.clear()
