"""BuildSubTree (paper §4.2.2) — from (L, B) to the suffix sub-tree.

Node layout is structure-of-arrays (TPU/cache friendly, replacing the
paper's pointer nodes):

* ``parent[v]``     — parent node id (-1 for the sub-tree root)
* ``depth[v]``      — string depth (symbols from the global root to ``v``)
* ``witness[v]``    — a leaf position under ``v``; the edge label of
                      ``(parent[v], v)`` is ``S[witness+depth[parent]] ..
                      S[witness+depth[v]-1]``, so edges cost two ints as in
                      the paper (§2, O(n) representation).

Node ids: leaves are ``0..F-1`` in lexicographic order (= positions in
``L``); internal nodes are allocated from ``F`` upward; there are at most
``F`` internal nodes (paper §4.1: #internal == #leaves for the binary-ish
worst case, never more).

Three implementations, all checked against ``ref.tree_intervals``:

* ``build_numpy``    — paper Alg. BuildSubTree verbatim (sequential stack);
* ``build_scan``     — same algorithm as a ``jax.lax.scan`` with an explicit
                       fixed-depth stack (proves jax-expressibility; the
                       inner pop loop is a ``lax.while_loop``);
* ``build_parallel`` — beyond-paper: the internal nodes of the sub-tree are
                       exactly the Cartesian-tree nodes of ``B_off``; parent
                       links follow from all-nearest-smaller-values, which we
                       compute with a sparse-table + vectorized binary
                       search in O(F log F) fully parallel work.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rmq


class SubTreeNodes(NamedTuple):
    parent: np.ndarray | jax.Array  # int32[2F] (slot F+F-1 may be unused)
    depth: np.ndarray | jax.Array   # int32[2F]
    witness: np.ndarray | jax.Array  # int32[2F]
    n_nodes: int | jax.Array        # total valid nodes (leaves + internal)
    n_leaves: int | jax.Array


def nodes_to_host(nodes: SubTreeNodes) -> SubTreeNodes:
    """Normalize a node set to host form in ONE transfer per field.

    The scan/parallel builders return device arrays (and traced scalar
    counts); consumers that walk the arrays element-wise
    (:func:`nodes_to_intervals`, ``SuffixTreeIndex.save``/``_descend``)
    must convert once up front — per-element ``int(...)`` on a device
    array is a device sync inside the loop.  No-op for numpy inputs.
    """
    return SubTreeNodes(
        parent=np.asarray(nodes.parent),
        depth=np.asarray(nodes.depth),
        witness=np.asarray(nodes.witness),
        n_nodes=int(nodes.n_nodes),
        n_leaves=int(nodes.n_leaves),
    )


# ---------------------------------------------------------------------------
# Faithful sequential builder (numpy, host) — Alg. BuildSubTree
# ---------------------------------------------------------------------------

def build_numpy(ell: np.ndarray, b_off: np.ndarray, n_total: int) -> SubTreeNodes:
    """``ell``: int leaf positions (lex order); ``b_off[i]``: divergence depth
    of leaves i-1, i (b_off[0] unused); ``n_total``: len(S) incl. terminal."""
    f = len(ell)
    cap = 2 * max(f, 1)
    parent = np.full(cap, -1, dtype=np.int32)
    depth = np.zeros(cap, dtype=np.int32)
    witness = np.full(cap, -1, dtype=np.int32)

    root = f  # internal ids from f; root is the first internal node
    n_internal = 1
    depth[root] = 0
    witness[root] = int(ell[0]) if f else -1

    if f == 0:
        return SubTreeNodes(parent, depth, witness, 1, 0)

    # push leaf 0
    parent[0] = root
    depth[0] = n_total - int(ell[0])
    witness[0] = int(ell[0])
    stack = [root, 0]  # path of node ids, root at bottom

    for i in range(1, f):
        off = int(b_off[i])
        # pop while the stack-top *edge* is deeper than off
        last = -1
        while depth[stack[-1]] > off:
            last = stack.pop()
        top = stack[-1]
        if depth[top] == off:
            u = top
        else:
            # break edge (top -> last) at depth off
            t = f + n_internal
            n_internal += 1
            parent[t] = top
            depth[t] = off
            witness[t] = witness[last]
            parent[last] = t
            stack.append(t)
            u = t
        # new leaf i
        parent[i] = u
        depth[i] = n_total - int(ell[i])
        witness[i] = int(ell[i])
        stack.append(i)

    return SubTreeNodes(parent, depth, witness, f + n_internal, f)


# ---------------------------------------------------------------------------
# Faithful builder as a lax.scan (explicit fixed-depth stack)
# ---------------------------------------------------------------------------

def build_scan(ell: jax.Array, b_off: jax.Array, n_total: int) -> SubTreeNodes:
    f = ell.shape[0]
    cap = 2 * f
    root = f

    parent0 = jnp.full(cap, -1, jnp.int32).at[0].set(root)
    depth0 = jnp.zeros(cap, jnp.int32).at[0].set(n_total - ell[0])
    witness0 = jnp.full(cap, -1, jnp.int32).at[root].set(ell[0]).at[0].set(ell[0])

    stack0 = jnp.full(f + 2, -1, jnp.int32).at[0].set(root).at[1].set(0)

    def step(carry, i):
        parent, depth, witness, stack, sp, n_int = carry
        off = b_off[i]

        def pop_cond(c):
            _last, sp_ = c
            return depth[stack[sp_]] > off

        def pop_body(c):
            _last, sp_ = c
            return stack[sp_], sp_ - 1

        last, sp = jax.lax.while_loop(pop_cond, pop_body, (jnp.int32(-1), sp))
        top = stack[sp]
        need_break = depth[top] != off
        t = f + n_int  # candidate new internal id

        u = jnp.where(need_break, t, top)
        parent = parent.at[t].set(jnp.where(need_break, top, parent[t]))
        depth = depth.at[t].set(jnp.where(need_break, off, depth[t]))
        witness = witness.at[t].set(jnp.where(need_break, witness[last], witness[t]))
        parent = parent.at[last].set(jnp.where(need_break, t, parent[last]))
        sp = jnp.where(need_break, sp + 1, sp)
        stack = stack.at[sp].set(jnp.where(need_break, t, stack[sp]))
        n_int = n_int + need_break.astype(jnp.int32)

        # new leaf i
        parent = parent.at[i].set(u)
        depth = depth.at[i].set(n_total - ell[i])
        witness = witness.at[i].set(ell[i])
        sp = sp + 1
        stack = stack.at[sp].set(i)
        return (parent, depth, witness, stack, sp, n_int), None

    carry0 = (parent0, depth0, witness0, stack0, jnp.int32(1), jnp.int32(1))
    (parent, depth, witness, _, _, n_int), _ = jax.lax.scan(
        step, carry0, jnp.arange(1, f, dtype=jnp.int32)
    )
    return SubTreeNodes(parent, depth, witness, f + n_int, f)


# ---------------------------------------------------------------------------
# Beyond-paper: fully parallel Cartesian-tree builder (ANSV by doubling)
# ---------------------------------------------------------------------------
# The sparse-table RMQ machinery this builder runs on is shared with the
# analytics engine and lives in :mod:`repro.core.rmq`.


def build_parallel(ell: jax.Array, b_off: jax.Array, n_total: int) -> SubTreeNodes:
    """Parallel construction: suffix sub-tree == Cartesian tree of B_off.

    Event ``i`` (1 <= i < F) carries depth ``h[i] = b_off[i]``.  The internal
    node containing event i is canonically represented by the *leftmost*
    event j in i's LCP-interval with ``h[j] == min == h[i]``; parent links
    follow from previous/next strictly-smaller values.  All queries are
    O(log F) vectorized binary searches over a range-min sparse table.
    """
    f = ell.shape[0]
    if f == 1:
        parent = jnp.full(2, -1, jnp.int32).at[0].set(1)
        depth = jnp.zeros(2, jnp.int32).at[0].set(n_total - ell[0])
        witness = jnp.stack([ell[0], ell[0]]).astype(jnp.int32)
        return SubTreeNodes(parent, depth, witness, 2, 1)

    h = b_off.astype(jnp.int32).at[0].set(-1)  # sentinel left wall at 0
    n_levels = rmq.log2_ceil(f) + 2
    vals, args = rmq.sparse_table(h, n_levels)
    idx = jnp.arange(f, dtype=jnp.int32)

    # psv[i]: largest j < i with h[j] < h[i]  (exists: h[0] = -1 wall)
    psv = rmq.prev_less(vals, idx, h)

    # nsv[i]: smallest j > i with h[j] < h[i]; == f if none.  Computed as a
    # PSV over [wall] + reversed(h): extended index r <-> original f - r.
    h_rev_ext = jnp.concatenate([jnp.array([-1], jnp.int32), h[::-1]])
    vals_rev, _ = rmq.sparse_table(h_rev_ext, n_levels)
    psv_rev = rmq.prev_less(vals_rev, f - idx, h)  # init f - i, target h[i]
    nsv = f - psv_rev

    # canonical representative: leftmost argmin of h in (psv[i], i]
    rep = rmq.range_argmin(vals, args, psv + 1, idx)  # for event i (i>=1)
    rep = rep.at[0].set(0)

    # parent event: the deeper of h[psv], h[nsv]; rep() of that event.
    h_ext = jnp.concatenate([h, jnp.array([-1], jnp.int32)])  # h[F] = -1 wall
    pl = h[jnp.maximum(psv, 0)]
    pr = h_ext[jnp.minimum(nsv, f)]
    parent_event = jnp.where(pl >= pr, jnp.maximum(psv, 0), jnp.minimum(nsv, f - 1))
    parent_rep = rep[parent_event]

    # node ids: internal node for canonical event j lives at id f + j
    # (j >= 1); the sub-tree root is the canonical event of the global min.
    is_rep = rep == idx
    root_event = rmq.range_argmin(vals, args, jnp.ones((), jnp.int32),
                                  jnp.full((), f - 1, jnp.int32))
    root_id = f + root_event

    cap = 2 * f
    parent = jnp.full(cap, -1, jnp.int32)
    depth = jnp.zeros(cap, jnp.int32)
    witness = jnp.full(cap, -1, jnp.int32)

    # internal nodes
    ev = idx
    int_ids = f + ev
    int_parent = jnp.where(
        ev == root_event, -1, f + parent_rep
    )
    valid_int = is_rep & (ev >= 1)
    parent = parent.at[jnp.where(valid_int, int_ids, cap - 1)].set(
        jnp.where(valid_int, int_parent, parent[cap - 1])
    )
    depth = depth.at[jnp.where(valid_int, int_ids, cap - 1)].set(
        jnp.where(valid_int, h[ev], depth[cap - 1])
    )
    witness = witness.at[jnp.where(valid_int, int_ids, cap - 1)].set(
        jnp.where(valid_int, ell[ev - 1].astype(jnp.int32), witness[cap - 1])
    )

    # leaves: leaf k's parent is the deeper of events k, k+1
    hk = h_ext[idx]       # event on the left of leaf k
    hk1 = h_ext[idx + 1]  # event on the right
    lev = jnp.where(hk >= hk1, idx, jnp.minimum(idx + 1, f - 1))
    leaf_parent = f + rep[lev]
    parent = parent.at[idx].set(leaf_parent)
    depth = depth.at[idx].set((n_total - ell).astype(jnp.int32))
    witness = witness.at[idx].set(ell.astype(jnp.int32))

    n_internal = jnp.sum(valid_int)
    return SubTreeNodes(parent, depth, witness, f + n_internal, f)


# ---------------------------------------------------------------------------
# Batched builder: every sub-tree of a whole build in ONE vmapped call
# ---------------------------------------------------------------------------
# Rows are per-PREFIX (one sub-tree each), padded to a common width F_pad.
# Padding is depth-0: padded positions get ``b_off = 0`` and ``ell =
# n_total``.  Real divergence depths are >= 1 (every vertical-partition
# prefix has length >= 1), so all padded events collapse into exactly ONE
# artificial internal node at string depth 0 — the canonical event is the
# first padded position f — which adopts the real sub-tree root and every
# padded leaf.  That node is the same depth-0 super-root ``build_numpy``
# allocates, so extraction to the compact per-sub-tree layout is a pure id
# remap (no topology fixes).  ``PAD_MIN = 2`` guarantees (a) the artificial
# root always exists and (b) its node id ``F_pad + f`` never collides with
# the builder's scatter dump slot ``2*F_pad - 1``.

PAD_MIN = 2


def pad_width(max_freq: int) -> int:
    """Row width for :func:`build_parallel_batch` given the largest freq."""
    return max_freq + PAD_MIN


# Modeled fixed cost (in padded Cartesian-tree cells) of dispatching one
# more vmapped build bucket — the auto-tuner stops splitting once the
# padded-cell saving of another bucket drops below this.
BUCKET_OVERHEAD_CELLS = 4096


def bucket_pad_widths(freqs, max_buckets: int | None = None
                      ) -> list[tuple[int, np.ndarray]]:
    """Group row frequencies into histogram-driven pad-width buckets.

    Real sub-tree size mixes are skewed (a few huge prefixes, many tiny
    ones), so padding EVERY row to the global max wastes most of the
    vmapped Cartesian-tree work.  Rows are partitioned by
    ``pad_width(freq)`` rounded up to a power of four (at most log4
    distinct classes).  With ``max_buckets=None`` (default) the bucket
    COUNT is auto-tuned from the class histogram: a small DP over class
    boundaries finds, for every candidate count k, the k-bucket partition
    with the fewest padded cells ``sum(width_b * rows_b)``, and the k
    minimizing ``cells + k * BUCKET_OVERHEAD_CELLS`` wins — uniform mixes
    collapse to one bucket, heavy-tailed mixes split until another
    dispatch stops paying for itself.  An integer ``max_buckets`` keeps
    the legacy behavior: the largest ``max_buckets`` classes survive and
    smaller rows fall up into the narrowest surviving bucket.

    Each bucket's actual pad width is the exact ``pad_width`` of its
    largest member, so the widest bucket never pads beyond the old global
    width.  Returns ``[(width, row_indices), ...]`` widest bucket first;
    the indices partition ``range(len(freqs))``.
    """
    freqs = np.asarray(freqs, np.int64)
    if freqs.size == 0:
        return []
    pow4 = 4 ** np.ceil(
        np.log2(np.maximum(freqs + PAD_MIN, 1)) / 2).astype(np.int64)
    classes = np.sort(np.unique(pow4))[::-1]

    if max_buckets is not None:
        kept = classes[: max(1, max_buckets)]
        out = []
        for i, cls in enumerate(kept):
            # last (narrowest) kept class absorbs every smaller dropped class
            take = (pow4 <= cls) if i == len(kept) - 1 else (pow4 == cls)
            idx = np.nonzero(take)[0]
            if idx.size:
                out.append((pad_width(int(freqs[idx].max())), idx))
        return out

    # auto-tune: DP over contiguous class spans (widest class first; a
    # bucket is always a contiguous span — splitting a class never helps)
    m = len(classes)
    cls_idx = [np.nonzero(pow4 == cls)[0] for cls in classes]
    counts = np.array([len(ix) for ix in cls_idx], np.int64)
    widths = np.array([pad_width(int(freqs[ix].max())) for ix in cls_idx],
                      np.int64)
    csum = np.concatenate([[0], np.cumsum(counts)])

    def span_cells(a: int, b: int) -> int:
        # one bucket over classes a..b-1 pads every row to widths[a]
        return int(widths[a] * (csum[b] - csum[a]))

    inf = float("inf")
    best = [[inf] * (m + 1) for _ in range(m + 1)]
    cut = [[0] * (m + 1) for _ in range(m + 1)]
    best[0][0] = 0.0
    for k in range(1, m + 1):
        for j in range(k, m + 1):
            for a in range(k - 1, j):
                cand = best[k - 1][a] + span_cells(a, j)
                if cand < best[k][j]:
                    best[k][j] = cand
                    cut[k][j] = a
    k_best = min(range(1, m + 1),
                 key=lambda k: best[k][m] + k * BUCKET_OVERHEAD_CELLS)

    bounds = [m]
    j = m
    for k in range(k_best, 0, -1):
        j = cut[k][j]
        bounds.append(j)
    bounds.reverse()  # [0, ..., m]
    out = []
    for a, b in zip(bounds[:-1], bounds[1:]):
        idx = np.concatenate([cls_idx[i] for i in range(a, b)])
        out.append((int(widths[a]), np.sort(idx)))
    return out


def build_parallel_batch(ell_rows: jax.Array, boff_rows: jax.Array,
                         n_total: int) -> SubTreeNodes:
    """vmapped :func:`build_parallel` over (P, F_pad) padded rows.

    Deliberately NOT wrapped in ``jax.jit``: XLA:CPU expands the sparse
    table's dynamic-index gathers pathologically when the table is an
    intra-module value (minutes of compile at F_pad ~ 1k); eager vmap
    dispatches the same ops with per-op compiles and runs in well under a
    second at that size.  Revisit behind a flag if a TPU profile shows the
    dispatch overhead matters there.
    """
    return jax.vmap(lambda e, b: build_parallel(e, b, n_total))(
        ell_rows, boff_rows)


# ---------------------------------------------------------------------------
# Word-key node build: divergence depths recomputed from the TEXT
# ---------------------------------------------------------------------------
# The stored ``b_off`` rows are free, but they pin the node build to the
# construction state layout.  For adjacent leaves of one sub-tree the
# divergence depth IS the pairwise suffix LCP (areas only ever split in
# place, so the boundary B entry records exactly where the neighboring
# suffixes diverge) — which the word-compare currency recomputes straight
# from the dense text: gathered uint32 word rows + the
# ``lcp_adjacent_words`` XOR/clz/terminal-limit rules, no byte repack.
# ``REPRO_WORD_COMPARE=byte`` (or a byte string) pins the byte-key oracle
# through the same dispatch; results are bit-identical either way.


def lcp_from_text(s_text, pos_a, pos_b, *, w0: int = 64, w_cap: int = 256,
                  max_rounds: int = 10_000) -> np.ndarray:
    """Pairwise suffix LCP (in symbols) recomputed from the text.

    ``pos_a``/``pos_b``: int position arrays of DISTINCT suffixes (a pair
    of equal positions never terminates — the caller masks those out).
    Probes :func:`repro.kernels.ops.suffix_lcp_pairs` windows and doubles
    the window up to ``w_cap`` while pairs saturate; still-saturated
    pairs advance by the window and re-probe, so total work per pair is
    O(lcp).  Pending pairs are padded to a power of two so the jitted
    probe compiles ~log2 shapes, not one per round.
    """
    pos_a = np.asarray(pos_a, np.int64)
    pos_b = np.asarray(pos_b, np.int64)
    acc = np.zeros(pos_a.size, np.int64)
    pending = np.arange(pos_a.size)
    w = max(4, (w0 + 3) // 4 * 4)
    rounds = 0
    while pending.size:
        if rounds >= max_rounds:
            raise RuntimeError(
                f"lcp_from_text failed to resolve {pending.size} pairs "
                f"after {rounds} rounds (equal positions in the input?)")
        size = 1 << max(int(pending.size) - 1, 0).bit_length()
        sel = np.zeros(size, np.int64)  # pad rows probe pair (0, 0)
        sel[: pending.size] = pending
        a = jnp.asarray(pos_a[sel] + acc[sel], jnp.int32)
        b = jnp.asarray(pos_b[sel] + acc[sel], jnp.int32)
        a = jnp.where(jnp.arange(size) < pending.size, a, 0)
        b = jnp.where(jnp.arange(size) < pending.size, b, 0)
        from repro.kernels import ops as kops  # local: keep build importable
        lcp = np.asarray(kops.suffix_lcp_pairs(s_text, a, b,
                                               w))[: pending.size]
        acc[pending] += lcp
        pending = pending[lcp == w]  # saturated windows continue deeper
        w = min(w * 2, max(4, (w_cap + 3) // 4 * 4))
        rounds += 1
    return acc


def boff_rows_from_text(s_text, ell_rows, n_total: int) -> jax.Array:
    """(P, F_pad) divergence rows for :func:`build_parallel_batch`,
    recomputed from the text instead of gathered from stored ``b_off``.

    Padded cells carry ``ell = n_total`` (the depth-0 padding
    convention); any pair touching one keeps ``b_off = 0``, and column 0
    is the builder's sentinel slot either way.  Bit-identical node sets
    to the state-backed rows (pinned by tests/test_batched_build.py).
    """
    e = np.asarray(ell_rows, np.int64)
    p, f_pad = e.shape
    boff = np.zeros((p, f_pad), np.int32)
    if f_pad >= 2:
        a = e[:, :-1].reshape(-1)
        b = e[:, 1:].reshape(-1)
        real = (a < n_total) & (b < n_total)
        idx = np.nonzero(real)[0]
        lcp = np.zeros(a.size, np.int64)
        if idx.size:
            lcp[idx] = lcp_from_text(s_text, a[idx], b[idx])
        boff[:, 1:] = lcp.reshape(p, f_pad - 1).astype(np.int32)
    return jnp.asarray(boff)


def build_parallel_batch_from_text(s_text, ell_rows, n_total: int
                                   ) -> SubTreeNodes:
    """The word-key bucketed builder: vmapped Cartesian-tree build whose
    divergence depths come straight from the text (word currency)."""
    boff_rows = boff_rows_from_text(s_text, ell_rows, n_total)
    return build_parallel_batch(jnp.asarray(ell_rows), boff_rows, n_total)


def unpad_nodes_row(parent_row: np.ndarray, depth_row: np.ndarray,
                    witness_row: np.ndarray, f: int) -> SubTreeNodes:
    """Extract the compact 2f-slot node set of one sub-tree from a padded
    builder row (host numpy; arrays must already be on host).

    Row-space ids: leaves ``0..f-1`` (kept), internal ``F_pad + j`` for
    canonical events ``j`` in ``1..f-1`` (→ ``f + j``), and the artificial
    depth-0 root ``F_pad + f`` (→ ``f``, the slot event 0 never uses).
    """
    f_pad = len(parent_row) // 2
    cap = 2 * f

    def remap(v):
        v = np.asarray(v, np.int64)
        out = np.where(v == f_pad + f, f, np.where(v >= f_pad, v - f_pad + f, v))
        return out.astype(np.int32)

    parent = np.full(cap, -1, np.int32)
    depth = np.zeros(cap, np.int32)
    witness = np.full(cap, -1, np.int32)
    parent[:f] = remap(parent_row[:f])
    depth[:f] = depth_row[:f]
    witness[:f] = witness_row[:f]

    ev = np.arange(1, f + 1)            # candidate canonical events + root
    row_ids = f_pad + ev
    valid = witness_row[row_ids] >= 0   # written iff the event is canonical
    ev = ev[valid]
    lid = np.where(ev == f, f, f + ev)
    parent[lid] = remap(parent_row[f_pad + ev])
    depth[lid] = depth_row[f_pad + ev]
    witness[lid] = witness_row[f_pad + ev]
    return SubTreeNodes(parent, depth, witness, f + int(valid.sum()), f)


# ---------------------------------------------------------------------------
# Canonicalization for testing: node set -> (l, r, depth) intervals
# ---------------------------------------------------------------------------

def nodes_to_intervals(nodes: SubTreeNodes):
    """Internal-node intervals (leftmost leaf, rightmost leaf + 1, depth)."""
    nodes = nodes_to_host(nodes)
    parent = nodes.parent
    depth = nodes.depth
    f = nodes.n_leaves
    cap = len(parent)
    lo = np.full(cap, np.iinfo(np.int64).max)
    hi = np.full(cap, -1)
    used = np.zeros(cap, dtype=bool)
    for leaf in range(f):
        v = leaf
        steps = 0
        while v != -1:
            if steps > cap:
                raise RuntimeError(f"parent cycle detected at leaf {leaf}")
            lo[v] = min(lo[v], leaf)
            hi[v] = max(hi[v], leaf)
            used[v] = True
            v = int(parent[v])
            steps += 1
    out = []
    for v in range(f, cap):
        if used[v] and hi[v] >= lo[v] and (hi[v] > lo[v] or f == 1):
            out.append((int(lo[v]), int(hi[v]) + 1, int(depth[v])))
    # A depth-0 (0, f) node is an artificial unary super-root iff another
    # node also spans all leaves (at the true minimum divergence depth).
    has_real_root = any(l == 0 and r == f and d > 0 for (l, r, d) in out)
    if has_real_root:
        out = [iv for iv in out if iv != (0, f, 0)]
    return sorted(out)
