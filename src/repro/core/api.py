"""EraIndexer — the end-to-end serial ERA pipeline (paper §4).

vertical partitioning → grouping → per-group elastic-range SubTreePrepare →
BuildSubTree → assembled :class:`SuffixTreeIndex`.

The parallel drivers (shared-memory / shared-nothing analogues) live in
:mod:`repro.launch.era_run`; they reuse exactly these stages, distributing
groups over devices/workers.  The serving-side counterpart is
:meth:`EraIndexer.build_device` / :meth:`SuffixTreeIndex.to_device`, which
flatten the finished index into the device-resident batched query engine
(:mod:`repro.core.query`) driven by :mod:`repro.launch.query_serve`.
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core import build as build_mod
from repro.core.alphabet import Alphabet
from repro.core.prepare import (
    ElasticConfig,
    PrepareStats,
    segments_of,
    subtree_prepare,
)
from repro.core.suffix_tree import SubTree, SuffixTreeIndex
from repro.core.vertical import VerticalStats, vertical_partition_grouped

NODE_BYTES = 16  # sizeof(tree_node): parent + depth + witness + pad (SoA)


@dataclasses.dataclass(frozen=True)
class EraConfig:
    """Memory-budget and strategy knobs (paper §4.4 memory allocation)."""

    memory_bytes: int = 64 << 20   # total budget; 60% to the sub-tree (MTS)
    r_bytes: int = 1 << 20         # |R| read buffer (32MB DNA / 256MB protein in paper)
    w_min: int = 4
    w_max: int = 256
    elastic: bool = True
    static_w: int = 16             # used when elastic=False (Fig. 9b ablation)
    group: bool = True             # virtual trees on/off (Fig. 9a ablation)
    vertical_strategy: str = "histogram"  # or "positions" (beyond-paper)
    build_impl: str = "numpy"      # numpy | scan | parallel | none

    @property
    def mts_bytes(self) -> int:
        return int(0.6 * self.memory_bytes)

    @property
    def f_max(self) -> int:
        """Eq. 1: F_M = MTS / (2 * sizeof(tree_node))."""
        return max(2, self.mts_bytes // (2 * NODE_BYTES))

    @property
    def r_symbols(self) -> int:
        return self.r_bytes  # 1 byte per symbol code in this implementation


@dataclasses.dataclass
class BuildReport:
    vertical: VerticalStats
    prepare: PrepareStats
    n_prefixes: int = 0
    n_groups: int = 0
    f_max: int = 0
    t_vertical: float = 0.0
    t_prepare: float = 0.0
    t_build: float = 0.0

    @property
    def t_total(self) -> float:
        return self.t_vertical + self.t_prepare + self.t_build


_BUILDERS = {
    "numpy": lambda ell, b, n: build_mod.build_numpy(np.asarray(ell), np.asarray(b), n),
    "scan": lambda ell, b, n: build_mod.build_scan(jnp.asarray(ell), jnp.asarray(b), n),
    "parallel": lambda ell, b, n: build_mod.build_parallel(jnp.asarray(ell), jnp.asarray(b), n),
}


class EraIndexer:
    def __init__(self, alphabet: Alphabet, config: EraConfig = EraConfig()):
        self.alphabet = alphabet
        self.config = config

    def partition(self, s: np.ndarray, report: BuildReport | None = None):
        """Vertical partitioning + grouping (the master-node phase)."""
        cfg = self.config
        vstats = report.vertical if report else VerticalStats()
        t0 = time.perf_counter()
        groups = vertical_partition_grouped(
            s,
            base=self.alphabet.base,
            f_max=cfg.f_max,
            strategy=cfg.vertical_strategy,
            group=cfg.group,
            stats=vstats,
        )
        if report:
            report.t_vertical = time.perf_counter() - t0
            report.n_groups = len(groups)
            report.n_prefixes = sum(len(g.prefixes) for g in groups)
            report.f_max = cfg.f_max
        return groups

    def process_group(self, s_padded, group, capacity: int,
                      pstats: PrepareStats | None = None) -> list[SubTree]:
        """SubTreePrepare + BuildSubTree for one virtual tree (worker unit)."""
        cfg = self.config
        ecfg = ElasticConfig(
            r_budget_symbols=cfg.r_symbols,
            w_min=cfg.w_min,
            w_max=cfg.w_max,
            elastic=cfg.elastic,
            static_w=cfg.static_w,
        )
        state = subtree_prepare(s_padded, group, capacity, ecfg, pstats)
        ell = np.asarray(state.L)
        b_off = np.asarray(state.b_off)
        b_c1 = np.asarray(state.b_c1)
        b_c2 = np.asarray(state.b_c2)
        out = []
        n_total = None
        for (off, f), p in zip(segments_of(group), group.prefixes):
            seg_b = b_off[off : off + f].copy()
            seg_b[0] = 0
            st = SubTree(
                prefix=p.symbols,
                ell=ell[off : off + f].copy(),
                b_off=seg_b,
                b_c1=b_c1[off : off + f].copy(),
                b_c2=b_c2[off : off + f].copy(),
            )
            out.append(st)
        return out

    def build(self, s: np.ndarray, report: BuildReport | None = None) -> SuffixTreeIndex:
        cfg = self.config
        report = report if report is not None else BuildReport(VerticalStats(), PrepareStats())
        groups = self.partition(s, report)

        capacity = min(cfg.f_max, max((g.total_freq for g in groups), default=2))
        # pad so gathers past the end stay in-bounds (terminal padding)
        s_padded = jnp.asarray(self.alphabet.pad_string(s, extra=2 * cfg.w_max + 8))

        t0 = time.perf_counter()
        subtrees: dict[tuple, SubTree] = {}
        for g in groups:
            for st in self.process_group(s_padded, g, capacity, report.prepare):
                subtrees[st.prefix] = st
        report.t_prepare = time.perf_counter() - t0

        t0 = time.perf_counter()
        if cfg.build_impl != "none":
            builder = _BUILDERS[cfg.build_impl]
            n_total = len(s)
            for st in subtrees.values():
                st.nodes = builder(st.ell.astype(np.int32), st.b_off.astype(np.int32), n_total)
        report.t_build = time.perf_counter() - t0

        return SuffixTreeIndex(s=np.asarray(s), alphabet=self.alphabet, subtrees=subtrees)

    def build_device(self, s: np.ndarray, report: BuildReport | None = None,
                     **device_kwargs):
        """Build + flatten in one step: returns ``(index, device_index)``
        where the second element is the batched query engine
        (:class:`repro.core.query.DeviceIndex`)."""
        index = self.build(s, report)
        return index, index.to_device(**device_kwargs)

    def build_analytics(self, s: np.ndarray, report: BuildReport | None = None,
                        **device_kwargs):
        """Build + flatten + LCP in one step: returns ``(index, engine)``
        where the second element is the device-resident analytics engine
        (:class:`repro.core.analytics.AnalyticsEngine`)."""
        index = self.build(s, report)
        return index, index.analytics(**device_kwargs)
