"""EraIndexer — the end-to-end ERA pipeline (paper §4 + §5).

vertical partitioning → grouping → elastic-range SubTreePrepare →
BuildSubTree → assembled :class:`SuffixTreeIndex`.

Two construction engines share every stage (``EraConfig.construction``):

* ``batched`` (default) — ALL virtual trees stacked into one padded (G, F)
  state, driven by a single jitted vmapped elastic-range loop with donated
  buffers (:func:`repro.core.prepare.subtree_prepare_batch`); the node sets
  of every sub-tree are then built in ONE vmapped Cartesian-tree call
  (:func:`repro.core.build.build_parallel_batch`).  This is the paper's §5
  parallelism made the real path — ``shard_map`` over G distributes it.
* ``serial`` — the paper-faithful §4 reference: one group at a time through
  :func:`repro.core.prepare.subtree_prepare`, per-prefix host builders.
  Results are identical array-for-array; tier-1 tests cross-check.

The parallel drivers (shared-memory / shared-nothing analogues) live in
:mod:`repro.launch.era_run`; workers consume the same batched engine.  The
serving-side counterpart is :meth:`EraIndexer.build_device`, which goes
string → :class:`repro.core.query.DeviceIndex` directly — the leaf arrays
are gathered into suffix-array order on device and the per-prefix numpy
``SubTree`` dict is never materialized (use :meth:`build` when you need the
walkable per-sub-tree form).
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import build as build_mod
from repro.core import packing
from repro.core.alphabet import Alphabet
from repro.core.prepare import (
    ElasticConfig,
    PrepareStats,
    segments_of,
    subtree_prepare,
    subtree_prepare_batch,
)
from repro.core.suffix_tree import SubTree, SuffixTreeIndex
from repro.core.vertical import VerticalStats, vertical_partition_grouped

NODE_BYTES = 16  # sizeof(tree_node): parent + depth + witness + pad (SoA)


@dataclasses.dataclass(frozen=True)
class EraConfig:
    """Memory-budget and strategy knobs (paper §4.4 memory allocation)."""

    memory_bytes: int = 64 << 20   # total budget; 60% to the sub-tree (MTS)
    r_bytes: int = 1 << 20         # |R| read buffer (32MB DNA / 256MB protein in paper)
    w_min: int = 4
    w_max: int = 256
    elastic: bool = True
    static_w: int = 16             # used when elastic=False (Fig. 9b ablation)
    group: bool = True             # virtual trees on/off (Fig. 9a ablation)
    vertical_strategy: str = "histogram"  # or "positions" (beyond-paper)
    build_impl: str = "numpy"      # numpy | scan | parallel | none; selects the
    #                                serial engine's per-prefix builder — the
    #                                batched engine always uses the vmapped
    #                                parallel builder unless "none" (skip nodes)
    construction: str = "batched"  # batched (one (G,F) loop) | serial (per group)
    packing: str = "auto"          # device string representation (paper §6.1):
    #                                auto  — dense k-bit when the alphabet is
    #                                        denser than bytes (2-bit DNA,
    #                                        4-bit protein classes), else bytes
    #                                dense — force Alphabet.dense_bits packing
    #                                bytes — one byte per symbol (reference)

    @property
    def mts_bytes(self) -> int:
        return int(0.6 * self.memory_bytes)

    @property
    def f_max(self) -> int:
        """Eq. 1: F_M = MTS / (2 * sizeof(tree_node))."""
        return max(2, self.mts_bytes // (2 * NODE_BYTES))

    @property
    def r_symbols(self) -> int:
        return self.r_bytes  # 1 byte per symbol code in this implementation

    def elastic_config(self) -> ElasticConfig:
        return ElasticConfig(
            r_budget_symbols=self.r_symbols,
            w_min=self.w_min,
            w_max=self.w_max,
            elastic=self.elastic,
            static_w=self.static_w,
        )


@dataclasses.dataclass
class BuildReport:
    vertical: VerticalStats
    prepare: PrepareStats
    n_prefixes: int = 0
    n_groups: int = 0
    f_max: int = 0
    t_vertical: float = 0.0
    t_prepare: float = 0.0
    t_build: float = 0.0

    @property
    def t_total(self) -> float:
        return self.t_vertical + self.t_prepare + self.t_build


_BUILDERS = {
    "numpy": lambda ell, b, n: build_mod.build_numpy(np.asarray(ell), np.asarray(b), n),
    "scan": lambda ell, b, n: build_mod.build_scan(jnp.asarray(ell), jnp.asarray(b), n),
    "parallel": lambda ell, b, n: build_mod.build_parallel(jnp.asarray(ell), jnp.asarray(b), n),
}


def _sorted_segments(groups):
    """(prefix, group_index, offset, freq) per sub-tree, sorted by prefix.

    Prefix-freeness makes sorted tuple order the lexicographic suffix
    order, so concatenating the leaf segments in this order yields the
    suffix array (the DeviceIndex layout).
    """
    entries = []
    for g_i, g in enumerate(groups):
        for (off, freq), p in zip(segments_of(g), g.prefixes):
            entries.append((p.symbols, g_i, off, freq))
    entries.sort(key=lambda e: e[0])
    return entries


def _entry_flat_idx(entry, f_cap: int) -> np.ndarray:
    """Indices of one sub-tree's leaf segment in the flattened (G, F) state."""
    _, g_i, off, freq = entry
    return g_i * f_cap + off + np.arange(freq, dtype=np.int64)


class EraIndexer:
    def __init__(self, alphabet: Alphabet, config: EraConfig = EraConfig()):
        self.alphabet = alphabet
        self.config = config
        if config.construction not in ("serial", "batched"):
            raise ValueError(
                f"unknown construction engine {config.construction!r}; "
                "choose 'serial' or 'batched'")
        if config.packing not in ("auto", "dense", "bytes"):
            raise ValueError(
                f"unknown packing mode {config.packing!r}; "
                "choose 'auto', 'dense' or 'bytes'")
        if config.build_impl not in (*_BUILDERS, "none"):
            # fail fast: the batched engine always uses the vmapped parallel
            # builder (unless "none"), so a typo would otherwise pass silently
            raise ValueError(
                f"unknown build_impl {config.build_impl!r}; "
                f"choose one of {sorted((*_BUILDERS, 'none'))}")

    def partition(self, s: np.ndarray, report: BuildReport | None = None):
        """Vertical partitioning + grouping (the master-node phase)."""
        cfg = self.config
        vstats = report.vertical if report else VerticalStats()
        t0 = time.perf_counter()
        with obs.tracer().span("build/vertical", n=len(s),
                               f_max=cfg.f_max) as sp:
            groups = vertical_partition_grouped(
                s,
                base=self.alphabet.base,
                f_max=cfg.f_max,
                strategy=cfg.vertical_strategy,
                group=cfg.group,
                stats=vstats,
            )
            sp.set(groups=len(groups))
        if report:
            report.t_vertical = time.perf_counter() - t0
            report.n_groups = len(groups)
            report.n_prefixes = sum(len(g.prefixes) for g in groups)
            report.f_max = cfg.f_max
        return groups

    def _capacity(self, groups) -> int:
        return min(self.config.f_max,
                   max((g.total_freq for g in groups), default=2))

    def _pad(self, s: np.ndarray) -> jnp.ndarray:
        # pad so gathers past the end stay in-bounds (terminal padding)
        return jnp.asarray(self.alphabet.pad_string(s, extra=2 * self.config.w_max + 8))

    def _device_text(self, s: np.ndarray):
        """The device-resident string for construction gathers: dense
        k-bit :class:`repro.core.packing.PackedText` (paper §6.1 — the
        default for sub-byte alphabets) or the terminal-padded byte
        array, per ``EraConfig.packing``.  Construction output is
        bit-identical either way."""
        if packing.resolve_dense(self.config.packing, self.alphabet):
            return packing.pack_text(s, self.alphabet,
                                     extra=2 * self.config.w_max + 8)
        return self._pad(s)

    # ---- worker units ------------------------------------------------------

    def process_group(self, s_padded, group, capacity: int,
                      pstats: PrepareStats | None = None,
                      group_index: int | None = None) -> list[SubTree]:
        """SubTreePrepare + slicing for ONE virtual tree (serial reference)."""
        state = subtree_prepare(s_padded, group, capacity,
                                self.config.elastic_config(), pstats,
                                group_index=group_index)
        return self._slice_subtrees(state, group)

    def process_groups(self, s_padded, groups, capacity: int,
                       pstats: PrepareStats | None = None) -> list[list[SubTree]]:
        """SubTreePrepare + slicing for MANY virtual trees through the
        shared batched (G, F) engine — one elastic loop for the whole set.
        Returns one ``list[SubTree]`` per input group."""
        states = subtree_prepare_batch(s_padded, groups, capacity,
                                       self.config.elastic_config(), pstats)
        host = _HostState(states)
        return [self._slice_subtrees(host.group(g_i), g)
                for g_i, g in enumerate(groups)]

    @staticmethod
    def _slice_subtrees(state, group) -> list[SubTree]:
        ell = np.asarray(state.L)
        b_off = np.asarray(state.b_off)
        b_c1 = np.asarray(state.b_c1)
        b_c2 = np.asarray(state.b_c2)
        out = []
        for (off, f), p in zip(segments_of(group), group.prefixes):
            seg_b = b_off[off : off + f].copy()
            seg_b[0] = 0
            out.append(SubTree(
                prefix=p.symbols,
                ell=ell[off : off + f].copy(),
                b_off=seg_b,
                b_c1=b_c1[off : off + f].copy(),
                b_c2=b_c2[off : off + f].copy(),
            ))
        return out

    # ---- full builds -------------------------------------------------------

    def build(self, s: np.ndarray, report: BuildReport | None = None) -> SuffixTreeIndex:
        report = report if report is not None else BuildReport(VerticalStats(), PrepareStats())
        with obs.tracer().span("build/total", n=len(s),
                               engine=self.config.construction):
            if self.config.construction == "batched":
                return self._build_batched(s, report)
            return self._build_serial(s, report)

    def _build_serial(self, s: np.ndarray, report: BuildReport) -> SuffixTreeIndex:
        cfg = self.config
        groups = self.partition(s, report)
        capacity = self._capacity(groups)
        s_padded = self._device_text(s)

        t0 = time.perf_counter()
        subtrees: dict[tuple, SubTree] = {}
        for g_i, g in enumerate(groups):
            for st in self.process_group(s_padded, g, capacity, report.prepare,
                                         group_index=g_i):
                subtrees[st.prefix] = st
        report.t_prepare = time.perf_counter() - t0

        t0 = time.perf_counter()
        if cfg.build_impl != "none":
            builder = _BUILDERS[cfg.build_impl]
            n_total = len(s)
            for st in subtrees.values():
                st.nodes = builder(st.ell.astype(np.int32), st.b_off.astype(np.int32), n_total)
        report.t_build = time.perf_counter() - t0

        return SuffixTreeIndex(s=np.asarray(s), alphabet=self.alphabet, subtrees=subtrees)

    def _prepare_batched(self, s: np.ndarray, report: BuildReport):
        """partition → padded (G, F) batched prepare, timing into ``report``.

        Returns (groups, states); states is None when the string produced
        no groups (cannot happen for a non-empty terminated string).
        """
        groups = self.partition(s, report)
        if not groups:
            return groups, None
        capacity = self._capacity(groups)
        s_padded = self._device_text(s)
        t0 = time.perf_counter()
        states = subtree_prepare_batch(s_padded, groups, capacity,
                                       self.config.elastic_config(),
                                       report.prepare)
        report.t_prepare = time.perf_counter() - t0
        return groups, states

    def _build_batched(self, s: np.ndarray, report: BuildReport) -> SuffixTreeIndex:
        cfg = self.config
        groups, states = self._prepare_batched(s, report)
        subtrees: dict[tuple, SubTree] = {}
        if states is not None:
            t0 = time.perf_counter()
            host = _HostState(states)
            for g_i, g in enumerate(groups):
                for st in self._slice_subtrees(host.group(g_i), g):
                    subtrees[st.prefix] = st
            report.t_prepare += time.perf_counter() - t0

            t0 = time.perf_counter()
            if cfg.build_impl != "none":
                with obs.tracer().span("build/nodes",
                                       subtrees=len(subtrees)):
                    self._attach_nodes_batched(states, groups, subtrees,
                                               len(s))
            report.t_build = time.perf_counter() - t0

        return SuffixTreeIndex(s=np.asarray(s), alphabet=self.alphabet, subtrees=subtrees)

    def _attach_nodes_batched(self, states, groups, subtrees, n_total: int) -> None:
        """All sub-trees' node sets via size-bucketed vmapped builds.

        Per-prefix (ell, b_off) segments are gathered on device into padded
        rows (depth-0 padding — see repro.core.build) and built with the
        vmapped parallel Cartesian-tree builder.  Rows are grouped into
        pad-width buckets whose COUNT is auto-tuned from the freq
        histogram (:func:`repro.core.build.bucket_pad_widths`: uniform
        mixes collapse to one bucket, heavy-tailed mixes split until
        another vmapped dispatch stops paying) instead of padding every
        row to the global max freq — on skewed prefix mixes the narrow
        buckets hold most rows at a fraction of the padded work, with
        bit-identical node sets per row either way.
        """
        entries = _sorted_segments(groups)
        f_cap = states.L.shape[1]
        flat_L = states.L.reshape(-1)
        flat_b = states.b_off.reshape(-1)
        fill_hist = obs.metrics().histogram(
            "build_bucket_fill_ratio",
            buckets=(0.1, 0.25, 0.5, 0.75, 0.9, 1.0),
            help="real cells / padded cells per node-build bucket "
                 "(low = the pow2 padding is wasting vmapped work)")
        for f_pad, rows in build_mod.bucket_pad_widths(
                [e[3] for e in entries]):
            fill = 0.0
            if obs.metrics_enabled() or obs.trace_enabled():
                real_cells = sum(entries[e_i][3] for e_i in rows)
                fill = real_cells / (len(rows) * f_pad)
                fill_hist.observe(fill)
            with obs.tracer().span("build/node_bucket", f_pad=f_pad,
                                   rows=len(rows), fill=round(fill, 4)):
                idx = np.zeros((len(rows), f_pad), np.int64)
                mask = np.zeros((len(rows), f_pad), bool)
                for r, e_i in enumerate(rows):
                    freq = entries[e_i][3]
                    idx[r, :freq] = _entry_flat_idx(entries[e_i], f_cap)
                    mask[r, :freq] = True
                idx = jnp.asarray(idx, jnp.int32)
                mask = jnp.asarray(mask)
                ell_rows = jnp.where(mask, jnp.take(flat_L, idx), n_total)
                boff_rows = jnp.where(mask, jnp.take(flat_b, idx), 0)
                nodes = build_mod.build_parallel_batch(ell_rows, boff_rows,
                                                       n_total)
                parent = np.asarray(nodes.parent)
                depth = np.asarray(nodes.depth)
                witness = np.asarray(nodes.witness)
                for r, e_i in enumerate(rows):
                    prefix, _, _, freq = entries[e_i]
                    subtrees[prefix].nodes = build_mod.unpad_nodes_row(
                        parent[r], depth[r], witness[r], freq)

    def build_device(self, s: np.ndarray, report: BuildReport | None = None,
                     **device_kwargs):
        """String → :class:`repro.core.query.DeviceIndex` (the flattened
        batched query engine).

        With the batched engine the leaf arrays go straight from the
        (G, F) prepare state into suffix-array order with one device
        gather — no per-prefix numpy ``SubTree`` dict, no node build.  The
        serial engine builds the full index first and flattens it.
        ``device_kwargs``: ``route_cap``, ``max_pattern_len``, ``packing``
        (defaults to this indexer's ``EraConfig.packing``, so a dense
        build serves from the dense string).
        """
        report = report if report is not None else BuildReport(VerticalStats(), PrepareStats())
        device_kwargs.setdefault("packing", self.config.packing)
        if self.config.construction != "batched":
            return self.build(s, report).to_device(**device_kwargs)

        from repro.core.query import DeviceIndex  # local: avoid import cycle

        groups, states = self._prepare_batched(s, report)
        if states is None:
            raise ValueError("cannot flatten an empty index")
        entries = _sorted_segments(groups)
        f_cap = states.L.shape[1]
        flat_idx = np.concatenate([_entry_flat_idx(e, f_cap) for e in entries])
        ell = jnp.take(states.L.reshape(-1), jnp.asarray(flat_idx, jnp.int32))
        return DeviceIndex.from_prepare(
            alphabet=self.alphabet,
            s=np.asarray(s),
            prefixes=[e[0] for e in entries],
            freqs=np.array([e[3] for e in entries], np.int32),
            ell=ell,
            **device_kwargs,
        )

    def build_sharded(self, s: np.ndarray, n_shards: int | None = None,
                      report: BuildReport | None = None, *,
                      mesh=None, sort_fuse: bool = True, **device_kwargs):
        """String → :class:`repro.core.fabric.ShardedIndex`: SPMD
        construction over the device mesh, then the flattened leaf
        arrays sharded by top-trie route key.

        ``n_shards`` defaults to the mesh size (all local devices); the
        construction mesh and the index shard count are independent —
        group blocks parallelize the elastic loop, route-key shards
        partition the query fabric.  Results are bit-identical to
        :meth:`build_device` (same flatten, same probe) — see
        tests/test_fabric.py.
        """
        from repro.core import fabric  # local: avoid import cycle

        report = report if report is not None else BuildReport(
            VerticalStats(), PrepareStats())
        device_kwargs.setdefault("packing", self.config.packing)
        mesh = mesh or fabric.fabric_mesh()
        if n_shards is None:
            n_shards = mesh.devices.size
        groups = self.partition(s, report)
        if not groups:
            raise ValueError("cannot shard an empty index")
        capacity = self._capacity(groups)
        s_padded = self._device_text(s)
        t0 = time.perf_counter()
        states = fabric.sharded_prepare(
            s_padded, groups, capacity, self.config.elastic_config(),
            mesh=mesh, stats=report.prepare, sort_fuse=sort_fuse)
        report.t_prepare = time.perf_counter() - t0
        entries = _sorted_segments(groups)
        f_cap = states.L.shape[1]
        flat_idx = np.concatenate([_entry_flat_idx(e, f_cap) for e in entries])
        ell = jnp.take(states.L.reshape(-1), jnp.asarray(flat_idx, jnp.int32))
        return fabric.ShardedIndex.from_flat(
            alphabet=self.alphabet, s=np.asarray(s),
            prefixes=[e[0] for e in entries],
            freqs=np.array([e[3] for e in entries], np.int32),
            ell=ell, n_shards=n_shards, **device_kwargs)

    def build_analytics(self, s: np.ndarray, report: BuildReport | None = None,
                        **device_kwargs):
        """Build + flatten + LCP in one step: returns ``(index, engine)``
        where the second element is the device-resident analytics engine
        (:class:`repro.core.analytics.AnalyticsEngine`).  Flattening
        kwargs default ``packing`` to this indexer's config."""
        index = self.build(s, report)
        if device_kwargs or self.config.packing != "auto":
            # honor a non-default packing even on the no-kwargs path (the
            # engine is then built uncached; "auto" keeps the shared cache,
            # whose default is the same "auto")
            device_kwargs.setdefault("packing", self.config.packing)
        return index, index.analytics(**device_kwargs)


class _HostState:
    """One bulk device→host transfer of a (G, F) state, sliceable per group."""

    def __init__(self, states):
        self.L = np.asarray(states.L)
        self.b_off = np.asarray(states.b_off)
        self.b_c1 = np.asarray(states.b_c1)
        self.b_c2 = np.asarray(states.b_c2)

    def group(self, g_i: int) -> "_HostState":
        view = object.__new__(_HostState)
        view.L = self.L[g_i]
        view.b_off = self.b_off[g_i]
        view.b_c1 = self.b_c1[g_i]
        view.b_c2 = self.b_c2[g_i]
        return view
