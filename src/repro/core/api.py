"""EraIndexer — the end-to-end ERA pipeline (paper §4 + §5).

vertical partitioning → grouping → elastic-range SubTreePrepare →
BuildSubTree → assembled :class:`SuffixTreeIndex`.

Two construction engines share every stage (``EraConfig.construction``):

* ``batched`` (default) — ALL virtual trees stacked into one padded (G, F)
  state, driven by a single jitted vmapped elastic-range loop with donated
  buffers (:func:`repro.core.prepare.subtree_prepare_batch`); the node sets
  of every sub-tree are then built in ONE vmapped Cartesian-tree call
  (:func:`repro.core.build.build_parallel_batch`).  This is the paper's §5
  parallelism made the real path — ``shard_map`` over G distributes it.
* ``serial`` — the paper-faithful §4 reference: one group at a time through
  :func:`repro.core.prepare.subtree_prepare`, per-prefix host builders.
  Results are identical array-for-array; tier-1 tests cross-check.

The parallel drivers (shared-memory / shared-nothing analogues) live in
:mod:`repro.launch.era_run`; workers consume the same batched engine.  The
serving-side counterpart is :meth:`EraIndexer.build_device`, which goes
string → :class:`repro.core.query.DeviceIndex` directly — the leaf arrays
are gathered into suffix-array order on device and the per-prefix numpy
``SubTree`` dict is never materialized (use :meth:`build` when you need the
walkable per-sub-tree form).
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import build as build_mod
from repro.core import packing
from repro.core.alphabet import Alphabet
from repro.core.prepare import (
    ElasticConfig,
    PrepareStats,
    StreamReport,
    segments_of,
    subtree_prepare,
    subtree_prepare_batch,
    subtree_prepare_stream,
)
from repro.core.suffix_tree import SubTree, SuffixTreeIndex
from repro.core.vertical import VerticalStats, vertical_partition_grouped

NODE_BYTES = 16  # sizeof(tree_node): parent + depth + witness + pad (SoA)


@dataclasses.dataclass(frozen=True)
class EraConfig:
    """Memory-budget and strategy knobs (paper §4.4 memory allocation)."""

    memory_bytes: int = 64 << 20   # total budget; 60% to the sub-tree (MTS)
    r_bytes: int = 1 << 20         # |R| read buffer (32MB DNA / 256MB protein in paper)
    w_min: int = 4
    w_max: int = 256
    elastic: bool = True
    static_w: int = 16             # used when elastic=False (Fig. 9b ablation)
    group: bool = True             # virtual trees on/off (Fig. 9a ablation)
    vertical_strategy: str = "histogram"  # or "positions" (beyond-paper)
    build_impl: str = "numpy"      # numpy | scan | parallel | none; selects the
    #                                serial engine's per-prefix builder — the
    #                                batched engine always uses the vmapped
    #                                parallel builder unless "none" (skip nodes)
    construction: str = "batched"  # batched (one (G,F) loop) | serial (per group)
    packing: str = "auto"          # device string representation (paper §6.1):
    #                                auto  — dense k-bit when the alphabet is
    #                                        denser than bytes (2-bit DNA,
    #                                        4-bit protein classes), else bytes
    #                                dense — force Alphabet.dense_bits packing
    #                                bytes — one byte per symbol (reference)
    sort_fuse: bool | None = None  # fused single-lane sort keys in the elastic
    #                                step; None = promoted default (on) unless
    #                                REPRO_SORT=lexsort pins the oracle
    compaction: bool | None = None  # tail compaction (sort only still-active
    #                                rows); None = promoted default (on) unless
    #                                REPRO_COMPACT=off pins the oracle
    node_lcp: str = "state"        # node-build divergence source:
    #                                state — stored b_off from the prepare
    #                                        state (free, the default)
    #                                words — recomputed from the text via the
    #                                        word-compare LCP (bit-identical;
    #                                        decouples the Cartesian-tree pass
    #                                        from the construction state)

    @property
    def mts_bytes(self) -> int:
        return int(0.6 * self.memory_bytes)

    @property
    def f_max(self) -> int:
        """Eq. 1: F_M = MTS / (2 * sizeof(tree_node))."""
        return max(2, self.mts_bytes // (2 * NODE_BYTES))

    @property
    def r_symbols(self) -> int:
        return self.r_bytes  # 1 byte per symbol code in this implementation

    def elastic_config(self) -> ElasticConfig:
        return ElasticConfig(
            r_budget_symbols=self.r_symbols,
            w_min=self.w_min,
            w_max=self.w_max,
            elastic=self.elastic,
            static_w=self.static_w,
        )


@dataclasses.dataclass
class BuildReport:
    vertical: VerticalStats
    prepare: PrepareStats
    n_prefixes: int = 0
    n_groups: int = 0
    f_max: int = 0
    t_vertical: float = 0.0
    t_prepare: float = 0.0
    t_build: float = 0.0

    @property
    def t_total(self) -> float:
        return self.t_vertical + self.t_prepare + self.t_build


@dataclasses.dataclass
class AppendReport:
    """Accounting for one incremental append (build only the affected
    sub-trees, reuse every untouched leaf segment)."""

    n_old: int = 0             # |S_old| real symbols
    n_new: int = 0             # |S_new| real symbols
    b_star: int = 0            # start of the terminal-affected suffix tail
    n_prefixes: int = 0        # sub-trees in the merged index
    n_affected: int = 0        # sub-trees rebuilt
    leaves_rebuilt: int = 0
    leaves_reused: int = 0
    t_scan: float = 0.0        # terminal-affected boundary scan (queries)
    partition_fallback: bool = False  # delta changed the split structure
    t_partition: float = 0.0
    t_prepare: float = 0.0     # elastic-range loop over affected groups
    t_merge: float = 0.0

    @property
    def t_total(self) -> float:
        return self.t_scan + self.t_partition + self.t_prepare + self.t_merge

    @property
    def reuse_frac(self) -> float:
        total = self.leaves_rebuilt + self.leaves_reused
        return self.leaves_reused / total if total else 0.0


def _terminal_affected_start(count_fn, s_new: np.ndarray, n_old_real: int,
                             max_plen: int, batch: int = 64) -> int:
    """First position ``b*`` of the terminal-affected suffix tail.

    Replacing the old terminal with appended symbols can only reorder a
    sub-tree if some pair of its suffixes used to diverge AT the old
    terminal — i.e. the later suffix's whole tail ``S_old[b:]`` occurs at
    least twice in ``S_old``.  That predicate is suffix-closed (if a tail
    repeats, every shorter tail repeats too), so the affected positions
    form one contiguous range ``[b*, n_old_real)`` found by a backward
    scan of count queries against the OLD index — O(log n) queries on
    random text.  Tails longer than the index's ``max_pattern_len`` are
    checked on their truncated prefix: count < 2 there proves the full
    tail unique (necessary condition), count >= 2 is treated as affected
    (conservative, never unsound).
    """
    cap = max(4, max_plen // 4 * 4)  # stays under pad_batch's width check
    b = n_old_real - 1
    while b >= 0:
        bs = list(range(b, max(b - batch, -1), -1))
        pats = [np.asarray(s_new[bb:min(n_old_real, bb + cap)],
                           np.int32) for bb in bs]
        counts = count_fn(pats)
        for bb, c in zip(bs, counts):
            if int(c) < 2:
                return bb + 1
        b -= batch
    return 0


_BUILDERS = {
    "numpy": lambda ell, b, n: build_mod.build_numpy(np.asarray(ell), np.asarray(b), n),
    "scan": lambda ell, b, n: build_mod.build_scan(jnp.asarray(ell), jnp.asarray(b), n),
    "parallel": lambda ell, b, n: build_mod.build_parallel(jnp.asarray(ell), jnp.asarray(b), n),
}


def _sorted_segments(groups):
    """(prefix, group_index, offset, freq) per sub-tree, sorted by prefix.

    Prefix-freeness makes sorted tuple order the lexicographic suffix
    order, so concatenating the leaf segments in this order yields the
    suffix array (the DeviceIndex layout).
    """
    entries = []
    for g_i, g in enumerate(groups):
        for (off, freq), p in zip(segments_of(g), g.prefixes):
            entries.append((p.symbols, g_i, off, freq))
    entries.sort(key=lambda e: e[0])
    return entries


def _entry_flat_idx(entry, f_cap: int) -> np.ndarray:
    """Indices of one sub-tree's leaf segment in the flattened (G, F) state."""
    _, g_i, off, freq = entry
    return g_i * f_cap + off + np.arange(freq, dtype=np.int64)


def _flatten_state(groups, states):
    """(prefixes, freqs, ell) in sorted prefix order from a final (G, F)
    prepare state — the shared flatten behind every index assembly path.
    Device states stay on device (one gather); the streaming engine's
    host (numpy) states flatten host-side."""
    entries = _sorted_segments(groups)
    f_cap = states.L.shape[1]
    flat_idx = np.concatenate([_entry_flat_idx(e, f_cap) for e in entries])
    if isinstance(states.L, np.ndarray):
        ell = states.L.reshape(-1)[flat_idx].astype(np.int32)
    else:
        ell = jnp.take(states.L.reshape(-1), jnp.asarray(flat_idx, jnp.int32))
    prefixes = [e[0] for e in entries]
    freqs = np.array([e[3] for e in entries], np.int32)
    return prefixes, freqs, ell


class EraIndexer:
    def __init__(self, alphabet: Alphabet, config: EraConfig = EraConfig()):
        self.alphabet = alphabet
        self.config = config
        if config.construction not in ("serial", "batched"):
            raise ValueError(
                f"unknown construction engine {config.construction!r}; "
                "choose 'serial' or 'batched'")
        if config.packing not in ("auto", "dense", "bytes"):
            raise ValueError(
                f"unknown packing mode {config.packing!r}; "
                "choose 'auto', 'dense' or 'bytes'")
        if config.build_impl not in (*_BUILDERS, "none"):
            # fail fast: the batched engine always uses the vmapped parallel
            # builder (unless "none"), so a typo would otherwise pass silently
            raise ValueError(
                f"unknown build_impl {config.build_impl!r}; "
                f"choose one of {sorted((*_BUILDERS, 'none'))}")
        if config.node_lcp not in ("state", "words"):
            raise ValueError(
                f"unknown node_lcp {config.node_lcp!r}; "
                "choose 'state' or 'words'")

    def partition(self, s: np.ndarray, report: BuildReport | None = None):
        """Vertical partitioning + grouping (the master-node phase)."""
        cfg = self.config
        vstats = report.vertical if report else VerticalStats()
        t0 = time.perf_counter()
        with obs.tracer().span("build/vertical", n=len(s),
                               f_max=cfg.f_max) as sp:
            groups = vertical_partition_grouped(
                s,
                base=self.alphabet.base,
                f_max=cfg.f_max,
                strategy=cfg.vertical_strategy,
                group=cfg.group,
                stats=vstats,
            )
            sp.set(groups=len(groups))
        if report:
            report.t_vertical = time.perf_counter() - t0
            report.n_groups = len(groups)
            report.n_prefixes = sum(len(g.prefixes) for g in groups)
            report.f_max = cfg.f_max
        return groups

    def _capacity(self, groups) -> int:
        return min(self.config.f_max,
                   max((g.total_freq for g in groups), default=2))

    def _pad(self, s: np.ndarray) -> jnp.ndarray:
        # pad so gathers past the end stay in-bounds (terminal padding)
        return jnp.asarray(self.alphabet.pad_string(s, extra=2 * self.config.w_max + 8))

    def _device_text(self, s: np.ndarray):
        """The device-resident string for construction gathers: dense
        k-bit :class:`repro.core.packing.PackedText` (paper §6.1 — the
        default for sub-byte alphabets) or the terminal-padded byte
        array, per ``EraConfig.packing``.  Construction output is
        bit-identical either way."""
        if packing.resolve_dense(self.config.packing, self.alphabet):
            return packing.pack_text(s, self.alphabet,
                                     extra=2 * self.config.w_max + 8)
        return self._pad(s)

    # ---- worker units ------------------------------------------------------

    def process_group(self, s_padded, group, capacity: int,
                      pstats: PrepareStats | None = None,
                      group_index: int | None = None) -> list[SubTree]:
        """SubTreePrepare + slicing for ONE virtual tree (serial reference)."""
        state = subtree_prepare(s_padded, group, capacity,
                                self.config.elastic_config(), pstats,
                                group_index=group_index)
        return self._slice_subtrees(state, group)

    def process_groups(self, s_padded, groups, capacity: int,
                       pstats: PrepareStats | None = None) -> list[list[SubTree]]:
        """SubTreePrepare + slicing for MANY virtual trees through the
        shared batched (G, F) engine — one elastic loop for the whole set.
        Returns one ``list[SubTree]`` per input group."""
        states = subtree_prepare_batch(s_padded, groups, capacity,
                                       self.config.elastic_config(), pstats,
                                       sort_fuse=self.config.sort_fuse,
                                       compact=self.config.compaction)
        host = _HostState(states)
        return [self._slice_subtrees(host.group(g_i), g)
                for g_i, g in enumerate(groups)]

    @staticmethod
    def _slice_subtrees(state, group) -> list[SubTree]:
        ell = np.asarray(state.L)
        b_off = np.asarray(state.b_off)
        b_c1 = np.asarray(state.b_c1)
        b_c2 = np.asarray(state.b_c2)
        out = []
        for (off, f), p in zip(segments_of(group), group.prefixes):
            seg_b = b_off[off : off + f].copy()
            seg_b[0] = 0
            out.append(SubTree(
                prefix=p.symbols,
                ell=ell[off : off + f].copy(),
                b_off=seg_b,
                b_c1=b_c1[off : off + f].copy(),
                b_c2=b_c2[off : off + f].copy(),
            ))
        return out

    # ---- full builds -------------------------------------------------------

    def build(self, s: np.ndarray, report: BuildReport | None = None) -> SuffixTreeIndex:
        report = report if report is not None else BuildReport(VerticalStats(), PrepareStats())
        with obs.tracer().span("build/total", n=len(s),
                               engine=self.config.construction):
            if self.config.construction == "batched":
                return self._build_batched(s, report)
            return self._build_serial(s, report)

    def _build_serial(self, s: np.ndarray, report: BuildReport) -> SuffixTreeIndex:
        cfg = self.config
        groups = self.partition(s, report)
        capacity = self._capacity(groups)
        s_padded = self._device_text(s)

        t0 = time.perf_counter()
        subtrees: dict[tuple, SubTree] = {}
        for g_i, g in enumerate(groups):
            for st in self.process_group(s_padded, g, capacity, report.prepare,
                                         group_index=g_i):
                subtrees[st.prefix] = st
        report.t_prepare = time.perf_counter() - t0

        t0 = time.perf_counter()
        if cfg.build_impl != "none":
            builder = _BUILDERS[cfg.build_impl]
            n_total = len(s)
            for st in subtrees.values():
                st.nodes = builder(st.ell.astype(np.int32), st.b_off.astype(np.int32), n_total)
        report.t_build = time.perf_counter() - t0

        return SuffixTreeIndex(s=np.asarray(s), alphabet=self.alphabet, subtrees=subtrees)

    def _prepare_batched(self, s: np.ndarray, report: BuildReport):
        """partition → padded (G, F) batched prepare, timing into ``report``.

        Returns (groups, states, s_padded); states is None when the string
        produced no groups (cannot happen for a non-empty terminated
        string).  ``s_padded`` is the device text the prepare ran on, so
        downstream stages (the word-key node build) reuse it instead of
        re-packing.
        """
        groups = self.partition(s, report)
        if not groups:
            return groups, None, None
        capacity = self._capacity(groups)
        s_padded = self._device_text(s)
        t0 = time.perf_counter()
        states = subtree_prepare_batch(s_padded, groups, capacity,
                                       self.config.elastic_config(),
                                       report.prepare,
                                       sort_fuse=self.config.sort_fuse,
                                       compact=self.config.compaction)
        report.t_prepare = time.perf_counter() - t0
        return groups, states, s_padded

    def _build_batched(self, s: np.ndarray, report: BuildReport) -> SuffixTreeIndex:
        cfg = self.config
        groups, states, s_padded = self._prepare_batched(s, report)
        subtrees: dict[tuple, SubTree] = {}
        if states is not None:
            t0 = time.perf_counter()
            host = _HostState(states)
            for g_i, g in enumerate(groups):
                for st in self._slice_subtrees(host.group(g_i), g):
                    subtrees[st.prefix] = st
            report.t_prepare += time.perf_counter() - t0

            t0 = time.perf_counter()
            if cfg.build_impl != "none":
                with obs.tracer().span("build/nodes",
                                       subtrees=len(subtrees),
                                       node_lcp=cfg.node_lcp):
                    self._attach_nodes_batched(states, groups, subtrees,
                                               len(s), s_text=s_padded)
            report.t_build = time.perf_counter() - t0

        return SuffixTreeIndex(s=np.asarray(s), alphabet=self.alphabet, subtrees=subtrees)

    def _attach_nodes_batched(self, states, groups, subtrees, n_total: int,
                              s_text=None) -> None:
        """All sub-trees' node sets via size-bucketed vmapped builds.

        Per-prefix (ell, b_off) segments are gathered on device into padded
        rows (depth-0 padding — see repro.core.build) and built with the
        vmapped parallel Cartesian-tree builder.  Rows are grouped into
        pad-width buckets whose COUNT is auto-tuned from the freq
        histogram (:func:`repro.core.build.bucket_pad_widths`: uniform
        mixes collapse to one bucket, heavy-tailed mixes split until
        another vmapped dispatch stops paying) instead of padding every
        row to the global max freq — on skewed prefix mixes the narrow
        buckets hold most rows at a fraction of the padded work, with
        bit-identical node sets per row either way.

        With ``EraConfig.node_lcp="words"`` (and a device text) the
        divergence rows come from the word-compare LCP on the text
        (:func:`repro.core.build.boff_rows_from_text`) instead of the
        stored ``b_off`` — bit-identical node sets, no dependence on the
        construction state's B entries.
        """
        use_words = self.config.node_lcp == "words" and s_text is not None
        entries = _sorted_segments(groups)
        f_cap = states.L.shape[1]
        flat_L = states.L.reshape(-1)
        flat_b = states.b_off.reshape(-1)
        fill_hist = obs.metrics().histogram(
            "build_bucket_fill_ratio",
            buckets=(0.1, 0.25, 0.5, 0.75, 0.9, 1.0),
            help="real cells / padded cells per node-build bucket "
                 "(low = the pow2 padding is wasting vmapped work)")
        for f_pad, rows in build_mod.bucket_pad_widths(
                [e[3] for e in entries]):
            fill = 0.0
            if obs.metrics_enabled() or obs.trace_enabled():
                real_cells = sum(entries[e_i][3] for e_i in rows)
                fill = real_cells / (len(rows) * f_pad)
                fill_hist.observe(fill)
            with obs.tracer().span("build/node_bucket", f_pad=f_pad,
                                   rows=len(rows), fill=round(fill, 4)):
                idx = np.zeros((len(rows), f_pad), np.int64)
                mask = np.zeros((len(rows), f_pad), bool)
                for r, e_i in enumerate(rows):
                    freq = entries[e_i][3]
                    idx[r, :freq] = _entry_flat_idx(entries[e_i], f_cap)
                    mask[r, :freq] = True
                idx = jnp.asarray(idx, jnp.int32)
                mask = jnp.asarray(mask)
                ell_rows = jnp.where(mask, jnp.take(flat_L, idx), n_total)
                if use_words:
                    boff_rows = build_mod.boff_rows_from_text(
                        s_text, ell_rows, n_total)
                else:
                    boff_rows = jnp.where(mask, jnp.take(flat_b, idx), 0)
                nodes = build_mod.build_parallel_batch(ell_rows, boff_rows,
                                                       n_total)
                parent = np.asarray(nodes.parent)
                depth = np.asarray(nodes.depth)
                witness = np.asarray(nodes.witness)
                for r, e_i in enumerate(rows):
                    prefix, _, _, freq = entries[e_i]
                    subtrees[prefix].nodes = build_mod.unpad_nodes_row(
                        parent[r], depth[r], witness[r], freq)

    def build_device(self, s: np.ndarray, report: BuildReport | None = None,
                     **device_kwargs):
        """String → :class:`repro.core.query.DeviceIndex` (the flattened
        batched query engine).

        With the batched engine the leaf arrays go straight from the
        (G, F) prepare state into suffix-array order with one device
        gather — no per-prefix numpy ``SubTree`` dict, no node build.  The
        serial engine builds the full index first and flattens it.
        ``device_kwargs``: ``route_cap``, ``max_pattern_len``, ``packing``
        (defaults to this indexer's ``EraConfig.packing``, so a dense
        build serves from the dense string).
        """
        report = report if report is not None else BuildReport(VerticalStats(), PrepareStats())
        device_kwargs.setdefault("packing", self.config.packing)
        if self.config.construction != "batched":
            return self.build(s, report).to_device(**device_kwargs)

        from repro.core.query import DeviceIndex  # local: avoid import cycle

        groups, states, _ = self._prepare_batched(s, report)
        if states is None:
            raise ValueError("cannot flatten an empty index")
        prefixes, freqs, ell = _flatten_state(groups, states)
        return DeviceIndex.from_prepare(
            alphabet=self.alphabet,
            s=np.asarray(s),
            prefixes=prefixes,
            freqs=freqs,
            ell=ell,
            **device_kwargs,
        )

    def build_stream(self, s: np.ndarray, report: BuildReport | None = None,
                     *, device_budget: int | None = None,
                     overlap: bool = True,
                     stream_report: StreamReport | None = None,
                     **device_kwargs):
        """String → :class:`repro.core.query.DeviceIndex` through the
        out-of-core streaming pipeline.

        Vertical-partition groups are sliced into chunks whose
        double-buffered (G_chunk, F) state fits ``device_budget`` bytes
        (:func:`repro.core.iomodel.plan_stream`), and the host→device
        copy of chunk k+1 overlaps the elastic-range loop of chunk k
        (:func:`repro.core.prepare.subtree_prepare_stream`).  The result
        is bit-identical to :meth:`build_device` — range choice never
        changes results — while peak device state is ~``2/n_chunks`` of
        the one-shot build's.  Returns ``(index, stream_report)``.
        """
        from repro.core.query import DeviceIndex  # local: avoid import cycle

        report = report if report is not None else BuildReport(
            VerticalStats(), PrepareStats())
        device_kwargs.setdefault("packing", self.config.packing)
        groups = self.partition(s, report)
        if not groups:
            raise ValueError("cannot flatten an empty index")
        capacity = self._capacity(groups)
        s_padded = self._device_text(s)
        t0 = time.perf_counter()
        states, srep = subtree_prepare_stream(
            s_padded, groups, capacity, self.config.elastic_config(),
            device_budget=device_budget, overlap=overlap,
            stats=report.prepare, report=stream_report,
            sort_fuse=self.config.sort_fuse,
            compact=self.config.compaction)
        report.t_prepare = time.perf_counter() - t0
        prefixes, freqs, ell = _flatten_state(groups, states)
        dev = DeviceIndex.from_prepare(
            alphabet=self.alphabet, s=np.asarray(s), prefixes=prefixes,
            freqs=freqs, ell=ell, **device_kwargs)
        return dev, srep

    # ---- incremental append ------------------------------------------------

    def _incremental_partition(self, s_new: np.ndarray, old_prefixes,
                               old_freqs, old_offs, old_ell,
                               n_old_real: int):
        """Derive ``s_new``'s vertical-partition prefix table from the OLD
        flat tables by rescanning only the *dirty window tail*.

        A window position's owning prefix depends on at most
        ``max_prefix_len`` symbols, so only positions in
        ``[n_old_real - max_prefix_len + 1, n_new_real]`` — the windows
        that used to read the old terminal plus every appended position —
        can change ownership or create occurrences.  Each dirty position
        walks the old prefix trie under S_new: landing on a member prefix
        bumps its count; falling off the trie (a branch that had zero
        occurrences before) creates a new survivor, exactly the node the
        full scan would keep.  Old occurrence lists come for free from the
        flat index: a sub-tree's ``ell`` segment IS its position set.

        A member (or fresh branch) whose updated count overflows ``f_max``
        splits locally: its merged position list is refined into children
        by gathering the next symbol — the same fixed point as the full
        scan's refinement phase, reached without touching clean positions.
        Returns ``(table, dirty_flags)`` — aligned lists of
        :class:`SubTreePrefix` and whether each sub-tree's leaf SET
        changed — or ``(None, None)`` in the one delta the local view
        cannot decide: an old EXPANDED node whose subtree count drops back
        to ``f_max`` or below, which the full scan would re-merge into a
        single sub-tree (shrinking appends don't exist, so this needs the
        terminal-tail occupancy to collapse — rare).  Frequencies are
        exact, so the fallback triggers iff the full scan would produce a
        different prefix set.
        """
        from repro.core.vertical import SubTreePrefix

        base = self.alphabet.base
        terminal = base - 1
        f_max = self.config.f_max
        n_new_real = len(s_new) - 1
        old_syms = [tuple(int(c) for c in p) for p in old_prefixes]
        max_plen = max(len(p) for p in old_syms)
        dirty_lo = max(0, n_old_real - max_plen + 1)

        members = set(old_syms)
        interior: set[tuple] = set()
        for p in old_syms:
            for t in range(1, len(p)):
                interior.add(p[:t])

        pad = np.full(max_plen + 2, terminal, np.uint8)
        sp = np.concatenate([np.asarray(s_new, np.uint8), pad])
        owned: dict[tuple, list[int]] = {}
        new_members: set[tuple] = set()
        for b in range(dirty_lo, n_new_real + 1):
            p: tuple = ()
            for t in range(max_plen + 1):
                p = p + (int(sp[b + t]),)
                if p in members or p in new_members:
                    owned.setdefault(p, []).append(b)
                    break
                if p in interior:
                    continue
                # first node off the old trie: the zero-frequency branch
                # the full scan would now keep as a fresh survivor
                new_members.add(p)
                owned.setdefault(p, []).append(b)
                break
            else:  # deeper than every old prefix: structure changed
                return None, None

        s_arr = np.asarray(s_new, np.uint8)

        def _next_sym(pos: np.ndarray, t: int) -> np.ndarray:
            """Symbol t past each position, terminal beyond the end (the
            window-code padding rule of :func:`vertical_partition`)."""
            idx = pos + t
            sym = np.full(pos.size, terminal, np.int64)
            inside = idx < s_arr.size
            sym[inside] = s_arr[idx[inside]]
            return sym

        table: list[SubTreePrefix] = []
        dirty_flags: list[bool] = []
        interior_freq: dict[tuple, int] = {}
        pending: list[tuple[tuple, np.ndarray]] = []  # overflows to split

        def _account(p: tuple, freq: int) -> None:
            for t in range(1, len(p)):
                q = p[:t]
                interior_freq[q] = interior_freq.get(q, 0) + freq

        for p, f, o in zip(old_syms, old_freqs, old_offs):
            seg = old_ell[int(o):int(o) + int(f)]
            lost = int((seg >= dirty_lo).sum())
            gained = owned.get(p, ())
            freq = int(f) - lost + len(gained)
            _account(p, freq)
            if freq == 0:
                continue                   # every occurrence moved away
            if lost or gained:
                keep = seg[seg < dirty_lo].astype(np.int64)
                pos = np.sort(np.concatenate(
                    [keep, np.asarray(gained, np.int64)]))
                if freq > f_max:
                    pending.append((p, pos))
                    continue
                table.append(SubTreePrefix(symbols=p, freq=freq,
                                           positions=pos))
                dirty_flags.append(True)
            else:
                table.append(SubTreePrefix(symbols=p, freq=freq,
                                           positions=seg.astype(np.int64)))
                dirty_flags.append(False)
        for p in sorted(new_members):
            pos = np.asarray(owned[p], np.int64)
            _account(p, int(pos.size))
            if pos.size > f_max:
                pending.append((p, pos))
                continue
            table.append(SubTreePrefix(symbols=p, freq=int(pos.size),
                                       positions=pos))
            dirty_flags.append(True)
        # every node the old scan expanded must still overflow, else the
        # full scan would KEEP it instead of its children
        if any(f <= f_max for f in interior_freq.values()):
            return None, None
        # local refinement of overflowing sub-trees (vertical phase 2 on
        # the merged position lists; masks keep positions ascending)
        while pending:
            p, pos = pending.pop()
            if pos.size == 0:
                continue
            if pos.size <= f_max:
                table.append(SubTreePrefix(symbols=p, freq=int(pos.size),
                                           positions=pos))
                dirty_flags.append(True)
                continue
            nxt = _next_sym(pos, len(p))
            for c in range(base):
                child = pos[nxt == c]
                if child.size:
                    pending.append((p + (c,), child))
        return table, dirty_flags

    def _append_merge(self, s_new: np.ndarray, old_prefixes, old_freqs,
                      old_offs, old_ell, count_fn, max_plen: int,
                      arep: AppendReport):
        """The shared append engine: rebuild only affected sub-trees of
        ``s_new``, reuse every other leaf segment of the old flat layout.

        A sub-tree of the NEW partition is *affected* (must be rebuilt on
        S_new) iff any of:

        * its prefix is new or its occurrence count changed (windows
          overlapping the appended region create occurrences the old
          index never saw);
        * its prefix contains the terminal symbol (the terminal moved);
        * it owns a suffix position in the terminal-affected tail
          ``[b*, n_old_real)`` (:func:`_terminal_affected_start`): those
          suffixes used to diverge at the old terminal, so their order
          within the sub-tree may change even though the leaf SET didn't.

        Every other sub-tree has the same leaf set AND the same sorted
        order as before (suffix pairs sharing its prefix diverge at real
        symbols in the common region), so its old ``ell`` segment is
        reused verbatim — which is what makes the merged index
        bit-identical to a full rebuild.
        """
        terminal = self.alphabet.base - 1
        n_old_real = int(np.asarray(old_freqs, np.int64).sum()) - 1
        n_new_real = len(s_new) - 1
        if int(s_new[-1]) != terminal:
            raise ValueError("appended string must end with the terminal")
        if n_new_real <= n_old_real:
            raise ValueError(
                f"append needs new symbols: |S_new|={n_new_real} real "
                f"symbols vs |S_old|={n_old_real}")
        arep.n_old = n_old_real
        arep.n_new = n_new_real

        t0 = time.perf_counter()
        b_star = _terminal_affected_start(count_fn, s_new, n_old_real,
                                          max_plen)
        arep.b_star = b_star
        arep.t_scan = time.perf_counter() - t0

        t0 = time.perf_counter()
        table, dirty_flags = self._incremental_partition(
            s_new, old_prefixes, old_freqs, old_offs, old_ell, n_old_real)
        if table is None:  # split structure changed: full scan (rare)
            arep.partition_fallback = True
            breport = BuildReport(VerticalStats(), PrepareStats())
            groups_new = self.partition(s_new, breport)
            table = [p for g in groups_new for p in g.prefixes]
            dirty_flags = None
        arep.t_partition = time.perf_counter() - t0

        old_map = {p: (int(f), int(o))
                   for p, f, o in zip(old_prefixes, old_freqs, old_offs)}
        all_prefixes = table
        affected = []
        with obs.tracer().span("append/classify", prefixes=len(table),
                               fallback=int(dirty_flags is None)) as sp:
            for i, p in enumerate(all_prefixes):
                old = old_map.get(p.symbols)
                if dirty_flags is not None:
                    # incremental table: leaf-set changes are already
                    # flagged; an unchanged set still rebuilds when any
                    # suffix lies in the terminal-comparison tail
                    changed = dirty_flags[i]
                    if not changed and bool(
                            ((p.positions >= b_star)
                             & (p.positions < n_old_real)).any()):
                        p.positions = np.sort(p.positions)
                        changed = True
                elif (old is None or old[0] != p.freq
                        or terminal in p.symbols
                        or bool(((p.positions >= b_star)
                                 & (p.positions < n_old_real)).any())):
                    changed = True
                else:
                    changed = False
                if changed:
                    affected.append(p)
            sp.set(affected=len(affected), b_star=b_star)
        arep.n_prefixes = len(all_prefixes)
        arep.n_affected = len(affected)

        rebuilt: dict[tuple, np.ndarray] = {}
        if affected:
            from repro.core.vertical import group_prefixes
            t0 = time.perf_counter()
            re_groups = group_prefixes(affected, self.config.f_max)
            capacity = min(self.config.f_max,
                           max(g.total_freq for g in re_groups))
            s_padded = self._device_text(s_new)
            with obs.tracer().span("append/prepare",
                                   groups=len(re_groups),
                                   subtrees=len(affected)):
                states = subtree_prepare_batch(
                    s_padded, re_groups, capacity,
                    self.config.elastic_config(),
                    sort_fuse=self.config.sort_fuse,
                    compact=self.config.compaction)
            L_host = np.asarray(states.L)
            for g_i, g in enumerate(re_groups):
                for (off, freq), p in zip(segments_of(g), g.prefixes):
                    rebuilt[p.symbols] = L_host[g_i, off:off + freq]
            arep.t_prepare = time.perf_counter() - t0

        t0 = time.perf_counter()
        order = sorted(range(len(all_prefixes)),
                       key=lambda i: all_prefixes[i].symbols)
        segs, pref_out, freq_out = [], [], []
        reused = 0
        for i in order:
            p = all_prefixes[i]
            seg = rebuilt.get(p.symbols)
            if seg is None:
                f, o = old_map[p.symbols]
                seg = old_ell[o:o + f]
                reused += f
            segs.append(np.asarray(seg, np.int32))
            pref_out.append(p.symbols)
            freq_out.append(p.freq)
        ell = np.concatenate(segs).astype(np.int32)
        arep.leaves_reused = reused
        arep.leaves_rebuilt = int(ell.size) - reused
        arep.t_merge = time.perf_counter() - t0
        return pref_out, np.asarray(freq_out, np.int32), ell

    @staticmethod
    def _check_append_prefix(old_codes: np.ndarray, s_new: np.ndarray,
                             n_old_real: int) -> None:
        if not np.array_equal(np.asarray(s_new[:n_old_real], np.uint8),
                              np.asarray(old_codes[:n_old_real], np.uint8)):
            raise ValueError(
                "append requires S_new to extend the indexed string: the "
                f"first {n_old_real} symbols differ")

    def append_device(self, dev, s_new: np.ndarray,
                      report: AppendReport | None = None, **device_kwargs):
        """Incrementally extend a :class:`DeviceIndex` over ``S_old`` to
        index ``s_new`` (= S_old's real symbols + appended symbols +
        terminal) WITHOUT a full rebuild.

        Only the affected sub-trees run the elastic-range loop (see
        :meth:`_append_merge`); unaffected leaf segments are copied from
        the old index.  The result is bit-identical to
        ``build_device(s_new)`` with the same flatten kwargs, carries
        ``epoch = dev.epoch + 1`` so serving caches invalidate, and
        returns ``(index, append_report)``.
        """
        from repro.core.query import DeviceIndex  # local: avoid import cycle

        s_new = np.asarray(s_new)
        arep = report if report is not None else AppendReport()
        plen = np.asarray(dev.sub_plen)
        pref = np.asarray(dev.sub_prefix)
        old_prefixes = [tuple(int(c) for c in pref[t, :plen[t]])
                        for t in range(len(plen))]
        old_freqs = np.asarray(dev.sub_freq)
        old_offs = np.asarray(dev.sub_off)
        self._check_append_prefix(dev.string_codes(), s_new,
                                  int(old_freqs.sum()) - 1)

        def count_fn(pats):
            padded, lengths, route = dev.pad_batch(pats)
            _, cnt = dev.find_batch_ranges(padded, lengths, route)
            return np.asarray(cnt)

        with obs.tracer().span("append/total", n_old=dev.n_leaves - 1,
                               n_new=len(s_new) - 1):
            prefixes, freqs, ell = self._append_merge(
                s_new, old_prefixes, old_freqs, old_offs, dev.ell_host,
                count_fn, dev.max_pattern_len, arep)
            device_kwargs.setdefault("packing", self.config.packing)
            device_kwargs.setdefault("max_pattern_len", dev.max_pattern_len)
            device_kwargs.setdefault("epoch", dev.epoch + 1)
            new_dev = DeviceIndex.from_prepare(
                alphabet=self.alphabet, s=s_new, prefixes=prefixes,
                freqs=freqs, ell=ell, **device_kwargs)
        return new_dev, arep

    def append_sharded(self, sharded, s_new: np.ndarray,
                       report: AppendReport | None = None, *,
                       n_shards: int | None = None, **device_kwargs):
        """Incremental append for a :class:`repro.core.fabric.ShardedIndex`.

        The route-ordered per-shard tables concatenate into exactly the
        single-device flat layout (``ShardedIndex.flat_table``), the same
        merge runs there, and the merged layout re-shards through the
        route-interval planner (``ShardedIndex.from_flat`` /
        ``plan_shards``) — so per-shard ``…_shard{k}.npz`` archives
        refresh without any shard ever rebuilding its unaffected
        segments.  Returns ``(sharded_index, append_report)``.
        """
        from repro.core import fabric  # local: avoid import cycle

        s_new = np.asarray(s_new)
        arep = report if report is not None else AppendReport()
        old_prefixes, old_freqs, old_ell = sharded.flat_table()
        old_offs = np.concatenate(
            [[0], np.cumsum(old_freqs)[:-1]]).astype(np.int64)
        self._check_append_prefix(sharded.string_codes(), s_new,
                                  int(old_freqs.sum()) - 1)

        def count_fn(pats):
            return np.asarray([len(h) for h in sharded.find_batch(pats)],
                              np.int64)

        with obs.tracer().span("append/total", n_old=sharded.n_leaves - 1,
                               n_new=len(s_new) - 1, shards=sharded.n_shards):
            prefixes, freqs, ell = self._append_merge(
                s_new, old_prefixes, old_freqs, old_offs, old_ell,
                count_fn, sharded.max_pattern_len, arep)
            device_kwargs.setdefault("packing", self.config.packing)
            device_kwargs.setdefault("max_pattern_len",
                                     sharded.max_pattern_len)
            device_kwargs.setdefault("epoch", sharded.epoch + 1)
            new_idx = fabric.ShardedIndex.from_flat(
                alphabet=self.alphabet, s=s_new, prefixes=prefixes,
                freqs=freqs, ell=ell,
                n_shards=n_shards or sharded.n_shards, **device_kwargs)
        return new_idx, arep

    def build_sharded(self, s: np.ndarray, n_shards: int | None = None,
                      report: BuildReport | None = None, *,
                      mesh=None, sort_fuse: bool | None = None,
                      **device_kwargs):
        """String → :class:`repro.core.fabric.ShardedIndex`: SPMD
        construction over the device mesh, then the flattened leaf
        arrays sharded by top-trie route key.

        ``n_shards`` defaults to the mesh size (all local devices); the
        construction mesh and the index shard count are independent —
        group blocks parallelize the elastic loop, route-key shards
        partition the query fabric.  Results are bit-identical to
        :meth:`build_device` (same flatten, same probe) — see
        tests/test_fabric.py.
        """
        from repro.core import fabric  # local: avoid import cycle

        report = report if report is not None else BuildReport(
            VerticalStats(), PrepareStats())
        device_kwargs.setdefault("packing", self.config.packing)
        mesh = mesh or fabric.fabric_mesh()
        if n_shards is None:
            n_shards = mesh.devices.size
        groups = self.partition(s, report)
        if not groups:
            raise ValueError("cannot shard an empty index")
        capacity = self._capacity(groups)
        s_padded = self._device_text(s)
        t0 = time.perf_counter()
        states = fabric.sharded_prepare(
            s_padded, groups, capacity, self.config.elastic_config(),
            mesh=mesh, stats=report.prepare,
            sort_fuse=(sort_fuse if sort_fuse is not None
                       else self.config.sort_fuse))
        report.t_prepare = time.perf_counter() - t0
        prefixes, freqs, ell = _flatten_state(groups, states)
        return fabric.ShardedIndex.from_flat(
            alphabet=self.alphabet, s=np.asarray(s),
            prefixes=prefixes, freqs=freqs,
            ell=ell, n_shards=n_shards, **device_kwargs)

    def build_analytics(self, s: np.ndarray, report: BuildReport | None = None,
                        **device_kwargs):
        """Build + flatten + LCP in one step: returns ``(index, engine)``
        where the second element is the device-resident analytics engine
        (:class:`repro.core.analytics.AnalyticsEngine`).  Flattening
        kwargs default ``packing`` to this indexer's config."""
        index = self.build(s, report)
        if device_kwargs or self.config.packing != "auto":
            # honor a non-default packing even on the no-kwargs path (the
            # engine is then built uncached; "auto" keeps the shared cache,
            # whose default is the same "auto")
            device_kwargs.setdefault("packing", self.config.packing)
        return index, index.analytics(**device_kwargs)


class _HostState:
    """One bulk device→host transfer of a (G, F) state, sliceable per group."""

    def __init__(self, states):
        self.L = np.asarray(states.L)
        self.b_off = np.asarray(states.b_off)
        self.b_c1 = np.asarray(states.b_c1)
        self.b_c2 = np.asarray(states.b_c2)

    def group(self, g_i: int) -> "_HostState":
        view = object.__new__(_HostState)
        view.L = self.L[g_i]
        view.b_off = self.b_off[g_i]
        view.b_c1 = self.b_c1[g_i]
        view.b_c2 = self.b_c2[g_i]
        return view
