"""String datasets for the ERA indexing engine.

Provides the paper's dataset kinds (DNA / protein / English), synthetic
generators with controllable repeat structure (repeats stress the elastic
range: deep LCPs → many iterations), a FASTA loader, and a chunked
sequential reader that models the paper's disk-stream discipline for
strings that exceed a memory budget.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Iterator

import numpy as np

from repro.core.alphabet import ALPHABETS, Alphabet


def synthetic_string(alphabet: Alphabet, n: int, *, seed: int = 0,
                     repeat_fraction: float = 0.3,
                     repeat_len: int = 64) -> np.ndarray:
    """Random string with planted repeats (deep suffix-tree paths)."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, len(alphabet.symbols), size=n, dtype=np.uint8)
    n_rep = int(n * repeat_fraction / max(1, repeat_len))
    if n_rep and n > 2 * repeat_len:
        motif = rng.integers(0, len(alphabet.symbols), size=repeat_len, dtype=np.uint8)
        for _ in range(n_rep):
            p = int(rng.integers(0, n - repeat_len))
            base[p : p + repeat_len] = motif
    return np.concatenate([base, np.array([alphabet.terminal_code], np.uint8)])


def load_fasta(path: str, alphabet: Alphabet, *, max_symbols: int | None = None) -> np.ndarray:
    """Concatenate FASTA records into one terminated code string."""
    chunks = []
    total = 0
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith((">", ";")):
                continue
            line = line.upper().replace("N", alphabet.symbols[0])
            arr = alphabet.encode(line, terminate=False)
            chunks.append(arr)
            total += len(arr)
            if max_symbols and total >= max_symbols:
                break
    s = np.concatenate(chunks) if chunks else np.empty(0, np.uint8)
    if max_symbols:
        s = s[:max_symbols]
    return np.concatenate([s, np.array([alphabet.terminal_code], np.uint8)])


@dataclasses.dataclass
class StreamStats:
    blocks_read: int = 0
    bytes_read: int = 0
    seeks: int = 0


class BlockStream:
    """Sequential block reader over a code string — the paper's disk model.

    ``read_all()`` streams every block in order (WaveFront discipline);
    ``read_for_offsets(offs, w)`` streams only blocks containing a needed
    symbol, skipping gaps with a seek (paper §4.4 heuristic).  Counts feed
    the I/O benchmarks.
    """

    def __init__(self, s: np.ndarray, block_bytes: int = 1 << 20):
        self.s = s
        self.block = block_bytes
        self.stats = StreamStats()

    def read_all(self) -> Iterator[np.ndarray]:
        n_blocks = -(-len(self.s) // self.block)
        for b in range(n_blocks):
            self.stats.blocks_read += 1
            self.stats.bytes_read += self.block
            yield self.s[b * self.block : (b + 1) * self.block]

    def read_for_offsets(self, offs: np.ndarray, w: int) -> Iterator[tuple[int, np.ndarray]]:
        if len(offs) == 0:
            return
        lo = np.asarray(offs) // self.block
        hi = (np.asarray(offs) + w - 1) // self.block
        needed = np.unique(np.concatenate([np.arange(a, b + 1) for a, b in zip(lo, hi)]))
        prev = None
        for b in needed:
            if prev is not None and b != prev + 1:
                self.stats.seeks += 1
            self.stats.blocks_read += 1
            self.stats.bytes_read += self.block
            prev = b
            yield int(b), self.s[b * self.block : (b + 1) * self.block]


def dataset(name: str, n: int, seed: int = 0) -> tuple[np.ndarray, Alphabet]:
    """Named datasets mirroring the paper's evaluation set."""
    if name in ("dna", "genome"):
        a = ALPHABETS["dna"]
    elif name == "protein":
        a = ALPHABETS["protein"]
    elif name == "english":
        a = ALPHABETS["english"]
    elif name == "byte":
        a = ALPHABETS["byte"]
    else:
        raise KeyError(name)
    rep = {"dna": 0.30, "genome": 0.45, "protein": 0.15, "english": 0.20,
           "byte": 0.10}[name]
    return synthetic_string(a, n, seed=seed, repeat_fraction=rep), a
