"""Token pipeline for LM training: deterministic synthetic shards with
checkpointable iterator state (step → batch is a pure function, so restore
is exact), plus a suffix-tree-backed dedup filter — ERA's index applied to
the training data path (exact substring dedup over the token stream).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.alphabet import DNA
from repro.core.api import EraConfig, EraIndexer


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0


def batch_at_step(cfg: TokenPipelineConfig, step: int) -> dict:
    """Pure function step -> batch; restart-safe by construction."""
    rng = np.random.default_rng((cfg.seed << 20) ^ step)
    tokens = rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq_len + 1), dtype=np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def dedup_mask(sequences: np.ndarray, *, min_repeat: int = 32,
               mem_budget: int = 1 << 16) -> np.ndarray:
    """ERA-backed exact-repeat detection over a token batch.

    Maps token ids into a small code alphabet (ids mod |Σ|), indexes the
    concatenated stream with the ERA suffix tree, and flags sequences
    whose content contains a repeated run of >= ``min_repeat`` symbols
    appearing elsewhere in the batch.  Returns keep-mask (True = keep).
    """
    b, s = sequences.shape
    codes = (sequences % len(DNA.symbols)).astype(np.uint8)
    flat = np.concatenate([codes.reshape(-1), [DNA.terminal_code]]).astype(np.uint8)
    idx = EraIndexer(DNA, EraConfig(memory_bytes=mem_budget, r_bytes=4096,
                                    build_impl="none")).build(flat)
    keep = np.ones(b, dtype=bool)
    seen_owner: dict[tuple, int] = {}
    for prefix, st in idx.subtrees.items():
        # deep duplicated paths = long exact repeats: b_off >= min_repeat
        deep = np.asarray(st.b_off) >= min_repeat
        for i in np.nonzero(deep)[0]:
            for pos in (int(st.ell[i - 1]), int(st.ell[i])):
                owner = pos // s
                key = prefix
                if key in seen_owner and seen_owner[key] != owner and 0 <= owner < b:
                    keep[owner] = False
                else:
                    seen_owner[key] = owner
    return keep
