"""ERA inside the LM data path: exact-substring dedup of a token stream.

The generalized suffix tree over a token batch finds long exact repeats in
one pass — the indexing engine applied to training-data hygiene.

    PYTHONPATH=src python examples/corpus_index.py
"""

import numpy as np

from repro.data.tokens import TokenPipelineConfig, batch_at_step, dedup_mask


def main():
    cfg = TokenPipelineConfig(vocab=32_000, batch=16, seq_len=256, seed=0)
    batch = batch_at_step(cfg, 0)
    seqs = batch["tokens"].copy()

    # plant contamination: three sequences share a 128-token block
    seqs[5, 50:178] = seqs[2, 50:178]
    seqs[11, 0:128] = seqs[2, 50:178]

    keep = dedup_mask(seqs, min_repeat=64)
    flagged = np.nonzero(~keep)[0].tolist()
    print(f"batch of {len(seqs)}: flagged duplicates at rows {flagged}")
    assert len(flagged) >= 1
    print(f"kept {int(keep.sum())}/{len(seqs)} sequences")


if __name__ == "__main__":
    main()
