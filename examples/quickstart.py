"""Quickstart: build an ERA suffix-tree index and query it.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.alphabet import DNA
from repro.core.api import BuildReport, EraConfig, EraIndexer
from repro.core.prepare import PrepareStats
from repro.core.vertical import VerticalStats
from repro.data.strings import dataset


def main():
    # 1. a string to index (synthetic DNA with planted repeats)
    s, alphabet = dataset("dna", 50_000, seed=0)
    print(f"string: {len(s):,} symbols over Σ={alphabet.symbols!r}+'$'")

    # 2. build the index under a deliberately tight memory budget so the
    #    vertical partitioner has real work to do.  construction="batched"
    #    (the default) stacks ALL virtual trees into one (G, F) state and
    #    drives a single vmapped elastic-range loop on device, then builds
    #    every sub-tree's nodes in one vmapped Cartesian-tree call;
    #    construction="serial" is the paper-faithful per-group reference —
    #    results are identical array-for-array, batched is just faster.
    cfg = EraConfig(
        memory_bytes=64 << 10,   # 64KB "RAM" -> many virtual trees
        r_bytes=4 << 10,         # |R| elastic-range read buffer
        construction="batched",  # one elastic loop for all groups (default)
    )
    report = BuildReport(VerticalStats(), PrepareStats())
    idx = EraIndexer(alphabet, cfg).build(s, report)

    print(f"built {len(idx.subtrees)} sub-trees in {report.n_groups} virtual "
          f"trees; F_M={report.f_max}")
    print(f"  vertical: {report.t_vertical:.2f}s ({report.vertical.scans} scans)")
    print(f"  prepare : {report.t_prepare:.2f}s ({report.prepare.iterations} "
          f"elastic iterations, ranges {min(report.prepare.ranges)}–"
          f"{max(report.prepare.ranges)})")
    print(f"  build   : {report.t_build:.2f}s "
          f"({idx.n_leaves:,} leaves, {idx.n_internal:,} internal nodes)")

    # 3. query: all occurrences of a pattern
    pattern = s[1234:1244]
    hits = idx.find(pattern)
    print(f"pattern {alphabet.decode(pattern)!r}: {len(hits)} occurrences "
          f"at {hits[:8].tolist()}…")
    assert 1234 in hits

    # 4. the same query through the tree walk (paper's O(|P|) descent)
    hits2 = idx.find_walk(pattern)
    assert np.array_equal(hits, hits2)
    print("tree-walk search agrees ✓")

    # 5. batched device path: a whole list of patterns resolves with one
    #    routing gather + vectorized binary search (repro.core.query)
    batch = [s[i : i + 8] for i in (100, 2_000, 30_000)] + [pattern]
    batch_hits = idx.find_batch(batch)
    assert np.array_equal(batch_hits[-1], hits)
    print(f"batched device search agrees ✓ "
          f"({[len(h) for h in batch_hits]} hits per pattern)")

    # 5b. serving-only deployments: EraIndexer.build_device goes string ->
    #     DeviceIndex directly — the leaf arrays are gathered into suffix-
    #     array order on device, and the per-prefix numpy SubTree dict is
    #     never materialized.  Use build() (as above) when you also need
    #     the walkable per-sub-tree form (find_walk, save/load, analytics).
    dev = EraIndexer(alphabet, cfg).build_device(s)
    assert np.array_equal(dev.find_batch([pattern])[0], hits)
    print("direct string -> DeviceIndex pipeline agrees ✓")

    # 5c. dense packing (paper §6.1, generalized per alphabet): with the
    #     default EraConfig.packing="auto" the device string is stored at
    #     Alphabet.dense_bits bits per symbol whenever that is denser than
    #     bytes — 2-bit DNA (this run), 4-bit reduced-protein classes —
    #     and construction gathers, probes and analytics all read the
    #     packed words directly, repacking to identical sort keys
    #     in-register.  Results are bit-identical to packing="bytes";
    #     the index string and its HBM probe traffic shrink ~8/bits x.
    import dataclasses
    dev_bytes = EraIndexer(
        alphabet, dataclasses.replace(cfg, packing="bytes")).build_device(s)
    assert dev.packed and dev.s_bits == alphabet.dense_bits == 2
    for a, b in zip(dev.find_batch(batch), dev_bytes.find_batch(batch)):
        assert np.array_equal(a, b)
    print(f"dense-packed index agrees ✓ (string storage: "
          f"{dev.string_nbytes:,} B packed vs {dev_bytes.string_nbytes:,} B "
          f"bytes — {dev_bytes.string_nbytes / dev.string_nbytes:.1f}x smaller)")

    # 5d. word-parallel querying: on a dense index every hot comparison —
    #     the construction sort, find_batch probes, matching statistics,
    #     suffix LCP — runs on the packed uint32 words DIRECTLY (16 DNA
    #     symbols per compare; LCP = XOR + count-leading-zeros) instead
    #     of byte-expanded keys.  That is the default; the byte-key
    #     comparison path is kept as a bit-identical oracle behind
    #     REPRO_WORD_COMPARE=byte (CI re-runs the packed suite with it
    #     pinned).  Same index, both currencies, same answers:
    import os
    prev = os.environ.get("REPRO_WORD_COMPARE")
    os.environ["REPRO_WORD_COMPARE"] = "byte"
    try:
        oracle_hits = dev.find_batch(batch)
    finally:
        if prev is None:
            del os.environ["REPRO_WORD_COMPARE"]
        else:
            os.environ["REPRO_WORD_COMPARE"] = prev
    for a, b in zip(dev.find_batch(batch), oracle_hits):
        assert np.array_equal(a, b)
    print("word-compare probes agree with the byte-key oracle ✓")

    # 5e. sustained serving: repro.launch.serving turns the single-batch
    #     engine into a continuous-batching server.  Requests are admitted
    #     into a bounded queue (overflow is rejected and counted), drained
    #     into pow2-bucketed padded batches, and dispatched WITHOUT
    #     blocking — JAX's async dispatch lets the host pad/pack batch k+1
    #     while the device searches batch k; results only synchronize at
    #     consume time (np.asarray), one dispatch behind.  A hot-prefix
    #     RouteCache (keyed on the dense top-trie route + exact pattern)
    #     memoizes materialized responses so the head of a skewed query
    #     distribution skips search AND result assembly, byte-identically.
    #     ServeConfig knobs read REPRO_SERVE_* env vars (queue depth, max
    #     batch, cache size, fused-fetch width, pipeline on/off); fetch>0
    #     returns a text window per match via the fused probe+gather
    #     kernel — one launch to verify the match and fetch its context.
    #     Caveats: the pipeline only overlaps while ≥2 batches are in the
    #     system, and cache hits land one batch late (a dispatch is in
    #     flight when its predecessor's results are consumed).
    from repro.launch.serving import ServeConfig, run_closed_loop
    stream = [s[i : i + 12] for i in (100, 2_000, 100, 30_000, 100, 2_000)]
    served, stats = run_closed_loop(
        dev, stream, ServeConfig(pipeline=True, cache_size=256, max_batch=2))
    for (pos, _), p in zip(served, stream):
        assert np.array_equal(pos, idx.find(p))
    print(f"continuous-batching server agrees ✓ ({stats['batches']} batches, "
          f"cache hit rate {stats['cache']['hit_rate']:.0%})")

    # 6. analytics: the global LCP array over the flattened index unlocks
    #    substring analytics beyond exact search (repro.core.analytics)
    eng = idx.analytics()
    rep = eng.longest_repeat()
    motif = alphabet.decode(s[rep["witness"] : rep["witness"] + rep["length"]])
    print(f"longest repeated substring: {rep['length']} symbols × "
          f"{rep['count']} occurrences ({motif[:32]!r}…)")
    print(f"distinct substrings: {eng.distinct_substrings():,}")

    # matching statistics: per-position longest match of a query vs the
    # index — a planted slice matches deep, a random tail matches shallow
    rng = np.random.default_rng(1)
    query = np.concatenate([
        s[5_000:5_040],
        rng.integers(0, 4, size=40).astype(np.uint8),
    ])
    ms, witness = eng.matching_stats(query)
    assert ms[0] >= 40  # the planted slice matches at least itself
    assert 5_000 in (witness[0], *ref_positions(idx, query[:ms[0]]))
    print(f"matching statistics: planted head matches {ms[0]} symbols, "
          f"random tail averages {ms[40:].mean():.1f}")

    # 7. observability: the flight recorder (repro.obs) traces spans and
    #    counts metrics across build, kernels, and serving — OFF by
    #    default (REPRO_TRACE=1 / REPRO_METRICS=1 env knobs, or
    #    obs.configure for scripts).  Enable BEFORE constructing what you
    #    want observed: instruments bind at creation time.
    from repro import obs
    obs.configure(trace=True, metrics_on=True, clear=True)
    dev2 = EraIndexer(alphabet, cfg).build_device(s, max_pattern_len=64)
    run_closed_loop(dev2, stream,
                    ServeConfig(pipeline=True, cache_size=256, max_batch=2))
    trace_path, prom_path = obs.export_all(
        trace_path="era_trace.json", metrics_path="era_metrics.prom")
    spans = obs.tracer().events()
    hits_total = obs.metrics().counter("serve_cache_hits_total").value
    print(f"flight recorder: {len(spans)} spans -> {trace_path} "
          f"(open at https://ui.perfetto.dev or chrome://tracing)")
    print(f"metrics snapshot -> {prom_path} "
          f"(cache hits counted: {hits_total:.0f})")
    obs.configure(trace=False, metrics_on=False, clear=True)

    # 8. sharded index fabric: on a multi-device mesh (or a simulated one:
    #    XLA_FLAGS=--xla_force_host_platform_device_count=N, set BEFORE
    #    jax imports — `python -m repro.launch.shard_run` owns that for
    #    you) construction runs SPMD via shard_map: virtual-tree groups
    #    are partitioned across the mesh, the string is replicated, and a
    #    per-shard convergence mask lets each shard leave the elastic-
    #    range loop independently.  build_sharded returns a ShardedIndex:
    #    leaf arrays sharded by top-trie route key with a replicated
    #    route→shard table, so find_batch splits each batch by route and
    #    dispatches per shard.  Results are bit-identical to the single-
    #    device engine; save() writes one archive per shard
    #    ({path}_shard{k}.npz) so each host can load only its slice.
    import jax
    n_shards = min(2, jax.device_count())
    sh = EraIndexer(alphabet, cfg).build_sharded(
        s, n_shards=n_shards, max_pattern_len=64)
    for a, b in zip(sh.find_batch(batch), dev.find_batch(batch)):
        assert np.array_equal(a, b)
    print(f"sharded fabric agrees ✓ ({sh.n_shards} shard(s) over "
          f"{jax.device_count()} device(s), route depth k={sh.k_route}; "
          f"serve with: python -m repro.launch.serving --shards N, "
          f"bench with: python -m repro.launch.shard_run --mode bench)")

    # 9. out-of-core streaming + incremental append: build_stream runs the
    #    SAME elastic-range engine through a memory-budget planner
    #    (repro.core.iomodel.plan_stream) — virtual-tree groups are sliced
    #    into chunks whose PrepareState fits device_budget bytes, and a
    #    double-buffered pipeline issues chunk k+1's host→device copy
    #    while chunk k's elastic loop runs, hiding most of the copy
    #    (StreamReport.overlap_frac).  The result is bit-identical to the
    #    one-shot build.  append_device then extends a live index without
    #    a full rebuild: a terminal-tail scan + incremental re-partition
    #    finds the few affected sub-trees, only those re-run the elastic
    #    loop, and every untouched leaf segment is spliced over verbatim
    #    (AppendReport.reuse_frac).  Each append bumps DeviceIndex.epoch —
    #    persisted in save()/load() — so AsyncServer.update_index knows to
    #    flush its RouteCaches when handed the new index.
    dev_s, sr = EraIndexer(alphabet, cfg).build_stream(
        s, device_budget=64 << 10, max_pattern_len=64)
    for a, b in zip(dev_s.find_batch(batch), dev.find_batch(batch)):
        assert np.array_equal(a, b)
    print(f"streaming build agrees ✓ ({sr.n_chunks} chunks, "
          f"overlap_frac={sr.overlap_frac:.2f})")
    extra = np.random.default_rng(9).integers(
        0, alphabet.base - 1, size=500).astype(s.dtype)
    s_grown = np.concatenate([s[:-1], extra, s[-1:]])
    from repro.core.api import AppendReport
    # a tight budget means MANY small sub-trees, so the append's affected
    # set is a thin slice of the partition and most leaves carry over
    import dataclasses as _dc
    tight = EraIndexer(alphabet, _dc.replace(cfg, memory_bytes=8 << 10))
    dev_t = tight.build_device(s, max_pattern_len=64)
    arep = AppendReport()
    dev_g, _ = tight.append_device(dev_t, s_grown, arep)
    full = tight.build_device(s_grown, max_pattern_len=64)
    for a, b in zip(dev_g.find_batch(batch), full.find_batch(batch)):
        assert np.array_equal(a, b)
    print(f"incremental append agrees ✓ (rebuilt {arep.n_affected}/"
          f"{arep.n_prefixes} sub-trees, reuse_frac={arep.reuse_frac:.2f}, "
          f"epoch {dev_t.epoch}→{dev_g.epoch})")

    # 10. engine tuning knobs: every construction path above ran the
    #     PROMOTED hot-path defaults — fused single-lane sort keys (the
    #     (area, key, tie) triple packed into one uint32 lane when the
    #     bit budget fits) and tail compaction (once most rows have
    #     converged, each iteration gathers only the still-active rows
    #     into a narrow (G, f') state, steps there, and scatters back).
    #     Both are exact transforms with escape hatches for A/B runs and
    #     bisection: REPRO_SORT=lexsort and REPRO_COMPACT=off pin the
    #     reference engines (or EraConfig(sort_fuse=..., compaction=...)
    #     / the --sort / --no-compact driver flags per run); CI keeps the
    #     lexsort oracle leg green on every PR.  EraConfig(
    #     node_lcp="words") additionally rebuilds the node-build
    #     divergence rows from the packed text via the word-compare LCP
    #     kernel instead of the stored construction state — same nodes.
    #
    #     Kernel tile shapes come from repro.roofline.autotune: dispatch
    #     resolves each (backend, kernel, dtype-bits, n-bucket) through
    #     an on-disk autotune table when one exists (REPRO_AUTOTUNE_TABLE,
    #     default .repro_autotune.json — written only by explicit sweeps,
    #     never at import), else the VMEM/HBM roofline model when
    #     REPRO_AUTOTUNE=model, else the static defaults.  Tiles change
    #     DMA granularity, never results:
    from repro.roofline import autotune
    table = autotune.AutotuneTable()
    table.fill_model("cpu", {"range_gather": 64, "suffix_lcp": 256},
                     bits=alphabet.dense_bits, n=len(s))
    autotune.set_active_table(table)      # or table.save(path) + env
    dev_tuned = EraIndexer(alphabet, cfg).build_device(s)
    autotune.set_active_table(None)
    for a, b in zip(dev_tuned.find_batch(batch), dev.find_batch(batch)):
        assert np.array_equal(a, b)
    print(f"autotuned tiles agree ✓ ({len(table.entries)} table entries, "
          f"e.g. range_gather -> "
          f"{table.get('cpu', 'range_gather', alphabet.dense_bits, len(s))})")


def ref_positions(idx, pattern):
    return idx.find(np.asarray(pattern)).tolist()


if __name__ == "__main__":
    main()
