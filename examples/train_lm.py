"""Train an LM end-to-end on CPU with the full substrate: AdamW + cosine
schedule, remat, checkpoint/restart, deterministic data pipeline, and the
ERA-backed dedup filter on the input batches.

Default is a fast smoke run; ``--hundred-m`` trains a ~100M-parameter
config for a few hundred steps (slow on one CPU core — the driver is the
same one the production mesh uses).

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""

import argparse
import dataclasses

import jax.numpy as jnp

from repro.launch.train import train
from repro.models.config import ModelConfig
from repro.models.registry import ARCHS, get_config


def hundred_m_config() -> ModelConfig:
    """~100M-parameter dense config (qwen3-style)."""
    return dataclasses.replace(
        get_config("qwen3-1.7b"),
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_head=64,
        d_ff=2048, vocab=32_000,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--hundred-m", action="store_true",
                    help="~100M-param model, a few hundred steps")
    args = ap.parse_args()

    if args.hundred_m:
        import repro.models.registry as reg
        cfg = hundred_m_config()
        n = cfg.param_count() / 1e6
        print(f"training ~{n:.0f}M-param model for {max(args.steps, 200)} steps")
        # register it under a temp name so the driver can find it
        import repro.configs.qwen3_1_7b as mod
        mod.CONFIG = cfg  # the driver reads the registry fresh
        params, losses = train("qwen3-1.7b", smoke=False,
                               steps=max(args.steps, 200), batch=4,
                               seq=256, ckpt_dir=args.ckpt_dir)
    else:
        params, losses = train(args.arch, smoke=True, steps=args.steps,
                               batch=args.batch, seq=args.seq,
                               ckpt_dir=args.ckpt_dir)
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
