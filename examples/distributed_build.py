"""Distributed ERA construction with fault tolerance — the paper's
shared-nothing architecture (§5) plus the production machinery:
work-queue scheduling, node-failure recovery, per-group checkpointing.

    PYTHONPATH=src python examples/distributed_build.py
"""

import os
import tempfile
import time

import numpy as np

from repro.core.api import EraConfig, EraIndexer
from repro.data.strings import dataset
from repro.launch.era_run import build_distributed


def main():
    s, alphabet = dataset("dna", 300_000, seed=4)
    cfg = EraConfig(memory_bytes=128 << 10, r_bytes=16 << 10, build_impl="none")

    # serial reference
    t0 = time.perf_counter()
    serial = EraIndexer(alphabet, cfg).build(s)
    t_serial = time.perf_counter() - t0
    print(f"serial build: {t_serial:.1f}s, {len(serial.subtrees)} sub-trees")

    # distributed, 4 workers, with per-group checkpointing
    ck = os.path.join(tempfile.mkdtemp(), "groups.jsonl")
    t0 = time.perf_counter()
    idx, qstats, workers = build_distributed(
        s, alphabet, cfg, n_workers=4, checkpoint_path=ck)
    t_dist = time.perf_counter() - t0
    busy = max(w.seconds for w in workers)
    print(f"\n4 workers: wall {t_dist:.1f}s, max-busy {busy:.1f}s "
          f"(modeled speedup {sum(w.seconds for w in workers) / busy:.2f}x)")
    for w in workers:
        print(f"  {w.worker}: {w.groups} groups, {w.seconds:.2f}s busy")

    # node failure mid-build: w1 dies after its first group
    t0 = time.perf_counter()
    idx2, qstats2, _ = build_distributed(
        s, alphabet, cfg, n_workers=4, fail_worker="w1", fail_after=1)
    print(f"\nwith node failure: all {qstats2['done']} groups still completed "
          f"({qstats2['reattempts']} re-dispatches) in "
          f"{time.perf_counter() - t0:.1f}s")

    # results identical in all three runs
    for p in serial.subtrees:
        assert np.array_equal(serial.subtrees[p].ell, idx.subtrees[p].ell)
        assert np.array_equal(serial.subtrees[p].ell, idx2.subtrees[p].ell)
    print("\nall three builds produced identical indexes ✓")


if __name__ == "__main__":
    main()
