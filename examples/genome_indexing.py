"""End-to-end driver — the paper's headline scenario, scaled to this host:
index a genome-scale string under a memory budget much smaller than |S|,
report the phase breakdown + I/O model, persist, reload, and serve queries.

    PYTHONPATH=src python examples/genome_indexing.py --n 2000000 --mem-kb 256
"""

import argparse
import time

import numpy as np

from repro.core.api import BuildReport, EraConfig, EraIndexer
from repro.core.iomodel import amortization_factor
from repro.core.prepare import PrepareStats
from repro.core.suffix_tree import SuffixTreeIndex
from repro.core.vertical import VerticalStats
from repro.data.strings import dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--mem-kb", type=int, default=256)
    ap.add_argument("--dataset", default="genome")
    ap.add_argument("--out", default="/tmp/genome_index.npz")
    args = ap.parse_args()

    s, alphabet = dataset(args.dataset, args.n, seed=0)
    ratio = len(s) / (args.mem_kb << 10)
    print(f"indexing {len(s):,} symbols with a {args.mem_kb}KB budget "
          f"(string is {ratio:.0f}x the memory)")

    cfg = EraConfig(memory_bytes=args.mem_kb << 10, r_bytes=32 << 10,
                    build_impl="numpy")
    report = BuildReport(VerticalStats(), PrepareStats())
    t0 = time.perf_counter()
    idx = EraIndexer(alphabet, cfg).build(s, report)
    dt = time.perf_counter() - t0

    print(f"\ntotal {dt:.1f}s  ({len(s) / dt / 1e6:.2f} Msym/s)")
    print(f"  vertical partition: {report.t_vertical:.1f}s, "
          f"{report.n_prefixes} prefixes -> {report.n_groups} virtual trees "
          f"(amortization {amortization_factor(report.n_prefixes, report.n_groups):.1f}x)")
    print(f"  elastic prepare   : {report.t_prepare:.1f}s, "
          f"{report.prepare.iterations} iterations, "
          f"{report.prepare.symbols_fetched / 1e6:.1f}M symbols fetched")
    print(f"  batch build       : {report.t_build:.1f}s, "
          f"{idx.n_leaves:,} leaves + {idx.n_internal:,} internal")

    idx.save(args.out)
    idx2 = SuffixTreeIndex.load(args.out, alphabet)
    print(f"\npersisted + reloaded index ({args.out})")

    rng = np.random.default_rng(1)
    t0 = time.perf_counter()
    n_q = 200
    for _ in range(n_q):
        i = int(rng.integers(0, len(s) - 12))
        hits = idx2.find(s[i : i + 12])
        assert i in hits
    print(f"{n_q} exact-match queries in {(time.perf_counter() - t0) * 1e3:.0f}ms "
          f"({(time.perf_counter() - t0) / n_q * 1e6:.0f}us/query)")


if __name__ == "__main__":
    main()
