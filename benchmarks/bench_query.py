"""Batched device query engine vs the per-pattern Python path.

Emits one row per batch size: the device path's per-batch time, with the
derived column carrying queries/sec and the speedup over running the same
batch through per-pattern ``SuffixTreeIndex.find`` (scalar numpy binary
search) — the host-bound path this engine replaces.  Each batch size also
gets a ``packed`` row: the same search served from the dense 2-bit string
(the default index representation for DNA), with the index's string
storage bytes recorded for both.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.api import EraConfig, EraIndexer
from repro.data.strings import dataset


def run(quick: bool = True) -> None:
    n = 50_000 if quick else 500_000
    s, alphabet = dataset("dna", n, seed=0)
    cfg = EraConfig(memory_bytes=1 << 18, build_impl="none")
    index = EraIndexer(alphabet, cfg).build(s)
    dev = index.to_device(packing="bytes")
    dev_packed = index.to_device(packing="dense")

    rng = np.random.default_rng(1)
    for batch in (8, 64, 256):
        pats = []
        for _ in range(batch):
            m = int(rng.integers(4, 17))
            i = int(rng.integers(0, len(s) - 1 - m))
            pats.append(np.asarray(s[i : i + m]))
        padded, lengths, route = dev.pad_batch(pats)

        def device_batch(d=dev):
            start, count = d.find_batch_ranges(padded, lengths, route)
            np.asarray(count)  # block

        t_dev = timeit(device_batch, repeats=3, warmup=1)
        t_py = timeit(lambda: [index.find(p) for p in pats], repeats=1)
        emit(f"query/batch{batch}", t_dev,
             f"qps={batch / max(t_dev, 1e-9):.0f} "
             f"speedup={t_py / max(t_dev, 1e-9):.1f}x "
             f"string_bytes={dev.string_nbytes}")
        t_pk = timeit(lambda: device_batch(dev_packed), repeats=3, warmup=1)
        emit(f"query/batch{batch}_packed", t_pk,
             f"qps={batch / max(t_pk, 1e-9):.0f} "
             f"vs_byte={t_dev / max(t_pk, 1e-9):.2f}x "
             f"string_bytes={dev_packed.string_nbytes}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
