"""Batched device query engine vs the per-pattern Python path.

Emits one row per batch size: the device path's per-batch time, with the
derived column carrying queries/sec and the speedup over running the same
batch through per-pattern ``SuffixTreeIndex.find`` (scalar numpy binary
search) — the host-bound path this engine replaces.  Each batch size also
gets a ``packed`` row: the same search served from the dense 2-bit string
(the default index representation for DNA), with the index's string
storage bytes recorded for both.

Sustained-load ``serve/`` rows drive the continuous-batching stack of
:mod:`repro.launch.serving` over a skewed request stream: ``serve/sync``
is the synchronous one-batch-at-a-time baseline, ``serve/async`` the
overlapped pipeline, ``serve/async_cached`` the pipeline plus hot-prefix
route cache — each reporting qps at its p99 latency (plus hit rate),
with us_per_call = wall time per request so the regression gate applies.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.api import EraConfig, EraIndexer
from repro.data.strings import dataset
from repro.launch.serving import ServeConfig, make_hot_workload, run_closed_loop


def run(quick: bool = True) -> None:
    n = 50_000 if quick else 500_000
    s, alphabet = dataset("dna", n, seed=0)
    cfg = EraConfig(memory_bytes=1 << 18, build_impl="none")
    index = EraIndexer(alphabet, cfg).build(s)
    dev = index.to_device(packing="bytes")
    dev_packed = index.to_device(packing="dense")

    rng = np.random.default_rng(1)
    for batch in (8, 64, 256):
        pats = []
        for _ in range(batch):
            m = int(rng.integers(4, 17))
            i = int(rng.integers(0, len(s) - 1 - m))
            pats.append(np.asarray(s[i : i + m]))
        padded, lengths, route = dev.pad_batch(pats)

        def device_batch(d=dev):
            start, count = d.find_batch_ranges(padded, lengths, route)
            np.asarray(count)  # block

        t_dev = timeit(device_batch, repeats=3, warmup=1)
        t_py = timeit(lambda: [index.find(p) for p in pats], repeats=1)
        emit(f"query/batch{batch}", t_dev,
             f"qps={batch / max(t_dev, 1e-9):.0f} "
             f"speedup={t_py / max(t_dev, 1e-9):.1f}x "
             f"string_bytes={dev.string_nbytes}")
        t_pk = timeit(lambda: device_batch(dev_packed), repeats=3, warmup=1)
        emit(f"query/batch{batch}_packed", t_pk,
             f"qps={batch / max(t_pk, 1e-9):.0f} "
             f"vs_byte={t_dev / max(t_pk, 1e-9):.2f}x "
             f"string_bytes={dev_packed.string_nbytes}")

    # sustained load through the continuous-batching serving stack
    requests = 4096 if quick else 16384
    pats = make_hot_workload(s, rng, n_requests=requests, hot_pool=32,
                             hot_frac=0.85, min_len=4, max_len=24,
                             n_symbols=4)
    configs = [
        ("serve/sync", ServeConfig(pipeline=False, cache_size=0)),
        ("serve/async", ServeConfig(pipeline=True, cache_size=0)),
        ("serve/async_cached", ServeConfig(pipeline=True)),
    ]
    qps_sync = None
    for name, cfg in configs:
        run_closed_loop(dev_packed, pats, cfg)  # warm this mode's shapes
        # best-of-3: a closed loop over thousands of tiny host-side batches
        # is scheduler-noise bound, and the noise only ever slows a run
        stats = min((run_closed_loop(dev_packed, pats, cfg)[1]
                     for _ in range(3)), key=lambda st: st["wall_s"])
        if name == "serve/sync":
            qps_sync = stats["qps"]
        derived = (f"qps={stats['qps']:.0f} p99_ms={stats['lat_p99_ms']} "
                   f"vs_sync={stats['qps'] / max(qps_sync, 1e-9):.2f}x")
        if cfg.cache_size:
            derived += f" hit_rate={stats['cache']['hit_rate']:.2f}"
        emit(name, stats["wall_s"] / requests, derived)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
