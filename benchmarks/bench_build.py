"""Construction throughput: batched (G, F) engine vs the serial per-group loop.

One row per engine at each input size, derived carrying groups, leaves/sec
and the batched-over-serial speedup — the construction-side counterpart of
bench_query.  Also times ``EraIndexer.build_device`` (string → DeviceIndex
with no intermediate SubTree dict) against serial build + flatten.
"""

from __future__ import annotations

import os

from benchmarks.common import emit, timeit
from repro.core.api import BuildReport, EraConfig, EraIndexer
from repro.core.prepare import PrepareStats
from repro.core.vertical import VerticalStats
from repro.data.strings import dataset
from repro.kernels import ops as kops


def _cfg(construction: str, memory_bytes: int) -> EraConfig:
    return EraConfig(memory_bytes=memory_bytes, r_bytes=4096,
                     build_impl="none", construction=construction)


def engine_stamp(node_lcp: str = "state") -> str:
    """Engine-config attribution for every construction row: a number
    without the sort/compaction/autotune mode it ran under is
    uncomparable across PRs."""
    return (f"fused_sort={'on' if kops._use_sort_fuse() else 'off'} "
            f"compaction={'tail' if kops._use_compaction() else 'off'} "
            f"word_node_build={node_lcp} "
            f"autotune={os.environ.get('REPRO_AUTOTUNE', 'off')}")


def run(quick: bool = True) -> None:
    sizes = (60_000,) if quick else (150_000, 400_000)
    for n in sizes:
        s, alphabet = dataset("dna", n, seed=0)
        # tight budget -> many virtual trees, so the group axis is real work
        memory_bytes = 1 << 15

        last_rep = {}

        def build(construction):
            rep = BuildReport(VerticalStats(), PrepareStats())
            EraIndexer(alphabet, _cfg(construction, memory_bytes)).build(s, rep)
            last_rep[construction] = rep  # report of the last timed run

        t_ser = timeit(lambda: build("serial"), repeats=2, warmup=1)
        t_bat = timeit(lambda: build("batched"), repeats=2, warmup=1)
        rep_ser, rep_bat = last_rep["serial"], last_rep["batched"]
        g = rep_bat.n_groups
        prep_speedup = rep_ser.t_prepare / max(rep_bat.t_prepare, 1e-9)
        stamp = engine_stamp()
        emit(f"build/serial/n={n}", t_ser, f"groups={g} {stamp}")
        emit(f"build/batched/n={n}", t_bat,
             f"groups={g} leaves_per_s={n / max(t_bat, 1e-9):.0f} "
             f"speedup={t_ser / max(t_bat, 1e-9):.2f}x "
             f"prepare_speedup={prep_speedup:.2f}x {stamp}")

        t_dev = timeit(
            lambda: EraIndexer(alphabet, _cfg("batched", memory_bytes)).build_device(s),
            repeats=2, warmup=1)
        emit(f"build/device_direct/n={n}", t_dev,
             f"vs_serial={t_ser / max(t_dev, 1e-9):.2f}x {stamp}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
