"""CI trace-smoke: prove the flight recorder works AND costs ~nothing.

Runs a small build + closed-loop serve twice over the same workload with
a warm jit cache — once with tracing/metrics ON, once OFF — then:

1. exports the Chrome trace and validates it against the ``trace_event``
   schema subset (:func:`repro.obs.validate_chrome_trace`);
2. asserts the per-batch serving spans the ISSUE names are present
   (``serve/queue_wait``, ``serve/pad_pack``, ``serve/device_dispatch``,
   ``serve/consume_sync``) plus the construction spans;
3. asserts the Prometheus snapshot carries the cache hit rate, the
   batch-fill histogram, and per-impl kernel dispatch counters;
4. gates overhead: instrumented qps must stay within ``--threshold`` of
   the uninstrumented run (default 0.5 — CI runners are noisy; the guard
   is against pathological slowdowns, not 5% drift).

Exit status is nonzero on any failure, with every problem printed.

    REPRO_TRACE=1 REPRO_METRICS=1 python -m benchmarks.trace_smoke \
        --out-dir /tmp/trace_smoke
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

REQUIRED_SPANS = (
    "build/vertical",
    "prepare/step",
    "stream/pipeline",
    "stream/chunk",
    "serve/queue_wait",
    "serve/pad_pack",
    "serve/device_dispatch",
    "serve/consume_sync",
)
REQUIRED_PROM = (
    "serve_cache_hit_rate",
    "serve_batch_fill_bucket",
    "serve_queue_wait_ms_bucket",
    "serve_batch_age_ms_bucket",
    "kernel_dispatch_total",
    "prepare_group_iterations_bucket",
)


def _serve_once(dev, pats, cfg_kw) -> float:
    """One closed-loop pass; returns qps."""
    from repro.launch.serving import AsyncServer, ServeConfig

    server = AsyncServer(dev, ServeConfig(**cfg_kw))
    t0 = time.perf_counter()
    server.serve(pats)
    return len(pats) / max(time.perf_counter() - t0, 1e-9)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n", type=int, default=20_000,
                    help="text length for the smoke build")
    ap.add_argument("--requests", type=int, default=1024)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed passes per arm; best-of wins (noise guard)")
    ap.add_argument("--threshold", type=float, default=0.5,
                    help="min instrumented/uninstrumented qps ratio")
    ap.add_argument("--out-dir", default=".",
                    help="where the trace/metrics artifacts land")
    args = ap.parse_args()

    from repro import obs
    from repro.core.alphabet import DNA
    from repro.core.api import EraConfig, EraIndexer
    from repro.launch.serving import make_hot_workload

    problems: list[str] = []
    cfg_kw = dict(pipeline=True, cache_size=512, max_batch=64)

    # ---- instrumented arm: build + serve with the recorder on -------------
    obs.configure(trace=True, metrics_on=True, clear=True)
    s = DNA.random_string(args.n, seed=0)
    # the streaming builder (budget forces several chunks) exercises the
    # stream/* spans and emits the same index the one-shot path would
    indexer = EraIndexer(DNA, EraConfig(
        memory_bytes=1 << 20, build_impl="none"))
    dev, sreport = indexer.build_stream(
        s, device_budget=64 << 10, max_pattern_len=64)
    print(f"stream build: {sreport.n_chunks} chunks, "
          f"overlap_frac={sreport.overlap_frac:.2f}")
    rng = np.random.default_rng(7)
    pats = make_hot_workload(s, rng, n_requests=args.requests, hot_pool=32,
                             hot_frac=0.8, min_len=4, max_len=24,
                             n_symbols=4)
    _serve_once(dev, pats, cfg_kw)  # warmup: compiles + kernel counters
    qps_on = max(_serve_once(dev, pats, cfg_kw)
                 for _ in range(args.repeats))

    # ---- export + validate ------------------------------------------------
    os.makedirs(args.out_dir, exist_ok=True)
    trace_path = os.path.join(args.out_dir, "era_trace.json")
    prom_path = os.path.join(args.out_dir, "era_metrics.prom")
    obs.export_all(trace_path=trace_path, metrics_path=prom_path)
    print(f"wrote {trace_path}")
    print(f"wrote {prom_path}")

    with open(trace_path) as f:
        trace = json.load(f)
    for err in obs.validate_chrome_trace(trace):
        problems.append(f"trace schema: {err}")
    names = {e["name"] for e in trace["traceEvents"]}
    for span in REQUIRED_SPANS:
        if span not in names:
            problems.append(f"trace missing required span {span!r}")

    # span links: every serving batch's device_dispatch span must carry a
    # link id that some serve/queue_wait span also carries — the join key
    # that attributes device work back to the admission wait that fed it
    link_of = lambda e: (e.get("args") or {}).get("link")
    qw_links = {link_of(e) for e in trace["traceEvents"]
                if e["name"] == "serve/queue_wait"} - {None}
    dd_links = [link_of(e) for e in trace["traceEvents"]
                if e["name"] == "serve/device_dispatch"]
    if not dd_links or None in dd_links:
        problems.append("device_dispatch spans missing link attribute")
    elif not set(dd_links) <= qw_links:
        problems.append(
            f"device_dispatch links {sorted(set(dd_links) - qw_links)} "
            "have no matching serve/queue_wait span")
    elif not qw_links:
        problems.append("no linked serve/queue_wait spans in trace")

    with open(prom_path) as f:
        prom = f.read()
    for needle in REQUIRED_PROM:
        if needle not in prom:
            problems.append(f"prometheus snapshot missing {needle!r}")
    if 'impl="pallas"' not in prom and 'impl="ref"' not in prom:
        problems.append("kernel dispatch counters carry no impl label")

    # ---- uninstrumented arm: same warm jit cache, recorder off ------------
    obs.configure(trace=False, metrics_on=False)
    _serve_once(dev, pats, cfg_kw)  # warmup parity
    qps_off = max(_serve_once(dev, pats, cfg_kw)
                  for _ in range(args.repeats))

    ratio = qps_on / max(qps_off, 1e-9)
    print(f"qps instrumented={qps_on:.0f} off={qps_off:.0f} "
          f"ratio={ratio:.2f} (threshold {args.threshold})")
    if ratio < args.threshold:
        problems.append(
            f"instrumentation overhead: qps ratio {ratio:.2f} "
            f"< {args.threshold}")

    n_spans = len([e for e in trace["traceEvents"] if e.get("ph") == "X"])
    print(f"trace: {n_spans} spans, {len(names)} distinct names")
    if problems:
        for p in problems:
            print(f"FAIL: {p}", file=sys.stderr)
        sys.exit(1)
    print("trace_smoke: OK")


if __name__ == "__main__":
    main()
