"""Sharded-fabric construction throughput: SPMD mesh vs single-device.

One baseline row (``subtree_prepare_batch``, the default batched engine)
and one sharded row (:func:`repro.core.fabric.sharded_prepare` over the
device mesh) at a G ≈ 100 workload, derived carrying the speedup and its
attribution.  On the CI host the mesh is SIMULATED
(``--xla_force_host_platform_device_count``) on one physical core, so any
speedup is NOT device parallelism.  The fused sort key and tail
compaction that used to be fabric-only are now the default batched
engine too (both rows run them), so the remaining delta is the fabric's
per-shard convergence mask on the tail iterations.  On a real
multi-device mesh the same program adds actual parallel speedup on top.

If the current process has a single device, the sharded leg runs in a
subprocess (``python -m repro.launch.shard_run --mode bench --json``)
that owns its XLA_FLAGS; the in-process leg is preferred because it
shares jit caches with the rest of the suite.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit, timeit

DEVICES = 4


def _bench_subprocess(n: int, memory_bytes: int, repeats: int) -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src") or "src"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.shard_run", "--mode", "bench",
         "--json", "--devices", str(DEVICES), "--n", str(n),
         "--memory-bytes", str(memory_bytes), "--repeats", str(repeats)],
        capture_output=True, text=True, timeout=1800, env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"shard_run bench failed:\n{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _bench_inprocess(n: int, memory_bytes: int, repeats: int) -> dict:
    import jax

    from repro.core import fabric
    from repro.core.api import EraConfig, EraIndexer
    from repro.core.prepare import subtree_prepare_batch
    from repro.data.strings import dataset

    s, alphabet = dataset("dna", n, seed=0)
    cfg = EraConfig(memory_bytes=memory_bytes, r_bytes=4096,
                    build_impl="none")
    ix = EraIndexer(alphabet, cfg)
    groups = ix.partition(s)
    capacity = ix._capacity(groups)
    s_padded = ix._device_text(s)
    ecfg = cfg.elastic_config()
    t_base = timeit(
        lambda: subtree_prepare_batch(s_padded, groups, capacity, ecfg),
        repeats=repeats, warmup=1)
    t_shard = timeit(
        lambda: fabric.sharded_prepare(s_padded, groups, capacity, ecfg),
        repeats=repeats, warmup=1)
    return {"devices": jax.device_count(), "groups": len(groups),
            "capacity": capacity, "t_baseline_s": t_base,
            "t_sharded_s": t_shard, "speedup": t_base / max(t_shard, 1e-9)}


def run(quick: bool = True) -> None:
    n = 120_000 if quick else 400_000
    memory_bytes = 1 << 16 if quick else 1 << 17
    repeats = 2 if quick else 3

    import jax

    if jax.device_count() >= 2:
        res = _bench_inprocess(n, memory_bytes, repeats)
    else:
        res = _bench_subprocess(n, memory_bytes, repeats)

    from benchmarks.bench_build import engine_stamp

    g, cap = res.get("groups", "?"), res.get("capacity", "?")
    stamp = engine_stamp()
    emit(f"fabric/baseline/n={n}", res["t_baseline_s"],
         f"groups={g} capacity={cap} engine=batched {stamp}")
    emit(f"fabric/sharded/n={n}", res["t_sharded_s"],
         f"devices={res['devices']} groups={g} "
         f"speedup={res['speedup']:.2f}x "
         f"attribution=shard_mask {stamp} "
         f"simulated_mesh={jax.default_backend() == 'cpu'}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
