"""Benchmark-regression check: diff a bench JSON against the previous one.

``benchmarks.run --json`` rows never used to land anywhere diffable — CI
uploaded them as a build artifact and they vanished with it.  Now every
PR records a ``BENCH_<pr>.json`` at the repo root (``make bench-smoke``
locally, the CI smoke step in automation) and this checker compares the
current run against the most recent committed artifact:

    python -m benchmarks.check_regression --current bench-results.json
    python -m benchmarks.check_regression \
        --baseline BENCH_PR4.json --current BENCH_PR5.json --strict

Only the device-hot suites are gated (``packed/``, ``query/``,
``serve/`` and ``stream/`` rows; ``build/`` rows are compared
warn-only): a row whose
``us_per_call`` grew more than
``--threshold`` (default 20%) over the baseline is reported as a
throughput drop.  Exit status is 0 unless ``--strict`` (warn-by-default:
CI runners are noisy; the signal is the printed table and the committed
trajectory, the hard gate is opt-in).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

# suites gated for regressions (prefix of the row name)
WATCH_PREFIXES = ("packed/", "query/", "serve/", "stream/")
# suites compared and reported but not escalated to drops by default —
# construction timings carry more host-side noise; ``--gate-build``
# promotes them to the watched set now that the batched engine rows are
# attributed (engine stamp in derived) and stable enough to gate
WARN_PREFIXES = ("build/",)


def split_prefixes(gate_build: bool) -> tuple[tuple[str, ...],
                                              tuple[str, ...]]:
    """(watched, warn-only) row-name prefixes for this run."""
    if gate_build:
        return WATCH_PREFIXES + WARN_PREFIXES, ()
    return WATCH_PREFIXES, WARN_PREFIXES


def load_rows(path: str) -> dict[str, float]:
    with open(path) as f:
        payload = json.load(f)
    rows = payload["rows"] if isinstance(payload, dict) else payload
    return {r["name"]: float(r["us_per_call"]) for r in rows
            if "name" in r and "us_per_call" in r}


def latest_baseline(root: str = ".") -> str | None:
    """The newest ``BENCH_*.json`` at the repo root.

    "Newest" is decided by the number embedded in the filename (PR
    numbers grow monotonically; git checkouts do NOT preserve mtimes, so
    modification time alone would pick an arbitrary committed file) with
    mtime as the tiebreak for number-less names like ``BENCH_local.json``.
    """
    cands = glob.glob(os.path.join(root, "BENCH_*.json"))
    if not cands:
        return None

    def key(path: str):
        m = re.search(r"(\d+)", os.path.basename(path))
        return (1, int(m.group(1))) if m else (0, os.path.getmtime(path))

    return max(cands, key=key)


def compare(base: dict[str, float], cur: dict[str, float],
            threshold: float, *,
            gate_build: bool = False) -> tuple[list[str], list[str]]:
    """(drops, notes): warning lines for watched regressions + info lines.

    Rows under the warn-only prefixes are compared and reported (prefixed
    ``warn`` when past threshold) but land in ``notes`` — they never fail
    a ``--strict`` run.  ``gate_build`` moves ``build/`` rows into the
    watched set."""
    watch, warn = split_prefixes(gate_build)
    drops: list[str] = []
    notes: list[str] = []
    for name in sorted(set(base) & set(cur)):
        gated = name.startswith(watch)
        if not gated and not (warn and name.startswith(warn)):
            continue
        b, c = base[name], cur[name]
        if b <= 0:
            continue
        ratio = c / b
        line = f"{name}: {b:.1f}us -> {c:.1f}us ({ratio:.2f}x)"
        if ratio > 1 + threshold:
            if gated:
                drops.append(line)
            else:
                notes.append(f"warn  {line}")
        else:
            notes.append(line)
    missing = [n for n in sorted(base) if n.startswith(watch)
               and n not in cur]
    for n in missing:
        drops.append(f"{n}: present in baseline, missing from current run")
    return drops, notes


def delta_table(base: dict[str, float], cur: dict[str, float],
                threshold: float, *, gate_build: bool = False) -> list[str]:
    """Aligned per-row delta table over every compared row — printed on
    both the warn and the strict path so a red CI run shows the exact
    numbers it compared, not just the verdict.  Status column: ``ok``,
    ``DROP`` (gated, past threshold), ``warn`` (warn-only, past
    threshold), ``new`` (no baseline row), ``missing`` (gone from the
    current run)."""
    watch, warn = split_prefixes(gate_build)
    names = [n for n in sorted(set(base) | set(cur))
             if n.startswith(watch) or (warn and n.startswith(warn))]
    if not names:
        return []
    w = max(len(n) for n in names)
    head = (f"  {'row'.ljust(w)}  {'baseline_us':>11}  {'current_us':>10}"
            f"  {'ratio':>6}  status")
    out = [head, "  " + "-" * (len(head) - 2)]
    for name in names:
        b, c = base.get(name), cur.get(name)
        if b is None:
            out.append(f"  {name.ljust(w)}  {'-':>11}  {c:>10.1f}  "
                       f"{'-':>6}  new")
            continue
        if c is None:
            status = "missing" if name.startswith(watch) else "warn"
            out.append(f"  {name.ljust(w)}  {b:>11.1f}  {'-':>10}  "
                       f"{'-':>6}  {status}")
            continue
        if b <= 0:
            out.append(f"  {name.ljust(w)}  {b:>11.1f}  {c:>10.1f}  "
                       f"{'-':>6}  ok")
            continue
        ratio = c / b
        if ratio > 1 + threshold:
            status = "DROP" if name.startswith(watch) else "warn"
        else:
            status = "ok"
        out.append(f"  {name.ljust(w)}  {b:>11.1f}  {c:>10.1f}  "
                   f"{ratio:>5.2f}x  {status}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", required=True,
                    help="bench JSON of the run under test")
    ap.add_argument("--baseline", default=None,
                    help="previous BENCH_<pr>.json (default: newest "
                         "BENCH_*.json at the repo root)")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="fractional us_per_call growth that counts as a "
                         "drop (default 0.20 = 20%%)")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero when any watched row dropped")
    ap.add_argument("--gate-build", action="store_true",
                    help="promote build/ construction rows from warn-only "
                         "to the watched (gated) set")
    args = ap.parse_args()

    baseline = args.baseline or latest_baseline()
    if baseline is None:
        print("check_regression: no BENCH_*.json baseline found — "
              "nothing to compare (first recorded run?)")
        return
    if os.path.abspath(baseline) == os.path.abspath(args.current):
        print(f"check_regression: baseline == current ({baseline}); "
              "nothing to compare")
        return

    base = load_rows(baseline)
    cur = load_rows(args.current)
    drops, _ = compare(base, cur, args.threshold,
                       gate_build=args.gate_build)

    mode = "strict" if args.strict else "warn-only"
    if args.gate_build:
        mode += "+gate-build"
    print(f"check_regression: comparing against baseline {baseline} "
          f"({len(base)} rows, threshold {args.threshold:.0%}, {mode})")
    print(f"current : {args.current} ({len(cur)} rows)")
    for line in delta_table(base, cur, args.threshold,
                            gate_build=args.gate_build):
        print(line)
    if drops:
        for line in drops:
            print(f"  DROP  {line}", file=sys.stderr)
        print(f"check_regression: {len(drops)} watched row(s) regressed "
              f"more than {args.threshold:.0%} vs {baseline}",
              file=sys.stderr)
        if args.strict:
            sys.exit(1)
    else:
        print(f"check_regression: no watched regressions vs {baseline}")


if __name__ == "__main__":
    main()
