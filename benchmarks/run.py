# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  fig7   bench_horizontal   ERA-str vs ERA-str+mem
  fig8   bench_rtuning      |R| read-buffer tuning (DNA vs protein)
  fig9a  bench_vertical     virtual trees on/off
  fig9b  bench_elastic      elastic vs static range
  fig10  bench_baselines    ERA vs WaveFront-style vs SA-based (B²ST-style)
  fig11  bench_alphabet     alphabet sensitivity
  tbl3   bench_scaling      strong/weak scaling (scheduler busy-time model)
  roofl  bench_roofline     dry-run roofline table (reads experiments/dryrun.json)
  build      bench_build      batched (G,F) construction engine vs serial loop
  query      bench_query      batched device query engine vs per-pattern Python
  analytics  bench_analytics  LCP analytics engine vs per-position Python
  packed     bench_packed     dense k-bit string gather/probe vs byte path
  fabric     bench_fabric     sharded SPMD construction vs single-device
  stream     bench_stream     out-of-core streaming build + incremental append

``python -m benchmarks.run``            — quick pass over everything
``python -m benchmarks.run --full``     — paper-scale (slower) settings
``python -m benchmarks.run --smoke``    — CI mode: quick settings, errors
                                          fatal at exit, intended with --json
``python -m benchmarks.run --json results.json``  — persist rows as JSON
``python -m benchmarks.run --only fig9b``
"""

from __future__ import annotations

import argparse
import inspect
import json
import platform
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke pass: quick settings, nonzero exit if any "
                         "suite errored")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write all emitted rows to PATH as JSON")
    ap.add_argument("--only", default=None,
                    help="run a subset of suites (comma-separated, e.g. "
                         "--only query,packed)")
    args = ap.parse_args()
    quick = not args.full or args.smoke

    from benchmarks import (
        bench_alphabet,
        bench_analytics,
        bench_baselines,
        bench_build,
        bench_elastic,
        bench_fabric,
        bench_horizontal,
        bench_packed,
        bench_query,
        bench_roofline,
        bench_rtuning,
        bench_scaling,
        bench_stream,
        bench_vertical,
        common,
    )

    suites = {
        "fig7": bench_horizontal.run,
        "fig8": bench_rtuning.run,
        "fig9a": bench_vertical.run,
        "fig9b": bench_elastic.run,
        "fig10": bench_baselines.run,
        "fig11": bench_alphabet.run,
        "tbl3": bench_scaling.run,
        "roofline": bench_roofline.run,
        "build": bench_build.run,
        "query": bench_query.run,
        "analytics": bench_analytics.run,
        "packed": bench_packed.run,
        "fabric": bench_fabric.run,
        "stream": bench_stream.run,
    }
    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - set(suites)
        if unknown:
            ap.error(f"unknown suite(s) {sorted(unknown)}; "
                     f"choose from {sorted(suites)}")
    common.RESULTS.clear()  # in-process reruns must not accumulate rows
    print("name,us_per_call,derived")
    errors: list[str] = []
    for key, fn in suites.items():
        if only and key not in only:
            continue
        try:
            if "quick" in inspect.signature(fn).parameters:
                fn(quick=quick)
            else:
                fn()
        except Exception as e:  # report, keep the suite going
            errors.append(f"{key}: {type(e).__name__}: {e}")
            print(f"{key}/ERROR,0,{type(e).__name__}: {e}", file=sys.stderr)

    if args.json:
        payload = {
            "mode": "smoke" if args.smoke else ("full" if args.full else "quick"),
            "python": platform.python_version(),
            "platform": platform.platform(),
            "provenance": common.provenance(),
            "rows": common.RESULTS,
            "errors": errors,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {len(common.RESULTS)} rows to {args.json}", file=sys.stderr)

    if args.smoke and errors:
        sys.exit(1)


if __name__ == "__main__":
    main()
