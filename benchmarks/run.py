# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  fig7   bench_horizontal   ERA-str vs ERA-str+mem
  fig8   bench_rtuning      |R| read-buffer tuning (DNA vs protein)
  fig9a  bench_vertical     virtual trees on/off
  fig9b  bench_elastic      elastic vs static range
  fig10  bench_baselines    ERA vs WaveFront-style vs SA-based (B²ST-style)
  fig11  bench_alphabet     alphabet sensitivity
  tbl3   bench_scaling      strong/weak scaling (scheduler busy-time model)
  roofl  bench_roofline     dry-run roofline table (reads experiments/dryrun.json)
  query  bench_query        batched device query engine vs per-pattern Python

``python -m benchmarks.run``            — quick pass over everything
``python -m benchmarks.run --full``     — paper-scale (slower) settings
``python -m benchmarks.run --only fig9b``
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (
        bench_alphabet,
        bench_baselines,
        bench_elastic,
        bench_horizontal,
        bench_query,
        bench_roofline,
        bench_rtuning,
        bench_scaling,
        bench_vertical,
    )

    suites = {
        "fig7": bench_horizontal.run,
        "fig8": bench_rtuning.run,
        "fig9a": bench_vertical.run,
        "fig9b": bench_elastic.run,
        "fig10": bench_baselines.run,
        "fig11": bench_alphabet.run,
        "tbl3": bench_scaling.run,
        "roofline": bench_roofline.run,
        "query": bench_query.run,
    }
    print("name,us_per_call,derived")
    for key, fn in suites.items():
        if args.only and key != args.only:
            continue
        try:
            fn(quick=quick)
        except TypeError:
            fn()
        except Exception as e:  # report, keep the suite going
            print(f"{key}/ERROR,0,{type(e).__name__}: {e}", file=sys.stderr)


if __name__ == "__main__":
    main()
