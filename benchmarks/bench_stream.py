"""Out-of-core streaming construction + incremental append throughput.

Four rows:

* ``stream/build_sync``     — chunked build, standby buffer DISABLED (the
  synchronous baseline: every chunk's host→device copy is on the critical
  path).
* ``stream/build_overlap``  — same plan with the double-buffered pipeline;
  derived carries ``overlap_frac`` (fraction of copy seconds hidden
  behind the previous chunk's elastic loop — the ISSUE gate is ≥ 0.5).
* ``stream/rebuild``        — full one-shot rebuild of an appended string
  (the baseline an incremental append competes with).
* ``stream/append``         — ``EraIndexer.append_device``: terminal-tail
  scan + incremental re-partition + elastic loop over only the affected
  sub-trees; derived carries the speedup vs the rebuild row (ISSUE gate:
  ≥ 5x for a ≤ 10% append), ``reuse_frac`` of leaf segments carried over
  verbatim, and whether the incremental partition fell back to a full
  scan (it must not at these settings).

Both legs are warmed once before timing so jit compilation and the query
kernels' dispatch are off the clock — the steady-state regime is the one
that matters for a long-lived index absorbing appends.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit


def _bench_stream_build(quick: bool) -> None:
    from repro.core.api import EraConfig, EraIndexer
    from repro.data.strings import dataset

    n = 60_000 if quick else 200_000
    budget = 48 << 10
    repeats = 2 if quick else 3
    s, alphabet = dataset("dna", n, seed=0)
    ix = EraIndexer(alphabet, EraConfig(memory_bytes=1 << 20,
                                        build_impl="none"))

    reports: dict[str, object] = {}

    def build(overlap: bool):
        dev, sr = ix.build_stream(s, device_budget=budget, overlap=overlap)
        reports["on" if overlap else "off"] = sr
        return dev

    t_sync = timeit(lambda: build(False), repeats=repeats, warmup=1)
    t_over = timeit(lambda: build(True), repeats=repeats, warmup=1)
    sr_off, sr_on = reports["off"], reports["on"]
    emit(f"stream/build_sync/n={n}", t_sync,
         f"chunks={sr_off.n_chunks} copied_mb={sr_off.bytes_copied / 1e6:.1f} "
         f"overlap_frac={sr_off.overlap_frac:.2f}")
    emit(f"stream/build_overlap/n={n}", t_over,
         f"chunks={sr_on.n_chunks} overlap_frac={sr_on.overlap_frac:.2f} "
         f"copy_ms={sr_on.copy_s * 1e3:.1f} "
         f"hidden_ms={sr_on.copy_hidden_s * 1e3:.1f} "
         f"speedup_vs_sync={t_sync / max(t_over, 1e-9):.2f}x")


def _bench_append(quick: bool) -> None:
    from repro.core.api import AppendReport, EraConfig, EraIndexer
    from repro.data.strings import dataset

    # the proven ≥5x regime: many small sub-trees (tiny f_max) so the
    # affected set is a thin slice of the partition; the appended run is
    # 0.5% of the string, far under the ISSUE's ≤10% bound
    n = 120_000 if quick else 240_000
    m = 300 if quick else 600
    mem = 4 << 10
    repeats = 3
    s_old, alphabet = dataset("dna", n, seed=0)
    rng = np.random.default_rng(3)
    extra = rng.integers(0, alphabet.base - 1, size=m).astype(s_old.dtype)
    s_new = np.concatenate([s_old[:-1], extra, s_old[-1:]])

    ix = EraIndexer(alphabet, EraConfig(memory_bytes=mem, build_impl="none"))
    dev_old = ix.build_device(s_old)

    reports: dict[str, AppendReport] = {}

    def rebuild():
        ix.build_device(s_new)

    def append():
        rep = AppendReport()
        ix.append_device(dev_old, s_new, rep)
        reports["last"] = rep

    t_full = timeit(rebuild, repeats=repeats, warmup=1)
    t_inc = timeit(append, repeats=repeats, warmup=1)
    rep = reports["last"]
    emit(f"stream/rebuild/n={n + m}", t_full,
         f"prefixes={rep.n_prefixes} engine=one_shot")
    emit(f"stream/append/n={n}+{m}", t_inc,
         f"speedup={t_full / max(t_inc, 1e-9):.2f}x "
         f"reuse_frac={rep.reuse_frac:.2f} "
         f"affected={rep.n_affected}/{rep.n_prefixes} "
         f"partition_fallback={rep.partition_fallback} "
         f"scan_ms={rep.t_scan * 1e3:.1f} part_ms={rep.t_partition * 1e3:.1f} "
         f"prep_ms={rep.t_prepare * 1e3:.1f} merge_ms={rep.t_merge * 1e3:.1f}")


def run(quick: bool = True) -> None:
    _bench_stream_build(quick)
    _bench_append(quick)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
