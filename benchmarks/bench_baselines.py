"""Fig. 10 — ERA vs WaveFront-style vs suffix-array-based (B²ST-style).

All three implemented in this repo on identical substrate:
* ERA            — elastic range + virtual trees (the paper);
* WaveFront-like — static range 1, no grouping, 50/50 memory split
                   (its documented best setting halves the tree budget);
* SA-based       — prefix-doubling suffix array + Kasai LCP + batch build
                   (B²ST's sort-then-build flavor, in-memory variant).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import ref
from repro.core.api import EraConfig, EraIndexer
from repro.core.branch_edge import StrStats, wavefront_build
from repro.core.build import build_numpy
from repro.core.vertical import vertical_partition
from repro.data.strings import dataset


def _era(s, alpha, mem):
    # serial engine: fig10 compares the paper's serial ERA against baselines
    EraIndexer(alpha, EraConfig(memory_bytes=mem, r_bytes=max(256, mem // 64),
                                construction="serial")).build(s)


def _wavefront(s, alpha, mem):
    # 50% of memory to buffers -> half the sub-tree budget (paper §3)
    f_max = max(2, int(0.5 * mem) // 32)
    parts = vertical_partition(s, alpha.base, f_max)
    st = StrStats()
    for p in parts:
        wavefront_build(s, p.positions, p.length, st)


def _sa_based(s, alpha, mem):
    sa = ref.suffix_array(s)
    lcp = ref.lcp_array(s, sa)
    b = lcp.astype(np.int32)
    b[0] = 0
    build_numpy(sa.astype(np.int32), b, len(s))


def run(sizes=(4_000, 16_000), mems=(2_048, 8_192), quick=False):
    if quick:
        sizes, mems = sizes[:1], mems[:1]
    for n in sizes:
        s, alpha = dataset("dna", n, seed=11)
        times = {}
        for name, fn in (("era", _era), ("wavefront", _wavefront), ("sa-b2st", _sa_based)):
            t = timeit(lambda fn=fn: fn(s, alpha, mems[-1]),
                       warmup=1 if name == "era" else 0)  # exclude jit compile
            times[name] = t
            emit(f"fig10b/{name}/n={n}", t, "")
        emit(f"fig10b/era-speedup/n={n}", times["era"],
             f"vs_wavefront={times['wavefront'] / max(times['era'], 1e-9):.2f}x;"
             f"vs_sa={times['sa-b2st'] / max(times['era'], 1e-9):.2f}x")
    s, alpha = dataset("dna", sizes[-1], seed=11)
    for mem in mems:
        for name, fn in (("era", _era), ("wavefront", _wavefront)):
            t = timeit(lambda fn=fn: fn(s, alpha, mem),
                       warmup=1 if name == "era" else 0)
            emit(f"fig10a/{name}/mem={mem}", t, "")


if __name__ == "__main__":
    run()
