"""Fig. 8 — tuning |R|: small alphabets want small R, large want large."""

from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core.api import EraConfig, EraIndexer
from repro.data.strings import dataset


def run(n=16_000, r_sizes=(64, 256, 1024, 4096), quick=False):
    if quick:
        r_sizes = r_sizes[:3]
    for name in ("dna", "protein"):
        s, alpha = dataset(name, n, seed=8)
        for r in r_sizes:
            # serial engine: |R| drives each group's own elastic range as
            # in the paper (batched keys the range to the busiest group)
            cfg = EraConfig(memory_bytes=16_384, r_bytes=r, build_impl="none",
                            construction="serial")
            t = timeit(lambda: EraIndexer(alpha, cfg).build(s))
            emit(f"fig8/{name}/R={r}", t, f"r_bytes={r}")


if __name__ == "__main__":
    run()
