"""Dense-packed vs byte string pipeline: gather + probe traffic/throughput.

Paper §6.1 packs DNA at 2 bits/symbol to cut the memory traffic of the
bandwidth-bound construction/probe gathers.  This suite measures the two
hot primitives the dense representation accelerates, byte path vs packed
path over the SAME random DNA string:

* ``gather``  — the elastic-range read (``range_gather`` family): F
  offsets x w symbols into byte sort keys;
* ``probe``   — the query binary-search inner step (``pattern_probe``
  family): B masked suffix-vs-pattern verdicts.

PR 5 adds the WORD-COMPARE rows: the same primitives with dense uint32
words as the comparison currency (no byte repack at all) —
``gather_words`` (raw word sort keys), ``probe_words`` (k-bit pattern
words vs shifted text words) and the ``suffix_lcp`` pair (byte-key
repack vs XOR + count-leading-zeros).  Their speedups are measured
against the PR-4 byte-repack packed path, the regression budget CI
watches.

Each row's derived column records the STRING bytes a row of the gather
touches under each representation (``row_bytes``; the packed window is
``w*bits/8`` plus one uint32 halo) and the wall-clock speedup — the JSON
artifact tracks both so CI catches traffic or throughput regressions.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import packing
from repro.core.alphabet import DNA
from repro.kernels import ops as kops
from repro.kernels import ref as kref

W = 64          # symbols per gather row (a mid-build elastic range)
F = 65_536      # gather rows / probe batch per call
PAT_LEN = 16    # probe pattern length (symbols)


def _string(n: int) -> np.ndarray:
    rng = np.random.default_rng(0)
    s = rng.integers(0, 4, size=n, dtype=np.uint8)
    return np.concatenate([s, np.array([4], np.uint8)])


def run(quick: bool = True) -> None:
    # sized so the byte string spills cache while the packed words stay
    # resident — the regime the paper's traffic argument is about (a
    # genome is ~3 GB; any realistic serving corpus dwarfs L3)
    n = 32_000_000 if quick else 128_000_000
    s = _string(n)
    pt = packing.pack_text(s, DNA, extra=W + 8)
    sp = jnp.asarray(DNA.pad_string(s, extra=W + 8))
    rng = np.random.default_rng(1)
    offs = jnp.asarray(rng.integers(0, n, size=F).astype(np.int32))

    use_pallas = kops._use_pallas()
    gather = jax.jit(lambda st, o: kops.range_gather_impl(use_pallas)(st, o, W))

    def timed(fn, *args):
        # best-of-9: single-digit repeats leave ±40% jitter on shared
        # hosts, which drowns the row-vs-row speedups this suite reports
        return timeit(lambda: jax.block_until_ready(fn(*args)),
                      repeats=9, warmup=2)

    # --- gather: F x W symbols -> byte sort keys ---------------------------
    t_byte = timed(gather, sp, offs)
    t_packed = timed(gather, pt, offs)
    byte_row = W
    packed_row = (-(-W // pt.syms_per_word) + 1) * 4
    emit("packed/gather_byte", t_byte,
         f"n={n} f={F} w={W} row_bytes={byte_row}")
    emit("packed/gather_dense", t_packed,
         f"n={n} f={F} w={W} row_bytes={packed_row} "
         f"bytes_ratio={byte_row / packed_row:.2f}x "
         f"speedup={t_byte / max(t_packed, 1e-9):.2f}x")

    # --- probe: B masked suffix-vs-pattern verdicts ------------------------
    # real-symbol patterns only (codes < terminal): the workload every
    # probe variant serves — terminal-bearing patterns are degenerate and
    # route to the byte fallback in production, so benchmarking them
    # against the word row would compare different work
    m_pad = -(-PAT_LEN // 4) * 4
    sym = rng.integers(0, 4, size=(F, m_pad)).astype(np.int32)
    lengths = rng.integers(1, PAT_LEN + 1, size=F)
    valid = np.arange(m_pad)[None, :] < lengths[:, None]
    pat = jnp.asarray(np.asarray(kref.pack_words_ref(
        jnp.asarray(np.where(valid, sym, 0)))))
    mask = jnp.asarray(np.asarray(kref.pack_words_ref(
        jnp.asarray(np.where(valid, 0xFF, 0)))))
    probe = jax.jit(lambda st, p: kops.pattern_probe_impl(use_pallas)(
        st, p, pat, mask))
    pos = jnp.asarray(rng.integers(0, n, size=F).astype(np.int32))

    t_byte_p = timed(probe, sp, pos)
    t_packed_p = timed(probe, pt, pos)
    byte_probe = m_pad
    packed_probe = (-(-m_pad // pt.syms_per_word) + 1) * 4
    emit("packed/probe_byte", t_byte_p,
         f"n={n} b={F} m={m_pad} row_bytes={byte_probe}")
    emit("packed/probe_dense", t_packed_p,
         f"n={n} b={F} m={m_pad} row_bytes={packed_probe} "
         f"bytes_ratio={byte_probe / packed_probe:.2f}x "
         f"speedup={t_byte_p / max(t_packed_p, 1e-9):.2f}x")

    # --- combined gather+probe (the serving hot loop mix) ------------------
    t_byte_gp = t_byte + t_byte_p
    t_packed_gp = t_packed + t_packed_p
    nominal = 8 / DNA.dense_bits
    emit("packed/gather_probe_total", t_packed_gp,
         f"byte_total_us={t_byte_gp * 1e6:.1f} "
         f"speedup={t_byte_gp / max(t_packed_gp, 1e-9):.2f}x "
         f"stored_bits={DNA.dense_bits} nominal_bytes_ratio={nominal:.0f}x")

    # --- WORD-COMPARE rows: dense words as the comparison currency ---------
    # speedups are vs the PR-4 byte-repack packed path above (the word
    # path's baseline), not vs the unpacked byte string.
    bits = pt.bits
    spw = pt.syms_per_word

    # gather_words: raw uint32 word sort keys, never spread back to bytes
    gather_w = jax.jit(lambda st, o: kops.range_gather_words_impl(
        use_pallas)(st, o, W))
    t_words_g = timed(gather_w, pt, offs)
    emit("packed/gather_words", t_words_g,
         f"n={n} f={F} w={W} key_words={-(-W // spw)} "
         f"vs_byte_keys={W // 4} "
         f"speedup={t_packed / max(t_words_g, 1e-9):.2f}x")

    # probe_words: k-bit pattern words vs shifted text words directly
    pat_sym = jnp.asarray(np.where(valid, sym, 0))
    pat_d = packing.pack_pattern_dense(pat_sym, bits, pt.terminal)
    mask_d = packing.pack_dense(
        jnp.asarray(np.where(valid, (1 << bits) - 1, 0)), bits)
    len_arr = jnp.asarray(lengths.astype(np.int32))
    probe_w = jax.jit(lambda st, p: kops.pattern_probe_words_impl(
        use_pallas)(st, p, pat_d, mask_d, len_arr))
    t_words_p = timed(probe_w, pt, pos)
    emit("packed/probe_words", t_words_p,
         f"n={n} b={F} m={m_pad} pat_words={pat_d.shape[1]} "
         f"vs_byte_words={m_pad // 4} "
         f"speedup={t_packed_p / max(t_words_p, 1e-9):.2f}x")

    # suffix-pair LCP: byte-key repack + row-LCP (PR 4) vs first
    # differing word + count-leading-zeros (PR 5)
    pos_b2 = jnp.asarray(rng.integers(0, n, size=F).astype(np.int32))
    gather = kops.range_gather_impl(use_pallas)
    lcp_bytekeys = jax.jit(lambda st, a, b: kref.lcp_pairs_ref(
        gather(st, a, W), gather(st, b, W), W)[0])
    if use_pallas:
        from repro.kernels.packed_gather import suffix_lcp_words

        lcp_words_fn = jax.jit(lambda st, a, b: suffix_lcp_words(
            st, a, b, W, interpret=jax.default_backend() != "tpu"))
    else:
        lcp_words_fn = jax.jit(
            lambda st, a, b: kref.suffix_lcp_words_ref(st, a, b, W))
    t_lcp_byte = timed(lcp_bytekeys, pt, pos, pos_b2)
    t_lcp_words = timed(lcp_words_fn, pt, pos, pos_b2)
    emit("packed/suffix_lcp_bytekeys", t_lcp_byte, f"n={n} b={F} w={W}")
    emit("packed/suffix_lcp_words", t_lcp_words,
         f"n={n} b={F} w={W} "
         f"speedup={t_lcp_byte / max(t_lcp_words, 1e-9):.2f}x")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
