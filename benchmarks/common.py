"""Shared benchmark plumbing: timing + CSV emission + JSON recording.

Every benchmark prints ``name,us_per_call,derived`` rows (derived carries
the figure-specific metric: modeled I/O bytes, iterations, speedup, …).
Rows are also accumulated in :data:`RESULTS` so the harness
(``benchmarks.run --json``) can persist the run — CI uploads that file as
a build artifact to record the perf trajectory per PR.
"""

from __future__ import annotations

import time

# rows accumulated across suites for --json; reset by the harness
RESULTS: list[dict] = []


def timeit(fn, *, repeats: int = 1, warmup: int = 0):
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, seconds: float, derived: str = ""):
    RESULTS.append({"name": name, "us_per_call": round(seconds * 1e6, 1),
                    "derived": derived})
    print(f"{name},{seconds * 1e6:.1f},{derived}")
