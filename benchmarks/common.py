"""Shared benchmark plumbing: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (derived carries
the figure-specific metric: modeled I/O bytes, iterations, speedup, …).
"""

from __future__ import annotations

import time


def timeit(fn, *, repeats: int = 1, warmup: int = 0):
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}")
