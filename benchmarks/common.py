"""Shared benchmark plumbing: timing + CSV emission + JSON recording.

Every benchmark prints ``name,us_per_call,derived`` rows (derived carries
the figure-specific metric: modeled I/O bytes, iterations, speedup, …).
Rows are also accumulated in :data:`RESULTS` so the harness
(``benchmarks.run --json``) can persist the run — CI uploads that file as
a build artifact to record the perf trajectory per PR.
"""

from __future__ import annotations

import subprocess
import time

# rows accumulated across suites for --json; reset by the harness
RESULTS: list[dict] = []

_PROVENANCE: dict | None = None


def provenance() -> dict:
    """Environment stamp for every recorded row: git SHA, jax version,
    active backend.  Numbers without this are uncomparable across
    machines/commits — a regression vs a row from a different backend is
    not a regression.  Cached after the first call (the git subprocess
    and backend probe are not free)."""
    global _PROVENANCE
    if _PROVENANCE is None:
        try:
            sha = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                capture_output=True, text=True, timeout=5,
            ).stdout.strip() or "unknown"
        except Exception:
            sha = "unknown"
        try:
            import jax
            jax_version = jax.__version__
            backend = jax.default_backend()
        except Exception:
            jax_version = backend = "unknown"
        _PROVENANCE = {"git_sha": sha, "jax": jax_version,
                       "backend": backend}
    return dict(_PROVENANCE)


def timeit(fn, *, repeats: int = 1, warmup: int = 0):
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, seconds: float, derived: str = ""):
    row = {"name": name, "us_per_call": round(seconds * 1e6, 1),
           "derived": derived}
    row.update(provenance())
    RESULTS.append(row)
    print(f"{name},{seconds * 1e6:.1f},{derived}")
