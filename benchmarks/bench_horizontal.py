"""Fig. 7 — horizontal partitioning: ERA-str vs ERA-str+mem.

(a) construction time vs string size at fixed memory;
(b) construction time vs memory at fixed string size.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.alphabet import DNA
from repro.core.api import EraConfig, EraIndexer
from repro.core.branch_edge import StrStats, compute_suffix_subtree
from repro.core.vertical import vertical_partition
from repro.data.strings import dataset


def _era_str(s, f_max: int):
    parts = vertical_partition(s, DNA.base, f_max)
    for p in parts:
        compute_suffix_subtree(s, p.positions, p.length, StrStats())


def _era_str_mem(s, f_max: int):
    # serial engine: this arm IS the paper's §4 pipeline (fig7 comparability)
    cfg = EraConfig(memory_bytes=f_max * 32, r_bytes=4096, build_impl="numpy",
                    construction="serial")
    EraIndexer(DNA, cfg).build(s)


def run(sizes=(2_000, 8_000, 32_000), mems=(64, 256, 1024), quick=False):
    if quick:
        sizes, mems = sizes[:2], mems[:2]
    for n in sizes:
        s, _ = dataset("dna", n, seed=7)
        t1 = timeit(lambda: _era_str(s, 256))
        t2 = timeit(lambda: _era_str_mem(s, 256), warmup=1)  # exclude jit compile
        emit(f"fig7a/era-str/n={n}", t1, f"n={n}")
        emit(f"fig7a/era-str+mem/n={n}", t2, f"speedup={t1 / max(t2, 1e-9):.2f}x")
    s, _ = dataset("dna", sizes[-1], seed=7)
    for fm in mems:
        t1 = timeit(lambda: _era_str(s, fm))
        t2 = timeit(lambda: _era_str_mem(s, fm), warmup=1)
        emit(f"fig7b/era-str/fmax={fm}", t1, "")
        emit(f"fig7b/era-str+mem/fmax={fm}", t2,
             f"speedup={t1 / max(t2, 1e-9):.2f}x")


if __name__ == "__main__":
    run()
