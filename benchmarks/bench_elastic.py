"""Fig. 9(b) — elastic range vs static ranges 16 and 32.

Metrics: wall time, total iterations (= string scans per unit) and
fetched symbols (the gather-traffic analogue of the paper's I/O)."""

from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core.api import BuildReport, EraConfig, EraIndexer
from repro.core.prepare import PrepareStats
from repro.core.vertical import VerticalStats
from repro.data.strings import dataset, synthetic_string
from repro.core.alphabet import DNA


def run(n=16_000, quick=False):
    # repeat-heavy string: deep paths stress the range policy (paper: gain
    # grows with string length / repeat structure)
    s = synthetic_string(DNA, n, seed=10, repeat_fraction=0.5, repeat_len=96)
    variants = [("elastic", True, 0), ("static-16", False, 16), ("static-32", False, 32)]
    results = {}
    for name, elastic, w in variants:
        # serial engine: per-group iteration/fetch accounting (paper units)
        cfg = EraConfig(memory_bytes=8_192, r_bytes=512, elastic=elastic,
                        static_w=w, build_impl="none", construction="serial")
        rep = BuildReport(VerticalStats(), PrepareStats())
        t = timeit(lambda: EraIndexer(DNA, cfg).build(s, rep), warmup=1)
        results[name] = t
        emit(f"fig9b/{name}", t,
             f"iters={rep.prepare.iterations};fetched={rep.prepare.symbols_fetched}")
    if "elastic" in results:
        for other in ("static-16", "static-32"):
            emit(f"fig9b/elastic-vs-{other}", results[other],
                 f"elastic_speedup={results[other] / max(results['elastic'], 1e-9):.2f}x")


if __name__ == "__main__":
    run()
