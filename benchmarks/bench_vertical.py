"""Fig. 9(a) — effect of virtual trees (grouping) on time and modeled I/O."""

from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core.api import BuildReport, EraConfig, EraIndexer
from repro.core.iomodel import amortization_factor
from repro.core.prepare import PrepareStats
from repro.core.vertical import VerticalStats
from repro.data.strings import dataset


def run(n=16_000, quick=False):
    s, alpha = dataset("dna", n, seed=9)
    for group in (True, False):
        # serial engine: the figure's accounting (iterations = string
        # passes PER UNIT) is the paper's per-group loop, not the joint
        # batched rounds
        cfg = EraConfig(memory_bytes=8_192, r_bytes=1024, group=group,
                        build_impl="none", construction="serial")
        rep = BuildReport(VerticalStats(), PrepareStats())
        t = timeit(lambda: EraIndexer(alpha, cfg).build(s, rep))
        scans = rep.prepare.iterations  # each iteration = one string pass/unit
        amort = amortization_factor(rep.n_prefixes, rep.n_groups)
        emit(f"fig9a/{'virtual-trees' if group else 'no-grouping'}", t,
             f"units={rep.n_groups};prefixes={rep.n_prefixes};"
             f"amortization={amort:.1f}x;prepare_iters={scans}")


if __name__ == "__main__":
    run()
