"""Table 3 + Figs. 12/13 — strong/weak scaling of the parallel build.

This container has one core, so speedup is measured the way the paper's
Table 3 measures load balance: per-worker busy time from the scheduler.
strong speedup(k) = serial_time / max_worker_busy_time(k) — exact for the
shared-nothing model (workers independent, no merge phase), optimistic
only about network interference which the paper also excludes.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.api import EraConfig, EraIndexer
from repro.data.strings import dataset
from repro.launch.era_run import build_distributed


def run(n=24_000, workers=(1, 2, 4, 8), quick=False):
    if quick:
        workers = workers[:3]
    s, alpha = dataset("dna", n, seed=13)
    cfg = EraConfig(memory_bytes=4_096, r_bytes=512, build_impl="none")
    # one group per pull: tbl3's busy-time accounting is per TASK (chunked
    # pulls would average elapsed_s over the chunk and coarsen max-busy)
    pull = dict(groups_per_pull=1)

    # warm the jit caches so worker busy-times measure steady-state work
    build_distributed(s, alpha, cfg, n_workers=1, **pull)

    serial = None
    for k in workers:
        _, qstats, per_worker = build_distributed(s, alpha, cfg, n_workers=k, **pull)
        busy = [w.seconds for w in per_worker]
        t_parallel = max(busy) if busy else 0.0
        total = sum(busy)
        if k == 1:
            serial = total
        speedup = serial / max(t_parallel, 1e-9)
        emit(f"table3/strong/k={k}", t_parallel,
             f"speedup={speedup:.2f};efficiency={speedup / k:.2f};"
             f"groups={qstats['total']}")

    # weak scaling: n grows with k (paper Fig. 13)
    base = 4_000
    for k in workers:
        s_k, _ = dataset("dna", base * k, seed=14)
        _, qstats, per_worker = build_distributed(s_k, alpha, cfg, n_workers=k,
                                                  **pull)
        t_parallel = max((w.seconds for w in per_worker), default=0.0)
        emit(f"fig13/weak/k={k}", t_parallel,
             f"n={base * k};groups={qstats['total']}")


if __name__ == "__main__":
    run()
