"""Device-resident analytics engine vs per-position host Python loops.

Rows: batched matching statistics at several batch sizes (the derived
column carries positions/sec and the speedup over a per-position Python
binary-search loop on the host suffix array — the loop the fused
probe-kernel pass replaces), plus one-shot rows for LCP construction,
top-k repeat mining, distinct-substring counting and the k-mer spectrum.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.api import EraConfig, EraIndexer
from repro.data.strings import dataset
from repro.launch.analytics_serve import make_query


def matching_stats_python(s: np.ndarray, sa: np.ndarray, q: np.ndarray):
    """Per-position host loop: for every query position, a Python binary
    search over the suffix array plus neighbor LCP scans — the host-bound
    baseline the batched device pass replaces.  Returns (ms, witness)
    like ``AnalyticsEngine.matching_stats``."""
    n = len(s)
    ms = np.zeros(len(q), np.int64)
    wit = np.full(len(q), -1, np.int64)
    for i in range(len(q)):
        pat = q[i:]
        lo, hi = 0, n
        while lo < hi:
            mid = (lo + hi) // 2
            suf = s[sa[mid] : sa[mid] + len(pat)]
            c = -1 if tuple(suf) < tuple(pat) else 1
            if len(suf) >= len(pat) and np.array_equal(suf, pat):
                c = 0
            if c < 0:
                lo = mid + 1
            else:
                hi = mid
        for row in (lo - 1, lo):
            if 0 <= row < n:
                j = sa[row]
                h = 0
                while i + h < len(q) and j + h < n and q[i + h] == s[j + h]:
                    h += 1
                if h > ms[i]:
                    ms[i] = h
                    wit[i] = j
    return ms, wit


def run(quick: bool = True) -> None:
    n = 30_000 if quick else 200_000
    s, alphabet = dataset("dna", n, seed=0)
    cfg = EraConfig(memory_bytes=1 << 18, build_impl="none")
    indexer = EraIndexer(alphabet, cfg)

    index = indexer.build(s)
    t_lcp = timeit(lambda: index.analytics(), repeats=1)
    eng = index.analytics()
    emit("analytics/lcp_build", t_lcp, f"n={eng.total}")

    sa = eng.dev.ell_host
    rng = np.random.default_rng(1)
    for batch in (64, 256, 1024):
        # the serving driver's workload shape, all-planted (long matches)
        q = make_query(s, rng, batch=batch, planted_frac=1.0,
                       n_symbols=len(alphabet.symbols))

        def device_batch():
            ms, wit = eng.matching_stats(q, window=64)

        t_dev = timeit(device_batch, repeats=5, warmup=2)
        t_py = timeit(lambda: matching_stats_python(s, sa, q), repeats=1)
        emit(f"analytics/ms_batch{batch}", t_dev,
             f"pos_per_s={batch / max(t_dev, 1e-9):.0f} "
             f"speedup={t_py / max(t_dev, 1e-9):.1f}x")

    t_rep = timeit(lambda: eng.top_repeats(10), repeats=3, warmup=1)
    emit("analytics/top10_repeats", t_rep,
         f"longest={eng.longest_repeat()['length']}")
    t_distinct = timeit(lambda: eng.distinct_substrings(), repeats=3)
    emit("analytics/distinct", t_distinct, f"count={eng.distinct_substrings()}")
    t_kmer = timeit(lambda: eng.top_kmers(8, topk=10), repeats=3, warmup=1)
    emit("analytics/top_kmers_k8", t_kmer,
         f"max_count={eng.top_kmers(8, topk=1)[0]['count']}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
