"""Fig. 11 — alphabet-size sensitivity (DNA 4 / protein 20 / english 26)."""

from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core.api import BuildReport, EraConfig, EraIndexer
from repro.core.prepare import PrepareStats
from repro.core.vertical import VerticalStats
from repro.data.strings import dataset


def run(n=12_000, quick=False):
    for name, r in (("dna", 256), ("protein", 2048), ("english", 2048)):
        s, alpha = dataset(name, n, seed=12)
        # serial engine: per-group iteration accounting (paper units)
        cfg = EraConfig(memory_bytes=8_192, r_bytes=r, build_impl="none",
                        construction="serial")
        rep = BuildReport(VerticalStats(), PrepareStats())
        t = timeit(lambda: EraIndexer(alpha, cfg).build(s, rep))
        emit(f"fig11/{name}", t,
             f"sigma={len(alpha.symbols)};groups={rep.n_groups};"
             f"iters={rep.prepare.iterations}")


if __name__ == "__main__":
    run()
