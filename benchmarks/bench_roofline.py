"""§Roofline — emit the dry-run roofline table as CSV rows.

Reads experiments/dryrun.json (produced by repro.launch.dryrun); prints
one row per (arch × shape × mesh) with the three terms and bottleneck.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import emit

DRYRUN_JSON = os.environ.get("REPRO_DRYRUN_JSON", "experiments/dryrun.json")


def run(quick=False):
    if not os.path.exists(DRYRUN_JSON):
        emit("roofline/missing", 0.0, f"run repro.launch.dryrun first ({DRYRUN_JSON})")
        return
    with open(DRYRUN_JSON) as f:
        recs = json.load(f)
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] != "ok":
            emit(name, 0.0, r["status"])
            continue
        t = r["roofline"]
        emit(name, t["step_time_s"] * 1e0 if "step_time_s" in t else
             max(t["t_compute_s"], t["t_memory_s"], t["t_collective_s"]),
             f"bottleneck={t['bottleneck']};tc={t['t_compute_s']:.4g};"
             f"tm={t['t_memory_s']:.4g};tx={t['t_collective_s']:.4g};"
             f"useful={t['useful_flops_ratio']:.3f}")


if __name__ == "__main__":
    run()
