# Developer entry points.  PYTHONPATH=src is baked in so targets work from
# a fresh checkout with no install step.

PR ?= local
PY := PYTHONPATH=src python

.PHONY: test bench bench-smoke bench-check trace-smoke

test:
	$(PY) -m pytest -x -q

bench:
	$(PY) -m benchmarks.run

# Record the per-PR perf trajectory: one smoke pass, rows written to
# BENCH_$(PR).json at the repo root (commit it with the PR so the next
# PR's regression check has a baseline).  Example: make bench-smoke PR=PR6
# — uppercase PR<n>, the same scheme CI's record step uses.
bench-smoke:
	$(PY) -m benchmarks.run --smoke --json BENCH_$(PR).json

# Compare a fresh smoke run against the newest committed BENCH_*.json:
# warns on >20% throughput drops in the packed/query/serve rows
# (construction rows report warn-only).
bench-check:
	$(PY) -m benchmarks.run --smoke --json bench-results.json
	$(PY) -m benchmarks.check_regression --current bench-results.json

# Flight-recorder smoke: traced build + closed-loop serve, validates the
# Perfetto trace + Prometheus snapshot, gates instrumentation overhead.
# Artifacts land in trace-artifacts/ (open era_trace.json at
# https://ui.perfetto.dev).
trace-smoke:
	REPRO_TRACE=1 REPRO_METRICS=1 $(PY) -m benchmarks.trace_smoke \
		--out-dir trace-artifacts
